"""Paper Table III (ablation): DecHetero -> DecDiff -> DecDiff+VT, isolating
the aggregation-function contribution from the virtual-teacher contribution.
Beyond-paper rows: VT grafted onto the baselines (dechetero+vt, cfa+vt)."""
from __future__ import annotations

import argparse

from benchmarks.common import WorldConfig, build_world, run_method, save_results

ROWS = ["dechetero", "decdiff", "decdiff+vt", "dechetero+vt", "cfa", "cfa+vt"]


def run(dataset="synth-mnist", rounds=60, num_nodes=30, data_scale=0.08,
        verbose=True):
    wc = WorldConfig(dataset=dataset, rounds=rounds, num_nodes=num_nodes,
                     data_scale=data_scale)
    world = build_world(wc)
    results = {"_world": {"gini": world[5], "dataset": dataset, "rounds": rounds}}
    for method in ROWS:
        results[method] = run_method(wc, method, world=world)
        if verbose:
            print(f"[ablation] {method:14s} acc={results[method]['acc_mean']:.4f}")
    save_results("ablation_table", results)
    return results


def format_table(results) -> str:
    base = results["dechetero"]["acc_mean"]
    lines = ["| method | loss | aggregation | avg acc | gain vs DecHetero [%pt] |",
             "|---|---|---|---|---|"]
    meta = {
        "dechetero": ("CE", "DecAvg"), "decdiff": ("CE", "DecDiff"),
        "decdiff+vt": ("VT", "DecDiff"), "dechetero+vt": ("VT", "DecAvg"),
        "cfa": ("CE", "CFA"), "cfa+vt": ("VT", "CFA"),
    }
    for m in ROWS:
        if m not in results:
            continue
        acc = results[m]["acc_mean"]
        loss, agg = meta[m]
        lines.append(f"| {m} | {loss} | {agg} | {acc:.4f} | "
                     f"{100 * (acc - base):+.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    args = ap.parse_args()
    print(format_table(run(rounds=args.rounds)))


if __name__ == "__main__":
    main()
