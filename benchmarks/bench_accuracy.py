"""Paper Table II (+ Fig. 5/6 statistics): final average accuracy per method.

Runs the full method roster on one world instance per dataset and reports the
Table II layout (standalone baselines / partially-decentralized / SOTA DFL /
proposal).  Characteristic times (Table IV) are derived from the same
histories by bench_char_time.py — run this first.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    WorldConfig,
    build_world,
    run_centralized,
    run_method,
    save_results,
)

METHODS = ["isol", "fedavg", "dechetero", "cfa", "cfa-ge", "decdiff", "decdiff+vt"]


def run(datasets=("synth-mnist",), rounds=60, num_nodes=30, data_scale=0.08,
        verbose=True):
    all_results = {}
    for dataset in datasets:
        wc = WorldConfig(dataset=dataset, rounds=rounds, num_nodes=num_nodes,
                         data_scale=data_scale)
        world = build_world(wc)
        results = {"_world": {"gini": world[5], "nodes": num_nodes,
                              "rounds": rounds, "dataset": dataset,
                              "data_scale": data_scale}}
        results["centralized"] = run_centralized(wc, world=world)
        if verbose:
            print(f"[{dataset}] centralized acc={results['centralized']['acc_mean']:.4f}")
        for method in METHODS:
            results[method] = run_method(wc, method, world=world)
            if verbose:
                r = results[method]
                print(f"[{dataset}] {method:12s} acc={r['acc_mean']:.4f} "
                      f"±{r['acc_std']:.4f}  ({r['wall_s']:.0f}s)")
        all_results[dataset] = results
    save_results("accuracy_table", all_results)
    return all_results


def format_table(all_results) -> str:
    lines = ["| dataset | method | avg acc | ±std | node-wise IQR |",
             "|---|---|---|---|---|"]
    for dataset, results in all_results.items():
        for method, r in results.items():
            if method.startswith("_"):
                continue
            iqr = ""
            if "acc_per_node" in r:
                q = np.percentile(r["acc_per_node"], [25, 75])
                iqr = f"{q[1] - q[0]:.3f}"
            lines.append(f"| {dataset} | {method} | {r['acc_mean']:.4f} | "
                         f"{r.get('acc_std', 0):.4f} | {iqr} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["synth-mnist"])
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--nodes", type=int, default=30)
    ap.add_argument("--scale", type=float, default=0.08)
    args = ap.parse_args()
    res = run(args.datasets, args.rounds, args.nodes, args.scale)
    print(format_table(res))


if __name__ == "__main__":
    main()
