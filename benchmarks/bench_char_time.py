"""Paper Table IV: characteristic time (rounds to reach a fraction of the
centralized benchmark's accuracy), derived from bench_accuracy histories."""
from __future__ import annotations

from benchmarks.common import load_results

THRESHOLDS = (0.5, 0.8, 0.9, 0.95)


def characteristic_times(all_results):
    out = {}
    for dataset, results in all_results.items():
        cacc = results["centralized"]["acc_mean"]
        table = {}
        for method, r in results.items():
            if method.startswith("_") or method == "centralized":
                continue
            row = {}
            for thr in THRESHOLDS:
                target = thr * cacc
                hit = None
                for h in r["history"]:
                    if h["acc_mean"] >= target:
                        hit = h["round"]
                        break
                row[thr] = hit
            table[method] = row
        out[dataset] = {"centralized_acc": cacc, "times": table}
    return out


def format_table(ct) -> str:
    lines = ["| dataset | method | 50% | 80% | 90% | 95% |", "|---|---|---|---|---|---|"]
    for dataset, block in ct.items():
        for method, row in block["times"].items():
            cells = " | ".join("-" if row[t] is None else str(row[t])
                               for t in THRESHOLDS)
            lines.append(f"| {dataset} | {method} | {cells} |")
    return "\n".join(lines)


def main():
    res = load_results("accuracy_table")
    if res is None:
        raise SystemExit("run benchmarks.bench_accuracy first")
    print(format_table(characteristic_times(res)))


if __name__ == "__main__":
    main()
