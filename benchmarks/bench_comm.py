"""Communication cost: exact codec accounting + the accuracy-vs-bytes frontier.

Two claims, two artifacts:

  * `comm_table` — paper §VI-A.3 per-method bytes/round (DecDiff+VT ships
    parameters only; CFA-GE 4x; FedAvg scales with |V|), now priced per
    codec with the *exact* serialized payload size from
    `codec.payload_bytes_for` instead of hard-coded fp32 math.
  * `comm_frontier` — the tentpole measurement: DecDiff+VT on seeded 8-node
    smoke worlds (Barabási–Albert scale-free AND Erdős–Rényi — hub-heavy
    vs degree-homogeneous, the two graph families the paper leans on),
    swept over codecs x trigger policies (fixed drift thresholds and the
    per-edge adaptive drift-rate controller) x top-k variants (ratios,
    momentum masking), each point reporting final accuracy, total bytes on
    wire (the simulator's dynamic accounting, so event-triggered silence
    is priced in), and the triggered fraction.  This turns "DecDiff trains
    accurate local models in a more communication-efficient way" into a
    measured frontier with two acceptance gates: the PR-2 >= 2x-within-1%
    gate, and the PR-3 gate that the adaptive per-edge policy reaches at
    least the within-1% byte reduction of the best fixed-threshold int8
    point.

`gen_report.write_bench_comm()` folds both into BENCH_comm.json.
"""
from __future__ import annotations

import jax

from benchmarks.common import save_results
from repro.comm import CommConfig, make_codec
from repro.data import make_dataset, zipf_allocation
from repro.data.allocation import split_by_allocation
from repro.engine import Experiment, Schedule, World
from repro.fl.metrics import comm_bytes_per_round
from repro.graphs import make_topology
from repro.models.mlp_cnn import make_cnn, make_mlp
from repro.utils.pytree import tree_bytes, tree_size

METHODS = ["isol", "fedavg", "dechetero", "cfa", "cfa-ge", "decdiff", "decdiff+vt"]
CODECS = ["fp32", "bf16", "int8", "topk"]

# The seeded smoke sweeps: (codec, CommConfig overrides).  fp32/{} is the
# dense always-send reference every point in the SAME world is scored
# against.  The BA world carries the full sweep; the ER world re-runs the
# comparison subset (dense / fixed int8 / adaptive int8) so the adaptive
# policy is measured on both a hub-heavy and a degree-homogeneous graph.
FRONTIER = [
    ("fp32", {}),
    ("bf16", {}),
    ("int8", {}),
    ("int8", {"trigger_threshold": 0.5}),
    ("int8", {"trigger_threshold": 1.0}),
    ("int8", {"trigger_threshold": 2.5}),
    ("int8", {"policy": "adaptive", "target_trigger": 0.95}),
    ("int8", {"policy": "adaptive", "target_trigger": 0.9}),
    ("int8", {"policy": "adaptive", "target_trigger": 0.8}),
    ("int8", {"policy": "adaptive", "target_trigger": 0.5}),
    # top-k revisit: the PR-2 ratios underperformed (see ROADMAP); sweep
    # larger ratios and momentum masking on per-edge residuals.
    ("topk", {"topk_ratio": 0.05}),
    ("topk", {"topk_ratio": 0.01}),
    ("topk", {"topk_ratio": 0.1}),
    ("topk", {"topk_ratio": 0.25}),
    ("topk", {"topk_ratio": 0.1, "topk_momentum": 0.9, "per_edge": True}),
    ("topk", {"topk_ratio": 0.25, "topk_momentum": 0.9, "per_edge": True}),
]
ER_FRONTIER = [
    ("fp32", {}),
    ("int8", {}),
    ("int8", {"trigger_threshold": 1.0}),
    ("int8", {"policy": "adaptive", "target_trigger": 0.95}),
    ("int8", {"policy": "adaptive", "target_trigger": 0.8}),
]
WORLD_SWEEPS = (("ba", FRONTIER), ("er", ER_FRONTIER))


def static_table(verbose=True):
    """Per-method x per-codec bytes/round on the paper's 50-node ER(0.2)."""
    topo = make_topology("erdos_renyi", n=50, p=0.2, seed=0)
    models = {
        "mlp(mnist)": make_mlp(num_classes=10),
        "cnn(fashion)": make_cnn(num_classes=10),
        "cnn(emnist)": make_cnn(num_classes=26, use_pool_dropout=True),
    }
    rows = []
    for mname, model in models.items():
        params = model.init(jax.random.PRNGKey(0))
        n_params = tree_size(params)
        for codec_name in CODECS:
            # exact serialized payload size for one model transmission —
            # NOT n_params * 4 (int8 adds a scale word, top-k ships pairs)
            payload = make_codec(codec_name).payload_bytes_for(n_params)
            for method in METHODS:
                rows.append({
                    "model": mname, "params": n_params,
                    "model_mbytes": tree_bytes(params) / 1e6,
                    "codec": codec_name, "payload_bytes": payload,
                    "method": method,
                    "bytes_per_round": comm_bytes_per_round(method, topo, payload),
                })
    save_results("comm_table", rows)
    if verbose:
        print(format_table(rows))
    return rows


def smoke_world(seed=0, graph="ba"):
    """The seeded smoke configs shared with tests/test_system.py: an 8-node
    graph (graph="ba": Barabási–Albert scale-free, the default everything
    else pins; graph="er": Erdős–Rényi p=0.4), Zipf non-IID synth-mnist,
    small MLP."""
    ds = make_dataset("synth-mnist", seed=seed, scale=0.03)
    if graph == "ba":
        topo = make_topology("barabasi_albert", n=8, m=2, seed=1)
    elif graph == "er":
        topo = make_topology("erdos_renyi", n=8, p=0.4, seed=1)
    else:
        raise ValueError(f"unknown smoke graph {graph!r}")
    alloc = zipf_allocation(ds.y_train, 8, seed=1, min_per_class=1)
    xs, ys = split_by_allocation(ds.x_train, ds.y_train, alloc)
    model = make_mlp(num_classes=10, hidden=(64, 32))
    return ds, topo, xs, ys, model


def trigger_label(policy: str, threshold=0.0, target=None) -> str:
    """One rendering of a trigger config for every human-facing table (the
    bench log, gen_report's markdown, the example's terminal output)."""
    return (f"adaptive({target})" if policy == "adaptive"
            else f"thr={threshold}")


def _point_label(comm: CommConfig) -> str:
    if comm.codec == "topk" and comm.policy == "fixed":
        mom = f",mom={comm.topk_momentum}" if comm.topk_momentum > 0 else ""
        return f"r={comm.topk_ratio}{mom}"
    return trigger_label(comm.policy, comm.trigger_threshold,
                         comm.target_trigger)


def frontier(rounds=40, seed=0, verbose=True):
    """Sweep codecs x trigger policies on BA and ER worlds; emit the
    accuracy-vs-bytes frontier (per-world dense-normalized)."""
    rows = []
    for world, points in WORLD_SWEEPS:
        ds, topo, xs, ys, model = smoke_world(seed, graph=world)
        for codec, overrides in points:
            comm = CommConfig(codec=codec, **overrides)
            sim = Experiment(
                World(model=model, topo=topo, xs=xs, ys=ys,
                      x_test=ds.x_test, y_test=ds.y_test),
                "decdiff+vt", comm=comm,
                schedule=Schedule(rounds=rounds, eval_every=5),
                steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9,
                seed=seed)
            hist = sim.run()
            rows.append({
                "world": world, "codec": codec, "policy": comm.policy,
                "per_edge": comm.use_per_edge,
                "threshold": comm.trigger_threshold,
                "target_trigger": (comm.target_trigger
                                   if comm.policy == "adaptive" else None),
                "topk_ratio": comm.topk_ratio if codec == "topk" else None,
                "topk_momentum": (comm.topk_momentum
                                  if codec == "topk" else None),
                "rounds": rounds, "seed": seed,
                "acc_mean": hist[-1].acc_mean, "acc_std": hist[-1].acc_std,
                "bytes_on_wire": sim.comm_bytes_total,
                "payload_bytes": sim.transport.payload_bytes,
                "triggered_frac": hist[-1].triggered_frac,
            })
            if verbose:
                r = rows[-1]
                print(f"[{world}] {codec:>5} {_point_label(comm):<16} "
                      f"acc={r['acc_mean']:.4f} "
                      f"wire={r['bytes_on_wire'] / 1e6:8.2f} MB "
                      f"trig={r['triggered_frac']:.2f}", flush=True)
    for world, _ in WORLD_SWEEPS:
        dense = next(r for r in rows if r["world"] == world
                     and r["codec"] == "fp32" and r["policy"] == "fixed"
                     and r["threshold"] == 0.0)
        for r in rows:
            if r["world"] != world:
                continue
            r["reduction_vs_dense"] = (dense["bytes_on_wire"]
                                       / max(r["bytes_on_wire"], 1))
            r["acc_delta_vs_dense"] = r["acc_mean"] - dense["acc_mean"]
    save_results("comm_frontier", rows)
    return rows


def format_table(rows) -> str:
    lines = ["| model | codec | method | MB/round (50-node ER p=.2) |",
             "|---|---|---|---|"]
    for r in rows:
        if r["method"] not in ("fedavg", "cfa-ge", "decdiff+vt"):
            continue
        lines.append(f"| {r['model']} | {r['codec']} | {r['method']} | "
                     f"{r['bytes_per_round'] / 1e6:.2f} |")
    return "\n".join(lines)


def run(verbose=True, rounds=40, with_frontier=True):
    """Returns the static-table rows (benchmarks/run.py's contract); the
    frontier sweep (~10 min of simulator runs) is skippable for callers that
    only need the accounting table."""
    rows = static_table(verbose=verbose)
    if with_frontier:
        frontier(rounds=rounds, verbose=verbose)
    from benchmarks.gen_report import write_bench_comm

    path = write_bench_comm()  # no-op if the frontier artifact is absent
    if verbose and path:
        print("wrote", path)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
