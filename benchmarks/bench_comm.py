"""Communication cost: exact codec accounting + the accuracy-vs-bytes frontier.

Two claims, two artifacts:

  * `comm_table` — paper §VI-A.3 per-method bytes/round (DecDiff+VT ships
    parameters only; CFA-GE 4x; FedAvg scales with |V|), now priced per
    codec with the *exact* serialized payload size from
    `codec.payload_bytes_for` instead of hard-coded fp32 math.
  * `comm_frontier` — the tentpole measurement: DecDiff+VT on a seeded
    8-node Barabási–Albert smoke world, swept over codecs x drift-trigger
    thresholds, each point reporting final accuracy, total bytes on wire
    (the simulator's dynamic accounting, so event-triggered silence is
    priced in), and the triggered fraction.  This turns "DecDiff trains
    accurate local models in a more communication-efficient way" into a
    measured frontier with a >= 2x-within-1% acceptance gate.

`gen_report.write_bench_comm()` folds both into BENCH_comm.json.
"""
from __future__ import annotations

import jax

from benchmarks.common import save_results
from repro.comm import CommConfig, make_codec
from repro.data import make_dataset, zipf_allocation
from repro.data.allocation import split_by_allocation
from repro.fl import DFLSimulator, SimulatorConfig
from repro.fl.metrics import comm_bytes_per_round
from repro.graphs import make_topology
from repro.models.mlp_cnn import make_cnn, make_mlp
from repro.utils.pytree import tree_bytes, tree_size

METHODS = ["isol", "fedavg", "dechetero", "cfa", "cfa-ge", "decdiff", "decdiff+vt"]
CODECS = ["fp32", "bf16", "int8", "topk"]

# The seeded smoke sweep: (codec, trigger threshold, topk ratio).
# fp32/thr0 is the dense reference every other point is scored against.
FRONTIER = [
    ("fp32", 0.0, None),
    ("bf16", 0.0, None),
    ("int8", 0.0, None),
    ("int8", 0.5, None),
    ("int8", 1.0, None),
    ("int8", 2.5, None),
    ("topk", 0.0, 0.05),
    ("topk", 0.0, 0.01),
]


def static_table(verbose=True):
    """Per-method x per-codec bytes/round on the paper's 50-node ER(0.2)."""
    topo = make_topology("erdos_renyi", n=50, p=0.2, seed=0)
    models = {
        "mlp(mnist)": make_mlp(num_classes=10),
        "cnn(fashion)": make_cnn(num_classes=10),
        "cnn(emnist)": make_cnn(num_classes=26, use_pool_dropout=True),
    }
    rows = []
    for mname, model in models.items():
        params = model.init(jax.random.PRNGKey(0))
        n_params = tree_size(params)
        for codec_name in CODECS:
            # exact serialized payload size for one model transmission —
            # NOT n_params * 4 (int8 adds a scale word, top-k ships pairs)
            payload = make_codec(codec_name).payload_bytes_for(n_params)
            for method in METHODS:
                rows.append({
                    "model": mname, "params": n_params,
                    "model_mbytes": tree_bytes(params) / 1e6,
                    "codec": codec_name, "payload_bytes": payload,
                    "method": method,
                    "bytes_per_round": comm_bytes_per_round(method, topo, payload),
                })
    save_results("comm_table", rows)
    if verbose:
        print(format_table(rows))
    return rows


def smoke_world(seed=0):
    """The seeded smoke config shared with tests/test_system.py: 8-node BA
    scale-free graph, Zipf non-IID synth-mnist, small MLP."""
    ds = make_dataset("synth-mnist", seed=seed, scale=0.03)
    topo = make_topology("barabasi_albert", n=8, m=2, seed=1)
    alloc = zipf_allocation(ds.y_train, 8, seed=1, min_per_class=1)
    xs, ys = split_by_allocation(ds.x_train, ds.y_train, alloc)
    model = make_mlp(num_classes=10, hidden=(64, 32))
    return ds, topo, xs, ys, model


def frontier(rounds=40, seed=0, verbose=True):
    """Sweep codecs x trigger thresholds; emit the accuracy-vs-bytes frontier."""
    ds, topo, xs, ys, model = smoke_world(seed)
    rows = []
    for codec, thr, ratio in FRONTIER:
        kw = {"topk_ratio": ratio} if ratio is not None else {}
        comm = CommConfig(codec=codec, trigger_threshold=thr, **kw)
        cfg = SimulatorConfig(method="decdiff+vt", rounds=rounds,
                              steps_per_round=4, batch_size=32, lr=0.1,
                              momentum=0.9, eval_every=5, seed=seed, comm=comm)
        sim = DFLSimulator(model, topo, xs, ys, ds.x_test, ds.y_test, cfg)
        hist = sim.run()
        rows.append({
            "codec": codec, "threshold": thr, "topk_ratio": ratio,
            "rounds": rounds, "seed": seed,
            "acc_mean": hist[-1].acc_mean, "acc_std": hist[-1].acc_std,
            "bytes_on_wire": sim.comm_bytes_total,
            "payload_bytes": sim.transport.payload_bytes,
            "triggered_frac": hist[-1].triggered_frac,
        })
        if verbose:
            r = rows[-1]
            print(f"{codec:>5} thr={thr:<4} acc={r['acc_mean']:.4f} "
                  f"wire={r['bytes_on_wire'] / 1e6:8.2f} MB "
                  f"trig={r['triggered_frac']:.2f}")
    dense = next(r for r in rows if r["codec"] == "fp32" and r["threshold"] == 0.0)
    for r in rows:
        r["reduction_vs_dense"] = dense["bytes_on_wire"] / max(r["bytes_on_wire"], 1)
        r["acc_delta_vs_dense"] = r["acc_mean"] - dense["acc_mean"]
    save_results("comm_frontier", rows)
    return rows


def format_table(rows) -> str:
    lines = ["| model | codec | method | MB/round (50-node ER p=.2) |",
             "|---|---|---|---|"]
    for r in rows:
        if r["method"] not in ("fedavg", "cfa-ge", "decdiff+vt"):
            continue
        lines.append(f"| {r['model']} | {r['codec']} | {r['method']} | "
                     f"{r['bytes_per_round'] / 1e6:.2f} |")
    return "\n".join(lines)


def run(verbose=True, rounds=40, with_frontier=True):
    """Returns the static-table rows (benchmarks/run.py's contract); the
    frontier sweep (~10 min of simulator runs) is skippable for callers that
    only need the accounting table."""
    rows = static_table(verbose=verbose)
    if with_frontier:
        frontier(rounds=rounds, verbose=verbose)
    from benchmarks.gen_report import write_bench_comm

    path = write_bench_comm()  # no-op if the frontier artifact is absent
    if verbose and path:
        print("wrote", path)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
