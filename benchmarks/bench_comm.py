"""Paper §VI-A.3 claim: communication cost per round per method.

DecDiff+VT ships model parameters only (like DecAvg/CFA); CFA-GE ships models
+ aggregated models + gradients (4x); FedAvg scales with |V| (star) instead of
2|E|.  Reported for the paper's 50-node ER(0.2) world and each paper model."""
from __future__ import annotations

import jax

from benchmarks.common import save_results
from repro.fl.metrics import comm_bytes_per_round
from repro.graphs import make_topology
from repro.models.mlp_cnn import make_cnn, make_mlp
from repro.utils.pytree import tree_bytes, tree_size

METHODS = ["isol", "fedavg", "dechetero", "cfa", "cfa-ge", "decdiff", "decdiff+vt"]


def run(verbose=True):
    topo = make_topology("erdos_renyi", n=50, p=0.2, seed=0)
    models = {
        "mlp(mnist)": make_mlp(num_classes=10),
        "cnn(fashion)": make_cnn(num_classes=10),
        "cnn(emnist)": make_cnn(num_classes=26, use_pool_dropout=True),
    }
    rows = []
    for mname, model in models.items():
        params = model.init(jax.random.PRNGKey(0))
        mb = tree_bytes(params)
        for method in METHODS:
            rows.append({
                "model": mname, "params": tree_size(params),
                "model_mbytes": mb / 1e6, "method": method,
                "bytes_per_round": comm_bytes_per_round(method, topo, mb),
            })
    save_results("comm_table", rows)
    if verbose:
        print(format_table(rows))
    return rows


def format_table(rows) -> str:
    lines = ["| model | method | MB/round (50-node ER p=.2) |", "|---|---|---|"]
    for r in rows:
        lines.append(f"| {r['model']} | {r['method']} | "
                     f"{r['bytes_per_round'] / 1e6:.1f} |")
    return "\n".join(lines)


def main():
    run()


if __name__ == "__main__":
    main()
