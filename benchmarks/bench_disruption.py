"""Paper Fig. 1: the round-1 synchronization disruption.

100-node (reduced: 24) Barabási-Albert graph, IID data, heterogeneous init:
DecHetero's accuracy collapses right after the first aggregation while
FedAvg (common init) and DecDiff+VT do not."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import save_results
from repro.data import make_dataset
from repro.data.allocation import split_by_allocation
from repro.engine import Experiment, Schedule, World
from repro.graphs import make_topology
from repro.models.mlp_cnn import model_for_dataset


def run(num_nodes=24, rounds=8, data_scale=0.06, verbose=True):
    ds = make_dataset("synth-mnist", seed=0, scale=data_scale)
    topo = make_topology("barabasi_albert", n=num_nodes, m=2, seed=0)
    # IID allocation (the paper's Fig. 1 uses IID to isolate the init effect)
    rng = np.random.default_rng(0)
    order = rng.permutation(len(ds.y_train))
    alloc = np.array_split(order, num_nodes)
    xs, ys = split_by_allocation(ds.x_train, ds.y_train, [np.sort(a) for a in alloc])
    model = model_for_dataset("synth-mnist", ds.num_classes)

    out = {}
    for method in ("dechetero", "fedavg", "decdiff+vt"):
        sim = Experiment(
            World(model=model, topo=topo, xs=xs, ys=ys,
                  x_test=ds.x_test, y_test=ds.y_test),
            method, schedule=Schedule(rounds=rounds, eval_every=1),
            steps_per_round=8, batch_size=32, lr=0.1, momentum=0.9)
        hist = sim.run()
        out[method] = [{"round": m.round, "acc": m.acc_mean} for m in hist]
        if verbose:
            accs = ", ".join(f"{h['acc']:.3f}" for h in out[method])
            print(f"[disruption] {method:12s} acc/round: {accs}")
    # headline numbers: drop between round 0 and round 1
    summary = {m: out[m][0]["acc"] - out[m][1]["acc"] for m in out}
    save_results("disruption", {"curves": out, "round0_to_1_drop": summary})
    return out, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()
    _, summary = run(rounds=args.rounds)
    print("round-0 -> round-1 accuracy drop (positive = disruption):")
    for m, d in summary.items():
        print(f"  {m:12s} {d:+.4f}")


if __name__ == "__main__":
    main()
