"""Dynamics suite: accuracy/bytes under time-varying topologies.

The ISSUE-5 tentpole measurement: DecDiff+VT on the 16-node BA and ER smoke
worlds, dense fp32 vs the production int8+adaptive transport, under every
catalog `repro.dynamics.GraphProcess` vs the static baseline:

  * ``static``           — the frozen graph (the per-(world, comm) baseline
    every dynamic point is scored against),
  * ``dropout(p=0.2)``   — i.i.d. edge failures (the acceptance process),
  * ``gilbert_elliott``  — bursty links (0.1, 0.3): same 0.75 stationary
    up-rate as dropout p=0.25 but with multi-round outages,
  * ``churn``            — device churn (0.05, 0.5): ~91% stationary
    aliveness with full per-edge comm-state resets on rejoin,
  * ``rewire``           — periodic Watts–Strogatz re-draws (period 5, 4
    graphs) over the union layout.

Each point reports final accuracy, exact bytes on wire (live edges only —
a non-existent link costs nothing), the realized live-edge fraction and the
triggered fraction.  `gen_report.write_bench_dynamics()` folds the sweep
into BENCH_dynamics.json with the acceptance gate: int8+adaptive under
i.i.d. dropout (p=0.2) stays within 3% (relative) of its own static-graph
final accuracy on the 16-node BA world.

    PYTHONPATH=src python -m benchmarks.bench_dynamics [--rounds 40]
    PYTHONPATH=src python -m benchmarks.bench_dynamics --smoke   # CI lane
"""
from __future__ import annotations

import argparse

from benchmarks.common import save_results
from repro.comm import CommConfig
from repro.dynamics import (
    EdgeDropout,
    GilbertElliott,
    NodeChurn,
    PeriodicRewiring,
    StaticGraph,
)
from repro.engine import Experiment, Schedule, World

ROUNDS = 40
EVAL_EVERY = 5

# (label, process factory) — factories so every run binds a fresh instance
PROCESSES = [
    ("static", lambda: StaticGraph()),
    ("dropout(p=0.2)", lambda: EdgeDropout(p=0.2)),
    ("gilbert_elliott(0.1,0.3)", lambda: GilbertElliott(p_gb=0.1, p_bg=0.3)),
    ("churn(0.05,0.5)", lambda: NodeChurn(p_leave=0.05, p_rejoin=0.5)),
    ("rewire(ws,T=5,K=4)", lambda: PeriodicRewiring(period=5, num_graphs=4)),
]
COMMS = [
    ("dense-fp32", dict(codec="fp32")),
    ("int8+adaptive", dict(codec="int8", policy="adaptive",
                           target_trigger=0.95)),
]
WORLDS = [("ba", dict(topology="barabasi_albert", m=2)),
          ("er", dict(topology="erdos_renyi", p=0.3))]


def make_world(graph_kwargs, nodes=16, seed=0, dynamics=None):
    """The 16-node smoke worlds (bench_engine's scaled comm smoke config)."""
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=nodes, seed=seed,
                           scale=0.03,
                           model=make_mlp(num_classes=10, hidden=(64, 32)),
                           dynamics=dynamics, **graph_kwargs)


def run(rounds=ROUNDS, nodes=16, seed=0, worlds=None, verbose=True,
        smoke=False):
    rows = []
    for wname, wkw in (worlds or WORLDS):
        for cname, ckw in COMMS:
            for pname, factory in PROCESSES:
                world = make_world(wkw, nodes=nodes, seed=seed,
                                   dynamics=factory())
                exp = Experiment(
                    world, "decdiff+vt", comm=CommConfig(**ckw),
                    schedule=Schedule(rounds=rounds, eval_every=EVAL_EVERY),
                    steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9,
                    seed=seed)
                hist = exp.run()
                last = hist[-1]
                rows.append({
                    "world": wname, "process": pname, "comm": cname,
                    "nodes": nodes, "rounds": rounds, "seed": seed,
                    "acc_mean": last.acc_mean, "acc_std": last.acc_std,
                    "bytes_on_wire": exp.comm_bytes_total,
                    "payload_bytes": exp.transport.payload_bytes,
                    "triggered_frac": last.triggered_frac,
                    "live_edge_frac": last.live_edge_frac,
                })
                if verbose:
                    r = rows[-1]
                    print(f"[{wname}] {cname:>13} {pname:<24} "
                          f"acc={r['acc_mean']:.4f} "
                          f"wire={r['bytes_on_wire'] / 1e6:7.2f} MB "
                          f"live={r['live_edge_frac']:.2f} "
                          f"trig={r['triggered_frac']:.2f}", flush=True)
    # score every point against its own (world, comm) static baseline
    for r in rows:
        base = next(b for b in rows
                    if b["world"] == r["world"] and b["comm"] == r["comm"]
                    and b["process"] == "static")
        r["acc_delta_vs_static"] = r["acc_mean"] - base["acc_mean"]
        r["bytes_ratio_vs_static"] = (r["bytes_on_wire"]
                                      / max(base["bytes_on_wire"], 1))
    if smoke:
        save_results("dynamics_smoke", rows)
        return rows
    save_results("dynamics_suite", rows)
    from benchmarks.gen_report import write_bench_dynamics

    path = write_bench_dynamics()
    if verbose and path:
        print("wrote", path)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI lane: 8 nodes x 5 rounds on the BA world "
                         "only; writes the dynamics_smoke artifact and does "
                         "NOT touch BENCH_dynamics.json")
    args = ap.parse_args()
    if args.smoke:
        rows = run(rounds=5, nodes=8, seed=args.seed,
                   worlds=[WORLDS[0]], smoke=True)
        assert all(r["acc_mean"] == r["acc_mean"] for r in rows)  # finite
        print(f"smoke ok: {len(rows)} (process x comm) points")
    else:
        run(rounds=args.rounds, nodes=args.nodes, seed=args.seed)


if __name__ == "__main__":
    main()
