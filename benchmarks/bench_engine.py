"""Engine runner throughput: the scan-fused schedule vs the per-round loop.

The first perf artifact of this repo's runner layer.  One seeded 16-node
Barabási–Albert smoke world (the comm smoke config scaled to 16 nodes),
DecDiff+VT, identical math on every row — `Schedule(mode=...)` is pinned
bit-identical across modes by tests/test_engine.py — so the only thing
measured is the execution strategy:

  * ``loop``  — one jitted XLA dispatch per round + a jitted eval call at
    the eval cadence;
  * ``fused`` — the whole schedule (K rounds + flag-gated evals) compiled
    into ONE `lax.scan` program and dispatched once.

Reported per mode: rounds/sec (after a full warmup run that absorbs
compilation) and the compile+first-run wall time, on both backends where
the host allows.  When a pod axis exists, the shard_map exchange is also
timed on BOTH wires — ``encoded`` (the default: codec payload crosses the
pod axis, every pod decodes the gathered bytes) vs ``decoded`` (the
oracle: fp32 rows cross) — with an int8 event-triggered transport, so the
artifact records that the fused encoded default is no slower.
`gen_report.write_bench_engine()` folds the sweep into BENCH_engine.json
with the acceptance gates: fused >= 2x loop rounds/sec on the vmap
backend, and encoded >= 0.9x decoded rounds/sec on shard_map.

    PYTHONPATH=src python -m benchmarks.bench_engine [--rounds 60] [--smoke]

``--smoke`` shrinks the sweep (8 rounds, 1 timed repeat) and writes the
``engine_smoke`` artifact instead of the committed one — the CI multihost
lane uses it to exercise the shard_map encoded-payload path end to end.
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import load_results, save_results
from repro.comm import CommConfig
from repro.engine import Experiment, Schedule, World

ROUNDS = 60
EVAL_EVERY = 10
TIMED_REPEATS = 3  # best-of: the 2-core CPU container is a noisy neighbour


def smoke_world16(seed=0):
    """16-node BA scale-free world over reduced synth-mnist, the paper's
    Table-I MLP — the ISSUE-4 acceptance world."""
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=16,
                           topology="barabasi_albert", m=2, seed=seed,
                           scale=0.03,
                           model=make_mlp(num_classes=10, hidden=(64, 32)))


def _time_mode(world, mode, backend, rounds, eval_every, seed=0,
               comm=None, wire="encoded", repeats=TIMED_REPEATS):
    exp = Experiment(world, "decdiff+vt", backend=backend, comm=comm,
                     wire=wire,
                     schedule=Schedule(rounds=rounds, eval_every=eval_every,
                                       mode=mode),
                     steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9,
                     seed=seed)
    t0 = time.perf_counter()
    exp.run()  # compile + warmup (state evolves; the timed runs continue)
    compile_s = time.perf_counter() - t0
    wall = float("inf")
    for _ in range(repeats):  # best-of: de-noise the shared CPU
        t0 = time.perf_counter()
        hist = exp.run()
        wall = min(wall, time.perf_counter() - t0)
    return {
        "mode": mode, "backend": backend, "rounds": rounds,
        "eval_every": eval_every,
        "wire": wire,
        "comm": None if comm is None else "int8+trigger",
        "rounds_per_sec": rounds / wall,
        "wall_s": wall,
        "timed_repeats": repeats,
        "compile_and_first_run_s": compile_s,
        "final_acc": hist[-1].acc_mean,
    }


def run(rounds=ROUNDS, eval_every=EVAL_EVERY, seed=0, verbose=True,
        smoke=False):
    import jax

    repeats = 1 if smoke else TIMED_REPEATS
    world = smoke_world16(seed)
    rows = []
    backends = ["vmap"]
    # the shard_map lowering is also timed when a pod axis exists (on the
    # forced 4-device CPU mesh this measures lowering overhead, not a real
    # interconnect — recorded for trend, excluded from the acceptance gate)
    if len(jax.devices()) > 1 and 16 % len(jax.devices()) == 0:
        backends.append("shard_map")
    for backend in backends:
        for mode in ("loop", "fused"):
            row = _time_mode(world, mode, backend, rounds, eval_every,
                             seed=seed, repeats=repeats)
            rows.append(row)
            if verbose:
                print(f"[{backend:>9}/{mode:5}] {row['rounds_per_sec']:8.1f} "
                      f"rounds/s  (timed {row['wall_s']:.2f}s over {rounds} "
                      f"rounds, compile+warmup {row['compile_and_first_run_s']:.2f}s, "
                      f"final acc {row['final_acc']:.4f})", flush=True)
    by = {(r["backend"], r["mode"]): r for r in rows}
    speedup = (by[("vmap", "fused")]["rounds_per_sec"]
               / by[("vmap", "loop")]["rounds_per_sec"])
    if verbose:
        print(f"scan-fused speedup (vmap): {speedup:.2f}x")

    # wire comparison: the fused encoded-payload shard_map exchange (the
    # default) vs the decoded-rows oracle, int8 event-triggered transport.
    wire_rows, wire_ratio = [], None
    if "shard_map" in backends:
        comm = CommConfig(codec="int8", trigger_threshold=1.0)
        for wire in ("encoded", "decoded"):
            row = _time_mode(world, "fused", "shard_map", rounds, eval_every,
                             seed=seed, comm=comm, wire=wire,
                             repeats=repeats if smoke else 2 * TIMED_REPEATS)
            wire_rows.append(row)
            if verbose:
                print(f"[shard_map/fused/int8 wire={wire:7}] "
                      f"{row['rounds_per_sec']:8.1f} rounds/s", flush=True)
        wire_ratio = (wire_rows[0]["rounds_per_sec"]
                      / wire_rows[1]["rounds_per_sec"])
        if verbose:
            print(f"encoded/decoded rounds-per-sec ratio (shard_map): "
                  f"{wire_ratio:.2f}x")

    payload = {
        "world": {"graph": "barabasi_albert(n=16, m=2, seed=%d)" % seed,
                  "dataset": "synth-mnist(scale=0.03)",
                  "model": "mlp(64, 32)", "method": "decdiff+vt",
                  "steps_per_round": 4, "batch_size": 32},
        "rows": rows,
        "wire_rows": wire_rows,
        "fused_speedup_vmap": speedup,
        "encoded_over_decoded_shardmap": wire_ratio,
    }
    if smoke:
        # CI artifact only — the committed BENCH_engine.json is refreshed
        # by the full bench, never by the smoke lane.
        save_results("engine_smoke", payload)
        return payload
    save_results("engine_runner", payload)
    from benchmarks.gen_report import write_bench_engine

    path = write_bench_engine()
    if verbose and path:
        print("wrote", path)
    return payload


def run_wire_only(rounds=ROUNDS, eval_every=EVAL_EVERY, seed=0,
                 verbose=True):
    """Refresh ONLY the wire-comparison rows of the engine_runner artifact.

    The main backend/mode sweep is timed on the natural host (no forced
    device count — that splits the CPU threadpool and distorts the vmap
    numbers the 2x schedule gate is defined over), while the wire rows
    need a pod axis.  So the committed artifact is produced in two runs:
    the full bench on the natural host, then this under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
    """
    import jax

    if len(jax.devices()) < 2 or 16 % len(jax.devices()) != 0:
        raise SystemExit("--wire-only needs a pod axis (run under "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    res = load_results("engine_runner")
    if not res:
        raise SystemExit("engine_runner artifact missing; run the full "
                         "bench first")
    world = smoke_world16(seed)
    comm = CommConfig(codec="int8", trigger_threshold=1.0)
    wire_rows = []
    for wire in ("encoded", "decoded"):
        row = _time_mode(world, "fused", "shard_map", rounds, eval_every,
                         seed=seed, comm=comm, wire=wire,
                         repeats=2 * TIMED_REPEATS)
        wire_rows.append(row)
        if verbose:
            print(f"[shard_map/fused/int8 wire={wire:7}] "
                  f"{row['rounds_per_sec']:8.1f} rounds/s", flush=True)
    ratio = wire_rows[0]["rounds_per_sec"] / wire_rows[1]["rounds_per_sec"]
    if verbose:
        print(f"encoded/decoded rounds-per-sec ratio (shard_map): "
              f"{ratio:.2f}x")
    res["wire_rows"] = wire_rows
    res["encoded_over_decoded_shardmap"] = ratio
    save_results("engine_runner", res)
    from benchmarks.gen_report import write_bench_engine

    path = write_bench_engine()
    if verbose and path:
        print("wrote", path)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--eval-every", type=int, default=EVAL_EVERY)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep (8 rounds, 1 repeat); writes the "
                         "engine_smoke artifact only")
    ap.add_argument("--wire-only", action="store_true",
                    help="refresh only the encoded-vs-decoded wire rows of "
                         "the engine_runner artifact (needs a pod axis)")
    args = ap.parse_args()
    if args.smoke:
        run(rounds=8, eval_every=8, seed=args.seed, smoke=True)
    elif args.wire_only:
        run_wire_only(rounds=args.rounds, eval_every=args.eval_every,
                      seed=args.seed)
    else:
        run(rounds=args.rounds, eval_every=args.eval_every, seed=args.seed)


if __name__ == "__main__":
    main()
