"""Engine runner throughput: the scan-fused schedule vs the per-round loop.

The first perf artifact of this repo's runner layer.  One seeded 16-node
Barabási–Albert smoke world (the comm smoke config scaled to 16 nodes),
DecDiff+VT, identical math on every row — `Schedule(mode=...)` is pinned
bit-identical across modes by tests/test_engine.py — so the only thing
measured is the execution strategy:

  * ``loop``  — one jitted XLA dispatch per round + a jitted eval call at
    the eval cadence (the legacy `DFLSimulator.run` behaviour);
  * ``fused`` — the whole schedule (K rounds + flag-gated evals) compiled
    into ONE `lax.scan` program and dispatched once.

Reported per mode: rounds/sec (after a full warmup run that absorbs
compilation) and the compile+first-run wall time, on both backends where
the host allows.  `gen_report.write_bench_engine()` folds the sweep into
BENCH_engine.json with the acceptance gate: fused >= 2x loop rounds/sec on
the vmap backend.

    PYTHONPATH=src python -m benchmarks.bench_engine [--rounds 60]
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import save_results
from repro.engine import Experiment, Schedule, World

ROUNDS = 60
EVAL_EVERY = 10
TIMED_REPEATS = 3  # best-of: the 2-core CPU container is a noisy neighbour


def smoke_world16(seed=0):
    """16-node BA scale-free world over reduced synth-mnist, the paper's
    Table-I MLP — the ISSUE-4 acceptance world."""
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=16,
                           topology="barabasi_albert", m=2, seed=seed,
                           scale=0.03,
                           model=make_mlp(num_classes=10, hidden=(64, 32)))


def _time_mode(world, mode, backend, rounds, eval_every, seed=0):
    exp = Experiment(world, "decdiff+vt", backend=backend,
                     schedule=Schedule(rounds=rounds, eval_every=eval_every,
                                       mode=mode),
                     steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9,
                     seed=seed)
    t0 = time.perf_counter()
    exp.run()  # compile + warmup (state evolves; the timed runs continue)
    compile_s = time.perf_counter() - t0
    wall = float("inf")
    for _ in range(TIMED_REPEATS):  # best-of: de-noise the shared CPU
        t0 = time.perf_counter()
        hist = exp.run()
        wall = min(wall, time.perf_counter() - t0)
    return {
        "mode": mode, "backend": backend, "rounds": rounds,
        "eval_every": eval_every,
        "rounds_per_sec": rounds / wall,
        "wall_s": wall,
        "timed_repeats": TIMED_REPEATS,
        "compile_and_first_run_s": compile_s,
        "final_acc": hist[-1].acc_mean,
    }


def run(rounds=ROUNDS, eval_every=EVAL_EVERY, seed=0, verbose=True):
    import jax

    world = smoke_world16(seed)
    rows = []
    backends = ["vmap"]
    # the shard_map lowering is also timed when a pod axis exists (on the
    # forced 4-device CPU mesh this measures lowering overhead, not a real
    # interconnect — recorded for trend, excluded from the acceptance gate)
    if len(jax.devices()) > 1 and 16 % len(jax.devices()) == 0:
        backends.append("shard_map")
    for backend in backends:
        for mode in ("loop", "fused"):
            row = _time_mode(world, mode, backend, rounds, eval_every,
                             seed=seed)
            rows.append(row)
            if verbose:
                print(f"[{backend:>9}/{mode:5}] {row['rounds_per_sec']:8.1f} "
                      f"rounds/s  (timed {row['wall_s']:.2f}s over {rounds} "
                      f"rounds, compile+warmup {row['compile_and_first_run_s']:.2f}s, "
                      f"final acc {row['final_acc']:.4f})", flush=True)
    by = {(r["backend"], r["mode"]): r for r in rows}
    speedup = (by[("vmap", "fused")]["rounds_per_sec"]
               / by[("vmap", "loop")]["rounds_per_sec"])
    if verbose:
        print(f"scan-fused speedup (vmap): {speedup:.2f}x")
    payload = {
        "world": {"graph": "barabasi_albert(n=16, m=2, seed=%d)" % seed,
                  "dataset": "synth-mnist(scale=0.03)",
                  "model": "mlp(64, 32)", "method": "decdiff+vt",
                  "steps_per_round": 4, "batch_size": 32},
        "rows": rows,
        "fused_speedup_vmap": speedup,
    }
    save_results("engine_runner", payload)
    from benchmarks.gen_report import write_bench_engine

    path = write_bench_engine()
    if verbose and path:
        print("wrote", path)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--eval-every", type=int, default=EVAL_EVERY)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(rounds=args.rounds, eval_every=args.eval_every, seed=args.seed)


if __name__ == "__main__":
    main()
