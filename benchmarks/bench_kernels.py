"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (a
correctness harness, not a perf mode), so wall-times compare the pure-jnp
reference implementations (XLA-compiled on CPU) and report the kernels'
expected TPU roofline instead: all three are HBM-streaming ops, so
t_expected = bytes_moved / 819 GB/s per chip."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_results
from repro.kernels.ref import decdiff_update_ref, neighbor_avg_ref, vt_kl_loss_ref

HBM_BW = 819e9


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run(verbose=True):
    rows = []
    rng = np.random.default_rng(0)

    # decdiff_update: streams 3 model-sized vectors (read w, wbar; write w')
    for n in (1 << 20, 1 << 24):
        w = jnp.asarray(rng.standard_normal(n), jnp.float32)
        wb = jnp.asarray(rng.standard_normal(n), jnp.float32)
        f = jax.jit(decdiff_update_ref)
        us = _time(f, w, wb)
        bytes_moved = 3 * 4 * n
        rows.append({"name": f"decdiff_update/n={n}", "cpu_ref_us": us,
                     "tpu_roofline_us": bytes_moved / HBM_BW * 1e6})

    # vt_kl_loss: streams logits once (stats) — B*V fp32 read
    for (b, v) in ((256, 32000), (64, 151936)):
        z = jnp.asarray(rng.standard_normal((b, v)), jnp.float32)
        y = jnp.asarray(rng.integers(0, v, b), jnp.int32)
        f = jax.jit(lambda zz, yy: vt_kl_loss_ref(zz, yy, 0.95))
        us = _time(f, z, y)
        rows.append({"name": f"vt_kl_loss/b={b},v={v}", "cpu_ref_us": us,
                     "tpu_roofline_us": (4 * b * v) / HBM_BW * 1e6})

    # neighbor_avg: streams N stacked models
    for (n, d) in ((8, 1 << 22), (16, 1 << 20)):
        st = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        wts = jnp.asarray(rng.random(n) + 0.5, jnp.float32)
        f = jax.jit(neighbor_avg_ref)
        us = _time(f, st, wts)
        rows.append({"name": f"neighbor_avg/n={n},d={d}", "cpu_ref_us": us,
                     "tpu_roofline_us": (4 * n * d) / HBM_BW * 1e6})

    save_results("kernel_bench", rows)
    if verbose:
        for r in rows:
            print(f"{r['name']:32s} cpu_ref {r['cpu_ref_us']:10.1f} us   "
                  f"tpu_roofline {r['tpu_roofline_us']:8.1f} us")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
