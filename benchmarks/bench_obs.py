"""Telemetry overhead + ledger/trace smoke: what observability costs.

The repro.obs channels ride the engine's one `lax.scan` carry, so turning
ALL of them on must not meaningfully move the fused schedule's throughput.
On the 16-node BA engine-bench world (bench_engine's smoke config) with
heterogeneous compute and links (so every channel in the catalog is
selectable) this bench times the fused vmap schedule twice — telemetry=None
vs `Telemetry(channels="all")` — best-of-N on the warm program, and
records the overhead ratio.  Acceptance (folded into BENCH_obs.json by
`gen_report.write_bench_obs()`): all-channels rounds/sec within 5% of
telemetry-off.

The same run then exercises the full observability surface end to end:

  * a `Telemetry(ledger=...)` run writes the JSONL ledger and the bench
    re-validates every record against `repro.obs.SCHEMA`
    (`validate_ledger`),
  * `export_trace` renders the deadline-mode event clock to a Chrome-trace
    JSON, which is loaded back and cross-checked: the per-edge transfer
    spans' exact bytes must sum to the run's `bytes_on_wire`.

    PYTHONPATH=src python -m benchmarks.bench_obs [--rounds 40]
    PYTHONPATH=src python -m benchmarks.bench_obs --smoke   # CI lane

``--smoke`` shrinks the run (8 rounds) and writes the ``obs_smoke``
artifact instead of the committed one, so a down-scaled pass never
clobbers BENCH_obs.json inputs.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import ART_DIR, save_results
from repro.comm import CommConfig
from repro.engine import Experiment, Schedule, World
from repro.obs import Telemetry, export_trace, validate_ledger
from repro.timing import LognormalLink, LognormalStep, Timing

ROUNDS = 40
EVAL_EVERY = 10
DEADLINE = 6.0
TIMED_REPEATS = 3  # best-of: the 2-core CPU container is a noisy neighbour

HET = Timing(node=LognormalStep(sigma=0.5, seed=7),
             link=LognormalLink(seed=9))


def obs_world16(telemetry, seed=0):
    """bench_engine's 16-node BA world + the event clock (so the FULL
    channel catalog is selectable), with/without telemetry."""
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=16,
                           topology="barabasi_albert", m=2, seed=seed,
                           scale=0.03,
                           model=make_mlp(num_classes=10, hidden=(64, 32)),
                           timing=HET, telemetry=telemetry)


def _make_exp(telemetry, rounds, eval_every, seed=0):
    return Experiment(obs_world16(telemetry, seed), "decdiff+vt",
                      comm=CommConfig(codec="int8", trigger_threshold=0.3),
                      schedule=Schedule(rounds=rounds, eval_every=eval_every,
                                        deadline=DEADLINE, mode="fused"),
                      steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9,
                      seed=seed)


def _time_pair(rounds, eval_every, seed=0, repeats=TIMED_REPEATS):
    """Time the SAME fused schedule with telemetry off vs all channels on,
    interleaving the timed repeats (off, on, off, on, ...) so slow drift
    in the shared container's load cancels out of the ratio; each side
    reports its best-of."""
    exps = {"off": _make_exp(None, rounds, eval_every, seed),
            "all": _make_exp(Telemetry(channels="all"), rounds, eval_every,
                             seed)}
    hists, walls = {}, {"off": float("inf"), "all": float("inf")}
    for exp in exps.values():
        exp.run()  # compile + warmup (state evolves; timed runs continue)
    for _ in range(repeats):
        for label, exp in exps.items():
            t0 = time.perf_counter()
            hists[label] = exp.run()
            walls[label] = min(walls[label], time.perf_counter() - t0)
    rows = [{
        "telemetry": label,
        "rounds": rounds, "eval_every": eval_every, "mode": "fused",
        "rounds_per_sec": rounds / walls[label], "wall_s": walls[label],
        "timed_repeats": repeats,
        "final_acc": hists[label][-1].acc_mean,
        "bytes_on_wire": hists[label][-1].bytes_on_wire,
    } for label in ("off", "all")]
    return rows


def run(rounds=ROUNDS, eval_every=EVAL_EVERY, seed=0, verbose=True,
        smoke=False):
    # --- 1. overhead pair: identical run, only telemetry differs --------
    rows = _time_pair(rounds, eval_every, seed)
    off, on = rows
    overhead = off["rounds_per_sec"] / on["rounds_per_sec"] - 1.0
    if verbose:
        print(f"[obs] telemetry off: {off['rounds_per_sec']:8.2f} rounds/s")
        print(f"[obs] all channels:  {on['rounds_per_sec']:8.2f} rounds/s "
              f"({overhead * 100:+.1f}% overhead)")

    # --- 2. ledger + trace end to end ----------------------------------
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = "_smoke" if smoke else ""
    ledger_path = os.path.join(ART_DIR, f"obs_run{suffix}.jsonl")
    trace_path = os.path.join(ART_DIR, f"obs_trace{suffix}.json")
    exp = _make_exp(Telemetry(channels="all", ledger=ledger_path),
                    rounds, eval_every, seed)
    hist = exp.run()
    ledger_counts = validate_ledger(ledger_path)  # raises on any bad record
    export_trace(exp, trace_path)
    with open(trace_path) as f:
        trace = json.load(f)
    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    transfer_bytes = sum(e["args"]["bytes"] for e in spans
                         if e["pid"] == 1)
    trace_exact = transfer_bytes == hist[-1].bytes_on_wire
    detail = hist[-1].detail
    if verbose:
        print(f"[obs] ledger {os.path.basename(ledger_path)}: "
              f"{ledger_counts}")
        print(f"[obs] trace: {len(spans)} spans, transfer bytes "
              f"{transfer_bytes / 1e6:.2f} MB "
              f"({'exact' if trace_exact else 'MISMATCH'})")

    payload = {
        "world": "ba16 + lognormal compute/links (bench_engine smoke "
                 "config + event clock)",
        "rows": rows,
        "overhead_frac": overhead,
        "overhead_passed": bool(overhead <= 0.05),
        "ledger": {"path": os.path.basename(ledger_path),
                   "counts": ledger_counts},
        "trace": {"path": os.path.basename(trace_path),
                  "num_spans": len(spans),
                  "transfer_bytes": float(transfer_bytes),
                  "bytes_exact": bool(trace_exact)},
        "dispersion": {
            # the distributional story the channels exist for
            "acc_per_node_std": float(np.std(detail["node_acc"])),
            "node_steps_min": float(np.min(detail["node_steps"])),
            "node_steps_max": float(np.max(detail["node_steps"])),
            "edge_bytes_p50": float(np.percentile(detail["edge_bytes"], 50)),
            "edge_bytes_p95": float(np.percentile(detail["edge_bytes"], 95)),
        },
    }
    save_results("obs_smoke" if smoke else "obs_suite", payload)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--eval-every", type=int, default=EVAL_EVERY)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (8 rounds); writes the obs_smoke "
                         "artifact only")
    args = ap.parse_args()
    if args.smoke:
        payload = run(rounds=8, eval_every=4, smoke=True)
    else:
        payload = run(rounds=args.rounds, eval_every=args.eval_every)
    ok = payload["overhead_passed"] and payload["trace"]["bytes_exact"]
    print(f"[obs] acceptance: overhead {payload['overhead_frac'] * 100:+.1f}%"
          f" (gate <=5%), trace bytes "
          f"{'exact' if payload['trace']['bytes_exact'] else 'MISMATCH'}"
          f" -> {'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
