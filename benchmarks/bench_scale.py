"""Node-axis scaling: the dense padded engine vs the sparse edge-list one.

The dense layout carries O(N·max_deg) neighbour state and gathers an
[N, max_deg, D] value block every round — on a scale-free graph max_deg
grows with N, so the block is effectively O(N^2·D) and the engine hits a
memory wall around a few thousand nodes.  The sparse layout
(`Experiment(layout="sparse")` over a `repro.graphs.SparseTopology`) keeps
O(N + E) edge state and reduces degree-bucketed ragged blocks, so the node
axis extends to 10^4 engine nodes (and 10^5-10^6 for the graph builders
and the reduce kernel alone) on this 2-core CPU container.

Three tiers, recorded in one artifact (four with ``--dynamics``):

  * engine rounds/sec: a tiny-MLP gossip world (DecDiff), swept over N for
    BOTH layouts; dense stops where its padded block would not fit (the
    row records the projected bytes instead of crashing the host);
  * kernel reduce: `segment_neighbor_avg` walltime at 10^5 receivers;
  * graph build: `sparse_barabasi_albert` walltime at 10^6 nodes;
  * ``--dynamics``: the lifted sparse scenario cube at scale — DecDiff
    through the int8+adaptive PER-EDGE transport under 20% i.i.d. edge
    dropout at 10^4 nodes, sparse layout (the dense engine is
    memory-walled there; at oracle sizes the two are bit-identical, see
    tests/test_sparse_parity.py).

    PYTHONPATH=src python -m benchmarks.bench_scale [--smoke] [--dynamics]

``--smoke`` runs [64, 256] nodes x both layouts (plus downscaled kernel/
builder/dynamics tiers) and writes the ``scale_smoke`` artifact only — the
committed BENCH_scale.json is refreshed by the full bench via
`gen_report.write_bench_scale()`.
"""
from __future__ import annotations

import argparse
import dataclasses
import resource
import time

import numpy as np

from benchmarks.common import save_results

# dense is swept while its padded neighbour block stays under this budget;
# past it the row records the projection, not an OOM.
DENSE_BYTES_BUDGET = int(1.5e9)
ENGINE_NODES = (64, 256, 1024, 4096, 10000)
SMOKE_NODES = (64, 256)
ROUNDS = 3


def _maxrss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def tiny_world(n: int, seed: int = 0):
    """A minimal gossip world sized for the node axis, not the model axis:
    16-dim inputs, 4 samples per node, one-hidden-layer MLP (~850 params) —
    per-round cost is then dominated by the neighbour exchange, which is
    the thing this bench scales."""
    from repro.engine import World
    from repro.graphs.sparse import sparse_barabasi_albert
    from repro.models.mlp_cnn import make_mlp

    rng = np.random.default_rng(seed)
    dim, per_node, classes = 16, 4, 10
    xs = [rng.normal(size=(per_node, dim)).astype(np.float32)
          for _ in range(n)]
    ys = [rng.integers(0, classes, size=per_node).astype(np.int32)
          for _ in range(n)]
    x_test = rng.normal(size=(64, dim)).astype(np.float32)
    y_test = rng.integers(0, classes, size=64).astype(np.int32)
    st = sparse_barabasi_albert(n=n, m=2, seed=seed)
    model = make_mlp(num_classes=classes, input_dim=dim, hidden=(32,))
    return World(model=model, topo=st, xs=xs, ys=ys,
                 x_test=x_test, y_test=y_test), st


def dense_block_bytes(st, d_model: int) -> float:
    """The dense exchange's dominant allocation: the [N, max_deg, D] f32
    gathered-neighbour block (the padded index/weight panels are the same
    shape sans D)."""
    return 4.0 * st.num_nodes * st.max_degree * d_model


def _time_engine(world, layout: str, rounds: int, seed: int = 0):
    from repro.engine import Experiment, Schedule

    exp = Experiment(world, "decdiff", layout=layout,
                     schedule=Schedule(rounds=rounds, eval_every=rounds,
                                       mode="loop"),
                     steps_per_round=1, batch_size=4, eval_batch=64,
                     lr=0.1, seed=seed)
    exp.run()  # compile + warmup
    t0 = time.perf_counter()
    exp.run()
    wall = time.perf_counter() - t0
    return rounds / wall, wall


def engine_sweep(nodes, rounds: int, seed: int = 0, verbose: bool = True):
    import jax

    d_model = None
    rows = []
    for n in nodes:
        world, st = tiny_world(n, seed)
        if d_model is None:
            p = world.model.init(jax.random.PRNGKey(0))
            d_model = int(sum(np.prod(l.shape, dtype=int)
                              for l in jax.tree.leaves(p)))
        for layout in ("dense", "sparse"):
            row = {"nodes": n, "layout": layout, "d_model": d_model,
                   "edges_directed": st.num_directed,
                   "max_degree": st.max_degree,
                   "dense_block_bytes": dense_block_bytes(st, d_model),
                   "rounds": rounds}
            if (layout == "dense"
                    and row["dense_block_bytes"] > DENSE_BYTES_BUDGET):
                row["skipped"] = (
                    f"projected dense neighbour block "
                    f"{row['dense_block_bytes'] / 1e9:.1f} GB exceeds the "
                    f"{DENSE_BYTES_BUDGET / 1e9:.1f} GB budget")
                if verbose:
                    print(f"[n={n:6d} {layout:6}] SKIP ({row['skipped']})",
                          flush=True)
            else:
                rps, wall = _time_engine(world, layout, rounds, seed)
                row.update(rounds_per_sec=rps, wall_s=wall,
                           maxrss_mb=_maxrss_mb())
                if verbose:
                    print(f"[n={n:6d} {layout:6}] {rps:7.2f} rounds/s  "
                          f"(maxrss {row['maxrss_mb']:.0f} MB)", flush=True)
            rows.append(row)
    return rows


def kernel_tier(receivers: int = 100_000, width: int = 8, d: int = 256,
                verbose: bool = True):
    """The ragged reduce alone at 10^5 receivers (no training loop)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import segment_neighbor_avg

    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(receivers, width, d))
                       .astype(np.float32))
    w = jnp.asarray(rng.random((receivers, width)).astype(np.float32))
    sums, tot = segment_neighbor_avg(vals, w)  # compile + warmup
    jax.block_until_ready((sums, tot))
    t0 = time.perf_counter()
    sums, tot = segment_neighbor_avg(vals, w)
    jax.block_until_ready((sums, tot))
    wall = time.perf_counter() - t0
    row = {"receivers": receivers, "width": width, "d": d, "wall_s": wall,
           "edges_per_sec": receivers * width / wall}
    if verbose:
        print(f"[kernel n={receivers} k={width} d={d}] {wall:.2f}s "
              f"({row['edges_per_sec'] / 1e6:.2f}M edge-slots/s)", flush=True)
    return row


def builder_tier(n: int = 1_000_000, verbose: bool = True):
    """Vectorized sparse BA builder at the 10^6-node tier."""
    from repro.graphs.sparse import sparse_barabasi_albert

    t0 = time.perf_counter()
    st = sparse_barabasi_albert(n=n, m=2, seed=0, ensure_connected=False)
    wall = time.perf_counter() - t0
    row = {"nodes": n, "edges_directed": st.num_directed,
           "max_degree": st.max_degree, "wall_s": wall,
           "nodes_per_sec": n / wall}
    if verbose:
        print(f"[builder ba n={n}] {wall:.2f}s "
              f"(max_degree {st.max_degree})", flush=True)
    return row


def dynamics_tier(n: int = 10_000, rounds: int = ROUNDS, seed: int = 0,
                  verbose: bool = True):
    """The whole lifted scenario cube in one record: `layout="sparse"` x
    per-edge adaptive int8 transport x `EdgeDropout(p=0.2)` — the three
    combinations the sparse engine used to reject at construction, composed,
    at a node count the dense engine cannot reach."""
    from repro.comm import CommConfig
    from repro.dynamics import EdgeDropout
    from repro.engine import Experiment, Schedule

    world, st = tiny_world(n, seed)
    world = dataclasses.replace(world, dynamics=EdgeDropout(p=0.2))
    comm = CommConfig(codec="int8", policy="adaptive", target_trigger=0.6,
                      per_edge=True)
    exp = Experiment(world, "decdiff", layout="sparse", comm=comm,
                     schedule=Schedule(rounds=rounds, eval_every=rounds,
                                       mode="loop"),
                     steps_per_round=1, batch_size=4, eval_batch=64,
                     lr=0.1, seed=seed)
    exp.run()  # compile + warmup
    t0 = time.perf_counter()
    exp.run()
    wall = time.perf_counter() - t0
    row = {"nodes": n, "edges_directed": st.num_directed,
           "layout": "sparse", "method": "decdiff",
           "transport": "per-edge int8 adaptive (target_trigger=0.6)",
           "dropout_p": 0.2, "rounds": rounds,
           "rounds_per_sec": rounds / wall, "wall_s": wall,
           "maxrss_mb": _maxrss_mb(),
           "live_frac_mean": float(np.mean(exp.live_history[-rounds:])),
           "trig_frac_mean": float(np.mean(exp.trig_history[-rounds:])),
           "comm_bytes_total": int(exp.comm_bytes_total)}
    if verbose:
        print(f"[dynamics n={n} sparse int8+adaptive drop=0.2] "
              f"{row['rounds_per_sec']:.2f} rounds/s  "
              f"(live {row['live_frac_mean']:.3f}, "
              f"trig {row['trig_frac_mean']:.3f})", flush=True)
    return row


def run(smoke: bool = False, seed: int = 0, verbose: bool = True,
        dynamics: bool = False):
    nodes = SMOKE_NODES if smoke else ENGINE_NODES
    rows = engine_sweep(nodes, ROUNDS, seed=seed, verbose=verbose)
    kernel = kernel_tier(receivers=10_000 if smoke else 100_000,
                         verbose=verbose)
    builder = builder_tier(n=100_000 if smoke else 1_000_000,
                           verbose=verbose)
    dyn_row = (dynamics_tier(n=512 if smoke else 10_000, seed=seed,
                             verbose=verbose) if dynamics else None)
    payload = {
        "world": {"graph": "sparse_barabasi_albert(m=2)",
                  "model": "mlp(16->32->10)", "method": "decdiff",
                  "steps_per_round": 1, "batch_size": 4,
                  "rounds_timed": ROUNDS},
        "dense_bytes_budget": DENSE_BYTES_BUDGET,
        "rows": rows,
        "kernel": kernel,
        "builder": builder,
        "dynamics": dyn_row,
    }
    if smoke:
        # CI artifact only — the committed BENCH_scale.json is refreshed by
        # the full bench, never by the smoke lane.
        save_results("scale_smoke", payload)
        return payload
    save_results("scale_sweep", payload)
    from benchmarks.gen_report import write_bench_scale

    path = write_bench_scale()
    if verbose and path:
        print("wrote", path)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="[64, 256] nodes x both layouts + downscaled "
                         "kernel/builder tiers; writes the scale_smoke "
                         "artifact only")
    ap.add_argument("--dynamics", action="store_true",
                    help="add the sparse int8+adaptive-under-dropout tier "
                         "(10^4 nodes; 512 with --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, seed=args.seed, dynamics=args.dynamics)


if __name__ == "__main__":
    main()
