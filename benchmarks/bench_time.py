"""Time-to-accuracy suite: the event clock prices rounds in simulated
seconds, so methods are compared on WHEN they reach a target accuracy, not
in how many rounds (ISSUE-9 tentpole measurement).

On the 16-node BA and ER smoke worlds under heterogeneous per-node compute
(lognormal step times, sigma 0.5) and heterogeneous links (lognormal
latency/bandwidth priced from the codec's EXACT bytes on wire):

  * ``sync-fp32``       — the dense baseline: fp32 always-send gossip on a
    synchronous schedule; every round waits for the slowest node AND the
    slowest link (the clock reports the realized makespan),
  * ``deadline-int8``   — the production challenger: per-edge adaptive int8
    event-triggered transport under `Schedule(deadline=...)`; stragglers
    train what fits in the tick, late payloads fall into the stale path,
    and the int8 payload is ~4x cheaper on the same links.

The frontier metric is `time_to_target`: the first evaluated sim_time at
which node-mean accuracy reaches 90% of the sync baseline's OWN final
accuracy on that world.  Acceptance (folded into BENCH_time.json by
`gen_report.write_bench_time()`): the challenger reaches the target in
STRICTLY less simulated time on both worlds.

The straggler scenario reruns the challenger with 10% of nodes 8x slower
(`StragglerStep`) vs the homogeneous clock, same deadline: final accuracy
must stay within 3% (relative) — the deadline tick absorbs stragglers
instead of stalling the whole graph on them.

    PYTHONPATH=src python -m benchmarks.bench_time [--rounds 40]
    PYTHONPATH=src python -m benchmarks.bench_time --smoke   # CI lane
"""
from __future__ import annotations

import argparse

from benchmarks.common import save_results
from repro.comm import CommConfig
from repro.engine import Experiment, Schedule, World
from repro.timing import (
    ConstantStep,
    LognormalLink,
    LognormalStep,
    StragglerStep,
    Timing,
)

ROUNDS = 40
EVAL_EVERY = 5
DEADLINE = 6.0          # simulated seconds per deadline tick
TARGET_FRAC = 0.90      # of the sync baseline's own final accuracy

# lognormal links: ~50 ms latency, ~100 KB/s bandwidth — the ~210 KB fp32
# MLP payload costs ~2 s/edge, the int8 payload ~4x less, so the codec
# choice moves the clock, not just the byte counter.
LINK = dict(latency_median=0.05, latency_sigma=0.5,
            bandwidth_median=1e5, bandwidth_sigma=0.5, seed=11)

WORLDS = [("ba", dict(topology="barabasi_albert", m=2)),
          ("er", dict(topology="erdos_renyi", p=0.3))]

CONFIGS = [
    # (label, comm kwargs, deadline or None for synchronous)
    ("sync-fp32", dict(codec="fp32"), None),
    ("deadline-int8", dict(codec="int8", policy="adaptive",
                           target_trigger=0.95, per_edge=True), DEADLINE),
]


def make_world(graph_kwargs, timing, nodes=16, seed=0):
    """The 16-node smoke worlds (bench_dynamics' config) + an event clock."""
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=nodes, seed=seed,
                           scale=0.03,
                           model=make_mlp(num_classes=10, hidden=(64, 32)),
                           timing=timing, **graph_kwargs)


def _time_to(history, target_acc):
    """First evaluated sim_time with node-mean accuracy >= target."""
    for m in history:
        if m.acc_mean >= target_acc:
            return m.sim_time
    return None


def _run_one(wkw, timing, ckw, deadline, rounds, nodes, seed):
    world = make_world(wkw, timing, nodes=nodes, seed=seed)
    exp = Experiment(
        world, "decdiff+vt", comm=CommConfig(**ckw),
        schedule=Schedule(rounds=rounds, eval_every=EVAL_EVERY,
                          deadline=deadline),
        steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9, seed=seed)
    hist = exp.run()
    return exp, hist


def run(rounds=ROUNDS, nodes=16, seed=0, worlds=None, verbose=True,
        smoke=False, deadline=DEADLINE):
    het = Timing(node=LognormalStep(median=1.0, sigma=0.5, seed=7),
                 link=LognormalLink(**LINK))
    rows = []
    for wname, wkw in (worlds or WORLDS):
        for cname, ckw, dl in CONFIGS:
            if dl is not None:
                dl = deadline
            exp, hist = _run_one(wkw, het, ckw, dl, rounds, nodes, seed)
            last = hist[-1]
            rows.append({
                "world": wname, "config": cname, "scenario": "hetero",
                "nodes": nodes, "rounds": rounds, "seed": seed,
                "deadline": dl, "acc_mean": last.acc_mean,
                "sim_time": last.sim_time,
                "arrived_frac": last.arrived_frac,
                "triggered_frac": last.triggered_frac,
                "bytes_on_wire": exp.comm_bytes_total,
                "payload_bytes": exp.transport.payload_bytes,
                "history": [(m.sim_time, m.acc_mean) for m in hist],
            })
            if verbose:
                r = rows[-1]
                print(f"[{wname}] {cname:>14} acc={r['acc_mean']:.4f} "
                      f"t={r['sim_time']:8.1f}s "
                      f"arr={r['arrived_frac']:.2f} "
                      f"wire={r['bytes_on_wire'] / 1e6:6.2f} MB", flush=True)
    # frontier: challenger time-to-target vs the sync baseline, per world
    for wname, _ in (worlds or WORLDS):
        base = next(r for r in rows if r["world"] == wname
                    and r["config"] == "sync-fp32")
        target = TARGET_FRAC * base["acc_mean"]
        for r in rows:
            if r["world"] == wname:
                r["target_acc"] = target
                r["time_to_target"] = _time_to(
                    [type("M", (), {"acc_mean": a, "sim_time": t})()
                     for t, a in r["history"]], target)
    # straggler scenario: challenger clock with 10% of nodes 8x slower,
    # vs the homogeneous clock — same deadline, same links (BA world)
    cname, ckw, _ = CONFIGS[1]
    strag = {}
    for sname, node_model in [
            ("homogeneous", ConstantStep(dt=1.0)),
            ("straggler(0.1,8x)", StragglerStep(dt=1.0, frac=0.1,
                                                factor=8.0, seed=5))]:
        tm = Timing(node=node_model, link=LognormalLink(**LINK))
        exp, hist = _run_one(dict(WORLDS[0][1]), tm, ckw, deadline, rounds,
                             nodes, seed)
        last = hist[-1]
        strag[sname] = last.acc_mean
        rows.append({
            "world": "ba", "config": cname, "scenario": sname,
            "nodes": nodes, "rounds": rounds, "seed": seed,
            "deadline": deadline, "acc_mean": last.acc_mean,
            "sim_time": last.sim_time, "arrived_frac": last.arrived_frac,
            "triggered_frac": last.triggered_frac,
            "bytes_on_wire": exp.comm_bytes_total,
            "payload_bytes": exp.transport.payload_bytes,
            "history": [(m.sim_time, m.acc_mean) for m in hist],
        })
        if verbose:
            print(f"[ba] {sname:>17} acc={last.acc_mean:.4f} "
                  f"t={last.sim_time:8.1f}s", flush=True)
    for r in rows:
        if r["scenario"].startswith("straggler"):
            r["acc_delta_vs_homogeneous"] = (r["acc_mean"]
                                             - strag["homogeneous"])
    if smoke:
        save_results("time_smoke", rows)
        return rows
    save_results("time_suite", rows)
    from benchmarks.gen_report import write_bench_time

    path = write_bench_time()
    if verbose and path:
        print("wrote", path)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=DEADLINE)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI lane: 8 nodes x 5 rounds on the BA world "
                         "only; writes the time_smoke artifact and does NOT "
                         "touch BENCH_time.json")
    args = ap.parse_args()
    if args.smoke:
        rows = run(rounds=5, nodes=8, seed=args.seed, worlds=[WORLDS[0]],
                   smoke=True)
        assert all(r["acc_mean"] == r["acc_mean"] for r in rows)  # finite
        assert all(r["sim_time"] > 0 for r in rows)
        print(f"smoke ok: {len(rows)} (config x scenario) points")
    else:
        run(rounds=args.rounds, nodes=args.nodes, seed=args.seed,
            deadline=args.deadline)


if __name__ == "__main__":
    main()
