"""Beyond-paper: topology sensitivity of DecDiff+VT.

The paper fixes ER(50, 0.2) and defers topology effects to future work
([29],[30]).  This bench runs DecDiff+VT (and DecHetero as contrast) over
four network families at matched node count and reports final accuracy and
a mixing proxy (spectral gap of the normalized adjacency) — quantifying how
knowledge spread depends on the communication graph.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import save_results
from repro.data import make_dataset, zipf_allocation
from repro.data.allocation import split_by_allocation
from repro.engine import Experiment, Schedule, World
from repro.graphs import make_topology
from repro.models.mlp_cnn import model_for_dataset

TOPOLOGIES = [
    ("erdos_renyi", dict(p=0.25)),
    ("barabasi_albert", dict(m=2)),
    ("watts_strogatz", dict(k=4, p=0.2)),
    ("ring", dict()),
]


def spectral_gap(topo) -> float:
    a = topo.adjacency.astype(np.float64)
    d = np.maximum(a.sum(1), 1)
    p = a / d[:, None]
    ev = np.sort(np.abs(np.linalg.eigvals(p)))[::-1]
    return float(1.0 - ev[1])


def run(num_nodes=16, rounds=40, data_scale=0.04, methods=("decdiff+vt", "dechetero"),
        verbose=True):
    ds = make_dataset("synth-mnist", seed=0, scale=data_scale)
    model = model_for_dataset("synth-mnist", ds.num_classes)
    rows = []
    for name, kw in TOPOLOGIES:
        topo = make_topology(name, n=num_nodes, seed=0, **kw)
        alloc = zipf_allocation(ds.y_train, num_nodes, seed=0, min_per_class=1)
        xs, ys = split_by_allocation(ds.x_train, ds.y_train, alloc)
        gap = spectral_gap(topo)
        for method in methods:
            sim = Experiment(
                World(model=model, topo=topo, xs=xs, ys=ys,
                      x_test=ds.x_test, y_test=ds.y_test),
                method, schedule=Schedule(rounds=rounds, eval_every=rounds),
                steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9)
            hist = sim.run()
            rows.append({"topology": topo.name, "spectral_gap": gap,
                         "method": method, "acc": hist[-1].acc_mean,
                         "acc_std": hist[-1].acc_std,
                         "max_degree": topo.max_degree})
            if verbose:
                print(f"[topo] {topo.name:28s} gap={gap:.3f} {method:12s} "
                      f"acc={hist[-1].acc_mean:.4f}")
    save_results("topology_table", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    args = ap.parse_args()
    run(rounds=args.rounds)


if __name__ == "__main__":
    main()
