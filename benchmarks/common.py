"""Shared benchmark scaffolding: build a paper-style world and run methods.

The paper's full experiment is 50 nodes x (MNIST|Fashion|EMNIST) x ~800
rounds x 4 replicas on GPUs; this container is a 2-core CPU, so benchmarks
run REDUCED but structurally identical settings (explicitly recorded in every
result dict).  Claims validated are the paper's ordering/qualitative claims
(EXPERIMENTS.md §Repro maps each to its table/figure).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional

from repro.data import make_dataset, zipf_allocation
from repro.data.allocation import allocation_gini, split_by_allocation
from repro.engine import Experiment, Schedule, World
from repro.fl.metrics import comm_bytes_per_round
from repro.fl.trainer import centralized_train
from repro.graphs import make_topology
from repro.models.mlp_cnn import model_for_dataset
from repro.optim import make_optimizer
from repro.utils.pytree import tree_bytes

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "paper")


@dataclasses.dataclass
class WorldConfig:
    dataset: str = "synth-mnist"
    num_nodes: int = 30
    er_p: float = 0.2
    data_scale: float = 0.08
    seed: int = 0
    lr: float = 0.1
    momentum: float = 0.9
    batch_size: int = 32
    steps_per_round: int = 4
    beta: float = 0.95
    rounds: int = 60
    eval_every: int = 5
    topology: str = "erdos_renyi"


def build_world(wc: WorldConfig):
    ds = make_dataset(wc.dataset, seed=wc.seed, scale=wc.data_scale)
    if wc.topology == "erdos_renyi":
        topo = make_topology("erdos_renyi", n=wc.num_nodes, p=wc.er_p, seed=wc.seed)
    else:
        topo = make_topology(wc.topology, n=wc.num_nodes, seed=wc.seed)
    alloc = zipf_allocation(ds.y_train, wc.num_nodes, seed=wc.seed, min_per_class=1)
    xs, ys = split_by_allocation(ds.x_train, ds.y_train, alloc)
    gini = allocation_gini(alloc, ds.y_train)
    model = model_for_dataset(wc.dataset, ds.num_classes)
    return ds, topo, xs, ys, model, gini


def run_method(wc: WorldConfig, method: str, world=None, verbose=False,
               comm=None, mode="fused") -> Dict:
    ds, topo, xs, ys, model, gini = world or build_world(wc)
    exp = Experiment(
        World(model=model, topo=topo, xs=xs, ys=ys,
              x_test=ds.x_test, y_test=ds.y_test),
        method, comm=comm,
        schedule=Schedule(rounds=wc.rounds, eval_every=wc.eval_every,
                          mode=mode),
        steps_per_round=wc.steps_per_round, batch_size=wc.batch_size,
        lr=wc.lr, momentum=wc.momentum, beta=wc.beta, seed=wc.seed)
    t0 = time.time()
    hist = exp.run(verbose=verbose)
    wall = time.time() - t0
    import jax

    model_bytes = tree_bytes(model.init(jax.random.PRNGKey(0)))
    return {
        "method": method,
        "dataset": wc.dataset,
        "gini": gini,
        "rounds": wc.rounds,
        "wall_s": wall,
        "acc_mean": hist[-1].acc_mean,
        "acc_std": hist[-1].acc_std,
        "loss_mean": hist[-1].loss_mean,
        "acc_per_node": hist[-1].acc_per_node.tolist(),
        "history": [
            {"round": m.round, "acc_mean": m.acc_mean, "acc_std": m.acc_std,
             "loss_mean": m.loss_mean}
            for m in hist
        ],
        "comm_bytes_per_round": comm_bytes_per_round(method, topo, model_bytes),
    }


def run_centralized(wc: WorldConfig, world=None) -> Dict:
    ds, topo, xs, ys, model, gini = world or build_world(wc)
    opt = make_optimizer(lr=wc.lr / 2, momentum=wc.momentum)
    epochs = max(2, wc.rounds * wc.steps_per_round * wc.batch_size
                 // max(len(ds.x_train), 1))
    t0 = time.time()
    _, hist = centralized_train(model, opt, ds.x_train, ds.y_train,
                                ds.x_test, ds.y_test, epochs=min(epochs, 20),
                                batch_size=64, seed=wc.seed,
                                eval_every=max(1, min(epochs, 20) // 4))
    return {
        "method": "centralized", "dataset": wc.dataset, "gini": gini,
        "acc_mean": hist[-1]["acc"], "acc_std": 0.0,
        "loss_mean": hist[-1]["loss"], "wall_s": time.time() - t0,
        "history": hist, "comm_bytes_per_round": 0,
    }


def save_results(name: str, payload) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def load_results(name: str) -> Optional[Dict]:
    path = os.path.join(ART_DIR, name + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None
