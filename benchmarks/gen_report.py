"""Regenerate EXPERIMENTS.md from artifacts (dry-run, roofline, paper suite).

    PYTHONPATH=src python -m benchmarks.gen_report
"""
from __future__ import annotations

import json
import os
from typing import Optional

from benchmarks import roofline as rl
from benchmarks.common import load_results

ROOT = os.path.join(os.path.dirname(__file__), "..")


def repro_section() -> str:
    out = []
    acc = load_results("accuracy_table") or {}
    dis = load_results("disruption") or {}
    abl = load_results("ablation_table") or {}
    comm = load_results("comm_table") or []

    out.append("### Table II — final node-average accuracy (reduced rendition)\n")
    if acc:
        out.append("| dataset | GI | method | avg acc | ±std |")
        out.append("|---|---|---|---|---|")
        for ds, res in acc.items():
            gi = res.get("_world", {}).get("gini", 0)
            for m, r in res.items():
                if m.startswith("_"):
                    continue
                out.append(f"| {ds} | {gi:.2f} | {m} | {r['acc_mean']:.4f} | "
                           f"{r.get('acc_std', 0):.4f} |")
        out.append("")

        out.append("### Table IV — characteristic time (rounds to x% of centralized)\n")
        from benchmarks.bench_char_time import THRESHOLDS, characteristic_times
        ct = characteristic_times(acc)
        out.append("| dataset | method | 50% | 80% | 90% | 95% |")
        out.append("|---|---|---|---|---|---|")
        for ds, block in ct.items():
            for m, row in block["times"].items():
                cells = " | ".join("-" if row[t] is None else str(row[t])
                                   for t in THRESHOLDS)
                out.append(f"| {ds} | {m} | {cells} |")
        out.append("")

    if dis:
        out.append("### Fig. 1 — round-0 -> round-1 accuracy change "
                   "(positive = disruption)\n")
        out.append("| method | Δ accuracy |")
        out.append("|---|---|")
        for m, d in dis["round0_to_1_drop"].items():
            out.append(f"| {m} | {d:+.4f} |")
        out.append("")

    if abl:
        out.append("### Table III — ablation (CE/VT x DecAvg/DecDiff/CFA)\n")
        base = abl.get("dechetero", {}).get("acc_mean")
        out.append("| method | avg acc | gain vs DecHetero [%pt] |")
        out.append("|---|---|---|")
        for m, r in abl.items():
            if m.startswith("_"):
                continue
            gain = "" if base is None else f"{100 * (r['acc_mean'] - base):+.2f}"
            out.append(f"| {m} | {r['acc_mean']:.4f} | {gain} |")
        out.append("")

    if comm:
        out.append("### §VI-A.3 — communication bytes per round "
                   "(50-node ER p=.2)\n")
        out.append("| model | method | MB/round (fp32) |")
        out.append("|---|---|---|")
        for r in comm:
            if r.get("codec", "fp32") != "fp32":
                continue
            if r["method"] in ("isol", "fedavg", "cfa-ge", "decdiff+vt"):
                out.append(f"| {r['model']} | {r['method']} | "
                           f"{r['bytes_per_round'] / 1e6:.1f} |")
        out.append("")

    front = load_results("comm_frontier") or []
    if front:
        out.append("### Comm tentpole — accuracy-vs-bytes frontier "
                   "(8-node BA + ER smoke, DecDiff+VT)\n")
        out.append("Codec x trigger-policy sweep (fixed drift thresholds "
                   "and the per-edge adaptive controller); wire bytes are "
                   "the simulator's exact dynamic accounting "
                   "(event-triggered silence costs nothing).  Read it as: "
                   "how many bytes buy how much accuracy.\n")
        out.append("| world | codec | trigger | final acc | wire MB | "
                   "reduction | Δacc vs dense | trig frac |")
        out.append("|---|---|---|---|---|---|---|---|")
        from benchmarks.bench_comm import trigger_label

        for r in front:
            ratio = f" (r={r['topk_ratio']})" if r.get("topk_ratio") else ""
            if r.get("topk_momentum"):
                ratio += f" mom={r['topk_momentum']}"
            trig = trigger_label(r.get("policy", "fixed"), r["threshold"],
                                 r.get("target_trigger"))
            out.append(
                f"| {r.get('world', 'ba')} | {r['codec']}{ratio} | {trig} | "
                f"{r['acc_mean']:.4f} | {r['bytes_on_wire'] / 1e6:.2f} | "
                f"{r['reduction_vs_dense']:.1f}x | "
                f"{r['acc_delta_vs_dense']:+.4f} | {r['triggered_frac']:.2f} |")
        out.append("")
    return "\n".join(out)


def write_bench_comm() -> str:
    """Fold the comm artifacts into BENCH_comm.json: the static per-codec
    table, the accuracy-vs-bytes frontier (BA and ER worlds), and two
    acceptance verdicts — the PR-2 gate (some int8/top-k point with >= 2x
    fewer bytes within 1% of dense acc) and the PR-3 adaptive gate (some
    adaptive per-edge point within 1% of dense whose reduction is >= the
    best within-1% FIXED-threshold int8 reduction in the same world)."""
    table = load_results("comm_table") or []
    front = load_results("comm_frontier") or []
    if not front:
        # never clobber a committed BENCH_comm.json with an empty verdict
        # just because artifacts/ was cleaned; the frontier sweep
        # (bench_comm.frontier / bench_comm.run) is what refreshes it.
        print("comm_frontier artifact missing; BENCH_comm.json not rewritten")
        return None
    for r in front:  # tolerate pre-PR-3 artifacts
        r.setdefault("world", "ba")
        r.setdefault("policy", "fixed")
    dense = {
        w: next((r for r in front
                 if r["world"] == w and r["codec"] == "fp32"
                 and r["policy"] == "fixed" and r["threshold"] == 0.0), None)
        for w in {r["world"] for r in front}
    }

    def within_1pct(r):
        # at most 1% (relative) BELOW dense; better-than-dense passes
        d = dense.get(r["world"])
        return (d is not None and
                r["acc_delta_vs_dense"] >= -0.01 * max(d["acc_mean"], 1e-9))

    # the PR-2 gate keeps its original scope: the BA smoke world (an ER-only
    # pass must not mask a BA regression); the adaptive gate below is
    # per-world by construction.
    passing = [r for r in front
               if r["world"] == "ba" and r["codec"] in ("int8", "topk")
               and r["reduction_vs_dense"] >= 2.0 and within_1pct(r)]
    fixed_int8_bar = {
        w: max((r["reduction_vs_dense"] for r in front
                if r["world"] == w and r["codec"] == "int8"
                and r["policy"] == "fixed" and within_1pct(r)), default=None)
        for w in dense
    }
    adaptive_passing = [
        r for r in front
        if r["policy"] == "adaptive" and within_1pct(r)
        and fixed_int8_bar.get(r["world"]) is not None
        and r["reduction_vs_dense"] >= fixed_int8_bar[r["world"]]
    ]
    payload = {
        "dense_baseline": dense,
        "frontier": front,
        "acceptance": {
            "criterion": ">=2x bytes-on-wire reduction within 1% of dense "
                         "final accuracy (int8 or top-k, seeded BA smoke)",
            "passed": bool(passing),
            "passing_points": passing,
            "note": "fixed trigger_threshold > 0 points trade accuracy for "
                    "bytes on this short smoke run (see frontier deltas); "
                    "the within-1% bar is cleared by the always-send int8 "
                    "point and by the adaptive per-edge points (below). "
                    "The trigger's own guarantee (>=2x at bounded loss) is "
                    "pinned separately in tests/test_system.py.",
        },
        "adaptive_acceptance": {
            "criterion": "some adaptive per-edge point within 1% of dense "
                         "with bytes reduction >= the best within-1% "
                         "fixed-threshold int8 reduction (per world)",
            "fixed_int8_reduction_bar": fixed_int8_bar,
            "passed": bool(adaptive_passing),
            "passing_points": adaptive_passing,
        },
        "static_table": table,
    }
    path = os.path.join(ROOT, "BENCH_comm.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def write_bench_engine() -> str:
    """Fold the engine-runner sweep into BENCH_engine.json: rounds/sec per
    (backend, schedule mode) on the 16-node BA smoke world, plus the
    acceptance verdict — the scan-fused schedule must reach >= 2x the
    per-round Python loop's rounds/sec on the vmap backend (the repo's
    first runner-layer perf gate; see benchmarks/bench_engine.py)."""
    res = load_results("engine_runner") or {}
    if not res:
        print("engine_runner artifact missing; BENCH_engine.json not "
              "rewritten (run python -m benchmarks.bench_engine)")
        return None
    speedup = res.get("fused_speedup_vmap", 0.0)
    wire_ratio = res.get("encoded_over_decoded_shardmap")
    payload = {
        "world": res.get("world", {}),
        "rows": res.get("rows", []),
        "wire_rows": res.get("wire_rows", []),
        "acceptance": {
            "criterion": "scan-fused schedule >= 2x rounds/sec vs the "
                         "per-round Python loop (vmap backend, 16-node BA "
                         "smoke world)",
            "fused_speedup_vmap": speedup,
            "passed": bool(speedup >= 2.0),
            "note": "modes are bit-identical in math (pinned by "
                    "tests/test_engine.py); this measures pure execution "
                    "strategy: one lax.scan program dispatched once vs one "
                    "XLA dispatch per round plus jitted eval calls.",
        },
        "wire_acceptance": {
            "criterion": "shard_map encoded-payload exchange (the default "
                         "wire) >= 0.9x the decoded-rows oracle's "
                         "rounds/sec (int8 event-triggered transport; 0.9 "
                         "absorbs shared-CPU timing noise — the encoded "
                         "wire also ships ~4x fewer bytes across the pod "
                         "axis)",
            "encoded_over_decoded_shardmap": wire_ratio,
            "passed": None if wire_ratio is None else bool(wire_ratio >= 0.9),
            "note": "wires are informationally identical (one exchange "
                    "step is bitwise equal across wires; pinned by "
                    "tests/test_engine.py); null when the bench host had "
                    "no pod axis.",
        },
    }
    path = os.path.join(ROOT, "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def write_bench_dynamics() -> Optional[str]:
    """Fold the dynamics suite into BENCH_dynamics.json: accuracy/bytes for
    dense fp32 and int8+adaptive DecDiff+VT under every catalog
    GraphProcess vs the static baseline (BA and ER 16-node smoke worlds),
    plus the acceptance verdict — int8+adaptive under i.i.d. edge dropout
    (p=0.2) must stay within 3% (relative) of its OWN static-graph final
    accuracy on the BA world (see benchmarks/bench_dynamics.py)."""
    rows = load_results("dynamics_suite") or []
    if not rows:
        # never clobber a committed BENCH_dynamics.json just because
        # artifacts/ was cleaned; the full (non --smoke) sweep refreshes it.
        print("dynamics_suite artifact missing; BENCH_dynamics.json not "
              "rewritten (run python -m benchmarks.bench_dynamics)")
        return None
    statics = {(r["world"], r["comm"]): r for r in rows
               if r["process"] == "static"}
    accept_row = next(
        (r for r in rows
         if r["world"] == "ba" and r["comm"] == "int8+adaptive"
         and r["process"].startswith("dropout")), None)
    passed = False
    if accept_row is not None:
        base = statics.get(("ba", "int8+adaptive"))
        passed = (base is not None and
                  accept_row["acc_delta_vs_static"]
                  >= -0.03 * max(base["acc_mean"], 1e-9))
    payload = {
        "static_baselines": {f"{w}/{c}": r for (w, c), r in statics.items()},
        "rows": rows,
        "acceptance": {
            "criterion": "int8+adaptive under i.i.d. edge dropout (p=0.2) "
                         "within 3% (relative) of its static-graph final "
                         "accuracy (16-node BA smoke world, DecDiff+VT)",
            "passed": bool(passed),
            "point": accept_row,
            "note": "bytes are accounted on live edges only, so every "
                    "dynamic point also ships FEWER bytes than its static "
                    "baseline (see bytes_ratio_vs_static); the gate is "
                    "about accuracy surviving the missing edges.",
        },
    }
    path = os.path.join(ROOT, "BENCH_dynamics.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def write_bench_time() -> Optional[str]:
    """Fold the time-to-accuracy suite into BENCH_time.json: the event
    clock's frontier — per-edge adaptive int8 under `Schedule(deadline=...)`
    vs the synchronous fp32 baseline on the 16-node BA and ER smoke worlds
    under heterogeneous compute and links — plus the straggler scenario,
    and the acceptance verdicts: (a) the challenger reaches 90% of the
    baseline's own final accuracy in STRICTLY less simulated time on both
    worlds, (b) with 10% of nodes 8x slower the deadline run stays within
    3% (relative) of the homogeneous-clock run (see
    benchmarks/bench_time.py)."""
    rows = load_results("time_suite") or []
    if not rows:
        # never clobber a committed BENCH_time.json just because
        # artifacts/ was cleaned; the full (non --smoke) sweep refreshes it.
        print("time_suite artifact missing; BENCH_time.json not "
              "rewritten (run python -m benchmarks.bench_time)")
        return None
    hetero = [r for r in rows if r["scenario"] == "hetero"]
    frontier = []
    for wname in sorted({r["world"] for r in hetero}):
        base = next((r for r in hetero if r["world"] == wname
                     and r["config"] == "sync-fp32"), None)
        chal = next((r for r in hetero if r["world"] == wname
                     and r["config"] == "deadline-int8"), None)
        if base is None or chal is None:
            continue
        bt, ct = base.get("time_to_target"), chal.get("time_to_target")
        frontier.append({
            "world": wname, "target_acc": base.get("target_acc"),
            "sync_time_to_target": bt, "deadline_time_to_target": ct,
            "speedup": (bt / ct) if bt and ct else None,
            "passed": bool(bt is not None and ct is not None and ct < bt),
        })
    frontier_passed = bool(frontier) and all(f["passed"] for f in frontier)
    homog = next((r for r in rows if r["scenario"] == "homogeneous"), None)
    strag = next((r for r in rows
                  if r["scenario"].startswith("straggler")), None)
    strag_passed = bool(
        homog and strag
        and abs(strag["acc_mean"] - homog["acc_mean"])
        <= 0.03 * max(homog["acc_mean"], 1e-9))
    payload = {
        "rows": rows,
        "frontier": frontier,
        "acceptance": {
            "criterion": "event-triggered per-edge adaptive int8 under a "
                         "deadline reaches 90% of the synchronous fp32 "
                         "baseline's own final accuracy in strictly less "
                         "simulated time on BA and ER (16-node smoke "
                         "worlds, DecDiff+VT, lognormal compute + links)",
            "passed": frontier_passed,
            "straggler": {
                "criterion": "with 10% of nodes 8x slower, the deadline "
                             "run's final accuracy stays within 3% "
                             "(relative) of the homogeneous-clock run "
                             "(same deadline, same links)",
                "passed": strag_passed,
                "homogeneous_acc": homog and homog["acc_mean"],
                "straggler_acc": strag and strag["acc_mean"],
            },
            "note": "simulated time is the event clock's accounting: the "
                    "sync baseline pays the realized makespan (slowest "
                    "node + slowest live link, priced from the codec's "
                    "exact bytes on wire) every round, while the deadline "
                    "run pays exactly one tick and lets late payloads "
                    "fall into the stale silence path.",
        },
    }
    path = os.path.join(ROOT, "BENCH_time.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def write_bench_scale() -> Optional[str]:
    """Fold the node-axis scaling sweep into BENCH_scale.json: rounds/sec
    per (N, layout) on the tiny-MLP BA gossip world, the 10^5-receiver
    kernel tier, the 10^6-node builder tier, the dynamics tier
    (int8+adaptive per-edge transport under 20% dropout on the sparse
    engine), and the acceptance verdicts — the sparse layout must complete
    an engine round at >= 10^4 nodes at a node count where the dense layout
    is skipped (projected memory wall) or >= 5x slower, and the dynamics
    tier must run there with the realized live fraction at its 1 - p
    stationary value (see benchmarks/bench_scale.py)."""
    res = load_results("scale_sweep") or {}
    if not res:
        # never clobber a committed BENCH_scale.json just because
        # artifacts/ was cleaned; the full (non --smoke) sweep refreshes it.
        print("scale_sweep artifact missing; BENCH_scale.json not "
              "rewritten (run python -m benchmarks.bench_scale)")
        return None
    rows = res.get("rows", [])
    by_n = {}
    for r in rows:
        by_n.setdefault(r["nodes"], {})[r["layout"]] = r
    passing = []
    for n, pair in sorted(by_n.items()):
        dn, sp = pair.get("dense"), pair.get("sparse")
        if n < 10_000 or sp is None or "rounds_per_sec" not in sp:
            continue
        dense_walled = (dn is None or dn.get("skipped") is not None
                        or (dn.get("rounds_per_sec", 0.0)
                            <= sp["rounds_per_sec"] / 5.0))
        if dense_walled:
            passing.append({"nodes": n,
                            "sparse_rounds_per_sec": sp["rounds_per_sec"],
                            "dense": (dn or {}).get("skipped",
                                                    "not swept")
                            if dn is None or "rounds_per_sec" not in dn
                            else f"{dn['rounds_per_sec']:.3f} rounds/s"})
    dyn = res.get("dynamics")
    dyn_passed = bool(
        dyn and dyn.get("nodes", 0) >= 10_000
        and dyn.get("rounds_per_sec", 0.0) > 0.0
        and abs(dyn.get("live_frac_mean", 0.0)
                - (1.0 - dyn.get("dropout_p", 0.2))) < 0.02
        and 0.0 < dyn.get("trig_frac_mean", 0.0) <= 1.0)
    payload = {
        "world": res.get("world", {}),
        "dense_bytes_budget": res.get("dense_bytes_budget"),
        "rows": rows,
        "kernel": res.get("kernel"),
        "builder": res.get("builder"),
        "dynamics": dyn,
        "acceptance": {
            "criterion": "sparse layout completes engine rounds at >= 10^4 "
                         "nodes where dense is memory-walled (projected "
                         "block over budget) or >= 5x slower",
            "passed": bool(passing),
            "passing_points": passing,
            "dynamics": {
                "criterion": "int8+adaptive per-edge transport under 20% "
                             "i.i.d. edge dropout completes at >= 10^4 "
                             "nodes on the sparse engine, with the "
                             "realized live fraction within 0.02 of the "
                             "1 - p stationary value and a sane triggered "
                             "fraction",
                "passed": dyn_passed,
            },
            "note": "dense and sparse are bit-identical where both run — "
                    "methods x transports x dynamics x backends, pinned in "
                    "tests/test_sparse_parity.py; this artifact records "
                    "what the sparse layout buys past the dense wall.",
        },
    }
    path = os.path.join(ROOT, "BENCH_scale.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def write_bench_obs() -> Optional[str]:
    """Fold the telemetry bench into BENCH_obs.json: the all-channels
    overhead pair on the 16-node fused schedule, the ledger/trace
    validation results, and the acceptance verdicts — all-channels
    rounds/sec within 5% of telemetry-off, and the exported Chrome
    trace's per-edge transfer-span bytes summing EXACTLY to the run's
    bytes_on_wire (see benchmarks/bench_obs.py)."""
    res = load_results("obs_suite") or {}
    if not res:
        # never clobber a committed BENCH_obs.json just because
        # artifacts/ was cleaned; the full (non --smoke) run refreshes it.
        print("obs_suite artifact missing; BENCH_obs.json not "
              "rewritten (run python -m benchmarks.bench_obs)")
        return None
    payload = {
        "world": res.get("world"),
        "rows": res.get("rows", []),
        "ledger": res.get("ledger"),
        "trace": res.get("trace"),
        "dispersion": res.get("dispersion"),
        "acceptance": {
            "criterion": "with EVERY telemetry channel accumulating in "
                         "the scan carry (steps, compute seconds, "
                         "accuracy, trigger counts, exact bytes, "
                         "staleness, landing latency, consensus, drift), "
                         "the fused schedule's rounds/sec stays within "
                         "5% of telemetry=None on the 16-node BA world",
            "overhead_frac": res.get("overhead_frac"),
            "passed": bool(res.get("overhead_passed")),
            "trace": {
                "criterion": "the Chrome-trace export's per-edge "
                             "transfer spans carry exact payload bytes "
                             "that sum to RoundMetrics.bytes_on_wire",
                "passed": bool(res.get("trace", {}).get("bytes_exact")),
            },
        },
    }
    path = os.path.join(ROOT, "BENCH_obs.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def obs_section() -> str:
    """The observability tentpole's report section, built from the RUN
    LEDGER the bench emitted (not from in-memory results): per-node
    accuracy dispersion and the per-edge byte distribution at the final
    eval round — the distributional surface the node-mean tables hide."""
    res = load_results("obs_suite") or {}
    if not res:
        return ""
    from benchmarks.common import ART_DIR
    from repro.obs import read_ledger

    ledger_path = os.path.join(ART_DIR, res["ledger"]["path"])
    if not os.path.exists(ledger_path):
        return ""
    manifest, rounds, summaries = read_ledger(ledger_path)
    last = rounds[-1]
    detail = {k: [float(x) for x in v]
              for k, v in last.get("detail", {}).items()}
    out = ["### Observability tentpole — telemetry channels "
           f"(16-node BA, {manifest['method']}, all channels)\n",
           "Read back from the schema-validated run ledger "
           f"(`{res['ledger']['path']}`: {res['ledger']['counts']}); "
           "per-edge channels are in the canonical (dst, src) directed-"
           "edge order.  BENCH_obs.json carries the ≤5% overhead and "
           "exact-trace-bytes acceptance gates "
           f"(overhead {res['overhead_frac'] * 100:+.1f}%).\n"]

    def pct(vals, q):
        v = sorted(vals)
        return v[min(len(v) - 1, int(q / 100 * len(v)))]

    acc = last["acc_per_node"]
    out.append("| channel | min | p50 | p95 | max |")
    out.append("|---|---|---|---|---|")
    out.append(f"| node accuracy | {min(acc):.4f} | {pct(acc, 50):.4f} | "
               f"{pct(acc, 95):.4f} | {max(acc):.4f} |")
    for name, scale, fmt in (("node_steps", 1, ".0f"),
                             ("node_compute", 1, ".1f"),
                             ("edge_bytes", 1e6, ".2f"),
                             ("edge_trigger", 1, ".0f"),
                             ("edge_staleness", 1, ".0f"),
                             ("drift", 1, ".3f")):
        if name not in detail:
            continue
        v = [x / scale for x in detail[name]]
        label = name + (" (MB)" if scale == 1e6 else "")
        out.append(f"| {label} | {min(v):{fmt}} | {pct(v, 50):{fmt}} | "
                   f"{pct(v, 95):{fmt}} | {max(v):{fmt}} |")
    if summaries:
        s = summaries[-1]
        out.append("")
        out.append(f"Ledger summary: {s['rounds_per_sec']:.2f} rounds/s "
                   f"wall ({s['wall_s']:.1f}s"
                   + (f", cold compile {s['compile_s']:.1f}s"
                      if "compile_s" in s else "") + ").")
    out.append("")
    return "\n".join(out)


def time_section() -> str:
    rows = load_results("time_suite") or []
    if not rows:
        return ""
    out = ["### Event-clock tentpole — time-to-accuracy "
           "(16-node BA + ER smoke, DecDiff+VT)\n",
           "The clock prices every round in simulated seconds (lognormal "
           "per-node step times, lognormal per-edge latency/bandwidth over "
           "the codec's exact bytes on wire).  `t@target` is the first "
           "evaluated sim_time reaching 90% of the synchronous baseline's "
           "own final accuracy.  BENCH_time.json carries the frontier and "
           "straggler acceptance gates.\n",
           "| world | config | scenario | final acc | sim time (s) | "
           "t@target (s) | arrived frac | wire MB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ttt = r.get("time_to_target")
        out.append(
            f"| {r['world']} | {r['config']} | {r['scenario']} | "
            f"{r['acc_mean']:.4f} | {r['sim_time']:.1f} | "
            f"{'-' if ttt is None else f'{ttt:.1f}'} | "
            f"{r['arrived_frac']:.2f} | {r['bytes_on_wire'] / 1e6:.2f} |")
    out.append("")
    return "\n".join(out)


def dynamics_section() -> str:
    rows = load_results("dynamics_suite") or []
    if not rows:
        return ""
    out = ["### Dynamics tentpole — time-varying topologies "
           "(16-node BA + ER smoke, DecDiff+VT)\n",
           "Every `repro.dynamics.GraphProcess` vs the static baseline, "
           "dense fp32 and the production int8+adaptive transport.  Bytes "
           "are exact live-edge accounting (a non-existent link costs "
           "nothing); `Δacc` is against the SAME transport on the static "
           "graph.  BENCH_dynamics.json carries the within-3% dropout "
           "acceptance gate.\n",
           "| world | process | comm | final acc | Δacc vs static | "
           "wire MB | bytes vs static | live frac | trig frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['world']} | {r['process']} | {r['comm']} | "
            f"{r['acc_mean']:.4f} | {r['acc_delta_vs_static']:+.4f} | "
            f"{r['bytes_on_wire'] / 1e6:.2f} | "
            f"{r['bytes_ratio_vs_static']:.2f}x | "
            f"{r['live_edge_frac']:.2f} | {r['triggered_frac']:.2f} |")
    out.append("")
    return "\n".join(out)


def engine_section() -> str:
    res = load_results("engine_runner") or {}
    if not res:
        return ""
    out = ["### Engine runner — scan-fused schedule vs per-round loop "
           "(16-node BA smoke, DecDiff+VT)\n",
           "Same math bit-for-bit (tests/test_engine.py); only the "
           "execution strategy differs.  BENCH_engine.json carries the "
           ">= 2x acceptance gate.\n",
           "| backend | schedule | rounds/s | timed wall s | compile+first s |",
           "|---|---|---|---|---|"]
    for r in res.get("rows", []):
        out.append(f"| {r['backend']} | {r['mode']} | "
                   f"{r['rounds_per_sec']:.1f} | {r['wall_s']:.2f} | "
                   f"{r['compile_and_first_run_s']:.2f} |")
    out.append("")
    out.append(f"* scan-fused speedup (vmap): "
               f"**{res.get('fused_speedup_vmap', 0.0):.2f}x**")
    out.append("")
    return "\n".join(out)


def dryrun_section() -> str:
    out = []
    for mesh in ("single", "multi"):
        recs = rl.load(mesh=mesh)
        ok = sum(1 for r in recs if r.get("ok"))
        out.append(f"* **{mesh}-pod mesh**: {ok}/{len(recs)} combinations "
                   f"lower+compile OK"
                   + ("" if ok == len(recs) else "  <-- FAILURES, see artifacts"))
    out.append("")
    out.append("Multi-pod status per combo (compile time, per-chip terms in "
               "artifacts/dryrun/*__multi.json):")
    out.append("")
    out.append("| arch | train_4k | prefill_32k | decode_32k | long_500k |")
    out.append("|---|---|---|---|---|")
    recs = {(r["arch"], r["shape"]): r for r in rl.load(mesh="multi")}
    archs = sorted({a for a, _ in recs})
    for a in archs:
        cells = []
        for sh in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            r = recs.get((a, sh))
            cells.append("OK" if r and r.get("ok") else "FAIL")
        out.append(f"| {a} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def roofline_section() -> str:
    recs = rl.load(mesh="single")
    out = [rl.format_table(recs), ""]
    doms = rl.summarize(recs)
    for dom, combos in sorted(doms.items()):
        out.append(f"* **{dom}-bound** ({len(combos)}): {', '.join(combos)}")
        out.append(f"  * lever: {rl.LEVERS[dom]}")
    out.append("")
    out.append(f"* hillclimb picks: {rl.pick_hillclimb_candidates(recs)}")
    return "\n".join(out)


PERF_LOG = r"""
Three pairs (picked from the baseline table): **mixtral-8x7b/train_4k**
(most collective-bound), **arctic-480b/train_4k** (worst roofline fraction),
**qwen3-32b/train_4k** incl. its multi-pod DFL round (most representative of
the paper's technique — the DecDiff pod-gossip runs in this step).  All
numbers are per-chip seconds/step from the calibrated dry-run
(artifacts/perf/*.json); variants via `dryrun.py --variant`.

### mixtral-8x7b / train_4k  (baseline C 2.11 / M 24.98 / **Coll 30.85**)

| # | hypothesis | change | result (C/M/Coll s) | verdict |
|---|---|---|---|---|
| 1 | activation psums stem from FSDP weight sharding; forcing use-site weight gather (ZeRO-3 constraint) will trade 45 GB of activation all-reduce for ~0.8 GB of weight all-gather | `zero3_gather` flag: re-constrain per-layer weight slices to model-only inside the scan | 16.62 / 64.55 / 72.59 | **REFUTED** — GSPMD resolved the conflicting constraint by replicating compute (8× flops). Reverted. |
| 2 | the 9.4 GB fp32 per-layer all-reduce is the MoE global-capacity buffer crossing the batch sharding; batch-local dispatch keeps tokens on their shard | `moe_dispatch="batch_local"` — first as vmap (buffers replicated: only −20%), then explicit batch dim + constraints | 2.11 / 21.18 / **17.89** | **CONFIRMED** — collective −42%, memory −15%. vmap lesson: per-partition HLO shapes showed local B=256 (replicated) until the batch dim was explicit. |
| 3 | fp32 attention probs are the largest remaining buffer; casting to bf16 before the combine halves that traffic | `attn_probs_bf16` | 2.11 / 21.35 / 17.89 | **REFUTED** — no change; scores/softmax stay fp32 and the cast adds a conversion pass. |
| 4 | seq-sharding the scan carry removes the residual psum chain | `moelocal+seqshard` | 2.10 / 18.73 / 16.33 | **CONFIRMED (small)** — final: collective −47%, memory −25% vs baseline. |

### arctic-480b / train_4k  (baseline C 3.58 / **M 31.59** / Coll 27.47)

| # | hypothesis | change | result | verdict |
|---|---|---|---|---|
| 1 | mixtral's batch-local dispatch transfers | `moelocal` | 5.23 / 48.77 / 32.37 | **REFUTED** — the tradeoff flips: arctic's 13.4 B params/layer of expert weights make the forced weight-gather (26.8 GB/layer) far worse than the activation psum. Expert count changes the optimum. |
| 2 | true expert parallelism (E=128 % 16 == 0): experts sharded over model, tokens all-to-all | `expertpar` (E-dim sharding rule + buffer constraints) | 5.22 / 41.78 / 25.00 | **REFUTED overall** — collective −9% but memory +32% (fp32 dispatch buffers + per-row capacity rounding). The baseline "TP-inside-experts" never moves weights and is already decent. |
| 3 | per-layer saved residual dominates; seq-shard the carry | `seqshard` | **2.44 / 24.63 / 22.39** | **CONFIRMED** — all three terms down (compute −32%, memory −22%, collective −18%); bytes/device 164 -> 92 GB. |

### qwen3-32b / train_4k + multi-pod DFL  (baseline single C 4.58 / **M 30.44** / Coll 10.02; multi C 2.27 / M 10.18 / Coll 4.81)

| # | hypothesis | change | result | verdict |
|---|---|---|---|---|
| 1 | the [16,4096,5120] bf16 carry saved per layer (×64) is the memory wall; seq-sharding it over model removes both the capacity and the psum chain | `seqshard` (single-pod) | 4.54 / **15.50 / 1.08** | **CONFIRMED, biggest single win** — memory −49%, collective −89% (all-reduce 475 -> 37 GB/chip), bytes/device 131.6 -> 28.4 GB. |
| 2 | same for the multi-pod DFL round | `seqshard` (multi) | 2.27 / 9.17 / 4.76 | **PARTIAL** — only −10% memory; the vmapped round keeps its activation psums. sdy dumps show the constraints ARE correctly pod-prefixed (verified `spmd_axis_name`, now enabled) — GSPMD chooses a different global solution when the gossip einsum consumes the stacked params. Open item. |
| 3 | manual-pod shard_map round (explicit adjacency-masked ppermute ring per DESIGN.md §3) sidesteps GSPMD's choice | `build_dfl_round_shardmap` | — | **BLOCKED** — XLA SPMD partitioner CHECK failure (spmd_partitioner_util.cc:504) on the (2,16,16) partial-auto mesh; implementation kept (works on small meshes), documented as toolchain-blocked. |
| 4 | bf16 gossip halves the paper's exchange volume | `gossipbf16` | no measurable change | **CONFIRMED-IRRELEVANT** — napkin + measurement agree: DecDiff gossip volume is params/chip ≈ 0.25 GB ≈ 5 ms vs a 4.8 s round. At pod scale the paper's "parameters-only" exchange is already negligible; local training dominates. This *quantifies* the paper's communication-efficiency claim on real hardware. |

**Stopping:** mixtral iterations 3-4 and arctic 2-3 brought <5%-per-change on
their dominant terms after the confirmed wins; remaining headroom is in the
`bytes accessed` proxy (fp32 softmax/score paths) and the multi-pod DFL psum
question above.

**Paper-faithful vs beyond-paper summary** (dominant-term seconds):

| pair | baseline (faithful) | best variant | Δ |
|---|---|---|---|
| mixtral-8x7b/train_4k | Coll 30.85 | Coll 16.33 (moelocal+seqshard) | **−47%** |
| arctic-480b/train_4k | Mem 31.59 | Mem 24.63 (seqshard) | **−22%** |
| qwen3-32b/train_4k | Mem 30.44 | Mem 15.50 (seqshard) | **−49%** |
"""


def main():
    sections = []
    sections.append("""# EXPERIMENTS

All results produced inside this (CPU-only, offline) container.  Real
datasets are unavailable -> synthetic stand-ins (DESIGN.md §1, data gate);
accuracy numbers are NOT the paper's absolute numbers — the claims validated
are the paper's ordering/qualitative claims.  TPU numbers are *derived*
(dry-run compile + v5e constants: 197 TF bf16, 819 GB/s HBM, 50 GB/s/link
ICI), not measured.

Contents: §Repro · §Dry-run · §Roofline · §Perf.

---

## §Repro — validating the paper's claims

Reduced rendition of paper §V (ER graph, truncated-Zipf α=1.26 non-IID,
per-node random init, SGD+momentum; 150 rounds x 30 nodes on synth-mnist,
80 x 16 on the CNN datasets; 1 replica — CPU budget).  Claim scoreboard:

| claim | paper artifact | verdict |
|---|---|---|
| C1 round-1 disruption hits DecHetero only | Fig. 1 | **confirmed** — DecHetero is the only method whose accuracy drops after the first aggregation (see Fig.1 table below) |
| C2 DecDiff+VT > DecHetero, CFA; ≳ CFA-GE, FedAvg | Table II | **confirmed** — see Table II below (DecDiff+VT tops every decentralized baseline and FedAvg) |
| C3 ablation: +VT adds over DecDiff/DecAvg alone | Table III | **confirmed for VT** (+6 %pt over DecHetero); DecDiff-alone is mixed on the synthetic task — consistent with the paper's own EMNIST row (−0.87 %pt). Beyond-paper rows show VT lifting every aggregator. |
| C4 DecDiff+VT fastest to relative-accuracy thresholds | Table IV | **confirmed at 90/95%** (see Table IV) |
| C5 comms: parameters only; CFA-GE ships 4x | §VI-A.3 | **confirmed** — exact accounting, 4.0x (comm table) |
| C6 less overfitting / tighter node spread | Fig. 5/6 | **confirmed** — DecDiff+VT final node-accuracy σ is the smallest among decentralized methods (Table II ±std) |

Note: on the synthetic datasets DecDiff+VT can exceed the CE-trained
centralized benchmark — the virtual teacher acts as a strong label-smoothing
regularizer against the generator's noise.  This does not occur in the
paper's real-data setting and we do not claim it; the validated statement is
the ORDERING among methods.
""")
    sections.append(repro_section())
    eng = engine_section()
    if eng:
        sections.append(eng)
    dyn = dynamics_section()
    if dyn:
        sections.append(dyn)
    tim = time_section()
    if tim:
        sections.append(tim)
    obs = obs_section()
    if obs:
        sections.append(obs)
    sections.append("""
## §Dry-run — (10 archs × 4 shapes) × (single-pod 16x16, multi-pod 2x16x16)

`PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both` — every
combination must `.lower().compile()`.  Steps per shape: train_4k ->
train_step (single) / DFL round with DecDiff pod-gossip (multi); prefill_32k
-> forward; decode shapes -> serve_step (1 token vs KV cache; long_500k uses
the sub-quadratic path per DESIGN.md §4).

**Methodology notes (each verified, see memory/dryrun-calibration-findings):**
1. XLA's HloCostAnalysis counts `lax.scan` bodies ONCE — all roofline terms
   come from calibration compiles (1/2 layers, scans unrolled, chunk grids
   enlarged) extrapolated linearly; 3-point fit for the zamba2 hybrid.
2. cost_analysis is per-partition; memory_analysis per-device; collective
   bytes parsed from post-SPMD HLO (result-shape ÷/× group size).
3. `bytes accessed` double-counts producer/consumer pairs — treat memory
   terms as an upper bound (~2x), comparable across combos.
4. The per-device `temp` from the CPU backend includes fp32 staging XLA:TPU
   would fuse; `fits 16GB = NO` rows are upper-bound capacity flags, with
   the §Perf seqshard variant the worst offenders drop 2-5x.
""")
    sections.append(dryrun_section())
    sections.append("""
## §Roofline — per (arch × shape), single-pod, per chip per step
""")
    sections.append(roofline_section())
    sections.append("""
## §Perf — hypothesis → change → measure → validate
""")
    sections.append(PERF_LOG)

    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path, "w") as f:
        f.write("\n".join(sections))
    print("wrote", path)
    for p in (write_bench_comm(), write_bench_engine(),
              write_bench_dynamics(), write_bench_time(),
              write_bench_obs()):
        if p:
            print("wrote", p)


if __name__ == "__main__":
    main()
