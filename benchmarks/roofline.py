"""Deliverable (g): roofline analysis over the dry-run artifacts.

Reads artifacts/dryrun/<arch>__<shape>__<mesh>.json (written by
repro.launch.dryrun) and reports, per (arch x shape) on the single-pod mesh:

  compute term    = calibrated per-chip HLO FLOPs / 197 TF/s
  memory term     = calibrated per-chip bytes-accessed / 819 GB/s
  collective term = calibrated per-chip collective operand bytes / 50 GB/s

plus the dominant term, MODEL_FLOPS (6ND / 6·N_active·D), the useful-flops
ratio, per-device memory, and a one-line lever suggestion per bottleneck.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

LEVERS = {
    "compute": "raise arithmetic intensity: larger per-chip batch, fuse "
               "elementwise chains, bf16 the fp32 score paths",
    "memory": "cut bytes-accessed: fuse producer/consumer chains, avoid fp32 "
              "round-trips on the residual stream, larger fusion blocks",
    "collective": "reshard: all-gather weights instead of psum-ing "
                  "activations; batch gossip volumes; overlap collectives "
                  "with compute",
}


def load(dir_=DEFAULT_DIR, mesh="single"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def classify(rec):
    r = rec.get("roofline", {})
    terms = {"compute": r.get("compute_s", 0.0), "memory": r.get("memory_s", 0.0),
             "collective": r.get("collective_s", 0.0)}
    dom = max(terms, key=terms.get)
    return terms, dom


def format_table(recs) -> str:
    lines = [
        "| arch | shape | compute [ms] | memory [ms] | collective [ms] | "
        "dominant | useful-flops ratio | bytes/device [GB] | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if not rec.get("ok"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | FAILED: "
                         f"{rec.get('error', '')[:60]} | | | | | | |")
            continue
        terms, dom = classify(rec)
        ratio = rec.get("useful_flops_ratio")
        ratio_s = f"{ratio:.2f}" if ratio else "-"
        bpd = rec.get("bytes_per_device", 0) / 1e9
        fits = "yes" if rec.get("fits_hbm") else "NO"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {terms['compute']*1e3:.2f} | "
            f"{terms['memory']*1e3:.2f} | {terms['collective']*1e3:.2f} | "
            f"{dom} | {ratio_s} | {bpd:.2f} | {fits} |")
    return "\n".join(lines)


def summarize(recs):
    doms = {}
    for rec in recs:
        if rec.get("ok"):
            _, dom = classify(rec)
            doms.setdefault(dom, []).append(f"{rec['arch']}/{rec['shape']}")
    return doms


def pick_hillclimb_candidates(recs):
    """The three §Perf targets: worst roofline fraction (largest dominant
    term), most collective-bound, most representative of the technique
    (the multi-pod DFL train is the paper's op — approximated single-pod by
    the largest train_4k)."""
    ok = [r for r in recs if r.get("ok")]
    worst = max(ok, key=lambda r: max(classify(r)[0].values()), default=None)
    coll = max(ok, key=lambda r: classify(r)[0]["collective"], default=None)
    train = [r for r in ok if r["shape"] == "train_4k"]
    rep = max(train, key=lambda r: r.get("param_count", 0), default=None)
    out = []
    for tag, rec in (("worst-fraction", worst), ("most-collective-bound", coll),
                     ("paper-representative", rep)):
        if rec:
            out.append((tag, f"{rec['arch']}/{rec['shape']}"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DEFAULT_DIR)
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    if not recs:
        raise SystemExit("no dry-run artifacts found; run repro.launch.dryrun")
    print(format_table(recs))
    print()
    doms = summarize(recs)
    for dom, combos in sorted(doms.items()):
        print(f"{dom}-bound ({len(combos)}): {', '.join(combos[:6])}"
              + (" ..." if len(combos) > 6 else ""))
        print(f"  lever: {LEVERS[dom]}")
    print()
    print("hillclimb candidates:", pick_hillclimb_candidates(recs))


if __name__ == "__main__":
    main()
