"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints a ``name,us_per_call,derived`` CSV summary at the end (per-benchmark
detail printed as it runs).  --full uses paper-closer settings (3 datasets,
more rounds); the default is sized for this 2-core CPU container.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-sim", action="store_true",
                    help="skip the multi-minute simulation benches")
    args = ap.parse_args()

    csv = [("name", "us_per_call", "derived")]

    def record(name, t0, derived):
        csv.append((name, f"{(time.time() - t0) * 1e6:.0f}", derived))

    # --- kernels (fast) -------------------------------------------------
    from benchmarks import bench_kernels

    t0 = time.time()
    rows = bench_kernels.run()
    record("kernels", t0, f"{len(rows)} shapes vs TPU roofline")

    # --- engine runner (scan-fused vs per-round loop) -------------------
    from benchmarks import bench_engine

    t0 = time.time()
    eng = bench_engine.run(verbose=False)
    record("engine_runner", t0,
           f"scan-fused {eng['fused_speedup_vmap']:.2f}x vs per-round loop")

    # --- node-axis scaling (dense vs sparse layout) ---------------------
    from benchmarks import bench_scale

    t0 = time.time()
    # the reduced lane runs the smoke sweep (scale_smoke artifact) so a
    # down-scaled pass never clobbers the committed BENCH_scale.json;
    # --full refreshes the real artifact + BENCH verdict.
    sc = bench_scale.run(smoke=not args.full, verbose=False)
    sparse_rows = [r for r in sc["rows"]
                   if r["layout"] == "sparse" and "rounds_per_sec" in r]
    top = max(sparse_rows, key=lambda r: r["nodes"])
    record("scale", t0,
           f"sparse n={top['nodes']} {top['rounds_per_sec']:.2f} rounds/s; "
           f"builder n={sc['builder']['nodes']} "
           f"{sc['builder']['wall_s']:.1f}s")

    # --- dynamics suite (time-varying topologies) -----------------------
    from benchmarks import bench_dynamics

    t0 = time.time()
    # the reduced lane runs as a smoke sweep (dynamics_smoke artifact) so a
    # down-scaled pass never clobbers the committed BENCH_dynamics.json;
    # --full refreshes the real artifact + BENCH verdict.
    dyn_rows = bench_dynamics.run(
        rounds=40 if args.full else 15,
        nodes=16 if args.full else 12,
        verbose=False, smoke=not args.full)
    drop = next(r for r in dyn_rows
                if r["world"] == "ba" and r["comm"] == "int8+adaptive"
                and r["process"].startswith("dropout"))
    record("dynamics_suite", t0,
           f"int8+adaptive dropout(0.2) dAcc={drop['acc_delta_vs_static']:+.3f} "
           f"bytes={drop['bytes_ratio_vs_static']:.2f}x vs static")

    # --- time-to-accuracy suite (event clock) ---------------------------
    from benchmarks import bench_time

    t0 = time.time()
    # the reduced lane runs as a smoke sweep (time_smoke artifact) so a
    # down-scaled pass never clobbers the committed BENCH_time.json;
    # --full refreshes the real artifact + BENCH verdict.
    time_rows = bench_time.run(
        rounds=40 if args.full else 10,
        nodes=16 if args.full else 8,
        verbose=False, smoke=not args.full)
    tbase = next(r for r in time_rows if r["world"] == "ba"
                 and r["config"] == "sync-fp32")
    tchal = next(r for r in time_rows if r["world"] == "ba"
                 and r["config"] == "deadline-int8"
                 and r["scenario"] == "hetero")
    record("time_suite", t0,
           f"sync {tbase['sim_time']:.0f}s vs deadline "
           f"{tchal['sim_time']:.0f}s simulated (ba, "
           f"dAcc={tchal['acc_mean'] - tbase['acc_mean']:+.3f})")

    # --- telemetry overhead + ledger/trace (repro.obs) ------------------
    from benchmarks import bench_obs

    t0 = time.time()
    # same smoke convention: the reduced lane writes obs_smoke only;
    # --full refreshes the obs_suite artifact behind BENCH_obs.json.
    if args.full:
        obs = bench_obs.run(verbose=False)
    else:
        obs = bench_obs.run(rounds=8, eval_every=4, verbose=False,
                            smoke=True)
    record("obs_telemetry", t0,
           f"all-channels overhead {obs['overhead_frac'] * 100:+.1f}% "
           f"(gate <=5%), trace bytes "
           f"{'exact' if obs['trace']['bytes_exact'] else 'MISMATCH'}")

    # --- comm table (paper §VI-A.3) ------------------------------------
    from benchmarks import bench_comm

    t0 = time.time()
    rows = bench_comm.run(verbose=False, with_frontier=False)
    ge = next(r for r in rows if r["method"] == "cfa-ge" and "mlp" in r["model"]
              and r["codec"] == "fp32")
    dd = next(r for r in rows if r["method"] == "decdiff+vt"
              and "mlp" in r["model"] and r["codec"] == "fp32")
    record("comm_table", t0,
           f"cfa-ge/decdiff+vt bytes ratio={ge['bytes_per_round']/dd['bytes_per_round']:.1f}x")

    # --- roofline over dry-run artifacts (deliverable g) ----------------
    from benchmarks import roofline

    t0 = time.time()
    recs = roofline.load()
    if recs:
        ok = sum(1 for r in recs if r.get("ok"))
        print(roofline.format_table(recs))
        record("roofline", t0, f"{ok}/{len(recs)} single-pod combos ok")
    else:
        record("roofline", t0, "no dryrun artifacts (run repro.launch.dryrun)")

    if not args.skip_sim:
        # --- Fig. 1 disruption ------------------------------------------
        from benchmarks import bench_disruption

        t0 = time.time()
        _, summary = bench_disruption.run(
            num_nodes=24 if args.full else 12,
            rounds=8 if args.full else 5,
            data_scale=0.06 if args.full else 0.03)
        record("fig1_disruption", t0,
               f"dechetero drop={summary['dechetero']:+.3f} "
               f"decdiff+vt drop={summary['decdiff+vt']:+.3f}")

        # --- Table II accuracy + Table IV char-time ---------------------
        from benchmarks import bench_accuracy, bench_char_time

        t0 = time.time()
        datasets = (("synth-mnist", "synth-fashion", "synth-emnist")
                    if args.full else ("synth-mnist",))
        res = bench_accuracy.run(
            datasets=datasets,
            rounds=150 if args.full else 110,
            num_nodes=30 if args.full else 16,
            data_scale=0.08 if args.full else 0.04)
        print(bench_accuracy.format_table(res))
        first = res[datasets[0]]
        record("table2_accuracy", t0,
               f"decdiff+vt={first['decdiff+vt']['acc_mean']:.3f} "
               f"dechetero={first['dechetero']['acc_mean']:.3f} "
           f"isol={first['isol']['acc_mean']:.3f}")

        t0 = time.time()
        ct = bench_char_time.characteristic_times(res)
        print(bench_char_time.format_table(ct))
        record("table4_char_time", t0, "from accuracy histories")

        # --- Table III ablation ------------------------------------------
        from benchmarks import bench_ablation

        t0 = time.time()
        ab = bench_ablation.run(
            rounds=150 if args.full else 110,
            num_nodes=30 if args.full else 16,
            data_scale=0.08 if args.full else 0.04)
        print(bench_ablation.format_table(ab))
        record("table3_ablation", t0,
               f"decdiff+vt - dechetero = "
               f"{100*(ab['decdiff+vt']['acc_mean']-ab['dechetero']['acc_mean']):+.2f}%pt")

        if args.full:
            # --- beyond-paper: topology sensitivity ----------------------
            from benchmarks import bench_topology

            t0 = time.time()
            rows = bench_topology.run(rounds=40)
            record("topology", t0, f"{len(rows)} (topology x method) cells")

    print()
    for row in csv:
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
