"""Event-triggered, compressed DecDiff gossip — the comm layer end to end.

Runs DecDiff+VT on a seeded 8-node Barabási–Albert world under different
gossip transports and prints the accuracy-vs-bytes tradeoff, e.g.:

    PYTHONPATH=src python examples/compressed_gossip.py --rounds 15
    PYTHONPATH=src python examples/compressed_gossip.py \
        --codec int8 --threshold 1.0 --verbose
    PYTHONPATH=src python examples/compressed_gossip.py \
        --codec int8 --adaptive 0.9   # per-edge drift-rate control

With no --codec it sweeps the default frontier (fp32 dense reference, bf16,
int8 with fixed-threshold and adaptive per-edge triggering, top-k).  See
docs/comm.md for how to read the output; `python -m benchmarks.bench_comm`
is the full artifact-emitting version.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.comm import CommConfig
from repro.engine import Experiment, Schedule, World


def run_one(world, comm, rounds, verbose=False):
    ds, topo, xs, ys, model = world
    exp = Experiment(
        World(model=model, topo=topo, xs=xs, ys=ys,
              x_test=ds.x_test, y_test=ds.y_test),
        "decdiff+vt", comm=comm,
        schedule=Schedule(rounds=rounds, eval_every=5),
        steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9, seed=0)
    hist = exp.run(verbose=verbose)
    return exp, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--codec", choices=["fp32", "bf16", "int8", "topk"])
    ap.add_argument("--threshold", type=float, default=0.0)
    ap.add_argument("--adaptive", type=float, metavar="TARGET",
                    help="per-edge adaptive thresholds converging each "
                         "link's trigger rate to TARGET (overrides "
                         "--threshold)")
    ap.add_argument("--topk-ratio", type=float, default=0.05)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    from benchmarks.bench_comm import smoke_world, trigger_label

    world = smoke_world()
    if args.codec:
        kw = ({"policy": "adaptive", "target_trigger": args.adaptive}
              if args.adaptive is not None
              else {"trigger_threshold": args.threshold})
        sweep = [CommConfig(codec=args.codec, topk_ratio=args.topk_ratio,
                            **kw)]
    else:
        target = 0.9 if args.adaptive is None else args.adaptive
        sweep = [
            CommConfig(codec="fp32"),
            CommConfig(codec="bf16"),
            CommConfig(codec="int8"),
            CommConfig(codec="int8", trigger_threshold=1.0),
            CommConfig(codec="int8", policy="adaptive",
                       target_trigger=target),
            CommConfig(codec="topk", topk_ratio=args.topk_ratio),
        ]

    print(f"{'codec':>6} {'trigger':>14} | {'final acc':>9} | {'wire MB':>8} "
          f"| {'trig':>5} | reduction")
    dense_bytes = None
    for comm in sweep:
        sim, hist = run_one(world, comm, args.rounds, verbose=args.verbose)
        if dense_bytes is None and comm.codec == "fp32" \
                and comm.policy == "fixed" and comm.trigger_threshold == 0.0:
            dense_bytes = sim.comm_bytes_total
        red = ("-" if dense_bytes is None
               else f"{dense_bytes / max(sim.comm_bytes_total, 1):.1f}x")
        trig = trigger_label(comm.policy, comm.trigger_threshold,
                             comm.target_trigger)
        print(f"{comm.codec:>6} {trig:>14} | "
              f"{hist[-1].acc_mean:>9.4f} | "
              f"{sim.comm_bytes_total / 1e6:>8.2f} | "
              f"{hist[-1].triggered_frac:>5.2f} | {red}")


if __name__ == "__main__":
    main()
