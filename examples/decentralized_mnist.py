"""Paper-style experiment driver: every method on one world instance.

Reduced rendition of the paper's §V setup (ER graph, truncated-Zipf non-IID
split, SGD+momentum, per-node random init), producing a Table II-like summary
and a Table IV-like characteristic-time summary.  Each method runs through
`repro.engine.Experiment` (via benchmarks.common.run_method) with the
scan-fused schedule — the whole per-method experiment is one XLA program.

    PYTHONPATH=src python examples/decentralized_mnist.py [--rounds 60]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_accuracy import format_table, run
from benchmarks.bench_char_time import characteristic_times, format_table as fmt_ct


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--nodes", type=int, default=20)
    ap.add_argument("--dataset", default="synth-mnist")
    args = ap.parse_args()
    res = run(datasets=(args.dataset,), rounds=args.rounds,
              num_nodes=args.nodes, data_scale=0.05)
    print("\n=== Table II (accuracy) ===")
    print(format_table(res))
    print("\n=== Table IV (characteristic time) ===")
    print(fmt_ct(characteristic_times(res)))


if __name__ == "__main__":
    main()
