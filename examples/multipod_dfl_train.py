"""Multi-pod DFL training — the same Experiment spec on the pod mesh.

The `Experiment` that runs vmapped on one host lowers unchanged to the
shard_map backend: each pod (mesh axis "pod") owns a block of nodes' params,
optimizer state and data shards, and the DecDiff gossip exchange is an
all_gather over the pod ring.  The two lowerings are bit-identical
(tests/test_engine.py), so this script is about EXECUTION, not math: run it
under a forced multi-device CPU to watch the same seeded world split over a
real pod axis, scan-fused into one XLA program per schedule.

    PYTHONPATH=src python examples/multipod_dfl_train.py --nodes 8
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/multipod_dfl_train.py --nodes 8

For the assigned LM architectures the identical round shape lowers through
`repro.dist.dfl_step.build_dfl_round_shardmap` (launch/dryrun.py --mesh
multi), where the all_gather carries the encoded int8 payload and the
dequantize+average is fused into the `dequant_neighbor_avg_rows` Pallas
kernel — that path is exercised by the dry-run, not this example.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.engine import Experiment, Schedule, World
from repro.utils.pytree import tree_index, tree_l2_dist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--eval-every", type=int, default=5)
    args = ap.parse_args()

    world = World.synthetic(dataset="synth-mnist", nodes=args.nodes,
                            topology="ring", seed=0, scale=0.03)
    exp = Experiment(world, "decdiff+vt", backend="shard_map",
                     schedule=Schedule(rounds=args.rounds,
                                       eval_every=args.eval_every,
                                       mode="fused"),
                     steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9)
    n_pods = int(exp.mesh.shape["pod"])
    print(f"{len(jax.devices())} device(s) -> {n_pods}-pod mesh, "
          f"{args.nodes // n_pods} nodes per pod (heterogeneous init, "
          f"ring gossip)")

    d0 = float(tree_l2_dist(tree_index(exp.params, 0),
                            tree_index(exp.params, 1)))
    hist = exp.run(verbose=True)
    d1 = float(tree_l2_dist(tree_index(exp.params, 0),
                            tree_index(exp.params, 1)))
    print(f"\nnode0-node1 model distance: init {d0:.2f} -> final {d1:.2f} "
          f"({'converging' if d1 < d0 else 'diverging'}) — DecDiff pulls "
          f"heterogeneously-initialized nodes together without a server, "
          f"final acc {hist[-1].acc_mean:.3f} ± {hist[-1].acc_std:.3f}")


if __name__ == "__main__":
    main()
