"""Multi-node DFL training of an LM — the paper's Algorithm 1 at LM scale.

Each node holds its own (heterogeneously initialized) replica of a reduced
assigned architecture and its own synthetic token shard; every round the
nodes take local SGD steps and run DecDiff gossip (Eq. 5-6) over the node
axis.  On the production mesh the node axis is the `pod` mesh axis (see
launch/dryrun.py --mesh multi for the 512-chip lowering).

    PYTHONPATH=src python examples/multipod_dfl_train.py --nodes 4 --steps 60
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.dist.dfl_step import build_dfl_round
from repro.models.lm import build_lm
from repro.optim.sgd import sgd_momentum
from repro.utils.pytree import tree_index, tree_l2_dist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(n_layers=2, d_model=128, vocab=512)
    lm = build_lm(cfg)
    opt = sgd_momentum(lr=5e-3, momentum=0.9)

    keys = jax.random.split(jax.random.PRNGKey(0), args.nodes)
    params = jax.vmap(lm.init)(keys)  # different init per node (the hard case)
    opt_state = jax.vmap(opt.init)(params)

    # ring gossip graph over nodes
    adj = np.zeros((args.nodes, args.nodes), np.float32)
    for i in range(args.nodes):
        adj[i, (i + 1) % args.nodes] = adj[i, (i - 1) % args.nodes] = 0.5
    round_fn = jax.jit(build_dfl_round(lm, opt, jnp.asarray(adj)))

    from repro.data.tokens import synthetic_token_batch

    d0 = float(tree_l2_dist(tree_index(params, 0), tree_index(params, 1)))
    for step in range(args.steps):
        batch = {k: jnp.asarray(np.stack([
            synthetic_token_batch(args.batch, args.seq, cfg.vocab,
                                  seed=step * 100 + n)[k]
            for n in range(args.nodes)]))
            for k in ("tokens", "labels")}
        params, opt_state, loss = round_fn(params, opt_state, jnp.int32(step), batch)
        if step % 10 == 0 or step == args.steps - 1:
            d = float(tree_l2_dist(tree_index(params, 0), tree_index(params, 1)))
            print(f"round {step:4d}  loss {float(loss):.4f}  "
                  f"node0-node1 distance {d:.2f}", flush=True)
    d1 = float(tree_l2_dist(tree_index(params, 0), tree_index(params, 1)))
    print(f"\nmodel distance: init {d0:.2f} -> final {d1:.2f} "
          f"({'converging' if d1 < d0 else 'diverging'}) — DecDiff pulls "
          f"heterogeneously-initialized nodes together without a server.")


if __name__ == "__main__":
    main()
