"""Quickstart: coordination-free decentralized learning in ~40 lines.

16 devices on an Erdős–Rényi graph, non-IID synthetic MNIST, heterogeneous
model initialization — train with the paper's DecDiff+VT and compare the
final node-average accuracy against isolated training.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import make_dataset, zipf_allocation
from repro.data.allocation import allocation_gini, split_by_allocation
from repro.fl import DFLSimulator, SimulatorConfig
from repro.graphs import make_topology
from repro.models.mlp_cnn import model_for_dataset


def main():
    # 1. world: data, non-IID allocation, communication graph
    ds = make_dataset("synth-mnist", seed=0, scale=0.05)
    topo = make_topology("erdos_renyi", n=16, p=0.25, seed=0)
    alloc = zipf_allocation(ds.y_train, topo.num_nodes, seed=0, min_per_class=1)
    xs, ys = split_by_allocation(ds.x_train, ds.y_train, alloc)
    print(f"graph: {topo.name}  (connected={topo.connected})  "
          f"label-skew Gini={allocation_gini(alloc, ds.y_train):.2f}")

    # 2. the paper's model (Table I MLP) — each node draws its OWN init
    model = model_for_dataset("synth-mnist", ds.num_classes)

    # 3. run DecDiff+VT (Alg. 1) vs isolation
    for method in ("isol", "decdiff+vt"):
        cfg = SimulatorConfig(method=method, rounds=30, steps_per_round=4,
                              batch_size=32, lr=0.1, momentum=0.9,
                              beta=0.95, eval_every=10)
        sim = DFLSimulator(model, topo, xs, ys, ds.x_test, ds.y_test, cfg)
        hist = sim.run(verbose=True)
        print(f"--> {method}: final node-average accuracy "
              f"{hist[-1].acc_mean:.3f} ± {hist[-1].acc_std:.3f}\n")


if __name__ == "__main__":
    main()
