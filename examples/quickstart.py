"""Quickstart: coordination-free decentralized learning in ~40 lines.

16 devices on an Erdős–Rényi graph, non-IID synthetic MNIST, heterogeneous
model initialization — train with the paper's DecDiff+VT and compare the
final node-average accuracy against isolated training.  The whole schedule
(all rounds + evals) runs as ONE scan-fused XLA program per method.

    PYTHONPATH=src python examples/quickstart.py [--rounds 30 --nodes 16]

(`World.synthetic(...)` collapses step 1 into one call; it is spelled out
here to show what a World is made of.)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import make_dataset, zipf_allocation
from repro.data.allocation import allocation_gini, split_by_allocation
from repro.engine import Experiment, Schedule, World
from repro.graphs import make_topology
from repro.models.mlp_cnn import model_for_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--eval-every", type=int, default=10)
    args = ap.parse_args()

    # 1. world: data, non-IID allocation, communication graph, paper model
    ds = make_dataset("synth-mnist", seed=0, scale=0.05)
    topo = make_topology("erdos_renyi", n=args.nodes, p=0.25, seed=0)
    alloc = zipf_allocation(ds.y_train, topo.num_nodes, seed=0,
                            min_per_class=1)
    xs, ys = split_by_allocation(ds.x_train, ds.y_train, alloc)
    print(f"graph: {topo.name}  (connected={topo.connected})  "
          f"label-skew Gini={allocation_gini(alloc, ds.y_train):.2f}")
    world = World(model=model_for_dataset("synth-mnist", ds.num_classes),
                  topo=topo, xs=xs, ys=ys,
                  x_test=ds.x_test, y_test=ds.y_test)

    # 2. run DecDiff+VT (Alg. 1) vs isolation — each node draws its OWN init
    for method in ("isol", "decdiff+vt"):
        exp = Experiment(world, method,
                         schedule=Schedule(rounds=args.rounds,
                                           eval_every=args.eval_every,
                                           mode="fused"),
                         steps_per_round=4, batch_size=32, lr=0.1,
                         momentum=0.9, beta=0.95)
        hist = exp.run(verbose=True)
        print(f"--> {method}: final node-average accuracy "
              f"{hist[-1].acc_mean:.3f} ± {hist[-1].acc_std:.3f}\n")


if __name__ == "__main__":
    main()
