"""Serving example: batched autoregressive decoding with a KV cache.

Loads a reduced assigned architecture, prefills a batch of synthetic prompts
via the teacher-forced path, then decodes new tokens step by step (ring-
buffer cache, one serve_step per token) — the long_500k path in miniature.

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import build_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    max_len = args.prompt_len + args.new_tokens
    cache = lm.init_cache(args.batch, max_len)
    if lm.prep_decode_cache is not None:  # enc-dec: run the encoder once
        enc = jnp.asarray(rng.standard_normal(
            (args.batch, max_len // cfg.enc_seq_divisor, cfg.d_model)) * 0.05,
            cfg.adtype)
        cache = lm.prep_decode_cache(params, cache, enc)

    step = jax.jit(lm.decode_step)
    # prefill: feed prompt tokens through the cache path
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, t : t + 1])

    # decode: greedy sampling
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"arch={args.arch} batch={args.batch} "
          f"decoded {args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first sequence:", gen[0][:16], "...")
    assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
