"""Checkpointing: pytree save/restore with a JSON manifest.

Format: ``<dir>/step_<N>/arrays.npz`` (flat key = '/'-joined tree path) plus
``manifest.json`` recording step, tree paths, shapes, dtypes and user
metadata.  Restore rebuilds the exact pytree (dict nesting) and casts back to
the recorded dtypes.  Atomic via write-to-temp + rename.  On a real multi-host
deployment each host would write its addressable shards; here (single
process) we save fully-replicated values — the manifest's `sharding` field
records the intended PartitionSpec so a loader on the production mesh can
re-shard with `jax.device_put`.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_key(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_key(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return f"[{entry.idx}]"
    return str(entry)


def _insert(tree: dict, parts, value):
    head, rest = parts[0], parts[1:]
    if head.startswith("[") and head.endswith("]"):
        head = int(head[1:-1])
    if not rest:
        tree[head] = value
        return
    tree = tree.setdefault(head, {})
    _insert(tree, rest, value)


def _listify(tree):
    """Convert dicts whose keys are all ints 0..n-1 back into lists/tuples."""
    if isinstance(tree, dict):
        conv = {k: _listify(v) for k, v in tree.items()}
        if conv and all(isinstance(k, int) for k in conv):
            return [conv[i] for i in sorted(conv)]
        return conv
    return tree


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None,
                    shardings: Optional[Dict[str, str]] = None) -> str:
    """Save `tree` under directory/step_<step>.  Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    flat = _flatten_with_paths(tree)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
            "metadata": metadata or {},
            "sharding": shardings or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and d.split("_")[1].isdigit()
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int] = None
                       ) -> Tuple[Any, Dict]:
    """Restore (tree, manifest).  step=None -> latest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    tree: dict = {}
    for key in arrays.files:
        spec = manifest["keys"][key]
        val = arrays[key].astype(spec["dtype"])
        _insert(tree, key.split(_SEP), val)
    return _listify(tree), manifest
