"""repro.comm — the gossip transport layer between training and aggregation.

What the paper models as "ship full fp32 models to every neighbour every
round" becomes a measured quantity here:

  codecs    — per-edge payload compression (fp32 / bf16 / stochastic int8 /
              top-k with error feedback and optional momentum-masked
              selection), each with exact bytes_on_wire,
  trigger   — event-triggered transmission: send only when the model has
              drifted past a threshold since the last payload — per node
              (one scalar) or per edge (drift-rate-adaptive thresholds that
              converge each link to a target triggered fraction),
  transport — CommConfig + GossipTransport (per-node state) +
              EdgeGossipTransport (per-edge `[N, max_deg, ...]` state that
              survives link failures independently), each exposing ONE
              `exchange` written against a PodContext (row-slice +
              all-gather), so the engine rounds (repro.engine.backends) and
              the dist rounds (repro.dist.dfl_step) lower the same path on
              every backend, with bytes/round and triggered-fraction
              accounting.

Receivers always dequantize before aggregating, so DecDiff's Eq. 5-6 act on
reconstructed models and the algorithm's semantics never change — only the
bytes on the wire do.
"""
from repro.comm.codecs import (  # noqa: F401
    CODECS,
    BF16Codec,
    Codec,
    FP32Codec,
    Int8Codec,
    TopKCodec,
    make_codec,
    payload_nbytes,
)
from repro.comm.transport import (  # noqa: F401
    DENSE_CTX,
    WIRES,
    CommConfig,
    CommState,
    EdgeCommState,
    EdgeGossipTransport,
    GossipTransport,
    PodContext,
    SparseEdgeCommState,
    SparseEdgeGossipTransport,
    codec_roundtrip_stacked,
)
from repro.comm.trigger import (  # noqa: F401
    adaptive_threshold_update,
    drift_gate,
    edge_delivery,
    edge_drift_gate,
)
