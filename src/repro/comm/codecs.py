"""Per-edge payload codecs for the gossip transport.

A codec turns one node's flat model vector (or model delta) into a *wire
payload* — a small pytree whose leaves carry the exact dtypes that would be
serialized onto the network — and back.  Three contracts every codec obeys:

  * `decode(encode(x)) ≈ x` with a codec-specific error bound (exact for
    fp32, one bf16 ulp for bf16, one quantization grain for int8, and the
    error-feedback invariant for top-k/int8: residual' + decode(payload)
    == x + residual, so nothing is ever silently dropped — only delayed),
  * `bytes_on_wire(payload)` equals the byte length of the serialized
    payload (Σ leaf.size × leaf.dtype.itemsize — validated against
    `tobytes()` in tests/test_comm_codecs.py),
  * encode/decode are pure jnp functions of their arguments, so they vmap
    over the node axis and run inside jit/shard_map (dist/dfl_step.py
    all_gathers the *payload*, which is where the wire savings come from).

Codecs marked `is_delta=True` are meant to compress the model *difference*
w − w_last_sent (plus the carried residual); the transport reconstructs
ŵ = w_last_sent + decode(payload).  With a zero reference they degrade
gracefully to compressing the full model (the dist-layer rounds use them
that way, reference-free).

Stochastic int8 rounding is unbiased (E[decode] == input); pass rng=None for
deterministic round-to-nearest (required when the vmap and shard_map rounds
must agree bit-for-bit).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def payload_nbytes(payload) -> int:
    """Exact serialized size of a wire payload: every leaf ships as raw
    little-endian machine words, no framing (Σ size × itemsize)."""
    return int(sum(x.size * jnp.dtype(x.dtype).itemsize
                   for x in jax.tree.leaves(payload)))


@dataclasses.dataclass(frozen=True)
class Codec:
    """Base codec: interface + shared accounting."""

    name: str = "codec"
    is_delta: bool = False      # compresses w - w_last_sent (EF scheme)
    needs_rng: bool = False     # encode consumes a PRNG key
    has_residual: bool = False  # carries an error-feedback residual

    def init_residual(self, vec):
        return jnp.zeros_like(vec, jnp.float32) if self.has_residual else None

    def encode(self, vec, rng=None, residual=None):
        raise NotImplementedError

    def decode(self, payload, out_size=None):
        raise NotImplementedError

    def bytes_on_wire(self, payload) -> int:
        return payload_nbytes(payload)

    def payload_bytes_for(self, size: int) -> int:
        """Exact wire bytes for one encoded vector of `size` elements,
        computed from payload shapes alone (no FLOPs: jax.eval_shape)."""
        proto = jax.ShapeDtypeStruct((size,), jnp.float32)
        payload, _ = jax.eval_shape(lambda v: self.encode(v), proto)
        return payload_nbytes(payload)


@dataclasses.dataclass(frozen=True)
class FP32Codec(Codec):
    """Dense fp32 passthrough — the accounting baseline (bit-exact)."""

    name: str = "fp32"

    def encode(self, vec, rng=None, residual=None):
        return {"w": vec.astype(jnp.float32)}, residual

    def decode(self, payload, out_size=None):
        return payload["w"]


@dataclasses.dataclass(frozen=True)
class BF16Codec(Codec):
    """Dense bf16 cast — halves the wire, one-bf16-ulp relative error."""

    name: str = "bf16"

    def encode(self, vec, rng=None, residual=None):
        return {"w": vec.astype(jnp.bfloat16)}, residual

    def decode(self, payload, out_size=None):
        return payload["w"].astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class Int8Codec(Codec):
    """Symmetric per-vector int8 with optional stochastic rounding + EF.

    scale = max|x| / 127; wire = int8 values + one fp32 scale (4x fewer
    bytes than fp32, minus 4 bytes of scale).  Stochastic rounding keeps the
    quantizer unbiased across rounds; the residual catches the per-round
    grain so the error-feedback invariant holds exactly.
    """

    name: str = "int8"
    is_delta: bool = True
    needs_rng: bool = True   # only consumed when stochastic
    has_residual: bool = True
    stochastic: bool = True

    def encode(self, vec, rng=None, residual=None):
        x = vec.astype(jnp.float32)
        if residual is not None:
            x = x + residual
        amax = jnp.max(jnp.abs(x))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        y = x / scale
        if self.stochastic and rng is not None:
            u = jax.random.uniform(rng, y.shape)
        else:
            u = 0.5
        q = jnp.clip(jnp.floor(y + u), -127, 127).astype(jnp.int8)
        new_res = (x - q.astype(jnp.float32) * scale
                   if residual is not None else None)
        return {"q": q, "scale": scale}, new_res

    def decode(self, payload, out_size=None):
        return payload["q"].astype(jnp.float32) * payload["scale"]


@dataclasses.dataclass(frozen=True)
class TopKCodec(Codec):
    """Magnitude top-k sparsification with error-feedback residuals.

    Ships the k largest-|.| coordinates as (int32 index, fp32 value) pairs;
    everything else stays in the residual and rides along to the next send.
    k = max(1, round(ratio * size)) — static per vector length, so the wire
    size is static too (8k + 4 bytes incl. the length word).

    With `momentum > 0` the selection runs on a *momentum-masked* score
    (Deep-Gradient-Compression style): score = |x| + momentum * score_prev,
    so coordinates that keep mattering across rounds accumulate selection
    pressure and win a slot even when a transient spike would otherwise
    crowd them out; a selected coordinate resets its score to zero.  The
    score rides as a second row of the residual state (`[2, D]`: row 0 the
    EF residual, row 1 the score) — it never touches the wire, and
    momentum = 0 degenerates bit-for-bit to plain magnitude top-k with the
    legacy `[D]` residual.
    """

    name: str = "topk"
    is_delta: bool = True
    has_residual: bool = True
    ratio: float = 0.01
    momentum: float = 0.0

    def k_for(self, size: int) -> int:
        return max(1, int(round(self.ratio * size)))

    def init_residual(self, vec):
        if self.momentum > 0:
            return jnp.zeros((2,) + vec.shape[-1:], jnp.float32)
        return jnp.zeros_like(vec, jnp.float32)

    def encode(self, vec, rng=None, residual=None):
        x = vec.astype(jnp.float32)
        with_momentum = self.momentum > 0 and residual is not None
        if residual is not None:
            x = x + (residual[0] if with_momentum else residual)
        k = self.k_for(x.shape[-1])
        score = jnp.abs(x)
        if with_momentum:
            score = score + jnp.float32(self.momentum) * residual[1]
        _, idx = jax.lax.top_k(score, k)
        vals = x[idx]
        if residual is None:
            new_res = None
        elif with_momentum:
            new_res = jnp.stack([x.at[idx].set(0.0),
                                 score.at[idx].set(0.0)])
        else:
            new_res = x.at[idx].set(0.0)
        payload = {
            "idx": idx.astype(jnp.int32),
            "vals": vals.astype(jnp.float32),
            # length word: receivers must know the dense size to scatter into
            "size": jnp.asarray(x.shape[-1], jnp.int32),
        }
        return payload, new_res

    def decode(self, payload, out_size=None):
        # out_size must be given under jit/vmap: the payload's length word
        # is a traced scalar there and cannot size the dense output.  The
        # None path serves concrete (off-trace) payloads only.
        size = int(payload["size"]) if out_size is None else out_size
        return (jnp.zeros((size,), jnp.float32)
                .at[payload["idx"]].set(payload["vals"]))


CODECS = {
    "fp32": FP32Codec,
    "bf16": BF16Codec,
    "int8": Int8Codec,
    "topk": TopKCodec,
}


def make_codec(name: str, **kwargs) -> Codec:
    """Factory: `make_codec("int8", stochastic=False)`, `make_codec("topk",
    ratio=0.05)`, ..."""
    if name not in CODECS:
        raise ValueError(f"unknown codec {name!r}; available: {sorted(CODECS)}")
    return CODECS[name](**kwargs)
