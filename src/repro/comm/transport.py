"""Gossip transport: codecs x event trigger x exact bytes-on-wire accounting.

Sits between local training and aggregation.  Each round every node:

  1. measures its drift ||w_i - w_i^last_sent|| and decides whether to
     transmit (trigger.drift_gate; threshold 0 = always send),
  2. if transmitting, encodes its payload — delta codecs (int8, top-k)
     compress w_i - w_i^last_sent plus the carried error-feedback residual,
     dense codecs (fp32, bf16) the model itself,
  3. receivers dequantize first and aggregate second, so DecDiff's Eq. 5-6
     semantics are untouched: the aggregator simply sees ŵ_j instead of w_j.

The transport is a shared-memory stand-in for N independent radios, so the
"wire" state is held once: `last_sent[j]` doubles as the sender's trigger
reference AND the receivers' cached copy of j's reconstruction reference
(receivers of a delta codec start from the all-zeros reference, so no
out-of-band full-model bootstrap is assumed — the first payload carries the
whole model through the codec).

Accounting is exact and static: `payload_bytes` is the serialized size of
one payload (codec.payload_bytes_for), so bytes-on-wire per round is
payload_bytes x Σ_i gate_i x outdeg_i — a transmitting node broadcasts one
payload per outgoing edge.  Failed links still burn the sender's bytes
(the sender cannot know), they just deliver nothing.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm.codecs import Codec, make_codec
from repro.comm.trigger import drift_gate
from repro.utils.pytree import tree_flatten_stacked


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Transport knobs, carried on SimulatorConfig.comm.

    codec: "fp32" | "bf16" | "int8" | "topk".
    trigger_threshold: L2 drift below which a node stays silent (0 = the
      legacy always-send behaviour, bit-for-bit).
    topk_ratio: fraction of coordinates the top-k codec ships.
    stochastic: int8 rounding mode (True = unbiased stochastic rounding;
      False = deterministic nearest, needed for vmap/shard_map equality).
    on_silence: what receivers aggregate for a neighbour whose trigger did
      not fire.  "stale" (default, the Zehtabi et al. event-triggered DFL
      semantics): its cached last-transmitted model — silence means "use
      what you have", costs nothing, and degrades convergence more
      gracefully than dropping (staleness still drags; see the BENCH_comm
      frontier for the measured accuracy-vs-bytes tradeoff per threshold).
      "drop": mask the neighbour out entirely, like a failed link.
      Exogenous link failures always drop (a loss, not a decision).
    """

    codec: str = "fp32"
    trigger_threshold: float = 0.0
    topk_ratio: float = 0.01
    stochastic: bool = True
    on_silence: str = "stale"

    def __post_init__(self):
        if self.on_silence not in ("stale", "drop"):
            raise ValueError(f"on_silence must be 'stale' or 'drop', "
                             f"got {self.on_silence!r}")

    def make_codec(self) -> Codec:
        kwargs = {}
        if self.codec == "topk":
            kwargs["ratio"] = self.topk_ratio
        if self.codec == "int8":
            kwargs["stochastic"] = self.stochastic
        return make_codec(self.codec, **kwargs)


class CommState(NamedTuple):
    """Per-node transport state, threaded through the jitted round."""

    last_sent: jnp.ndarray            # [N, D] last reconstruction on the wire
    residual: Optional[jnp.ndarray]   # [N, D] EF residual (None if stateless)
    ever_sent: jnp.ndarray            # [N] {0,1}: has node i transmitted yet?


class GossipTransport:
    """Flatten -> trigger -> encode -> decode -> unflatten, vmapped over N."""

    def __init__(self, config: CommConfig, stacked_params):
        self.config = config
        self.codec = config.make_codec()
        mat, self._unflatten = tree_flatten_stacked(stacked_params)
        self.n, self.d = int(mat.shape[0]), int(mat.shape[1])
        # exact serialized payload size for ONE node's transmission
        self.payload_bytes = self.codec.payload_bytes_for(self.d)
        self.dense_bytes = 4 * self.d  # fp32 reference for reduction ratios
        self.wants_rng = (self.codec.needs_rng
                          and getattr(self.codec, "stochastic", True))

    def init_state(self, stacked_params) -> CommState:
        mat, _ = tree_flatten_stacked(stacked_params)
        residual = (jnp.zeros_like(mat) if self.codec.has_residual else None)
        # zero reference: the first transmission carries the full model
        # through the codec, so receivers need no out-of-band bootstrap.
        return CommState(last_sent=jnp.zeros_like(mat), residual=residual,
                         ever_sent=jnp.zeros((self.n,), jnp.float32))

    def exchange(self, stacked_params, state: CommState, rng=None):
        """One transport round for all nodes at once.

        Returns (decoded_models, gate, new_state):
          decoded_models — pytree with leaves [N, ...]: for each sender the
            model its neighbours reconstruct this round (rows of silent
            nodes hold their previous reconstruction; the aggregation mask
            zeroes them out anyway),
          gate — [N] {0,1} who transmitted,
          new_state — the threaded CommState.
        """
        codec = self.codec
        w, _ = tree_flatten_stacked(stacked_params)
        gate, _ = drift_gate(w, state.last_sent, self.config.trigger_threshold)

        x = w - state.last_sent if codec.is_delta else w
        if self.wants_rng:
            if rng is None:
                raise ValueError(f"codec {codec.name!r} needs an rng key")
            keys = jax.random.split(rng, self.n)
        else:
            keys = jnp.zeros((self.n, 2), jnp.uint32)

        def enc_dec(xi, key, res):
            payload, new_res = codec.encode(
                xi, rng=key if self.wants_rng else None, residual=res)
            return codec.decode(payload, out_size=self.d), new_res

        if codec.has_residual:
            dec, new_res = jax.vmap(enc_dec)(x, keys, state.residual)
        else:
            dec, _ = jax.vmap(lambda xi, key: enc_dec(xi, key, None))(x, keys)
            new_res = None

        recon = state.last_sent + dec if codec.is_delta else dec
        sent = gate[:, None] > 0
        new_last = jnp.where(sent, recon, state.last_sent)
        if codec.has_residual:
            # a silent node keeps accumulating: its un-flushed residual
            # stays put until the trigger fires again.
            new_res = jnp.where(sent, new_res, state.residual)
        new_state = CommState(last_sent=new_last, residual=new_res,
                              ever_sent=jnp.maximum(state.ever_sent, gate))
        return self._unflatten(new_last), gate, new_state


def codec_roundtrip_stacked(codec: Codec, stacked, rng=None):
    """Reference-free encode->decode of stacked [N, ...] models.

    The dist-layer rounds (repro.dist.dfl_step) use this to model wire
    effects without transport state: delta codecs compress against the
    implicit zero reference (= the full model goes through the codec).
    Returns the decoded stacked pytree (leaves cast back to input dtypes).
    """
    w, unflatten = tree_flatten_stacked(stacked)
    n, d = int(w.shape[0]), int(w.shape[1])
    wants_rng = codec.needs_rng and getattr(codec, "stochastic", True) \
        and rng is not None
    keys = (jax.random.split(rng, n) if wants_rng
            else jnp.zeros((n, 2), jnp.uint32))

    def enc_dec(xi, key):
        payload, _ = codec.encode(xi, rng=key if wants_rng else None)
        return codec.decode(payload, out_size=d)

    return unflatten(jax.vmap(enc_dec)(w, keys))
