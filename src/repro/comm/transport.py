"""Gossip transport: codecs x event trigger x exact bytes-on-wire accounting.

Sits between local training and aggregation.  Each round every node:

  1. measures its drift ||w_i - w^last_sent|| and decides whether to
     transmit (trigger module; threshold 0 = always send),
  2. if transmitting, encodes its payload — delta codecs (int8, top-k)
     compress the drift plus the carried error-feedback residual,
     dense codecs (fp32, bf16) the model itself,
  3. receivers dequantize first and aggregate second, so DecDiff's Eq. 5-6
     semantics are untouched: the aggregator simply sees ŵ_j instead of w_j.

Three transports share the codecs and that round shape:

`GossipTransport` — per-NODE state (the PR-2 broadcast model): one
`last_sent[j]` [N, D] doubles as sender j's trigger reference AND every
receiver's cached copy of j's reconstruction, one shared residual per node.
A node encodes once and broadcasts the same payload on all its edges.

`EdgeGossipTransport` — per-EDGE state in the padded-neighbour layout
(`[N, max_deg, ...]`): each directed link (i -> j) keeps its own
`last_sent[i, d]`, error-feedback `residual[i, d]`, adaptive `threshold
[i, d]` and drift EMA, where d is j's slot in i's neighbour list.  The
payload for each edge is encoded against *that edge's* reference, and —
the point of the exercise — state only advances on links that actually
delivered: a Bernoulli link failure on (i, j) leaves both (i, j)'s and
(i, k)'s residuals bit-identical to their no-traffic values instead of
poisoning a shared top-k error-feedback buffer for every neighbour.  The
receiver-side cache interpretation is exact: `last_sent[i, d]` IS what the
receiver on that edge holds (the per-node transport loses this the moment
one link drops), so "stale" aggregation serves genuinely per-link staleness.
Cost: encode runs per edge, not per node, and state is max_deg x larger —
the price of personalized links (the wire bytes are identical when all
edges of a node fire together).

`SparseEdgeGossipTransport` — the same per-edge semantics re-keyed to the
flat `[E]` CSR edge list of a `SparseTopology`: state is O(E) not
O(N·max_deg), there is no padding, no layout swap and no reverse-slot
gather (a CSR edge id addresses BOTH directions of the exchange), and the
per-edge rng stream is keyed by the same canonical directed-edge
enumeration the dense transport's slot panel indexes — which is what makes
the two layouts bit-identical on the same graph.

The ONE exchange path (every backend, every transport)
------------------------------------------------------

`exchange` is written once against a :class:`PodContext` — the pair of
(row-slice, all-gather) primitives that describe where the caller's block
of sender rows sits in the full node axis:

  * ``DENSE_CTX`` (the default) is the identity pair: the caller holds all
    N rows, nothing moves — the vmap backend and every direct caller;
  * the engine's shard_map backend passes a context whose ``rows`` slices
    the pod's block out of replicated [N, ...] quantities and whose
    ``gather`` is the tiled `all_gather` over the pod axis.

Sender-private state (error-feedback residuals, per-edge thresholds and
drift EMAs) lives in block rows and shards with its pod; receiver-facing
state (the `last_sent` reconstruction caches, the ever-sent/-delivered
flags) is REPLICATED: every pod recomputes the full-axis update from the
gathered wire deterministically, so the replicas cannot diverge and the
reverse-slot gather (receiver r reads sender j's slot toward r — resolved
by the `repro.kernels` gather-rows kernel over the flattened per-link
table) never crosses pods at aggregation time.  `state_specs` hands the
engine the matching PartitionSpec tree.

What the gather carries is the `wire` choice: ``"encoded"`` (the default)
moves the codec payload — int8 crosses the interconnect at 1/4 the fp32
footprint and every pod decodes the same bytes — while ``"decoded"`` moves
the reconstructed fp32 rows (the small-N oracle).  decode(encode(x)) is
deterministic, so the two wires are bit-identical by construction (pinned
in tests/test_engine.py); only bandwidth differs.

Thresholds are either `fixed` (the scalar `trigger_threshold` on every
edge) or `adaptive`: a per-edge Robbins-Monro controller tracks the
(1 - target_trigger)-quantile of that edge's drift so each link's long-run
triggered fraction converges to `target_trigger` (see trigger.py).

Accounting is exact and static: `payload_bytes` is the serialized size of
one payload (codec.payload_bytes_for).  Bytes-on-wire per round is
payload_bytes x (number of fired edges) — per-node: Σ_i gate_i x outdeg_i;
per-edge: Σ_ij gate_ij.  Failed links still burn the sender's bytes (the
sender cannot know *at send time*), they just deliver nothing; the per-edge
transport additionally models a link-layer ack, which is how it knows not
to advance a dropped link's reference.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codecs import Codec, make_codec
from repro.comm.trigger import (
    adaptive_threshold_update,
    drift_gate,
    edge_drift_gate,
)
from repro.utils.pytree import tree_flatten_stacked

POLICIES = ("fixed", "adaptive")
WIRES = ("encoded", "decoded")


class PodContext(NamedTuple):
    """Where the caller's block of sender rows sits in the full node axis.

    ``rows``   maps a replicated [N, ...] quantity to the caller's [R, ...]
               block (identity when the caller holds all rows);
    ``gather`` maps the caller's [R, ...] block to the full [N, ...] axis
               (the engine's tiled all_gather over the pod mesh axis;
               identity on the dense path);
    ``pod``    the caller's block index along the pod mesh axis (a traced
               scalar under shard_map; None on the single-block path).
    """

    rows: Callable
    gather: Callable
    pod: Optional[jnp.ndarray] = None


def _identity(a):
    return a


#: The dense (single-block) context: R == N, nothing moves.
DENSE_CTX = PodContext(rows=_identity, gather=_identity)


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Transport knobs, carried on Experiment(comm=...).

    codec: "fp32" | "bf16" | "int8" | "topk".
    trigger_threshold: L2 drift below which a sender stays silent (0 = the
      legacy always-send behaviour, bit-for-bit).  Used by the "fixed"
      policy; the "adaptive" policy learns per-edge thresholds instead.
    policy: "fixed" (one scalar threshold everywhere) or "adaptive"
      (per-edge drift-rate-controlled thresholds; implies per-edge state).
    per_edge: keep transport state per directed link `[N, max_deg, ...]`
      instead of per node — independent error-feedback residuals and
      staleness per link, surviving Bernoulli link failures independently.
      Forced on by policy="adaptive".
    target_trigger: adaptive policy's per-edge long-run triggered fraction
      target, in (0, 1].
    drift_ema_beta: decay of the per-edge drift EMA that scales the
      adaptive controller's step.
    threshold_rate: adaptive controller gain.
    topk_ratio: fraction of coordinates the top-k codec ships.
    topk_momentum: momentum-masked top-k selection (0 = plain magnitude
      top-k); see codecs.TopKCodec.
    stochastic: int8 rounding mode (True = unbiased stochastic rounding;
      False = deterministic nearest, needed for vmap/shard_map equality).
    on_silence: what receivers aggregate for a neighbour whose trigger did
      not fire.  "stale" (default, the Zehtabi et al. event-triggered DFL
      semantics): its cached last-transmitted model — silence means "use
      what you have", costs nothing, and degrades convergence more
      gracefully than dropping (staleness still drags; see the BENCH_comm
      frontier for the measured accuracy-vs-bytes tradeoff per threshold).
      "drop": mask the neighbour out entirely, like a failed link.
      Exogenous link failures always drop (a loss, not a decision).
    """

    codec: str = "fp32"
    trigger_threshold: float = 0.0
    policy: str = "fixed"
    per_edge: bool = False
    target_trigger: float = 0.5
    drift_ema_beta: float = 0.9
    threshold_rate: float = 0.5
    topk_ratio: float = 0.01
    topk_momentum: float = 0.0
    stochastic: bool = True
    on_silence: str = "stale"

    def __post_init__(self):
        if self.on_silence not in ("stale", "drop"):
            raise ValueError(f"on_silence must be 'stale' or 'drop', "
                             f"got {self.on_silence!r}")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {self.policy!r}")
        if self.policy == "adaptive" and not (0.0 < self.target_trigger <= 1.0):
            raise ValueError(f"target_trigger must be in (0, 1], "
                             f"got {self.target_trigger}")

    @property
    def use_per_edge(self) -> bool:
        """Per-edge state is explicit (`per_edge`) or implied by the
        adaptive policy (per-edge thresholds need per-edge references)."""
        return self.per_edge or self.policy == "adaptive"

    def make_codec(self) -> Codec:
        kwargs = {}
        if self.codec == "topk":
            kwargs["ratio"] = self.topk_ratio
            if self.topk_momentum > 0:
                kwargs["momentum"] = self.topk_momentum
        if self.codec == "int8":
            kwargs["stochastic"] = self.stochastic
        return make_codec(self.codec, **kwargs)


class CommState(NamedTuple):
    """Per-node transport state, threaded through the jitted round.

    `last_sent`, `ever_sent` and `ever_recv` are receiver-facing: replicated
    over pods (every pod recomputes the full-axis update from the gathered
    wire); `residual` is sender-private and shards with its rows.

    `ever_recv` is the per-EDGE delivery history (`[N, max_deg]` in the
    padded receiver layout, `[E]` over the CSR edge list — whichever layout
    the engine bound): has this edge ever actually DELIVERED a payload?  It
    is what the `on_silence="stale"` mask consults — a payload that was
    *sent but never arrived* (link failure, missed deadline) leaves it 0, so
    the receiver does not aggregate a cache it never filled.  `ever_sent`
    (sender-side, flips on transmission) is kept for byte/trigger
    accounting; it must NOT gate staleness.  `ever_recv` is None when the
    transport is built without an edge layout (direct construction) — the
    engine always supplies one.
    """

    last_sent: jnp.ndarray            # [N, D] last reconstruction on the wire
    residual: Optional[jnp.ndarray]   # [R, ...] EF residual (None if stateless)
    ever_sent: jnp.ndarray            # [N] {0,1}: has node i transmitted yet?
    ever_recv: Optional[jnp.ndarray] = None  # [N, max_deg] or [E] {0,1}


class EdgeCommState(NamedTuple):
    """Per-EDGE transport state, `[*, max_deg, ...]` padded-neighbour layout.

    Slot d of node i is the directed link i -> nbr_idx[i, d]; padding slots
    exist but never fire and never update.  `last_sent` and `ever_delivered`
    are receiver-facing (replicated over pods); the residual, threshold and
    drift-EMA rows are sender-private and shard with their pod.
    """

    last_sent: jnp.ndarray            # [N, E, D] per-link reconstruction ref
    residual: Optional[jnp.ndarray]   # [R, E, ...] per-link EF residual
    threshold: jnp.ndarray            # [R, E] per-link trigger thresholds
    drift_ema: jnp.ndarray            # [R, E] per-link drift EMA (adaptive)
    ever_delivered: jnp.ndarray       # [N, E] {0,1}: link ever delivered?


def _check_wire(wire: str):
    if wire not in WIRES:
        raise ValueError(f"wire must be one of {WIRES}, got {wire!r}")


class GossipTransport:
    """Flatten -> trigger -> encode -> wire -> decode -> unflatten.

    The optional edge-layout kwargs give the per-node transport a per-EDGE
    delivery history (`CommState.ever_recv`) in the engine's bound layout:
    pass `nbr_idx`/`nbr_valid` (the padded `[N, max_deg]` panels) on the
    dense layout, or `edge_src`/`edge_dst` (the CSR directed edge list) on
    the sparse one.  Without either the transport still runs (direct
    construction, the legacy shape) but carries no delivery history —
    `ever_recv` stays None."""

    def __init__(self, config: CommConfig, stacked_params, *,
                 nbr_idx=None, nbr_valid=None, edge_src=None, edge_dst=None):
        self.config = config
        self.codec = config.make_codec()
        mat, self._unflatten = tree_flatten_stacked(stacked_params)
        self.n, self.d = int(mat.shape[0]), int(mat.shape[1])
        # exact serialized payload size for ONE node's transmission
        self.payload_bytes = self.codec.payload_bytes_for(self.d)
        self.dense_bytes = 4 * self.d  # fp32 reference for reduction ratios
        self.wants_rng = (self.codec.needs_rng
                          and getattr(self.codec, "stochastic", True))
        if nbr_idx is not None:
            idx = np.asarray(nbr_idx, np.int64)
            self._recv_idx = jnp.asarray(np.maximum(idx, 0).astype(np.int32))
            self._recv_valid = jnp.asarray(
                np.asarray(nbr_valid, np.float32))
            self._recv_shape = self._recv_idx.shape
            self._edge_src = self._edge_dst = None
        elif edge_src is not None:
            self._edge_src = jnp.asarray(np.asarray(edge_src, np.int32))
            self._edge_dst = jnp.asarray(np.asarray(edge_dst, np.int32))
            self._recv_shape = self._edge_src.shape
            self._recv_idx = self._recv_valid = None
        else:
            self._recv_shape = None
            self._recv_idx = self._recv_valid = None
            self._edge_src = self._edge_dst = None

    def init_state(self, stacked_params) -> CommState:
        mat, _ = tree_flatten_stacked(stacked_params)
        residual = (jax.vmap(self.codec.init_residual)(mat)
                    if self.codec.has_residual else None)
        ever_recv = (jnp.zeros(self._recv_shape, jnp.float32)
                     if self._recv_shape is not None else None)
        # zero reference: the first transmission carries the full model
        # through the codec, so receivers need no out-of-band bootstrap.
        return CommState(last_sent=jnp.zeros_like(mat), residual=residual,
                         ever_sent=jnp.zeros((self.n,), jnp.float32),
                         ever_recv=ever_recv)

    def state_specs(self, shard, rep) -> CommState:
        """The PartitionSpec tree matching init_state's layout: replicated
        receiver-facing caches, sharded sender-private residual rows."""
        return CommState(
            last_sent=rep,
            residual=shard if self.codec.has_residual else None,
            ever_sent=rep,
            ever_recv=rep if self._recv_shape is not None else None)

    def note_delivery(self, state: CommState, delivered) -> CommState:
        """Fold one round's REALIZED deliveries (`[N, max_deg]` or `[E]`
        {0,1} in the bound layout: trigger AND link AND live AND arrival)
        into the per-edge delivery history.  Kept separate from `exchange`
        because only the engine knows the composed delivery mask — the
        transport sees the trigger gate, not the deadline."""
        if state.ever_recv is None:
            return state
        return state._replace(
            ever_recv=jnp.maximum(state.ever_recv, delivered))

    def reset_rows(self, state: CommState, reset,
                   ctx: PodContext = DENSE_CTX) -> CommState:
        """Rows where `reset` ([N] {0,1}) > 0 return to the zero bootstrap
        (reference, residual, ever_sent all cleared) — the defined semantics
        for a device that churned out and rejoined: it is a FRESH device, so
        its receivers' cached reconstruction of it is gone and its next
        transmission carries the full model through delta codecs again.
        (The per-node state conflates the sender reference with every
        receiver's cache, so a reset clears both; the per-edge transport
        resolves them per link — see EdgeGossipTransport.reset_edges.)
        A zero `reset` row is left bit-identical."""
        r = reset > 0
        residual = state.residual
        if residual is not None:
            rr = ctx.rows(reset) > 0
            rb = rr.reshape(rr.shape + (1,) * (residual.ndim - 1))
            residual = jnp.where(rb, 0.0, residual)
        ever_recv = state.ever_recv
        if ever_recv is not None:
            # every edge incident to a reset node (either direction) loses
            # its delivery history: the rejoined device's caches of its
            # peers AND its peers' caches of it are gone.
            if self._recv_idx is not None:
                clear = jnp.maximum(reset[:, None],
                                    reset[self._recv_idx]) * self._recv_valid
            else:
                clear = jnp.maximum(reset[self._edge_src],
                                    reset[self._edge_dst])
            ever_recv = jnp.where(clear > 0, 0.0, ever_recv)
        return CommState(
            last_sent=jnp.where(r[:, None], 0.0, state.last_sent),
            residual=residual,
            ever_sent=jnp.where(r, 0.0, state.ever_sent),
            ever_recv=ever_recv)

    def exchange(self, stacked_params, state: CommState, rng=None,
                 send_mask=None, *, ctx: PodContext = DENSE_CTX,
                 wire: str = "encoded"):
        """One transport round for the caller's block of sender rows.

        Args:
          stacked_params: pytree, leaves [R, ...] — the block's models (all
            N rows on the dense context).
          state: CommState (replicated caches + this block's residual rows).
          rng: PRNG key when the codec wants one — consumed REPLICATED over
            the full node axis and row-sliced, so every block draws the
            same per-node key regardless of where the rows live.
          send_mask: optional [R] {0,1} sender veto regardless of drift (a
            churned-out device transmits nothing and its state freezes).
          ctx: the block's PodContext (see module docstring).
          wire: "encoded" gathers the codec payload (every pod decodes the
            same bytes), "decoded" gathers the reconstructed rows — the
            dense oracle.  Bit-identical by construction.

        Returns (decoded_models, gate_full, new_state):
          decoded_models — pytree with leaves [N, ...]: for each sender the
            model its neighbours reconstruct this round (rows of silent
            nodes hold their previous reconstruction; the aggregation mask
            zeroes them out anyway),
          gate_full — [N] {0,1} who transmitted (replicated),
          new_state — the threaded CommState.
        """
        _check_wire(wire)
        codec = self.codec
        w, _ = tree_flatten_stacked(stacked_params)
        r = int(w.shape[0])
        if self.wants_rng:
            if rng is None:
                raise ValueError(f"codec {codec.name!r} needs an rng key")
            keys = ctx.rows(jax.random.split(rng, self.n))
        else:
            keys = jnp.zeros((r, 2), jnp.uint32)

        last_full = state.last_sent
        last = ctx.rows(last_full)
        gate, _ = drift_gate(w, last, self.config.trigger_threshold)
        if send_mask is not None:
            gate = gate * send_mask
        x = w - last if codec.is_delta else w

        def enc(xi, key, res):
            return codec.encode(xi, rng=key if self.wants_rng else None,
                                residual=res)

        if codec.has_residual:
            payload, new_res = jax.vmap(enc)(x, keys, state.residual)
        else:
            payload, _ = jax.vmap(lambda xi, key: enc(xi, key, None))(x, keys)
            new_res = None

        def dec(p):
            return codec.decode(p, out_size=self.d)

        if wire == "encoded":
            dec_full = jax.vmap(dec)(jax.tree.map(ctx.gather, payload))
        else:
            dec_full = ctx.gather(jax.vmap(dec)(payload))
        gate_full = ctx.gather(gate)

        recon = last_full + dec_full if codec.is_delta else dec_full
        new_last = jnp.where(gate_full[:, None] > 0, recon, last_full)
        if codec.has_residual:
            # a silent node keeps accumulating: its un-flushed residual
            # stays put until the trigger fires again.
            keep = gate.reshape((r,) + (1,) * (new_res.ndim - 1)) > 0
            new_res = jnp.where(keep, new_res, state.residual)
        new_state = CommState(
            last_sent=new_last, residual=new_res,
            ever_sent=jnp.maximum(state.ever_sent, gate_full),
            ever_recv=state.ever_recv)  # the engine folds realized
        # deliveries in afterwards (note_delivery) — exchange cannot know
        # the composed link x live x arrival mask.
        return self._unflatten(new_last), gate_full, new_state


class EdgeGossipTransport:
    """Per-edge transport: one (reference, residual, threshold) per link.

    Construction takes the graph's padded-neighbour layout (`nbr_idx`
    [N, E] int with -1 padding, `nbr_valid` [N, E] {0,1}) because per-edge
    state is keyed by (sender, slot) and the receiver-side gather needs the
    *reverse* slot map: receiver r hearing neighbour j at slot e reads
    sender j's edge state at slot rev[r, e] (the slot of r in j's list).
    The gather itself — receiver rows out of the flattened [N*E, D]
    per-link reference table — runs through the `repro.kernels` gather-rows
    Pallas kernel on every backend (a pure copy, bitwise identical to fancy
    indexing).
    """

    def __init__(self, config: CommConfig, stacked_params,
                 nbr_idx: np.ndarray, nbr_valid: np.ndarray):
        self.config = config
        self.codec = config.make_codec()
        mat, self._unflatten = tree_flatten_stacked(stacked_params)
        self.n, self.d = int(mat.shape[0]), int(mat.shape[1])
        self.e = int(nbr_idx.shape[1])
        self.payload_bytes = self.codec.payload_bytes_for(self.d)
        self.dense_bytes = 4 * self.d
        self.wants_rng = (self.codec.needs_rng
                          and getattr(self.codec, "stochastic", True))

        idx = np.asarray(nbr_idx, np.int64)
        valid = np.asarray(nbr_valid, np.float32)
        # reverse slot map: rev[r, e] = d s.t. nbr_idx[j, d] == r for
        # j = nbr_idx[r, e] (exists for every valid slot: undirected graph).
        rev = np.zeros((self.n, self.e), np.int32)
        for r in range(self.n):
            for e in range(self.e):
                j = idx[r, e]
                if j < 0:
                    continue
                (slots,) = np.nonzero(idx[j] == r)
                if slots.size == 0:
                    raise ValueError(
                        f"neighbour layout not symmetric: {r} lists {j} but "
                        f"{j} does not list {r} — per-edge state needs an "
                        f"undirected graph")
                rev[r, e] = int(slots[0])
        self.nbr_idx = jnp.asarray(np.maximum(idx, 0).astype(np.int32))
        self.nbr_valid = jnp.asarray(valid)
        self.rev_slot = jnp.asarray(rev)
        self.num_edges = float(valid.sum())  # directed edge count
        # canonical CSR directed-edge id of the link (i -> j) at sender slot
        # (i, d): receiver j's row offset plus i's position among j's senders
        # (ascending — the padded lists are sorted, so rev IS that position).
        # This is the exact enumeration SparseTopology sorts its edge list
        # by, which is what lets the sparse per-edge transport consume the
        # identical per-edge rng stream.  Padding slots alias edge 0; their
        # keys are drawn but never gate an update.
        deg = valid.sum(axis=1).astype(np.int64)
        offsets = np.concatenate([np.zeros(1, np.int64), np.cumsum(deg)])
        self.num_directed = int(deg.sum())
        self.edge_id = jnp.asarray(
            (offsets[np.maximum(idx, 0)] + rev).astype(np.int32))
        # the threshold an edge (re)starts from: the scalar for the fixed
        # policy, the always-send bootstrap for the adaptive one (shared by
        # init_state and reset_edges so a rejoined device re-bootstraps
        # exactly like a fresh one)
        self.thr0 = (config.trigger_threshold if config.policy == "fixed"
                     else 0.0)

    def init_state(self, stacked_params) -> EdgeCommState:
        mat, _ = tree_flatten_stacked(stacked_params)
        zeros_edges = jnp.zeros((self.n, self.e, self.d), jnp.float32)
        if self.codec.has_residual:
            res0 = self.codec.init_residual(mat[0])
            residual = jnp.zeros((self.n, self.e) + res0.shape, jnp.float32)
        else:
            residual = None
        # fixed policy: the scalar threshold on every edge; adaptive: start
        # at 0 (always-send bootstrap — the first payloads carry the full
        # model through delta codecs) and let the controller raise it.
        return EdgeCommState(
            last_sent=zeros_edges,
            residual=residual,
            threshold=jnp.full((self.n, self.e), self.thr0, jnp.float32),
            drift_ema=jnp.zeros((self.n, self.e), jnp.float32),
            ever_delivered=jnp.zeros((self.n, self.e), jnp.float32),
        )

    def state_specs(self, shard, rep) -> EdgeCommState:
        """The PartitionSpec tree matching init_state's layout: replicated
        receiver-facing caches, sharded sender-private controller rows."""
        return EdgeCommState(
            last_sent=rep,
            residual=shard if self.codec.has_residual else None,
            threshold=shard,
            drift_ema=shard,
            ever_delivered=rep)

    def reset_edges(self, state: EdgeCommState, reset,
                    ctx: PodContext = DENSE_CTX) -> EdgeCommState:
        """Per-link state on edges where `reset` [N, E] > 0 returns to its
        init_state values — the defined carry/reset semantics for edges
        whose endpoint churned out and REJOINED: the rejoined device is a
        fresh device, so the link's reconstruction reference, error-feedback
        residual, adaptive threshold/EMA and delivery history all restart
        (the first payload after a reset carries the full model through
        delta codecs again, and `on_silence="stale"` masks the link until
        that redelivery because `ever_delivered` is cleared).  An edge that
        merely DISAPPEARS (dropout / a Gilbert–Elliott burst / a rewiring
        phase) is NOT reset: its state freezes bit-identically — the
        existing failed-link semantics — and transmission resumes against
        the frozen reference when the edge returns.  Zero-`reset` edges are
        left bit-identical."""
        r = reset > 0
        rr = ctx.rows(reset) > 0
        residual = state.residual
        if residual is not None:
            rb = rr.reshape(rr.shape + (1,) * (residual.ndim - 2))
            residual = jnp.where(rb, 0.0, residual)
        return EdgeCommState(
            last_sent=jnp.where(r[:, :, None], 0.0, state.last_sent),
            residual=residual,
            threshold=jnp.where(rr, self.thr0, state.threshold),
            drift_ema=jnp.where(rr, 0.0, state.drift_ema),
            ever_delivered=jnp.where(r, 0.0, state.ever_delivered),
        )

    def _swap_layout(self, arr):
        """Swap a full [N, E, ...] array between the sender and receiver
        edge layouts (an involution: entry (i, e) of the result reads the
        other endpoint's slot for the same directed link, nbr_idx[i, e] at
        rev_slot[i, e]).  Receiver->sender: link_mask[r, e] becomes the
        sender-side ack for i -> nbr_idx[i, e].  Sender->receiver: edge
        state (i, d) lands at the slot where receiver r hears i.  Only
        legal on replicated quantities — the swap crosses rows."""
        return arr[self.nbr_idx, self.rev_slot]

    def recv_layout(self, arr):
        """Receiver-layout view of a full sender-layout [N, E] panel,
        zeroed on padding slots: entry (r, e) is the sender's value for
        the directed link (nbr_idx[r, e] -> r).  Padding slots of the swap
        alias edge (0, 0), so the valid mask is applied here — this is the
        orientation the telemetry channels (repro.obs) observe fired gates
        in, matching the per-node transport's receiver panel and the
        canonical (dst, src) edge order after the panel flatten."""
        return self._swap_layout(arr) * self.nbr_valid

    def _gather_receiver_rows(self, new_last_full, rows):
        """The reverse-slot gather: receiver row r's slot e reads sender
        nbr_idx[r, e]'s reference at slot rev_slot[r, e] out of the full
        per-link table — the gather-rows Pallas kernel over the flattened
        [N*E, D] view (a pure copy; bitwise identical to fancy indexing)."""
        from repro.kernels.ops import gather_rows

        flat_idx = (rows(self.nbr_idx) * self.e + rows(self.rev_slot))
        r = int(flat_idx.shape[0])
        gathered = gather_rows(new_last_full.reshape(self.n * self.e, self.d),
                               flat_idx.reshape(-1))
        gathered = self._unflatten(gathered)
        return jax.tree.map(
            lambda l: l.reshape((r, self.e) + l.shape[1:]), gathered)

    def exchange(self, stacked_params, state: EdgeCommState, link_mask,
                 rng=None, live=None, reset=None, *,
                 ctx: PodContext = DENSE_CTX, wire: str = "encoded"):
        """One per-edge transport round for the caller's block of rows.

        Args:
          stacked_params: pytree, leaves [R, ...] — the block's models (all
            N rows on the dense context).
          state: EdgeCommState (replicated caches + the block's controller
            rows).
          link_mask: FULL [N, E] receiver-layout exogenous link mask (1 =
            the (nbr_idx[r, e] -> r) link is up; includes neighbour
            validity and, under a dynamics process, the round's live-edge
            mask).  Always full-axis: the link-layer ack reaches the sender
            through the layout swap, which crosses rows.
          rng: PRNG key when the codec wants one (consumed replicated over
            the full edge set and row-sliced — see GossipTransport).
          live: optional FULL [N, E] {0,1} SYMMETRIC live-edge mask from a
            `repro.dynamics.GraphProcess` (symmetry makes the sender and
            receiver layouts coincide).  A dead edge does not exist this
            round: its sender cannot fire on it (no drift gate, no bytes)
            and its adaptive threshold/EMA freeze — unlike a `link_mask`
            failure, which is a LOSS the sender pays for.
          reset: optional FULL [N, E] {0,1} edges whose per-link state
            returns to bootstrap BEFORE this round's drift is measured (see
            reset_edges; the engine raises it on every edge incident to a
            node that rejoined after churn).
          ctx: the block's PodContext (see module docstring).
          wire: "encoded" gathers the codec payload, "decoded" the
            reconstructions — bit-identical, see GossipTransport.exchange.

        Returns (gathered, agg_mask, gate_full, new_state):
          gathered — pytree with leaves [R, E, ...]: slot e of block row r
            holds r's CURRENT reconstruction of neighbour nbr_idx[r, e]
            (fresh if the edge delivered this round, the per-link stale
            cache otherwise — receivers always have their own cache),
          agg_mask — [R, E] receiver-layout aggregation mask per the
            on_silence policy,
          gate_full — [N, E] sender-layout {0,1} fired edges, replicated
            (bytes accounting),
          new_state — the threaded EdgeCommState.
        """
        _check_wire(wire)
        codec, cfg = self.codec, self.config
        rows = ctx.rows
        w, _ = tree_flatten_stacked(stacked_params)
        r = int(w.shape[0])
        if reset is not None:
            state = self.reset_edges(state, reset, ctx=ctx)
        # a dynamics-dead edge is excluded from validity for the round:
        # no gate, no bytes, frozen controller state.
        valid_full = (self.nbr_valid if live is None
                      else self.nbr_valid * live)
        last_full = state.last_sent
        last = rows(last_full)
        gate, drift = edge_drift_gate(w, last, state.threshold,
                                      rows(valid_full))
        # link-layer ack: a payload advances its edge's state only if the
        # edge fired AND the link stayed up (sender layout; the swap crosses
        # rows, so it runs on the replicated full mask).
        sender_link_full = self._swap_layout(link_mask)
        delivered = gate * rows(sender_link_full)

        x = (w[:, None, :] - last if codec.is_delta
             else jnp.broadcast_to(w[:, None, :], last.shape))
        if self.wants_rng:
            if rng is None:
                raise ValueError(f"codec {codec.name!r} needs an rng key")
            # one key per CANONICAL directed edge (CSR id), not per padded
            # slot — the sparse per-edge transport indexes the same split,
            # so the two layouts' stochastic codecs agree bit-for-bit.
            keys = rows(jax.random.split(
                rng, max(self.num_directed, 1))[self.edge_id])
        else:
            keys = jnp.zeros((r, self.e, 2), jnp.uint32)

        def enc(xi, key, res):
            return codec.encode(xi, rng=key if self.wants_rng else None,
                                residual=res)

        vv = lambda f: jax.vmap(jax.vmap(f))  # noqa: E731
        if codec.has_residual:
            payload, enc_res = vv(enc)(x, keys, state.residual)
        else:
            payload, _ = vv(lambda xi, key: enc(xi, key, None))(x, keys)
            enc_res = None

        def dec(p):
            return codec.decode(p, out_size=self.d)

        if wire == "encoded":
            dec_full = vv(dec)(jax.tree.map(ctx.gather, payload))
        else:
            dec_full = ctx.gather(vv(dec)(payload))
        gate_full = ctx.gather(gate)
        delivered_full = gate_full * sender_link_full

        recon = last_full + dec_full if codec.is_delta else dec_full
        new_last = jnp.where(delivered_full[:, :, None] > 0, recon, last_full)
        if codec.has_residual:
            # the EF residual tracks DELIVERED information only: a dropped
            # or silent link keeps its residual bit-identical (the pending
            # drift is recomputed from the unchanged reference next round).
            keep = delivered.reshape(
                (r, self.e) + (1,) * (enc_res.ndim - 2)) > 0
            new_res = jnp.where(keep, enc_res, state.residual)
        else:
            new_res = None

        if cfg.policy == "adaptive":
            new_thr, new_ema = adaptive_threshold_update(
                state.threshold, state.drift_ema, drift, gate,
                rows(valid_full), target=cfg.target_trigger,
                ema_beta=cfg.drift_ema_beta, rate=cfg.threshold_rate)
        else:
            new_thr, new_ema = state.threshold, state.drift_ema
        ever = jnp.maximum(state.ever_delivered, delivered_full)
        new_state = EdgeCommState(last_sent=new_last, residual=new_res,
                                  threshold=new_thr, drift_ema=new_ema,
                                  ever_delivered=ever)

        # receiver view: slot e of block row r is sender j's edge state
        # toward r — the reverse-slot gather out of the replicated table.
        gathered = self._gather_receiver_rows(new_last, rows)
        if cfg.on_silence == "drop":
            agg_mask = rows(link_mask * self._swap_layout(gate_full))
        else:
            # stale: aggregate the per-link cache at full weight, masking
            # only links that never delivered (cache = zero bootstrap);
            # exogenous failures still drop (a loss, not a decision).
            agg_mask = rows(link_mask * self._swap_layout(ever))
        return gathered, agg_mask, gate_full, new_state


class SparseEdgeCommState(NamedTuple):
    """Per-edge transport state in the flat [E] CSR edge-list layout.

    Entry e is the directed link ``edge_src[e] -> edge_dst[e]`` of a
    :class:`~repro.graphs.sparse.SparseTopology` — the dense layout's
    `[N, max_deg]` panels with the padding removed.  All fields are
    replicated over pods: the edge axis does not tile the node-axis pod
    mesh (per-pod edge BANKS over the graph cut are the halo-exchange
    follow-up tracked in ROADMAP.md)."""

    last_sent: jnp.ndarray            # [E, D] per-link reconstruction ref
    residual: Optional[jnp.ndarray]   # [E, ...] per-link EF residual
    threshold: jnp.ndarray            # [E] per-link trigger thresholds
    drift_ema: jnp.ndarray            # [E] per-link drift EMA (adaptive)
    ever_delivered: jnp.ndarray       # [E] {0,1}: link ever delivered?


class SparseEdgeGossipTransport:
    """Per-edge transport over a flat CSR edge list — no layout swap at all.

    The dense :class:`EdgeGossipTransport` keys state by (sender, slot) and
    needs TWO index gymnastics per round: the `rev_slot` layout swap (sender
    acks from the receiver-layout link mask) and the reverse-slot gather
    (receivers read each sender's per-link reference).  In the CSR edge
    list, a directed edge id is simultaneously the sender-layout AND the
    receiver-layout address of the same link: the gate, the delivery, the
    aggregation mask and the reconstruction reference of edge e all live at
    position e, and receiver i's delivered neighbour models are exactly
    `last_sent[row_offsets[i]:row_offsets[i+1]]` — the CSR row the
    SparseNeighborhood buckets already enumerate (`WidthBucket.epos`).
    `rev_edge` (the permutation pairing e with its opposite direction) is
    kept for state introspection — e.g. asserting a churn reset cleared
    BOTH directed records of a link — not for the data path.

    Bit-parity with the dense twin is by construction: the per-edge drift
    gate, the Robbins-Monro controller and the codec are the same
    elementwise programs, the rng stream is keyed by the same canonical CSR
    edge id, and every mask composition is a product of exact {0,1} floats.

    The model rows are the only cross-pod movement (`ctx.gather` of the
    [R, D] block); encode/decode then runs replicated over the full edge
    axis, so the `wire` choice does not change what crosses pods here —
    accepted for signature parity with the dense transport."""

    def __init__(self, config: CommConfig, stacked_params, st):
        from repro.graphs.sparse import rev_edge_permutation

        self.config = config
        self.codec = config.make_codec()
        mat, self._unflatten = tree_flatten_stacked(stacked_params)
        self.n, self.d = int(mat.shape[0]), int(mat.shape[1])
        self.e_dir = int(st.num_directed)
        self.payload_bytes = self.codec.payload_bytes_for(self.d)
        self.dense_bytes = 4 * self.d
        self.wants_rng = (self.codec.needs_rng
                          and getattr(self.codec, "stochastic", True))
        self.edge_src = jnp.asarray(st.edge_src.astype(np.int32))
        self.edge_dst = jnp.asarray(st.edge_dst.astype(np.int32))
        self.rev_edge = jnp.asarray(rev_edge_permutation(st))
        self.num_edges = float(self.e_dir)  # directed edge count
        # shared (re)start threshold — see EdgeGossipTransport.thr0
        self.thr0 = (config.trigger_threshold if config.policy == "fixed"
                     else 0.0)

    def init_state(self, stacked_params) -> SparseEdgeCommState:
        mat, _ = tree_flatten_stacked(stacked_params)
        if self.codec.has_residual:
            res0 = self.codec.init_residual(mat[0])
            residual = jnp.zeros((self.e_dir,) + res0.shape, jnp.float32)
        else:
            residual = None
        return SparseEdgeCommState(
            last_sent=jnp.zeros((self.e_dir, self.d), jnp.float32),
            residual=residual,
            threshold=jnp.full((self.e_dir,), self.thr0, jnp.float32),
            drift_ema=jnp.zeros((self.e_dir,), jnp.float32),
            ever_delivered=jnp.zeros((self.e_dir,), jnp.float32),
        )

    def state_specs(self, shard, rep) -> SparseEdgeCommState:
        """All replicated: the edge axis does not tile the node-axis pod
        mesh, and every pod recomputes the full-edge update from the
        gathered model rows deterministically (so replicas cannot
        diverge).  Sharding the edge bank by pod-incident cut is the
        halo-exchange follow-up in ROADMAP.md."""
        del shard
        return SparseEdgeCommState(
            last_sent=rep,
            residual=rep if self.codec.has_residual else None,
            threshold=rep, drift_ema=rep, ever_delivered=rep)

    def reset_edges(self, state: SparseEdgeCommState, reset,
                    ctx: PodContext = DENSE_CTX) -> SparseEdgeCommState:
        """Edges where `reset` [E] > 0 return to their init_state values —
        the same rejoin semantics as EdgeGossipTransport.reset_edges
        (reference, residual, threshold/EMA and delivery history restart;
        zero-`reset` edges stay bit-identical).  The engine raises reset on
        BOTH directed records of every link incident to a rejoined node
        (`max(rejoined[edge_src], rejoined[edge_dst])` is symmetric under
        `rev_edge` by construction)."""
        del ctx  # state is replicated; kept for signature parity
        r = reset > 0
        residual = state.residual
        if residual is not None:
            rb = r.reshape(r.shape + (1,) * (residual.ndim - 1))
            residual = jnp.where(rb, 0.0, residual)
        return SparseEdgeCommState(
            last_sent=jnp.where(r[:, None], 0.0, state.last_sent),
            residual=residual,
            threshold=jnp.where(r, self.thr0, state.threshold),
            drift_ema=jnp.where(r, 0.0, state.drift_ema),
            ever_delivered=jnp.where(r, 0.0, state.ever_delivered),
        )

    def exchange(self, stacked_params, state: SparseEdgeCommState, link_mask,
                 rng=None, live=None, reset=None, *,
                 ctx: PodContext = DENSE_CTX, wire: str = "encoded"):
        """One per-edge transport round over the flat edge list.

        Args:
          stacked_params: pytree, leaves [R, ...] — the block's models (all
            N rows on the dense context).
          state: SparseEdgeCommState (replicated).
          link_mask: [E] {0,1} exogenous per-directed-edge link mask (the
            engine folds participation draws and, under dynamics, the live
            mask into it).
          rng: PRNG key when the codec wants one — split over the canonical
            directed edge ids, the SAME stream the dense per-edge transport
            indexes through its slot panel.
          live: optional [E] {0,1} live-edge mask from a GraphProcess: a
            dead edge does not exist this round (no gate, no bytes, frozen
            controller state), unlike a `link_mask` failure the sender pays
            for.
          reset: optional [E] {0,1} — edges rebooted BEFORE this round's
            drift is measured (see reset_edges).
          ctx / wire: see class docstring.

        Returns (edge_table, agg_mask, gate, new_state):
          edge_table — [E, D] fp32: entry e is what edge e's receiver
            currently holds for its sender (fresh if delivered this round,
            the per-link stale cache otherwise).  Feed it to
            SparseNeighborhood(edge_table=...) — receiver rows address it
            by CSR edge position, no gather needed.
          agg_mask — [E] receiver aggregation mask per on_silence,
          gate — [E] {0,1} fired edges (bytes accounting),
          new_state — the threaded SparseEdgeCommState.
        """
        _check_wire(wire)
        codec, cfg = self.codec, self.config
        w, _ = tree_flatten_stacked(stacked_params)
        w_full = ctx.gather(w)  # [N, D] — the only cross-pod movement
        if reset is not None:
            state = self.reset_edges(state, reset, ctx=ctx)
        valid = (jnp.ones((self.e_dir,), jnp.float32) if live is None
                 else live)
        last = state.last_sent
        w_edge = w_full[self.edge_src]  # [E, D] each edge's sender row
        # the same elementwise gate as the dense layout, on [E, 1] panels
        g2, d2 = edge_drift_gate(w_edge, last[:, None, :],
                                 state.threshold[:, None], valid[:, None])
        gate, drift = g2[:, 0], d2[:, 0]
        # link-layer ack — the edge id IS the sender-layout address, so the
        # dense path's rev_slot swap is the identity here.
        delivered = gate * link_mask

        x = w_edge - last if codec.is_delta else w_edge
        if self.wants_rng:
            if rng is None:
                raise ValueError(f"codec {codec.name!r} needs an rng key")
            keys = jax.random.split(rng, max(self.e_dir, 1))
        else:
            keys = jnp.zeros((self.e_dir, 2), jnp.uint32)

        def enc(xi, key, res):
            return codec.encode(xi, rng=key if self.wants_rng else None,
                                residual=res)

        if codec.has_residual:
            payload, enc_res = jax.vmap(enc)(x, keys, state.residual)
        else:
            payload, _ = jax.vmap(lambda xi, key: enc(xi, key, None))(x, keys)
            enc_res = None

        dec_all = jax.vmap(lambda p: codec.decode(p, out_size=self.d))(payload)
        recon = last + dec_all if codec.is_delta else dec_all
        new_last = jnp.where(delivered[:, None] > 0, recon, last)
        if codec.has_residual:
            # EF residual tracks DELIVERED information only (see the dense
            # twin): dropped/silent links keep their residual bit-identical.
            keep = delivered.reshape(
                (self.e_dir,) + (1,) * (enc_res.ndim - 1)) > 0
            new_res = jnp.where(keep, enc_res, state.residual)
        else:
            new_res = None

        if cfg.policy == "adaptive":
            new_thr, new_ema = adaptive_threshold_update(
                state.threshold, state.drift_ema, drift, gate, valid,
                target=cfg.target_trigger, ema_beta=cfg.drift_ema_beta,
                rate=cfg.threshold_rate)
        else:
            new_thr, new_ema = state.threshold, state.drift_ema
        ever = jnp.maximum(state.ever_delivered, delivered)
        new_state = SparseEdgeCommState(
            last_sent=new_last, residual=new_res, threshold=new_thr,
            drift_ema=new_ema, ever_delivered=ever)

        if cfg.on_silence == "drop":
            agg_mask = link_mask * gate
        else:
            agg_mask = link_mask * ever
        return new_last, agg_mask, gate, new_state


def codec_roundtrip_stacked(codec: Codec, stacked, rng=None):
    """Reference-free encode->decode of stacked [N, ...] models.

    The dist-layer rounds (repro.dist.dfl_step) use this to model wire
    effects without transport state: delta codecs compress against the
    implicit zero reference (= the full model goes through the codec).
    Returns the decoded stacked pytree (leaves cast back to input dtypes).
    """
    w, unflatten = tree_flatten_stacked(stacked)
    n, d = int(w.shape[0]), int(w.shape[1])
    wants_rng = codec.needs_rng and getattr(codec, "stochastic", True) \
        and rng is not None
    keys = (jax.random.split(rng, n) if wants_rng
            else jnp.zeros((n, 2), jnp.uint32))

    def enc_dec(xi, key):
        payload, _ = codec.encode(xi, rng=key if wants_rng else None)
        return codec.decode(payload, out_size=d)

    return unflatten(jax.vmap(enc_dec)(w, keys))
