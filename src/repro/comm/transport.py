"""Gossip transport: codecs x event trigger x exact bytes-on-wire accounting.

Sits between local training and aggregation.  Each round every node:

  1. measures its drift ||w_i - w^last_sent|| and decides whether to
     transmit (trigger module; threshold 0 = always send),
  2. if transmitting, encodes its payload — delta codecs (int8, top-k)
     compress the drift plus the carried error-feedback residual,
     dense codecs (fp32, bf16) the model itself,
  3. receivers dequantize first and aggregate second, so DecDiff's Eq. 5-6
     semantics are untouched: the aggregator simply sees ŵ_j instead of w_j.

Two transports share the codecs and that round shape:

`GossipTransport` — per-NODE state (the PR-2 broadcast model): one
`last_sent[j]` [N, D] doubles as sender j's trigger reference AND every
receiver's cached copy of j's reconstruction, one shared residual per node.
A node encodes once and broadcasts the same payload on all its edges.

`EdgeGossipTransport` — per-EDGE state in the padded-neighbour layout
(`[N, max_deg, ...]`): each directed link (i -> j) keeps its own
`last_sent[i, d]`, error-feedback `residual[i, d]`, adaptive `threshold
[i, d]` and drift EMA, where d is j's slot in i's neighbour list.  The
payload for each edge is encoded against *that edge's* reference, and —
the point of the exercise — state only advances on links that actually
delivered: a Bernoulli link failure on (i, j) leaves both (i, j)'s and
(i, k)'s residuals bit-identical to their no-traffic values instead of
poisoning a shared top-k error-feedback buffer for every neighbour.  The
receiver-side cache interpretation is exact: `last_sent[i, d]` IS what the
receiver on that edge holds (the per-node transport loses this the moment
one link drops), so "stale" aggregation serves genuinely per-link staleness.
Cost: encode runs per edge, not per node, and state is max_deg x larger —
the price of personalized links (the wire bytes are identical when all
edges of a node fire together).

Thresholds are either `fixed` (the scalar `trigger_threshold` on every
edge) or `adaptive`: a per-edge Robbins-Monro controller tracks the
(1 - target_trigger)-quantile of that edge's drift so each link's long-run
triggered fraction converges to `target_trigger` (see trigger.py).

Accounting is exact and static: `payload_bytes` is the serialized size of
one payload (codec.payload_bytes_for).  Bytes-on-wire per round is
payload_bytes x (number of fired edges) — per-node: Σ_i gate_i x outdeg_i;
per-edge: Σ_ij gate_ij.  Failed links still burn the sender's bytes (the
sender cannot know *at send time*), they just deliver nothing; the per-edge
transport additionally models a link-layer ack, which is how it knows not
to advance a dropped link's reference.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codecs import Codec, make_codec
from repro.comm.trigger import (
    adaptive_threshold_update,
    drift_gate,
    edge_drift_gate,
)
from repro.utils.pytree import tree_flatten_stacked

POLICIES = ("fixed", "adaptive")


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Transport knobs, carried on SimulatorConfig.comm.

    codec: "fp32" | "bf16" | "int8" | "topk".
    trigger_threshold: L2 drift below which a sender stays silent (0 = the
      legacy always-send behaviour, bit-for-bit).  Used by the "fixed"
      policy; the "adaptive" policy learns per-edge thresholds instead.
    policy: "fixed" (one scalar threshold everywhere) or "adaptive"
      (per-edge drift-rate-controlled thresholds; implies per-edge state).
    per_edge: keep transport state per directed link `[N, max_deg, ...]`
      instead of per node — independent error-feedback residuals and
      staleness per link, surviving Bernoulli link failures independently.
      Forced on by policy="adaptive".
    target_trigger: adaptive policy's per-edge long-run triggered fraction
      target, in (0, 1].
    drift_ema_beta: decay of the per-edge drift EMA that scales the
      adaptive controller's step.
    threshold_rate: adaptive controller gain.
    topk_ratio: fraction of coordinates the top-k codec ships.
    topk_momentum: momentum-masked top-k selection (0 = plain magnitude
      top-k); see codecs.TopKCodec.
    stochastic: int8 rounding mode (True = unbiased stochastic rounding;
      False = deterministic nearest, needed for vmap/shard_map equality).
    on_silence: what receivers aggregate for a neighbour whose trigger did
      not fire.  "stale" (default, the Zehtabi et al. event-triggered DFL
      semantics): its cached last-transmitted model — silence means "use
      what you have", costs nothing, and degrades convergence more
      gracefully than dropping (staleness still drags; see the BENCH_comm
      frontier for the measured accuracy-vs-bytes tradeoff per threshold).
      "drop": mask the neighbour out entirely, like a failed link.
      Exogenous link failures always drop (a loss, not a decision).
    """

    codec: str = "fp32"
    trigger_threshold: float = 0.0
    policy: str = "fixed"
    per_edge: bool = False
    target_trigger: float = 0.5
    drift_ema_beta: float = 0.9
    threshold_rate: float = 0.5
    topk_ratio: float = 0.01
    topk_momentum: float = 0.0
    stochastic: bool = True
    on_silence: str = "stale"

    def __post_init__(self):
        if self.on_silence not in ("stale", "drop"):
            raise ValueError(f"on_silence must be 'stale' or 'drop', "
                             f"got {self.on_silence!r}")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {self.policy!r}")
        if self.policy == "adaptive" and not (0.0 < self.target_trigger <= 1.0):
            raise ValueError(f"target_trigger must be in (0, 1], "
                             f"got {self.target_trigger}")

    @property
    def use_per_edge(self) -> bool:
        """Per-edge state is explicit (`per_edge`) or implied by the
        adaptive policy (per-edge thresholds need per-edge references)."""
        return self.per_edge or self.policy == "adaptive"

    def make_codec(self) -> Codec:
        kwargs = {}
        if self.codec == "topk":
            kwargs["ratio"] = self.topk_ratio
            if self.topk_momentum > 0:
                kwargs["momentum"] = self.topk_momentum
        if self.codec == "int8":
            kwargs["stochastic"] = self.stochastic
        return make_codec(self.codec, **kwargs)


class CommState(NamedTuple):
    """Per-node transport state, threaded through the jitted round."""

    last_sent: jnp.ndarray            # [N, D] last reconstruction on the wire
    residual: Optional[jnp.ndarray]   # [N, ...] EF residual (None if stateless)
    ever_sent: jnp.ndarray            # [N] {0,1}: has node i transmitted yet?


class EdgeCommState(NamedTuple):
    """Per-EDGE transport state, `[N, max_deg, ...]` padded-neighbour layout.

    Slot d of node i is the directed link i -> nbr_idx[i, d]; padding slots
    exist but never fire and never update.
    """

    last_sent: jnp.ndarray            # [N, E, D] per-link reconstruction ref
    residual: Optional[jnp.ndarray]   # [N, E, ...] per-link EF residual
    threshold: jnp.ndarray            # [N, E] per-link trigger thresholds
    drift_ema: jnp.ndarray            # [N, E] per-link drift EMA (adaptive)
    ever_delivered: jnp.ndarray       # [N, E] {0,1}: link ever delivered?


class GossipTransport:
    """Flatten -> trigger -> encode -> decode -> unflatten, vmapped over N."""

    def __init__(self, config: CommConfig, stacked_params):
        self.config = config
        self.codec = config.make_codec()
        mat, self._unflatten = tree_flatten_stacked(stacked_params)
        self.n, self.d = int(mat.shape[0]), int(mat.shape[1])
        # exact serialized payload size for ONE node's transmission
        self.payload_bytes = self.codec.payload_bytes_for(self.d)
        self.dense_bytes = 4 * self.d  # fp32 reference for reduction ratios
        self.wants_rng = (self.codec.needs_rng
                          and getattr(self.codec, "stochastic", True))

    def init_state(self, stacked_params) -> CommState:
        mat, _ = tree_flatten_stacked(stacked_params)
        residual = (jax.vmap(self.codec.init_residual)(mat)
                    if self.codec.has_residual else None)
        # zero reference: the first transmission carries the full model
        # through the codec, so receivers need no out-of-band bootstrap.
        return CommState(last_sent=jnp.zeros_like(mat), residual=residual,
                         ever_sent=jnp.zeros((self.n,), jnp.float32))

    def reset_rows(self, state: CommState, reset) -> CommState:
        """Rows where `reset` > 0 return to the zero bootstrap (reference,
        residual, ever_sent all cleared) — the defined semantics for a
        device that churned out and rejoined: it is a FRESH device, so its
        receivers' cached reconstruction of it is gone and its next
        transmission carries the full model through delta codecs again.
        (The per-node state conflates the sender reference with every
        receiver's cache, so a reset clears both; the per-edge transport
        resolves them per link — see EdgeGossipTransport.reset_edges.)
        A zero `reset` row is left bit-identical."""
        r = reset > 0
        residual = state.residual
        if residual is not None:
            rb = r.reshape(r.shape + (1,) * (residual.ndim - 1))
            residual = jnp.where(rb, 0.0, residual)
        return CommState(
            last_sent=jnp.where(r[:, None], 0.0, state.last_sent),
            residual=residual,
            ever_sent=jnp.where(r, 0.0, state.ever_sent))

    def exchange_rows(self, w, state: CommState, keys, send_mask=None):
        """The per-row transport math for an arbitrary block of senders.

        `w` [R, D] flat models, `state` the block's CommState rows, `keys`
        [R, 2] codec keys (ignored unless the codec wants rng).
        `send_mask` [R] {0,1} optionally vetoes senders regardless of drift
        (a churned-out device transmits nothing and its state freezes).
        Returns (new_last [R, D], gate [R], new_state).  `exchange` is this
        over the full node axis; the engine's shard_map backend calls it per
        pod block (state rows shard with the nodes) and all_gathers
        `new_last`.
        """
        codec = self.codec
        rows = int(w.shape[0])
        gate, _ = drift_gate(w, state.last_sent, self.config.trigger_threshold)
        if send_mask is not None:
            gate = gate * send_mask

        x = w - state.last_sent if codec.is_delta else w

        def enc_dec(xi, key, res):
            payload, new_res = codec.encode(
                xi, rng=key if self.wants_rng else None, residual=res)
            return codec.decode(payload, out_size=self.d), new_res

        if codec.has_residual:
            dec, new_res = jax.vmap(enc_dec)(x, keys, state.residual)
        else:
            dec, _ = jax.vmap(lambda xi, key: enc_dec(xi, key, None))(x, keys)
            new_res = None

        recon = state.last_sent + dec if codec.is_delta else dec
        sent = gate[:, None] > 0
        new_last = jnp.where(sent, recon, state.last_sent)
        if codec.has_residual:
            # a silent node keeps accumulating: its un-flushed residual
            # stays put until the trigger fires again.
            keep = gate.reshape((rows,) + (1,) * (new_res.ndim - 1)) > 0
            new_res = jnp.where(keep, new_res, state.residual)
        new_state = CommState(last_sent=new_last, residual=new_res,
                              ever_sent=jnp.maximum(state.ever_sent, gate))
        return new_last, gate, new_state

    def exchange(self, stacked_params, state: CommState, rng=None,
                 send_mask=None):
        """One transport round for all nodes at once.

        Returns (decoded_models, gate, new_state):
          decoded_models — pytree with leaves [N, ...]: for each sender the
            model its neighbours reconstruct this round (rows of silent
            nodes hold their previous reconstruction; the aggregation mask
            zeroes them out anyway),
          gate — [N] {0,1} who transmitted,
          new_state — the threaded CommState.
        `send_mask` [N] optionally vetoes senders (see exchange_rows).
        """
        w, _ = tree_flatten_stacked(stacked_params)
        if self.wants_rng:
            if rng is None:
                raise ValueError(f"codec {self.codec.name!r} needs an rng key")
            keys = jax.random.split(rng, self.n)
        else:
            keys = jnp.zeros((self.n, 2), jnp.uint32)
        new_last, gate, new_state = self.exchange_rows(w, state, keys,
                                                       send_mask=send_mask)
        return self._unflatten(new_last), gate, new_state


class EdgeGossipTransport:
    """Per-edge transport: one (reference, residual, threshold) per link.

    Construction takes the graph's padded-neighbour layout (`nbr_idx`
    [N, E] int with -1 padding, `nbr_valid` [N, E] {0,1}) because per-edge
    state is keyed by (sender, slot) and the receiver-side gather needs the
    *reverse* slot map: receiver r hearing neighbour j at slot e reads
    sender j's edge state at slot rev[r, e] (the slot of r in j's list).
    """

    def __init__(self, config: CommConfig, stacked_params,
                 nbr_idx: np.ndarray, nbr_valid: np.ndarray):
        self.config = config
        self.codec = config.make_codec()
        mat, self._unflatten = tree_flatten_stacked(stacked_params)
        self.n, self.d = int(mat.shape[0]), int(mat.shape[1])
        self.e = int(nbr_idx.shape[1])
        self.payload_bytes = self.codec.payload_bytes_for(self.d)
        self.dense_bytes = 4 * self.d
        self.wants_rng = (self.codec.needs_rng
                          and getattr(self.codec, "stochastic", True))

        idx = np.asarray(nbr_idx, np.int64)
        valid = np.asarray(nbr_valid, np.float32)
        # reverse slot map: rev[r, e] = d s.t. nbr_idx[j, d] == r for
        # j = nbr_idx[r, e] (exists for every valid slot: undirected graph).
        rev = np.zeros((self.n, self.e), np.int32)
        for r in range(self.n):
            for e in range(self.e):
                j = idx[r, e]
                if j < 0:
                    continue
                (slots,) = np.nonzero(idx[j] == r)
                if slots.size == 0:
                    raise ValueError(
                        f"neighbour layout not symmetric: {r} lists {j} but "
                        f"{j} does not list {r} — per-edge state needs an "
                        f"undirected graph")
                rev[r, e] = int(slots[0])
        self.nbr_idx = jnp.asarray(np.maximum(idx, 0).astype(np.int32))
        self.nbr_valid = jnp.asarray(valid)
        self.rev_slot = jnp.asarray(rev)
        self.num_edges = float(valid.sum())  # directed edge count
        # the threshold an edge (re)starts from: the scalar for the fixed
        # policy, the always-send bootstrap for the adaptive one (shared by
        # init_state and reset_edges so a rejoined device re-bootstraps
        # exactly like a fresh one)
        self.thr0 = (config.trigger_threshold if config.policy == "fixed"
                     else 0.0)

    def init_state(self, stacked_params) -> EdgeCommState:
        mat, _ = tree_flatten_stacked(stacked_params)
        zeros_edges = jnp.zeros((self.n, self.e, self.d), jnp.float32)
        if self.codec.has_residual:
            res0 = self.codec.init_residual(mat[0])
            residual = jnp.zeros((self.n, self.e) + res0.shape, jnp.float32)
        else:
            residual = None
        # fixed policy: the scalar threshold on every edge; adaptive: start
        # at 0 (always-send bootstrap — the first payloads carry the full
        # model through delta codecs) and let the controller raise it.
        return EdgeCommState(
            last_sent=zeros_edges,
            residual=residual,
            threshold=jnp.full((self.n, self.e), self.thr0, jnp.float32),
            drift_ema=jnp.zeros((self.n, self.e), jnp.float32),
            ever_delivered=jnp.zeros((self.n, self.e), jnp.float32),
        )

    def reset_edges(self, state: EdgeCommState, reset) -> EdgeCommState:
        """Per-link state on edges where `reset` [N, E] > 0 returns to its
        init_state values — the defined carry/reset semantics for edges
        whose endpoint churned out and REJOINED: the rejoined device is a
        fresh device, so the link's reconstruction reference, error-feedback
        residual, adaptive threshold/EMA and delivery history all restart
        (the first payload after a reset carries the full model through
        delta codecs again, and `on_silence="stale"` masks the link until
        that redelivery because `ever_delivered` is cleared).  An edge that
        merely DISAPPEARS (dropout / a Gilbert–Elliott burst / a rewiring
        phase) is NOT reset: its state freezes bit-identically — the
        existing failed-link semantics — and transmission resumes against
        the frozen reference when the edge returns.  Zero-`reset` edges are
        left bit-identical."""
        r = reset > 0
        residual = state.residual
        if residual is not None:
            rb = r.reshape(r.shape + (1,) * (residual.ndim - 2))
            residual = jnp.where(rb, 0.0, residual)
        return EdgeCommState(
            last_sent=jnp.where(r[:, :, None], 0.0, state.last_sent),
            residual=residual,
            threshold=jnp.where(r, self.thr0, state.threshold),
            drift_ema=jnp.where(r, 0.0, state.drift_ema),
            ever_delivered=jnp.where(r, 0.0, state.ever_delivered),
        )

    def _swap_layout(self, arr):
        """Swap an [N, E, ...] array between the sender and receiver edge
        layouts (an involution: entry (i, e) of the result reads the other
        endpoint's slot for the same directed link, nbr_idx[i, e] at
        rev_slot[i, e]).  Receiver->sender: link_mask[r, e] becomes the
        sender-side ack for i -> nbr_idx[i, e].  Sender->receiver: edge
        state (i, d) lands at the slot where receiver r hears i."""
        return arr[self.nbr_idx, self.rev_slot]

    def exchange(self, stacked_params, state: EdgeCommState, link_mask,
                 rng=None, live=None, reset=None):
        """One per-edge transport round.

        Args:
          stacked_params: pytree, leaves [N, ...].
          state: EdgeCommState.
          link_mask: [N, E] receiver-layout exogenous link mask (1 = the
            (nbr_idx[r, e] -> r) link is up; includes neighbour validity
            and, under a dynamics process, the round's live-edge mask).
          rng: PRNG key when the codec wants one.
          live: optional [N, E] {0,1} SYMMETRIC live-edge mask from a
            `repro.dynamics.GraphProcess` (symmetry makes the sender and
            receiver layouts coincide).  A dead edge does not exist this
            round: its sender cannot fire on it (no drift gate, no bytes)
            and its adaptive threshold/EMA freeze — unlike a `link_mask`
            failure, which is a LOSS the sender pays for.
          reset: optional [N, E] {0,1} edges whose per-link state returns to
            bootstrap BEFORE this round's drift is measured (see
            reset_edges; the engine raises it on every edge incident to a
            node that rejoined after churn).

        Returns (gathered, agg_mask, gate, new_state):
          gathered — pytree with leaves [N, E, ...]: slot e of node r holds
            r's CURRENT reconstruction of neighbour nbr_idx[r, e] (fresh if
            the edge delivered this round, the per-link stale cache
            otherwise — receivers always have their own cache),
          agg_mask — [N, E] receiver-layout aggregation mask per the
            on_silence policy,
          gate — [N, E] sender-layout {0,1} fired edges (bytes accounting),
          new_state — the threaded EdgeCommState.
        """
        codec, cfg = self.codec, self.config
        w, _ = tree_flatten_stacked(stacked_params)
        if reset is not None:
            state = self.reset_edges(state, reset)
        # a dynamics-dead edge is excluded from validity for the round:
        # no gate, no bytes, frozen controller state.
        valid = (self.nbr_valid if live is None else self.nbr_valid * live)
        gate, drift = edge_drift_gate(w, state.last_sent, state.threshold,
                                      valid)
        # link-layer ack: a payload advances its edge's state only if the
        # edge fired AND the link stayed up (sender layout).
        sender_link = self._swap_layout(link_mask)
        delivered = gate * sender_link

        x = (w[:, None, :] - state.last_sent if codec.is_delta
             else jnp.broadcast_to(w[:, None, :], state.last_sent.shape))
        if self.wants_rng:
            if rng is None:
                raise ValueError(f"codec {codec.name!r} needs an rng key")
            keys = jax.random.split(rng, self.n * self.e).reshape(
                self.n, self.e, 2)
        else:
            keys = jnp.zeros((self.n, self.e, 2), jnp.uint32)

        def enc_dec(xi, key, res):
            payload, new_res = codec.encode(
                xi, rng=key if self.wants_rng else None, residual=res)
            return codec.decode(payload, out_size=self.d), new_res

        vv = lambda f: jax.vmap(jax.vmap(f))
        if codec.has_residual:
            dec, enc_res = vv(enc_dec)(x, keys, state.residual)
        else:
            dec, _ = vv(lambda xi, key: enc_dec(xi, key, None))(x, keys)
            enc_res = None

        recon = state.last_sent + dec if codec.is_delta else dec
        adv = delivered[:, :, None] > 0
        new_last = jnp.where(adv, recon, state.last_sent)
        if codec.has_residual:
            # the EF residual tracks DELIVERED information only: a dropped
            # or silent link keeps its residual bit-identical (the pending
            # drift is recomputed from the unchanged reference next round).
            keep = delivered.reshape(
                (self.n, self.e) + (1,) * (enc_res.ndim - 2)) > 0
            new_res = jnp.where(keep, enc_res, state.residual)
        else:
            new_res = None

        if cfg.policy == "adaptive":
            new_thr, new_ema = adaptive_threshold_update(
                state.threshold, state.drift_ema, drift, gate,
                valid, target=cfg.target_trigger,
                ema_beta=cfg.drift_ema_beta, rate=cfg.threshold_rate)
        else:
            new_thr, new_ema = state.threshold, state.drift_ema
        ever = jnp.maximum(state.ever_delivered, delivered)
        new_state = EdgeCommState(last_sent=new_last, residual=new_res,
                                  threshold=new_thr, drift_ema=new_ema,
                                  ever_delivered=ever)

        # receiver view: slot e of node r is sender j's edge state toward r.
        gathered = self._unflatten(
            self._swap_layout(new_last).reshape(self.n * self.e, self.d))
        gathered = jax.tree.map(
            lambda l: l.reshape((self.n, self.e) + l.shape[1:]), gathered)
        if cfg.on_silence == "drop":
            agg_mask = link_mask * self._swap_layout(gate)
        else:
            # stale: aggregate the per-link cache at full weight, masking
            # only links that never delivered (cache = zero bootstrap);
            # exogenous failures still drop (a loss, not a decision).
            agg_mask = link_mask * self._swap_layout(ever)
        return gathered, agg_mask, gate, new_state


def codec_roundtrip_stacked(codec: Codec, stacked, rng=None):
    """Reference-free encode->decode of stacked [N, ...] models.

    The dist-layer rounds (repro.dist.dfl_step) use this to model wire
    effects without transport state: delta codecs compress against the
    implicit zero reference (= the full model goes through the codec).
    Returns the decoded stacked pytree (leaves cast back to input dtypes).
    """
    w, unflatten = tree_flatten_stacked(stacked)
    n, d = int(w.shape[0]), int(w.shape[1])
    wants_rng = codec.needs_rng and getattr(codec, "stochastic", True) \
        and rng is not None
    keys = (jax.random.split(rng, n) if wants_rng
            else jnp.zeros((n, 2), jnp.uint32))

    def enc_dec(xi, key):
        payload, _ = codec.encode(xi, rng=key if wants_rng else None)
        return codec.decode(payload, out_size=d)

    return unflatten(jax.vmap(enc_dec)(w, keys))
