"""Event-triggered transmission policy (Zehtabi et al., 2022 style).

The paper imposes no round synchronization, and its communication-efficiency
claim invites going further: a node only *transmits* when its model has
drifted since the last payload it put on the wire,

    send_i = 1{ ||w_i - w_i^last_sent||_2 >= threshold },

so stretches of slow local progress cost zero bytes.  threshold = 0
degenerates to always-send (drift >= 0 holds identically), which is how the
equivalence tests pin this path against the legacy Bernoulli-mask round.

The gate is a per-*sender* decision; exogenous per-edge link failures (the
existing `participation` Bernoulli mask) compose multiplicatively on top:
an edge delivers iff the sender fired AND the link stayed up.

What a receiver does about a silent neighbour is the transport's
`on_silence` policy: "stale" aggregates the neighbour's cached
last-transmitted model (deliberate silence = "use what you have"), "drop"
feeds the gate into `edge_delivery` so silence looks like a failed link.
"""
from __future__ import annotations

import jax.numpy as jnp


def drift_gate(w, last_sent, threshold: float):
    """Per-node send gates from model drift.

    Args:
      w: [N, D] current flat models (fp32).
      last_sent: [N, D] flat models as of each node's last transmission.
      threshold: drift threshold in global-L2 units; 0 = always send.

    Returns:
      (gate [N] {0.,1.} float32, drift [N] float32 L2 drift per node).
    """
    drift = jnp.sqrt(jnp.sum(jnp.square(
        w.astype(jnp.float32) - last_sent.astype(jnp.float32)), axis=1))
    gate = (drift >= jnp.float32(threshold)).astype(jnp.float32)
    return gate, drift


def edge_delivery(gate, link_mask, nbr_idx):
    """Compose sender gates with an exogenous per-edge link mask.

    Args:
      gate: [N] sender gates.
      link_mask: [N, D] receiver-side mask in the padded-neighbour layout
        (1 = link up; already includes neighbour validity).
      nbr_idx: [N, D] int neighbour ids per slot.

    Returns [N, D] delivery mask: slot d of node i delivers iff neighbour
    j = nbr_idx[i, d] transmitted and the (j -> i) link was up.
    """
    return link_mask * gate[nbr_idx]
