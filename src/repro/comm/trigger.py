"""Event-triggered transmission policy (Zehtabi et al., 2022 style).

The paper imposes no round synchronization, and its communication-efficiency
claim invites going further: a node only *transmits* when its model has
drifted since the last payload it put on the wire.  Two granularities:

  per-NODE (`drift_gate`, the PR-2 policy): one reference per sender,

      send_i = 1{ ||w_i - w_i^last_sent||_2 >= threshold },

  per-EDGE (`edge_drift_gate`): one reference per directed link (i -> j),
  laid out `[N, max_deg]` in the padded-neighbour geometry, so a hub in a
  Barabási–Albert graph throttles each of its links independently and a
  dropped link's staleness never leaks into its siblings,

      send_ij = 1{ ||w_i - w_ij^last_sent||_2 >= threshold_ij }.

threshold = 0 degenerates to always-send (drift >= 0 holds identically),
which is how the equivalence tests pin both paths against the legacy
Bernoulli-mask round.

Per-edge thresholds can be *adaptive* (`adaptive_threshold_update`): each
edge runs a Robbins-Monro quantile tracker that nudges its threshold toward
the (1 - target)-quantile of that edge's observed drift, so the long-run
triggered fraction converges to `target` per link — the personalized
event-triggering Zehtabi et al. argue for on resource-constrained edges —
with the step size scaled by the edge's drift EMA so the controller is
scale-free in the model's units.

The gate is a per-*sender* decision; exogenous per-edge link failures (the
existing `participation` Bernoulli mask) compose multiplicatively on top:
an edge delivers iff the sender fired AND the link stayed up.

What a receiver does about a silent neighbour is the transport's
`on_silence` policy: "stale" aggregates the neighbour's cached
last-transmitted model (deliberate silence = "use what you have"), "drop"
feeds the gate into `edge_delivery` so silence looks like a failed link.
"""
from __future__ import annotations

import jax.numpy as jnp

# Floor for the EMA-scaled adaptation step: keeps the controller live when an
# edge's drift collapses to ~0 (converged model) without letting the
# threshold run away in units the drift can never reach again.
EMA_FLOOR = 1e-8


def drift_gate(w, last_sent, threshold: float):
    """Per-node send gates from model drift.

    Args:
      w: [N, D] current flat models (fp32).
      last_sent: [N, D] flat models as of each node's last transmission.
      threshold: drift threshold in global-L2 units; 0 = always send.

    Returns:
      (gate [N] {0.,1.} float32, drift [N] float32 L2 drift per node).
    """
    drift = jnp.sqrt(jnp.sum(jnp.square(
        w.astype(jnp.float32) - last_sent.astype(jnp.float32)), axis=1))
    gate = (drift >= jnp.float32(threshold)).astype(jnp.float32)
    return gate, drift


def edge_drift_gate(w, last_sent, threshold, valid):
    """Per-edge send gates from per-link drift.

    Args:
      w: [N, D] current flat models (fp32).
      last_sent: [N, E, D] per-edge reconstruction references — what the
        receiver on each outgoing edge actually holds (E = max_deg slots in
        the padded-neighbour layout).
      threshold: [N, E] per-edge thresholds (or a scalar broadcast).
      valid: [N, E] {0,1} edge validity (padding slots never fire).

    Returns:
      (gate [N, E] {0.,1.} float32, drift [N, E] float32 L2 drift per edge).
    """
    diff = (w.astype(jnp.float32)[:, None, :]
            - last_sent.astype(jnp.float32))
    drift = jnp.sqrt(jnp.sum(jnp.square(diff), axis=-1))
    gate = (drift >= threshold).astype(jnp.float32) * valid
    return gate, drift


def adaptive_threshold_update(threshold, drift_ema, drift, gate, valid, *,
                              target: float, ema_beta: float, rate: float):
    """One step of the per-edge drift-rate controller.

    A Robbins-Monro quantile tracker per edge: the threshold moves up when
    the edge fired and down when it stayed silent, with step sizes chosen so
    the unique fixed point of E[step] = 0 is a triggered fraction of exactly
    `target`:

        thr' = max(0, thr + rate * max(ema', floor) * (gate - target))

    The drift EMA scales the step so adaptation speed is proportional to the
    edge's own drift magnitude (scale-free: multiplying the model by c
    multiplies drift, EMA, threshold, and step all by c).  An all-zero
    initial threshold makes the first rounds always-send, which doubles as
    the bootstrap that carries the full model through delta codecs.

    Args:
      threshold: [N, E] current per-edge thresholds.
      drift_ema: [N, E] running drift EMA per edge.
      drift:     [N, E] this round's observed drift per edge.
      gate:      [N, E] {0,1} whether the edge fired this round.
      valid:     [N, E] {0,1} edge validity (padding slots stay frozen).
      target:    desired long-run triggered fraction per edge, in (0, 1].
      ema_beta:  drift EMA decay (state' = beta * state + (1-beta) * drift).
      rate:      controller gain.

    Returns (new_threshold [N, E], new_drift_ema [N, E]).
    """
    # seed the EMA with the first observed drift (an all-zero EMA would make
    # the controller's early steps vanishingly small and stretch the
    # always-send bootstrap for tens of rounds)
    new_ema = jnp.where(drift_ema > 0,
                        ema_beta * drift_ema + (1.0 - ema_beta) * drift,
                        drift)
    step = rate * jnp.maximum(new_ema, EMA_FLOOR) * (gate - jnp.float32(target))
    new_thr = jnp.maximum(threshold + step, 0.0)
    keep = valid > 0
    return (jnp.where(keep, new_thr, threshold),
            jnp.where(keep, new_ema, drift_ema))


def edge_delivery(gate, link_mask, nbr_idx):
    """Compose sender gates with an exogenous per-edge link mask.

    Args:
      gate: [N] sender gates.
      link_mask: [N, D] receiver-side mask in the padded-neighbour layout
        (1 = link up; already includes neighbour validity).
      nbr_idx: [N, D] int neighbour ids per slot.

    Returns [N, D] delivery mask: slot d of node i delivers iff neighbour
    j = nbr_idx[i, d] transmitted and the (j -> i) link was up.
    """
    return link_mask * gate[nbr_idx]
