from repro.configs.registry import get_config, list_archs, ARCH_IDS  # noqa: F401
