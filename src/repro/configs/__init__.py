from repro.configs.registry import ARCH_IDS, get_config, list_archs  # noqa: F401
