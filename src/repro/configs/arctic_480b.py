"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000;
MoE 128 experts top-2 IN PARALLEL with a dense residual MLP per layer
(dense-MoE hybrid).  [hf:Snowflake/snowflake-arctic-base]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="arctic-480b",
    family="moe",
    citation="hf:Snowflake/snowflake-arctic-base",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
)
