"""deepseek-7b [dense] — 30L d_model=4096 32H (kv=32, MHA) d_ff=11008
vocab=102400; llama architecture.  [arXiv:2401.02954]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-7b",
    family="dense",
    citation="arXiv:2401.02954 (DeepSeek LLM)",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    rope_theta=1e4,
    norm="rmsnorm",
    act="silu",
)
