"""llava-next-mistral-7b [vlm] — mistral-7B text trunk: 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000; vision tower + projector STUBBED, anyres
tiling = 576 base + 4x576 tile patch embeddings (2880 image tokens).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="llava-next-mistral-7b",
    family="vlm",
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    img_tokens=2880,  # anyres: 576 + 4*576
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
)
