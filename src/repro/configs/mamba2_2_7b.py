"""mamba2-2.7b [ssm] — 64L d_model=2560, attention-free, SSD (state-space
duality), ssm_state=128, d_inner=5120, head_dim=64 (80 heads), conv4.
vocab=50280.  [arXiv:2405.21060]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    citation="arXiv:2405.21060 (Mamba2 / SSD)",
    n_layers=64,
    d_model=2560,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    ssm_groups=1,
    norm="rmsnorm",
    act="silu",
)
