"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000; 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    citation="arXiv:2401.04088 (Mixtral of Experts)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
)
