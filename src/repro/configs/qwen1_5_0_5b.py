"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (kv=16, MHA) d_ff=2816
vocab=151936; QKV bias.  [hf:Qwen/Qwen1.5-0.5B]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1.5-0.5b",
    family="dense",
    citation="hf:Qwen/Qwen1.5-0.5B",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    qk_norm=False,
    qkv_bias=True,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
)
