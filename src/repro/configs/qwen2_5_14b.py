"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA + QKV bias.  [hf:Qwen/Qwen2.5-0.5B family card; 14B dims]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2.5-14b",
    family="dense",
    citation="hf:Qwen/Qwen2.5-0.5B (qwen2.5 family; 14B variant dims)",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qk_norm=False,
    qkv_bias=True,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
)
