"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936; qk_norm, GQA, head_dim=128.  [hf:Qwen/Qwen3-8B family card]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-32b",
    family="dense",
    citation="hf:Qwen/Qwen3-8B (qwen3 family; 32B variant dims)",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    qk_norm=True,
    qkv_bias=False,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
)
