"""Architecture registry: --arch <id> lookup for the assigned pool."""
from __future__ import annotations

import importlib
from typing import List

from repro.models.lm.config import ArchConfig

ARCH_IDS: List[str] = [
    "qwen3-32b",
    "qwen1.5-0.5b",
    "whisper-large-v3",
    "mixtral-8x7b",
    "arctic-480b",
    "qwen2.5-14b",
    "zamba2-2.7b",
    "mamba2-2.7b",
    "deepseek-7b",
    "llava-next-mistral-7b",
]

_MODULES = {
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "whisper-large-v3": "whisper_large_v3",
    "mixtral-8x7b": "mixtral_8x7b",
    "arctic-480b": "arctic_480b",
    "qwen2.5-14b": "qwen2_5_14b",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "deepseek-7b": "deepseek_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCH_IDS)
