"""whisper-large-v3 [audio, enc-dec] — 32 enc + 32 dec layers, d_model=1280,
20H (MHA), d_ff=5120, vocab=51866; conv frontend STUBBED (precomputed frame
embeddings at seq/2).  [arXiv:2212.04356]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-large-v3",
    family="encdec",
    citation="arXiv:2212.04356 (Whisper); large-v3 dims",
    n_layers=32,       # decoder layers
    n_enc_layers=32,   # encoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    norm="layernorm",
    act="gelu",
    rope_theta=1e4,    # RoPE replaces learned abs positions (DESIGN.md §4)
    enc_seq_divisor=2, # conv stride-2 downsampling stand-in
)
