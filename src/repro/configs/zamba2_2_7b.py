"""zamba2-2.7b [hybrid] — 54 Mamba2 layers d_model=2560 + ONE shared
attention block (32H MHA kv=32, d_ff=10240) invoked every 9 layers;
ssm_state=64.  Per-invocation LoRA deltas omitted (DESIGN.md §4).
[arXiv:2411.15242]"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    citation="arXiv:2411.15242 (Zamba2)",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    ssm_groups=1,
    shared_attn_every=9,
    rope_theta=1e4,
    norm="rmsnorm",
    act="silu",
)
