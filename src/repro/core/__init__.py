"""Core library: the paper's contribution as composable JAX modules.

  * :mod:`repro.core.decdiff`          — DecDiff aggregation (Eq. 5-6)
  * :mod:`repro.core.virtual_teacher`  — Virtual-Teacher KL loss (Eq. 7-8)
  * :mod:`repro.core.aggregation`      — baseline aggregators (DecAvg/CFA/...)
  * :mod:`repro.core.gossip`           — neighbour-exchange schedules
"""
from repro.core.aggregation import (  # noqa: F401
    AGGREGATORS,
    cfa_aggregate,
    cfa_ge_gradient_step,
    decavg_aggregate,
    fedavg_aggregate,
    get_aggregator,
    isolation_aggregate,
)
from repro.core.decdiff import (  # noqa: F401
    decdiff_aggregate,
    decdiff_aggregate_stacked,
    decdiff_step,
    neighborhood_average,
)
from repro.core.virtual_teacher import (  # noqa: F401
    cross_entropy_loss,
    make_loss_fn,
    soft_labels,
    vt_kl_loss,
)
