"""Aggregation strategies: the paper's baselines + our DecDiff.

All aggregators share one signature, operating on *stacked* neighbour models
(leading axis = padded neighbour slot) so the multi-node simulator can vmap a
whole network's aggregation step.  `mask` marks which slots hold a real,
delivered model this round (the paper imposes no synchronization: a node may
receive from only a fraction of its neighbours).

Implemented strategies (paper §III-C and §V-B.5):

  * ``decavg``    — Decentralized Federated Average, Eq. (4).  Coordinate-wise
                    weighted average of the local model and the neighbours'.
                    With common init this is "DecAvg"; with per-node random
                    init it is the paper's "DecHetero" baseline (the init is a
                    property of the experiment, not of the aggregator).
  * ``cfa``       — Consensus-based Federated Averaging (Savazzi et al.),
                    Eq. (9): w_i += eps * Σ_j p_ij (w_j - w_i), eps = 1/|N_i|.
  * ``decdiff``   — the paper's proposal, Eq. (5)+(6) (see core/decdiff.py).
  * ``none``      — isolation (no aggregation; the ISOL baseline).

CFA-GE (CFA + gradient exchange) additionally consumes neighbour *gradients*
and lives in :func:`cfa_ge_gradient_step`; the exchange itself is orchestrated
by the simulator since it requires neighbours to evaluate gradients of *our*
model on *their* data (doubling communication — the paper's point of
comparison for communication efficiency).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.decdiff import decdiff_aggregate_stacked

Aggregator = Callable  # (local, stacked_neighbors, weights, mask, **kw) -> new local


def _masked_weights(weights, mask):
    w = jnp.asarray(weights, jnp.float32)
    if mask is not None:
        w = w * jnp.asarray(mask, jnp.float32)
    return w


def decavg_aggregate(local_model, stacked_neighbors, weights, mask=None,
                     self_weight=None, **_):
    """Eq. (4): coordinate-wise average of {local} ∪ {delivered neighbours}.

    `weights` are the combined ω_ij * p_ij factors for the neighbour slots;
    `self_weight` the corresponding ω_ii * p_ii for the local model (defaults
    to the mean neighbour weight, i.e. the local model counts like one more
    neighbour).  We normalize by the total weight so the result is a convex
    combination (the paper's Eq. 4 normalizes by Σω; for unit ω and
    Σ_j p_ij = 1 the two coincide up to scale — a convex combination is the
    numerically sane reading and matches FedAvg's behaviour on a star).
    """
    w = _masked_weights(weights, mask)
    if self_weight is None:
        n_active = jnp.maximum(jnp.sum((w > 0).astype(jnp.float32)), 1.0)
        sw = jnp.sum(w) / n_active
    else:
        sw = jnp.asarray(self_weight, jnp.float32)
    total = jnp.sum(w) + sw
    wn = w / total

    def leaf(li, st):
        neigh = jnp.tensordot(wn, st.astype(jnp.float32), axes=(0, 0))
        return ((sw / total) * li.astype(jnp.float32) + neigh).astype(li.dtype)

    return jax.tree.map(leaf, local_model, stacked_neighbors)


def cfa_aggregate(local_model, stacked_neighbors, weights, mask=None,
                  eps=None, **_):
    """Eq. (9): w_i <- w_i + eps Σ_j p_ij (w_j - w_i), eps = 1/Δ (follow-up
    work's setting, which the paper adopts).

    `weights` here carry the p_ij data-size factors; ω_ij (graph weights) are
    folded in by the caller identically to the other aggregators.
    """
    w = _masked_weights(weights, mask)
    total = jnp.sum(w)
    safe_total = jnp.where(total > 0, total, 1.0)
    p = w / safe_total  # p_ij normalized over the delivered neighbours
    n_active = jnp.sum((w > 0).astype(jnp.float32))
    if eps is None:
        eps_val = jnp.where(n_active > 0, 1.0 / jnp.maximum(n_active, 1.0), 0.0)
    else:
        eps_val = jnp.asarray(eps, jnp.float32)
    gate = jnp.where(total > 0, 1.0, 0.0)

    def leaf(li, st):
        lf = li.astype(jnp.float32)
        delta = jnp.tensordot(p, st.astype(jnp.float32) - lf[None], axes=(0, 0))
        return (lf + gate * eps_val * delta).astype(li.dtype)

    return jax.tree.map(leaf, local_model, stacked_neighbors)


def isolation_aggregate(local_model, stacked_neighbors, weights, mask=None, **_):
    """ISOL baseline: ignore the neighbourhood entirely."""
    del stacked_neighbors, weights, mask
    return local_model


def cfa_ge_gradient_step(local_model, stacked_grads, weights, mask=None,
                         lr: float = 1.0, **_):
    """CFA-GE second phase: apply neighbour-computed gradients.

    After the CFA aggregation, node i receives ∇F_j(w_i) from each neighbour
    j (gradients of the *neighbour's* local loss evaluated at i's model — the
    "speed-up" implementation evaluates them at the previous round's model)
    and descends along their p_ij-weighted mean.
    """
    w = _masked_weights(weights, mask)
    total = jnp.sum(w)
    safe_total = jnp.where(total > 0, total, 1.0)
    p = w / safe_total
    gate = jnp.where(total > 0, 1.0, 0.0)

    def leaf(li, sg):
        g = jnp.tensordot(p, sg.astype(jnp.float32), axes=(0, 0))
        return (li.astype(jnp.float32) - gate * lr * g).astype(li.dtype)

    return jax.tree.map(leaf, local_model, stacked_grads)


def fedavg_aggregate(stacked_models, weights):
    """Server-side FedAvg: p_i-weighted average over *all* clients.

    Used by the partially-decentralised FED baseline (star topology)."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    return jax.tree.map(
        lambda st: jnp.tensordot(w, st.astype(jnp.float32), axes=(0, 0)).astype(st.dtype),
        stacked_models,
    )


AGGREGATORS: Dict[str, Aggregator] = {
    "decavg": decavg_aggregate,
    "cfa": cfa_aggregate,
    "decdiff": decdiff_aggregate_stacked,
    "none": isolation_aggregate,
}


def get_aggregator(name: str) -> Aggregator:
    try:
        return AGGREGATORS[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; available: {sorted(AGGREGATORS)}"
        ) from None
