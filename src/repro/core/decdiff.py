"""DecDiff aggregation — the paper's Eq. (5) and Eq. (6).

The two sources of heterogeneity the paper targets (non-IID data and
*uncoordinated model initialization*) make naive coordinate-wise averaging
destructive: after the first exchange, averaging weights that encode different
feature<->parameter assignments wipes out previously learned information
(paper Fig. 1).  DecDiff instead moves the local model toward the
neighbourhood average with a step attenuated by the *global* L2 distance
between the two:

    w_i <- w_i + (w̄_i - w_i) / (||w̄_i - w_i||_2 + s),     s >= 1    (Eq. 5)

    w̄_i = Σ_{j in N_i} ω_ij p_ij w_j / Σ_{j in N_i} ω_ij p_ij       (Eq. 6)

Note the average *excludes* the local model (it is a reference point, not a
replacement), and the norm is computed over the whole flattened model, so the
step size automatically shrinks when models are topologically far apart
(early rounds / heterogeneous init) and grows as they converge.

Everything here operates on pytrees; distances are accumulated leafwise in
fp32.  For sharded (pjit/shard_map) execution see `repro.dist.dfl_step`,
which applies the same update over a stacked node axis (vmapped, or
shard_mapped over the pod ring).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.utils.pytree import (
    tree_sq_norm,
    tree_sub,
    tree_weighted_sum,
)

DEFAULT_S = 1.0  # paper: smallest value limiting the denominator's influence.


def neighborhood_average(neighbor_models: Sequence, weights) -> object:
    """Eq. (6): weighted average of the *neighbours'* models.

    Args:
      neighbor_models: list of pytrees, the models received from N_i.
      weights: per-neighbour scalar weights ω_ij * p_ij (any positive scale —
        normalized internally).

    Returns:
      The neighbourhood average model w̄_i (same structure as the inputs).
    """
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    return tree_weighted_sum(list(neighbor_models), list(w))


def decdiff_step(local_model, avg_model, s: float = DEFAULT_S):
    """Eq. (5): distance-attenuated step from `local_model` toward `avg_model`.

    Returns the updated model.  The step length along (w̄ - w) is
    d / (d + s) < 1 with d = ||w̄ - w||_2, i.e. the update never overshoots
    the average model and vanishes smoothly as d -> 0 or d -> inf... more
    precisely the *relative* step d/(d+s) -> 1 as d -> inf but the *applied*
    scale 1/(d+s) -> 0, which is what bounds disruption for far-apart models.
    """
    diff = tree_sub(avg_model, local_model)
    d = jnp.sqrt(tree_sq_norm(diff))
    scale = 1.0 / (d + s)
    return jax.tree.map(lambda wi, di: (wi + scale * di).astype(wi.dtype), local_model, diff)


def decdiff_aggregate(local_model, neighbor_models: Sequence, weights,
                      s: float = DEFAULT_S):
    """Full DecDiff aggregation: Eq. (6) then Eq. (5).

    This is the function a node runs at each communication round (Alg. 1,
    lines 12-13) after receiving its neighbours' models.
    """
    if len(neighbor_models) == 0:
        return local_model  # isolated this round: keep the local model.
    avg = neighborhood_average(neighbor_models, weights)
    return decdiff_step(local_model, avg, s=s)


def decdiff_aggregate_stacked(local_model, stacked_neighbors, weights, mask=None,
                              s: float = DEFAULT_S):
    """Vectorized variant: neighbours stacked along a leading axis.

    Args:
      local_model: pytree with leaves of shape [...].
      stacked_neighbors: pytree with leaves of shape [N, ...].
      weights: [N] float weights (ω_ij p_ij).
      mask: optional [N] {0,1} — masks out neighbours that did not deliver a
        model this round (the paper does not impose synchronization; a node
        may hear from only a fraction of N_i).

    Used by the vmapped multi-node simulator where all nodes' neighbour sets
    are padded to the max degree.
    """
    w = jnp.asarray(weights, jnp.float32)
    if mask is not None:
        w = w * jnp.asarray(mask, jnp.float32)
    total = jnp.sum(w)
    # If no neighbour delivered, fall back to the local model (scale -> 0).
    safe_total = jnp.where(total > 0, total, 1.0)
    wn = w / safe_total

    def avg_leaf(stacked):
        return jnp.tensordot(wn, stacked.astype(jnp.float32), axes=(0, 0))

    avg = jax.tree.map(avg_leaf, stacked_neighbors)
    diff = jax.tree.map(lambda a, l: a - l.astype(jnp.float32), avg, local_model)
    d = jnp.sqrt(tree_sq_norm(diff))
    scale = jnp.where(total > 0, 1.0 / (d + s), 0.0)
    return jax.tree.map(
        lambda wi, di: (wi.astype(jnp.float32) + scale * di).astype(wi.dtype),
        local_model, diff,
    )
