"""Virtual Teacher (VT) — the paper's Eq. (7) and Eq. (8).

Instead of distilling from a trained teacher model (unavailable in a fully
decentralized system where *every* node is locally weak), each node emulates a
"virtual" teacher via a hand-crafted soft-label distribution:

    p_t(y) = beta                      if y == c (true class)
             (1 - beta) / (|L| - 1)    otherwise                     (Eq. 7)

and trains by minimizing KL(p_t || p_model) (Eq. 8).  beta >= 0.9 ("a good
teacher").  This costs *zero* extra communication and negligible compute — it
is a soft-labelling of the local dataset.

Closed form used throughout (and by the Pallas kernel in
`repro.kernels.vt_kl_loss`): with logits z in R^V, true class c, a = (1-beta)/(V-1):

    KL(p_t || p) = -H(p_t) - Σ_y p_t(y) log p(y)
                 = -H(p_t) - [ beta * z_c + a * (Σ_y z_y - z_c) - lse(z) ]

so only three reductions over the class axis are needed (z_c, Σz, lse); the
V-sized teacher distribution is never materialized.  This matters when |L| is
a 152k-entry LM vocabulary.  The gradient is softmax(z) - p_t.

When beta == 1 this reduces exactly to standard cross-entropy on hard labels
(the -H(p_t) term vanishes), a property we verify in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_BETA = 0.95


def teacher_entropy(beta: float, num_classes: int) -> jnp.ndarray:
    """H(p_t) for the virtual-teacher distribution of Eq. (7)."""
    beta = jnp.asarray(beta, jnp.float32)
    v = num_classes
    a = (1.0 - beta) / (v - 1)
    # -beta log beta - (v-1) a log a, with 0 log 0 = 0 handling for beta=1.
    t1 = -jnp.where(beta > 0, beta * jnp.log(jnp.maximum(beta, 1e-30)), 0.0)
    t2 = -jnp.where(a > 0, (v - 1) * a * jnp.log(jnp.maximum(a, 1e-30)), 0.0)
    return t1 + t2


def soft_labels(labels: jnp.ndarray, num_classes: int, beta: float) -> jnp.ndarray:
    """Materialized Eq. (7) distribution — O(B*V); reference/testing only."""
    a = (1.0 - beta) / (num_classes - 1)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    return onehot * beta + (1.0 - onehot) * a


def _select_true_class(z: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """z_c = z[..., labels] via a one-hot masked reduction over the class axis.

    Deliberately NOT take_along_axis: a positional gather along a sharded
    vocab axis makes GSPMD all-gather the full fp32 logits (measured: 3x40 GB
    temp for a 152k vocab at train_4k).  The iota-compare + select + reduce
    fuses into the vocab reduction and stays sharded (one tiny psum)."""
    idx = jax.lax.broadcasted_iota(jnp.int32, z.shape, z.ndim - 1)
    hit = idx == labels[..., None]
    return jnp.sum(jnp.where(hit, z, 0.0), axis=-1)


def vt_kl_loss(logits: jnp.ndarray, labels: jnp.ndarray, beta: float = DEFAULT_BETA,
               where=None) -> jnp.ndarray:
    """Mean KL(p_t || softmax(logits)) over the batch — Eq. (8), closed form.

    Args:
      logits: [..., V] float array.
      labels: [...] int array of true classes.
      beta: teacher confidence (Eq. 7).
      where: optional [...] bool mask (e.g. padding tokens); masked positions
        contribute zero and are excluded from the mean.
    """
    z = logits.astype(jnp.float32)
    v = z.shape[-1]
    a = (1.0 - beta) / (v - 1)
    lse = jax.nn.logsumexp(z, axis=-1)
    z_sum = jnp.sum(z, axis=-1)
    z_c = _select_true_class(z, labels)
    cross = beta * z_c + a * (z_sum - z_c) - lse  # Σ p_t log p
    kl = -teacher_entropy(beta, v) - cross
    if where is not None:
        where = jnp.asarray(where)
        kl = jnp.where(where, kl, 0.0)
        denom = jnp.maximum(jnp.sum(where), 1)
        return jnp.sum(kl) / denom
    return jnp.mean(kl)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray, where=None) -> jnp.ndarray:
    """Standard CE on hard labels (the paper's loss for all non-VT methods)."""
    z = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(z, axis=-1)
    z_c = _select_true_class(z, labels)
    ce = lse - z_c
    if where is not None:
        where = jnp.asarray(where)
        ce = jnp.where(where, ce, 0.0)
        denom = jnp.maximum(jnp.sum(where), 1)
        return jnp.sum(ce) / denom
    return jnp.mean(ce)


def make_loss_fn(kind: str, beta: float = DEFAULT_BETA):
    """Loss factory: 'vt' -> virtual-teacher KL (Eq. 8), 'ce' -> cross-entropy."""
    if kind == "vt":
        return lambda logits, labels, where=None: vt_kl_loss(logits, labels, beta=beta, where=where)
    if kind == "ce":
        return cross_entropy_loss
    raise ValueError(f"unknown loss kind {kind!r} (expected 'vt' or 'ce')")
