from repro.data.synth import SynthDataset, make_dataset, DATASETS  # noqa: F401
from repro.data.allocation import zipf_allocation, gini_index, split_by_allocation  # noqa: F401
from repro.data.pipeline import minibatches, Batcher  # noqa: F401
from repro.data.tokens import synthetic_token_batch, lm_input_specs  # noqa: F401
