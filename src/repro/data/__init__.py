from repro.data.allocation import gini_index, split_by_allocation, zipf_allocation  # noqa: F401
from repro.data.pipeline import Batcher, minibatches  # noqa: F401
from repro.data.synth import DATASETS, SynthDataset, make_dataset  # noqa: F401
from repro.data.tokens import lm_input_specs, synthetic_token_batch  # noqa: F401
