"""Non-IID data allocation across nodes (paper §V-3).

Class images are assigned to nodes via a **Truncated Zipf** distribution with
exponent alpha_zipf = 1.26: for each class we draw one Zipf share per node (the
node ranking is a per-class random permutation, so different nodes dominate
different classes), producing a highly skewed allocation where typically one
node holds the majority of a class.  Every node is guaranteed a minimum number
of samples per class to avoid boundary effects (paper: "all nodes see at
least some images for each class, however few").

Skew is quantified with the **Gini index** over per-node sample counts; the
paper works in the [0.7, 0.85] range and reports the GI of each run.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

PAPER_ZIPF_ALPHA = 1.26


def gini_index(counts) -> float:
    """Gini index of a non-negative allocation (0 = equal, 1 = one-holds-all)."""
    x = np.asarray(counts, np.float64).ravel()
    if x.size == 0 or x.sum() == 0:
        return 0.0
    x = np.sort(x)
    n = x.size
    cum = np.cumsum(x)
    # standard formula: G = (2 * sum_i i*x_i) / (n * sum x) - (n + 1) / n
    g = (2.0 * np.sum((np.arange(1, n + 1)) * x)) / (n * cum[-1]) - (n + 1.0) / n
    return float(max(0.0, min(1.0, g)))


def zipf_allocation(labels: np.ndarray, num_nodes: int, alpha: float = PAPER_ZIPF_ALPHA,
                    min_per_class: int = 2, seed: int = 0,
                    rank_correlation: float = 0.0) -> List[np.ndarray]:
    """Assign sample indices to nodes with per-class truncated-Zipf skew.

    Args:
      labels: [N] int labels of the training set.
      num_nodes: number of FL nodes.
      alpha: Zipf exponent (paper: 1.26).
      min_per_class: guaranteed per-node per-class floor.
      seed: rng seed.
      rank_correlation: in [0, 1].  0 = independent per-class node rankings
        (pure label-distribution skew; per-node totals stay balanced);
        1 = one global ranking for all classes (adds quantity skew: the same
        nodes dominate every class).  Intermediate values mix the two, letting
        experiments dial the run-level Gini into the paper's [0.7, 0.85] band.

    Returns:
      list of index arrays, one per node (disjoint, covering all samples).
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    node_indices: List[List[int]] = [[] for _ in range(num_nodes)]
    global_ranks = rng.permutation(num_nodes)
    for c in classes:
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        n_c = len(idx)
        # Zipf shares over a (possibly globally-correlated) node ranking.
        if rank_correlation >= 1.0:
            ranks = global_ranks.copy()
        elif rank_correlation <= 0.0:
            ranks = rng.permutation(num_nodes)
        else:
            # keep each node's global rank with prob rank_correlation,
            # shuffle the rest among themselves.
            ranks = global_ranks.copy()
            move = np.nonzero(rng.random(num_nodes) > rank_correlation)[0]
            ranks[move] = ranks[rng.permutation(move)] if len(move) else ranks[move]
        shares = 1.0 / np.power(np.arange(1, num_nodes + 1, dtype=np.float64), alpha)
        shares = shares / shares.sum()
        floor = min(min_per_class, max(n_c // num_nodes, 1))
        remaining = n_c - floor * num_nodes
        if remaining < 0:
            floor, remaining = 0, n_c
        counts = np.full(num_nodes, floor, np.int64)
        extra = np.floor(shares * remaining).astype(np.int64)
        counts[ranks] += extra
        # distribute the rounding remainder to the highest-share nodes
        leftover = n_c - counts.sum()
        order = ranks[np.argsort(-shares)]
        for k in range(int(leftover)):
            counts[order[k % num_nodes]] += 1
        # hand out slices
        off = 0
        for node in range(num_nodes):
            take = int(counts[node])
            node_indices[node].extend(idx[off : off + take].tolist())
            off += take
    return [np.asarray(sorted(ix), np.int64) for ix in node_indices]


def split_by_allocation(x: np.ndarray, y: np.ndarray, allocation: List[np.ndarray]
                        ) -> Tuple[List[np.ndarray], List[np.ndarray]]:
    xs = [x[ix] for ix in allocation]
    ys = [y[ix] for ix in allocation]
    return xs, ys


def allocation_gini(allocation: List[np.ndarray], labels: np.ndarray = None) -> float:
    """Run-level Gini index of the data allocation.

    Without labels: Gini over per-node totals (quantity skew only).  With
    labels: Gini over the flattened node x class count matrix, capturing the
    label-distribution skew the paper's heterogeneity targets (this is the
    quantity that lands in the paper's [0.7, 0.85] operating band for
    alpha_zipf = 1.26)."""
    if labels is None:
        return gini_index([len(ix) for ix in allocation])
    labels = np.asarray(labels)
    classes = np.unique(labels)
    counts = np.zeros((len(allocation), len(classes)), np.int64)
    class_pos = {c: k for k, c in enumerate(classes)}
    for i, ix in enumerate(allocation):
        for c, n in zip(*np.unique(labels[ix], return_counts=True)):
            counts[i, class_pos[c]] = n
    return gini_index(counts.ravel())


def pad_node_datasets(xs: List[np.ndarray], ys: List[np.ndarray]
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad per-node datasets to a common length for vmapped training.

    Returns (x_pad [N, M, ...], y_pad [N, M], counts [N]).  Padding samples
    repeat real ones (so they're harmless) but training draws minibatches
    only from the first `counts[i]` entries via modular indexing.
    """
    n = len(xs)
    m = max(len(x) for x in xs)
    x_pad = np.zeros((n, m) + xs[0].shape[1:], xs[0].dtype)
    y_pad = np.zeros((n, m), ys[0].dtype)
    counts = np.zeros(n, np.int64)
    for i, (x, y) in enumerate(zip(xs, ys)):
        k = len(x)
        counts[i] = k
        reps = -(-m // k)
        x_pad[i] = np.concatenate([x] * reps)[:m]
        y_pad[i] = np.concatenate([y] * reps)[:m]
    return x_pad, y_pad, counts
