"""Minimal-but-real data pipeline: deterministic shuffled minibatching.

Two entry points:
  * :func:`minibatches` — host-side generator over numpy arrays (used by the
    centralized / FedAvg baselines and examples).
  * :class:`Batcher` — device-side modular-gather batcher usable inside
    jit/vmap (used by the multi-node simulator where each of N nodes draws
    from its own padded shard with its own rng-free deterministic schedule).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax.numpy as jnp
import numpy as np


def minibatches(x: np.ndarray, y: np.ndarray, batch_size: int, *, rng: np.random.Generator,
                drop_remainder: bool = True) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    n = len(x)
    order = rng.permutation(n)
    end = (n // batch_size) * batch_size if drop_remainder else n
    for s in range(0, max(end, 0), batch_size):
        ix = order[s : s + batch_size]
        yield x[ix], y[ix]


@dataclasses.dataclass(frozen=True)
class Batcher:
    """Deterministic stride-gather batching inside jit.

    For node-local data padded to [M, ...] with `count` real samples, batch b
    takes indices (b*bs + arange(bs)) * stride mod count.  A coprime-ish odd
    stride decorrelates consecutive batches without needing a shuffle
    (important inside vmap where per-node permutations would be ragged).
    """

    batch_size: int
    stride: int = 7919  # prime

    def take(self, x: jnp.ndarray, y: jnp.ndarray, count: jnp.ndarray, step: jnp.ndarray):
        base = step.astype(jnp.int32) * self.batch_size
        idx = (base + jnp.arange(self.batch_size, dtype=jnp.int32)) * self.stride
        idx = idx % jnp.maximum(count.astype(jnp.int32), 1)
        return jnp.take(x, idx, axis=0), jnp.take(y, idx, axis=0)
