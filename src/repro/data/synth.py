"""Synthetic stand-ins for MNIST / Fashion-MNIST / EMNIST.

The evaluation container is offline, so the paper's datasets are not
available (DESIGN.md §7, data gate).  We generate *class-prototype* image
datasets with the same tensor shapes and class counts:

  synth-mnist    28x28 grayscale, 10 classes, 60k train / 10k test
  synth-fashion  28x28 grayscale, 10 classes, 60k train / 10k test
  synth-emnist   28x28 grayscale, 26 classes, 20.8k train / 3.28k test

Generation: each class c gets K random smooth prototypes (low-frequency
random fields, mimicking stroke-like structure).  A sample is a random convex
mixture of its class's prototypes plus per-sample smooth deformation noise and
pixel noise, then clipped to [0, 1].  Difficulty is controlled by the noise
scale and prototype separation; defaults are tuned so an MLP reaches >95% when
trained centrally but single-node non-IID shards overfit badly — matching the
regime the paper studies (large Centralized-vs-ISOL gap).

All generation is deterministic in (name, seed) and cheap (<2 s for 60k
images at 28x28 on this container).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SynthSpec:
    num_classes: int
    train_size: int
    test_size: int
    image_hw: Tuple[int, int] = (28, 28)
    prototypes_per_class: int = 4
    pixel_noise: float = 0.18
    deform_noise: float = 0.35
    mix_alpha: float = 0.8  # Dirichlet concentration over prototypes


DATASETS: Dict[str, SynthSpec] = {
    # shapes match the real datasets; sizes can be scaled down via `scale`.
    "synth-mnist": SynthSpec(num_classes=10, train_size=60_000, test_size=10_000,
                             pixel_noise=0.15, deform_noise=0.30),
    "synth-fashion": SynthSpec(num_classes=10, train_size=60_000, test_size=10_000,
                               pixel_noise=0.22, deform_noise=0.45),
    "synth-emnist": SynthSpec(num_classes=26, train_size=20_800, test_size=3_280,
                              pixel_noise=0.20, deform_noise=0.40),
}


@dataclasses.dataclass
class SynthDataset:
    name: str
    x_train: np.ndarray  # [N, H, W] float32 in [0,1]
    y_train: np.ndarray  # [N] int32
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int

    @property
    def input_shape(self):
        return self.x_train.shape[1:]


def _smooth_field(rng: np.random.Generator, hw, low: int = 7) -> np.ndarray:
    """Low-frequency random field: upsampled coarse noise (stroke-ish blobs)."""
    h, w = hw
    coarse = rng.standard_normal((low, low)).astype(np.float32)
    # bilinear upsample coarse -> (h, w)
    yi = np.linspace(0, low - 1, h)
    xi = np.linspace(0, low - 1, w)
    y0 = np.floor(yi).astype(int)
    x0 = np.floor(xi).astype(int)
    y1 = np.minimum(y0 + 1, low - 1)
    x1 = np.minimum(x0 + 1, low - 1)
    fy = (yi - y0)[:, None]
    fx = (xi - x0)[None, :]
    f = (
        coarse[np.ix_(y0, x0)] * (1 - fy) * (1 - fx)
        + coarse[np.ix_(y1, x0)] * fy * (1 - fx)
        + coarse[np.ix_(y0, x1)] * (1 - fy) * fx
        + coarse[np.ix_(y1, x1)] * fy * fx
    )
    return f.astype(np.float32)


def _normalize01(a: np.ndarray) -> np.ndarray:
    lo, hi = a.min(), a.max()
    return (a - lo) / max(hi - lo, 1e-6)


def _generate_split(rng: np.random.Generator, protos: np.ndarray, n: int,
                    spec: SynthSpec) -> Tuple[np.ndarray, np.ndarray]:
    c, k, h, w = protos.shape
    labels = rng.integers(0, c, size=n).astype(np.int32)
    mix = rng.dirichlet(np.full(k, spec.mix_alpha), size=n).astype(np.float32)
    base = np.einsum("nk,nkhw->nhw", mix, protos[labels])
    # per-sample smooth deformation + pixel noise, vectorized in chunks
    imgs = np.empty((n, h, w), np.float32)
    chunk = 4096
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        m = e - s
        deform = rng.standard_normal((m, 7, 7)).astype(np.float32)
        # cheap upsample via kron-ish repeat + crop
        reps = (h + 6) // 7
        deform_up = np.kron(deform, np.ones((1, reps, reps), np.float32))[:, :h, :w]
        noise = rng.standard_normal((m, h, w)).astype(np.float32)
        imgs[s:e] = base[s:e] + spec.deform_noise * deform_up + spec.pixel_noise * noise
    imgs = np.clip((imgs - imgs.min()) / max(imgs.max() - imgs.min(), 1e-6), 0, 1)
    return imgs, labels


def make_dataset(name: str, seed: int = 0, scale: float = 1.0) -> SynthDataset:
    """Build a deterministic synthetic dataset.  `scale` shrinks train/test
    sizes proportionally (benchmarks use scale < 1 to fit the CPU budget)."""
    spec = DATASETS[name]
    # NB: not Python's hash() — string hashing is randomized per process
    # (PYTHONHASHSEED), which silently broke the determinism contract.
    rng = np.random.default_rng([zlib.crc32(name.encode()), seed])
    h, w = spec.image_hw
    protos = np.stack([
        np.stack([
            _normalize01(_smooth_field(rng, (h, w)))
            for _ in range(spec.prototypes_per_class)
        ])
        for _ in range(spec.num_classes)
    ])  # [C, K, H, W]
    n_train = max(int(spec.train_size * scale), spec.num_classes * 8)
    n_test = max(int(spec.test_size * scale), spec.num_classes * 4)
    x_tr, y_tr = _generate_split(rng, protos, n_train, spec)
    x_te, y_te = _generate_split(rng, protos, n_test, spec)
    # Standardize with train statistics (the paper's pipeline normalizes via
    # torchvision; without this the shared DC component dominates and SGD
    # stalls — empirically verified).
    mean, std = x_tr.mean(), x_tr.std() + 1e-6
    x_tr = (x_tr - mean) / std
    x_te = (x_te - mean) / std
    return SynthDataset(name=name, x_train=x_tr, y_train=y_tr,
                        x_test=x_te, y_test=y_te, num_classes=spec.num_classes)
