"""Synthetic LM token streams + ShapeDtypeStruct input specs.

For the assigned large architectures the "dataset" is a next-token-prediction
stream.  Offline, we provide (a) a deterministic synthetic token generator
with Zipfian unigram statistics and short-range Markov structure (so models
actually reduce loss during smoke training), and (b) `lm_input_specs` — the
allocation-free ShapeDtypeStruct stand-ins used by the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np


def synthetic_token_batch(batch: int, seq_len: int, vocab: int, seed: int = 0
                          ) -> Dict[str, np.ndarray]:
    """Zipf-unigram + order-1 Markov synthetic tokens with labels = shift."""
    rng = np.random.default_rng(seed)
    v_eff = min(vocab, 4096)  # concentrate mass; large vocab tails unused
    ranks = np.arange(1, v_eff + 1, dtype=np.float64)
    p = 1.0 / ranks**1.1
    p /= p.sum()
    toks = rng.choice(v_eff, size=(batch, seq_len + 1), p=p).astype(np.int32)
    # short-range structure: with prob .5 copy-shift the previous token + 1
    copy = rng.random((batch, seq_len)) < 0.5
    toks[:, 1:][copy] = (toks[:, :-1][copy] + 1) % v_eff
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_input_specs(batch: int, seq_len: int, dtype=np.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), dtype),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), dtype),
    }
