"""Distribution layer: sharding rules, constraint wrappers, sharded DFL steps.

Three modules:

  * `sharding`    — divisibility-aware PartitionSpec inference over the
                    ("data", "model") mesh (plus the optional "pod" node axis).
  * `constraints` — `with_sharding_constraint` wrappers used inside model
                    forward passes; no-ops when no mesh is active, so the same
                    model code runs on a bare CPU and on the production mesh.
  * `dfl_step`    — the jit-able steps: single-pod train/prefill/serve and the
                    multi-pod DFL round with DecDiff gossip over the node axis.
"""
from repro.dist import constraints, dfl_step, sharding  # noqa: F401
