"""`with_sharding_constraint` wrappers used inside model forward passes.

Model code calls these unconditionally; each wrapper resolves the currently
active mesh and becomes a no-op when there is none (bare-CPU tests, the
vmapped simulator) — so a single forward implementation serves eager CPU
execution and the jit-compiled production mesh.

Axes that are absent from the active mesh, and dims that are not divisible by
their axis size, silently drop out of the constraint instead of erroring:
constraints here are hints to GSPMD, not hard requirements.
"""
from __future__ import annotations

import math

import jax
from jax._src import core as _core
from jax._src import mesh as _mesh_lib
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import DATA_AXIS, MODEL_AXIS, NODE_AXIS


def current_mesh():
    """The mesh installed by `with mesh:`, or None outside any mesh context."""
    mesh = _mesh_lib.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def _manual_axes():
    """Mesh axes currently mapped manually (inside shard_map / named vmap):
    those must not appear in a GSPMD sharding constraint."""
    return set(_core.get_axis_env().axis_sizes)


def _axis(mesh, name: str, dim: int, manual=frozenset()):
    if name in mesh.shape and name not in manual and dim % int(mesh.shape[name]) == 0:
        return name
    return None


def _batch_axes(mesh, dim: int, manual=frozenset()):
    """Data-parallel axes for a batch dim: ("pod", "data") when the pod axis
    exists (multi-pod prefill/serve shards the global batch over both)."""
    axes = [a for a in (NODE_AXIS, DATA_AXIS)
            if a in mesh.shape and a not in manual]
    total = math.prod(int(mesh.shape[a]) for a in axes)
    if axes and dim % total == 0:
        return axes[0] if len(axes) == 1 else tuple(axes)
    return _axis(mesh, DATA_AXIS, dim, manual)


def _constrain(mesh, x, spec):
    if all(s is None for s in spec):
        return x  # nothing left to say (e.g. every axis is manual here)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_batch(x):
    """Keep dim 0 (batch) sharded over the data-parallel axes."""
    mesh = current_mesh()
    if mesh is None or x.ndim == 0:
        return x
    spec = [None] * x.ndim
    spec[0] = _batch_axes(mesh, x.shape[0], _manual_axes())
    return _constrain(mesh, x, spec)


def constrain_residual(x, kind: str = "batch"):
    """Residual stream [B, S, D]: "batch" shards B over data; "batch_seq"
    additionally shards S over the model axis (sequence parallelism for the
    norm/elementwise segments between matmuls)."""
    mesh = current_mesh()
    if mesh is None or x.ndim < 2:
        return x
    manual = _manual_axes()
    spec = [None] * x.ndim
    spec[0] = _batch_axes(mesh, x.shape[0], manual)
    if kind == "batch_seq" and x.ndim >= 3:
        spec[1] = _axis(mesh, MODEL_AXIS, x.shape[1], manual)
    return _constrain(mesh, x, spec)


def constrain_logits(x):
    """Logits [B, S, V]: batch over data, vocab over model (the unembed
    matmul's natural output sharding — avoids gathering [B, S, V] fp32)."""
    mesh = current_mesh()
    if mesh is None or x.ndim < 2:
        return x
    manual = _manual_axes()
    spec = [None] * x.ndim
    spec[0] = _batch_axes(mesh, x.shape[0], manual)
    spec[-1] = _axis(mesh, MODEL_AXIS, x.shape[-1], manual)
    return _constrain(mesh, x, spec)


def constrain_expert_sharded(h):
    """MoE dispatch buffers [B, E, C, D] under expert parallelism: experts
    over the model axis (forces the slot all-to-all), batch over data."""
    mesh = current_mesh()
    if mesh is None or h.ndim < 2:
        return h
    manual = _manual_axes()
    spec = [None] * h.ndim
    spec[0] = _batch_axes(mesh, h.shape[0], manual)
    spec[1] = _axis(mesh, MODEL_AXIS, h.shape[1], manual)
    return _constrain(mesh, h, spec)


def gather_weights(layer_params):
    """ZeRO-3 style: constrain one layer's weights to replicated inside the
    scan body, so GSPMD materializes each layer with a just-in-time
    all-gather instead of keeping full weights resident."""
    mesh = current_mesh()
    if mesh is None:
        return layer_params
    if _manual_axes() >= set(mesh.shape):
        return layer_params  # fully manual block: weights are already local
    return jax.tree.map(
        lambda w: jax.lax.with_sharding_constraint(
            w, NamedSharding(mesh, P(*([None] * w.ndim)))),
        layer_params)
