"""Jit-able steps: train/prefill/serve and the pod-level DFL round.

The DFL round is the paper's Algorithm 1 executed over a stacked node axis:
every node takes a local SGD step on its own shard of the synthetic stream,
then DecDiff gossip (core/decdiff.py, Eq. 5-6) moves each node toward its
neighbourhood average with the distance-attenuated step.  Two executions:

  * `build_dfl_round`          — vmap over the node axis; on the production
    mesh the node dim shards over "pod" via in_shardings (launch/dryrun.py).
  * `build_dfl_round_shardmap` — explicit shard_map over the "pod" axis:
    neighbour models move with an all_gather over the pod ring and each pod
    applies Eq. 5-6 to its own nodes (see its docstring for the manual-axes
    rationale).

Both support per-neighbour delivery masks: the paper imposes no round
synchronization, so a node may hear from any subset of its neighbours; a
masked neighbour contributes nothing and a fully-masked node keeps its local
model (see `decdiff_aggregate_stacked`).

Both also accept a repro.comm codec: the exchange then carries the encoded
payload (int8 / top-k wire format — in the shard_map round the all_gather
itself moves the payload, which is the real inter-pod traffic win) and every
receiver dequantizes before DecDiff, leaving Eq. 5-6 semantics unchanged.
For int8 the shard_map round fuses the dequantization into the Eq. 6
reduction with the `dequant_neighbor_avg_rows` Pallas kernel (the gathered
payload is reduced directly; the fp32 neighbour models are never
materialized), with the vmap round as the equivalence oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.comm.codecs import Int8Codec
from repro.comm.transport import codec_roundtrip_stacked
from repro.core.decdiff import DEFAULT_S
from repro.dist.sharding import NODE_AXIS
from repro.utils.pytree import tree_flatten_stacked


def _normalized(adj, mask):
    adj = jnp.asarray(adj, jnp.float32)
    if mask is not None:
        adj = adj * jnp.asarray(mask, jnp.float32)
    row = jnp.sum(adj, axis=1)
    return adj / jnp.where(row > 0, row, 1.0)[:, None], row


def _decdiff_step_from_avg(local, avg, row, s):
    """Eq. 5 for a block of nodes, given the Eq. 6 average.

    `local` has leaves [R, ...] (the nodes being updated), `avg` the
    like-structured precomputed neighbourhood averages (fp32), `row` [R]
    the pre-normalization weight-row sums (0 -> the node heard from nobody
    and keeps its local model).  The SINGLE implementation of the
    gating/dtype rules — every execution of the round (vmap, shard_map,
    fused-payload shard_map) funnels through it so the rules cannot
    diverge.
    """
    diff = jax.tree.map(lambda a, x: a - x.astype(jnp.float32), avg, local)
    sq = jax.tree.reduce(
        jnp.add,
        jax.tree.map(
            lambda d: jnp.sum(jnp.square(d), axis=tuple(range(1, d.ndim))),
            diff),
    )
    scale = jnp.where(row > 0, 1.0 / (jnp.sqrt(sq) + s), 0.0)

    def step_leaf(x, d):
        sc = scale.reshape(scale.shape + (1,) * (d.ndim - 1))
        return (x.astype(jnp.float32) + sc * d).astype(x.dtype)

    return jax.tree.map(step_leaf, local, diff)


def _decdiff_apply(local, full, wn, row, s):
    """Eq. 6 then Eq. 5 for a block of nodes: `full` has leaves [N, ...]
    (every candidate neighbour, already cast for the exchange), `wn` [R, N]
    row-normalized weights."""
    avg = jax.tree.map(
        lambda x: jnp.einsum("rj,j...->r...", wn, x.astype(jnp.float32)), full)
    return _decdiff_step_from_avg(local, avg, row, s)


def decdiff_gossip(stacked, adj, s=DEFAULT_S, *, mask=None, gossip_dtype=None,
                   codec=None):
    """DecDiff aggregation for all nodes at once.

    Args:
      stacked: pytree with leaves [N, ...] — one model per node.
      adj: [N, N] non-negative gossip weights (omega_ij * p_ij); rows are
        normalized internally, the diagonal should be zero (Eq. 6 excludes
        the local model).
      s: the paper's denominator offset (Eq. 5).
      mask: optional [N, N] {0, 1} delivery mask; mask[i, j] = 0 means node i
        did not receive node j's model this round.
      gossip_dtype: optional dtype the exchanged models are cast to before
        averaging (e.g. bf16 gossip halves inter-pod traffic); the norm and
        the update stay fp32.
      codec: optional repro.comm codec modelling the wire: every exchanged
        model is encode->decode roundtripped (deterministic, reference-free)
        before averaging — dequantize-then-DecDiff, so Eq. 5-6 semantics are
        unchanged and the local (un-roundtripped) model stays exact.
        Takes precedence over `gossip_dtype`.

    Returns the updated stacked models; matches per-node
    `decdiff_aggregate` to fp32 round-off.
    """
    wn, row = _normalized(adj, mask)
    if codec is not None:
        full = codec_roundtrip_stacked(codec, stacked)
    elif gossip_dtype is not None:
        full = jax.tree.map(lambda x: x.astype(gossip_dtype), stacked)
    else:
        full = stacked
    return _decdiff_apply(stacked, full, wn, row, s)


def _make_node_step(lm, opt, loss_kind, beta):
    def loss_fn(params, batch):
        total, metrics = lm.loss(params, batch, loss_kind=loss_kind, beta=beta)
        return total, metrics

    def node_step(params, opt_state, step, batch):
        (total, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        new_params, new_state = opt.update(grads, opt_state, params, step)
        return new_params, new_state, total

    return node_step


def build_train_step(lm, opt, *, loss_kind: str = "vt", beta: float = 0.98):
    """(params, opt_state, step, batch) -> (params, opt_state, loss) for a
    single model replica (data-parallel / centralized reference)."""
    return _make_node_step(lm, opt, loss_kind, beta)


def build_prefill_step(lm):
    """(params, batch) -> logits — the forward pass, teacher-forced."""

    def prefill_step(params, batch):
        logits, _ = lm.forward(params, batch)
        return logits

    return prefill_step


def build_serve_step(lm):
    """(params, cache, tokens [B, 1]) -> (logits, cache) — one decode step
    against the ring-buffer KV / recurrent cache."""

    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens)

    return serve_step


def build_dfl_round(lm, opt, adj, *, loss_kind: str = "vt", beta: float = 0.98,
                    s=DEFAULT_S, gossip_dtype=None, mask=None, codec=None):
    """One DFL communication round over stacked per-node state.

    (params [N,...], opt_state [N,...], step, batch [N,B,S], mask=None) ->
    (params, opt_state, mean loss).  Local SGD steps run vmapped over the
    node axis, then DecDiff gossip with the fixed `adj` couples the nodes.

    Delivery masks: the builder kwarg `mask` bakes a fixed [N, N] mask in;
    the round function additionally accepts a runtime `mask` (overriding the
    baked one), so per-round stochastic delivery — the paper's
    no-synchronization model — needs no retrace.

    `codec` (repro.comm) compresses the gossip exchange: neighbours see the
    encode->decode roundtrip of each model (the local model and the norm
    stay exact).  Use a deterministic codec so this round stays equal to the
    shard_map formulation.
    """
    adj = jnp.asarray(adj, jnp.float32)
    node_step = _make_node_step(lm, opt, loss_kind, beta)
    built_mask = mask

    def round_fn(params, opt_state, step, batch, mask=None):
        new_params, new_state, losses = jax.vmap(
            node_step, in_axes=(0, 0, None, 0))(params, opt_state, step, batch)
        m = mask if mask is not None else built_mask
        new_params = decdiff_gossip(new_params, adj, s=s, mask=m,
                                    gossip_dtype=gossip_dtype, codec=codec)
        return new_params, new_state, jnp.mean(losses)

    return round_fn


def build_dfl_round_shardmap(lm, opt, adj, mesh, *, loss_kind: str = "vt",
                             beta: float = 0.98, s=DEFAULT_S,
                             gossip_dtype=None, mask=None, codec=None,
                             fuse_dequant: bool = True):
    """`build_dfl_round` as an explicit shard_map over the "pod" axis.

    Each pod holds `N / n_pods` nodes; the gossip exchange is an all_gather
    of the post-step models over the pod ring (cast to `gossip_dtype` first
    when set).  All mesh axes are manual — jaxlib 0.4.3x's partitioner
    CHECK-fails on shard_map with `auto` non-pod axes — so each pod holds
    its nodes' full replicas and Eq. 5's global squared norm is complete
    blockwise, no cross-axis reduction needed.  Delivery masks follow
    `build_dfl_round`: a baked builder `mask` plus an optional runtime
    `mask` argument on the round function.  Falls back to the vmap
    formulation when the mesh has no pod axis.

    With a `codec` (repro.comm) the all_gather moves the *encoded payload*
    (e.g. int8 values + one fp32 scale per node) instead of fp32 models —
    the actual inter-pod wire reduction.  For the int8 codec the post-gather
    path is KERNELIZED by default (`fuse_dequant=True`): instead of
    decode-then-average (which materializes N dequantized fp32 models — 4x
    the payload footprint plus an extra HBM round trip), the Pallas kernel
    `repro.kernels.dequant_neighbor_avg_rows` folds the per-sender scales
    into the Eq. 6 weights and reduces the int8 payload directly; Eq. 5 then
    runs on the flat per-pod block.  `fuse_dequant=False` keeps the
    decode-then-average formulation (the equivalence oracle, together with
    `build_dfl_round(codec=...)`).  The codec must be deterministic
    (stochastic=False for int8) so this round matches the vmap round.
    """
    if NODE_AXIS not in mesh.shape:
        return build_dfl_round(lm, opt, adj, loss_kind=loss_kind, beta=beta,
                               s=s, gossip_dtype=gossip_dtype, mask=mask,
                               codec=codec)

    adj = jnp.asarray(adj, jnp.float32)
    n_nodes = int(adj.shape[0])
    n_pods = int(mesh.shape[NODE_AXIS])
    if n_nodes % n_pods:
        raise ValueError(f"{n_nodes} DFL nodes do not tile the "
                         f"{n_pods}-pod axis")
    per_pod = n_nodes // n_pods
    node_step = _make_node_step(lm, opt, loss_kind, beta)
    built_mask = (jnp.asarray(mask, jnp.float32) if mask is not None
                  else jnp.ones_like(adj))
    fused_int8 = (fuse_dequant and isinstance(codec, Int8Codec))

    def gather_full(new_params):
        """The gossip exchange: what actually crosses the pod ring.

        codec set   -> all_gather the encoded payload (int8/top-k wire
                       format), dequantize after the gather;
        dtype set   -> all_gather the cast models (bf16 gossip);
        neither     -> all_gather the fp32 models.
        """
        if codec is not None:
            w, unflatten = tree_flatten_stacked(new_params)  # [per_pod, D]
            d = int(w.shape[1])
            payload, _ = jax.vmap(lambda xi: codec.encode(xi))(w)
            gathered = jax.tree.map(
                lambda x: jax.lax.all_gather(x, NODE_AXIS, axis=0, tiled=True),
                payload)
            dec = jax.vmap(lambda p: codec.decode(p, out_size=d))(gathered)
            return unflatten(dec)  # [N, ...] reconstructed models
        cast = ((lambda x: x.astype(gossip_dtype))
                if gossip_dtype is not None else (lambda x: x))
        return jax.tree.map(
            lambda x: jax.lax.all_gather(cast(x), NODE_AXIS, axis=0,
                                         tiled=True),
            new_params)

    def fused_block(new_params, wn_blk, row_blk):
        """Eq. 6 on the gathered int8 payload with dequantization fused
        into the reduction (dequant_neighbor_avg_rows) — the reconstructed
        fp32 neighbour models never exist in HBM — then the shared Eq. 5
        step on the flat [per_pod, D] view (`unflatten` restores leaf
        dtypes)."""
        from repro.kernels import dequant_neighbor_avg_rows

        w_local, unflatten = tree_flatten_stacked(new_params)  # [R, D] fp32
        payload, _ = jax.vmap(lambda xi: codec.encode(xi))(w_local)
        gathered = jax.tree.map(
            lambda x: jax.lax.all_gather(x, NODE_AXIS, axis=0, tiled=True),
            payload)  # q [N, D] int8, scale [N] fp32
        avg = dequant_neighbor_avg_rows(gathered["q"], gathered["scale"],
                                        wn_blk)  # [R, D]
        out = _decdiff_step_from_avg({"w": w_local}, {"w": avg}, row_blk, s)
        return unflatten(out["w"])

    def block(params, opt_state, step, batch, mask):
        new_params, new_state, losses = jax.vmap(
            node_step, in_axes=(0, 0, None, 0))(params, opt_state, step, batch)
        wn, row = _normalized(adj, mask)
        i0 = jax.lax.axis_index(NODE_AXIS) * per_pod
        wn_blk = jax.lax.dynamic_slice_in_dim(wn, i0, per_pod, axis=0)
        row_blk = jax.lax.dynamic_slice_in_dim(row, i0, per_pod, axis=0)
        if fused_int8:
            out = fused_block(new_params, wn_blk, row_blk)
        else:
            full = gather_full(new_params)
            out = _decdiff_apply(new_params, full, wn_blk, row_blk, s)
        loss = jax.lax.pmean(jnp.mean(losses), NODE_AXIS)
        return out, new_state, loss

    sharded = shard_map(
        block, mesh,
        in_specs=(P(NODE_AXIS), P(NODE_AXIS), P(), P(NODE_AXIS), P()),
        out_specs=(P(NODE_AXIS), P(NODE_AXIS), P()),
        check_rep=False)

    def round_fn(params, opt_state, step, batch, mask=None):
        m = mask if mask is not None else built_mask
        return sharded(params, opt_state, step, batch, m)

    return round_fn
