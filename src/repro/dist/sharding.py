"""PartitionSpec inference for the production mesh.

Mesh convention (launch/mesh.py):

  * "data"  — batch / data parallelism,
  * "model" — tensor parallelism (weights and feature dims),
  * "pod"   — optional leading axis carrying the DFL node dimension: one
              decentralized-learning participant per pod.

Specs are inferred per leaf from shape + dtype alone, so the same rules cover
every architecture family without per-model sharding tables:

  * integer/bool leaves replicate (token ids, slot maps, counters),
  * small leaves replicate (norm scales, biases — sharding them buys nothing
    and forces collectives on every use),
  * leading stack dims (scan-over-layers [L, ...] leaves, the DFL node dim)
    are never sharded over "data"/"model"; the node dim maps to "pod",
  * of the remaining dims, the largest dim divisible by the axis size goes to
    "model", the largest other divisible dim to "data"; non-divisible dims
    stay unsharded rather than forcing padding.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"
NODE_AXIS = "pod"

# Leaves with fewer elements than this (ignoring reserved leading dims)
# replicate: at bf16 this is a 128 KiB ceiling, well under one DMA's worth.
SMALL_LEAF_ELEMS = 1 << 16

# Keys whose subtrees carry stacked per-layer params with this many leading
# stack dims ([L, ...] from vmapped init; zamba's mamba blocks are [G, E, ...]).
_STACK_LEAD = {"layers": 1, "enc_layers": 1, "dec_layers": 1, "mamba": 2}

# MoE expert weights [L, E, D, F]: with expert parallelism the E dim shards
# over "model" (experts live on model shards; dispatch becomes an all-to-all).
_EXPERT_KEYS = {"wg", "wu", "wd"}


def _axis_size(mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.shape else 1


def _replicated(dtype) -> bool:
    return np.dtype(dtype).kind in "iub"


def leaf_spec(shape, dtype, n_lead, data_axis, model_axis, mesh):
    """Infer the PartitionSpec for one leaf.

    Args:
      shape, dtype: the leaf's shape and dtype.
      n_lead: number of leading stack dims that must stay unsharded here
        (layer-scan dims, the DFL node dim — the caller owns those).
      data_axis, model_axis: mesh axis names.
      mesh: anything with a `.shape` mapping axis name -> size.
    """
    shape = tuple(int(d) for d in shape)
    rank = len(shape)
    spec = [None] * rank
    if rank == 0 or rank <= n_lead or _replicated(dtype):
        return P(*spec)
    if math.prod(shape[n_lead:]) < SMALL_LEAF_ELEMS:
        return P(*spec)
    by_size = sorted(range(n_lead, rank), key=lambda i: (-shape[i], i))
    model_n = _axis_size(mesh, model_axis)
    model_dim = next((i for i in by_size if shape[i] % model_n == 0), None)
    if model_dim is not None:
        spec[model_dim] = model_axis
    data_n = _axis_size(mesh, data_axis)
    data_dim = next(
        (i for i in by_size if i != model_dim and shape[i] % data_n == 0), None
    )
    if data_dim is not None:
        spec[data_dim] = data_axis
    return P(*spec)


def _path_keys(path):
    keys = []
    for entry in path:
        key = getattr(entry, "key", None)
        if key is None:
            key = getattr(entry, "name", None)
        if key is None:
            key = getattr(entry, "idx", None)
        keys.append(str(key))
    return keys


def make_param_specs(params, mesh, *, dfl_node_axis: bool = False,
                     expert_parallel: bool = False):
    """PartitionSpecs for a parameter pytree (same structure, P leaves).

    With `dfl_node_axis=True` every leaf carries a leading per-node stack dim
    (one model per DFL participant) which maps to the "pod" mesh axis.
    """
    pod_n = _axis_size(mesh, NODE_AXIS)

    def one(path, leaf):
        keys = _path_keys(path)
        n_stack = max((_STACK_LEAD.get(k, 0) for k in keys), default=0)
        n_lead = int(dfl_node_axis) + n_stack
        shape = tuple(int(d) for d in leaf.shape)
        e_dim = n_lead
        if (expert_parallel and keys and keys[-1] in _EXPERT_KEYS
                and len(shape) > e_dim
                and shape[e_dim] % _axis_size(mesh, MODEL_AXIS) == 0):
            spec = [None] * len(shape)
            spec[e_dim] = MODEL_AXIS
            rest = sorted(range(e_dim + 1, len(shape)),
                          key=lambda i: (-shape[i], i))
            data_dim = next(
                (i for i in rest if shape[i] % _axis_size(mesh, DATA_AXIS) == 0),
                None)
            if data_dim is not None:
                spec[data_dim] = DATA_AXIS
        else:
            spec = list(leaf_spec(shape, leaf.dtype, n_lead,
                                  DATA_AXIS, MODEL_AXIS, mesh))
        if (dfl_node_axis and shape and NODE_AXIS in mesh.shape
                and shape[0] % pod_n == 0):
            spec[0] = NODE_AXIS
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params)


def make_batch_specs(batch, mesh, *, dfl_node_axis: bool = False,
                     dp_axes=(DATA_AXIS,)):
    """PartitionSpecs for input batches: the batch dim shards over `dp_axes`
    (e.g. ("pod", "data") for multi-pod prefill), everything else replicates.
    With `dfl_node_axis=True` dim 0 is the per-node stack dim -> "pod"."""
    total = math.prod(_axis_size(mesh, a) for a in dp_axes)

    def one(leaf):
        shape = tuple(int(d) for d in leaf.shape)
        rank = len(shape)
        spec = [None] * rank
        b_dim = 0
        if dfl_node_axis:
            if (rank and NODE_AXIS in mesh.shape
                    and shape[0] % _axis_size(mesh, NODE_AXIS) == 0):
                spec[0] = NODE_AXIS
            b_dim = 1
        if rank > b_dim and shape[b_dim] % total == 0:
            spec[b_dim] = dp_axes[0] if len(dp_axes) == 1 else tuple(dp_axes)
        return P(*spec)

    return jax.tree.map(one, batch)


def make_cache_specs(cache, mesh):
    """PartitionSpecs for decode caches.

    KV caches are [L, B, W, H, hd] (ring-buffer window W); SSM states are
    [L, B, ...].  The layer-stack dim and the window dim never shard (decode
    writes one slot per step — sharding W would turn every write into a
    collective); batch -> "data", and the largest divisible trailing feature
    dim (head_dim, conv channels, state) -> "model".  Integer leaves
    (slot_pos, length) replicate.
    """

    def one(leaf):
        shape = tuple(int(d) for d in leaf.shape)
        rank = len(shape)
        spec = [None] * rank
        if rank < 2 or _replicated(leaf.dtype):
            return P(*spec)
        if shape[1] % _axis_size(mesh, DATA_AXIS) == 0:
            spec[1] = DATA_AXIS
        model_n = _axis_size(mesh, MODEL_AXIS)
        first_feature = 3 if rank >= 4 else 2
        for i in range(rank - 1, first_feature - 1, -1):
            if shape[i] % model_n == 0:
                spec[i] = MODEL_AXIS
                break
        return P(*spec)

    return jax.tree.map(one, cache)


def named(specs, mesh):
    """Wrap a pytree of PartitionSpecs into NamedShardings for jit
    in_shardings/out_shardings."""
    return jax.tree.map(
        lambda s: s if isinstance(s, NamedSharding) else NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, (P, NamedSharding)),
    )
