"""repro.dynamics — time-varying topologies as a first-class subsystem.

    from repro.dynamics import EdgeDropout
    world = World.synthetic(nodes=16, topology="barabasi_albert", m=2,
                            dynamics=EdgeDropout(p=0.2))
    Experiment(world, "decdiff+vt").run()

A :class:`GraphProcess` turns the world's static topology into a per-round
sequence of edge masks — i.i.d. edge dropout, Gilbert–Elliott bursty links,
node churn (with explicit per-edge comm-state reset on rejoin), periodic
rewiring, scripted mask-table replay, and drift-adaptive energy churn
(observing the `repro.timing` event clock's realized compute cost) — each a
pure on-device state transition that compiles inside the engine's fused
``lax.scan`` schedule.  See docs/dynamics.md for the catalog and semantics.
"""
from repro.dynamics.processes import (  # noqa: F401
    PROCESSES,
    BoundProcess,
    EdgeDropout,
    EnergyChurn,
    GilbertElliott,
    GraphEvent,
    GraphProcess,
    NodeChurn,
    PeriodicRewiring,
    ScriptedGraph,
    StaticGraph,
    make_process,
)
