"""Time-varying communication graphs as pure on-device state transitions.

The paper's premise is that in pervasive edge scenarios "the interactions
(i.e., the connectivity graph) between devices might not be predetermined" —
yet a frozen :class:`~repro.graphs.topology.Topology` is exactly that.  A
:class:`GraphProcess` opens the axis: it turns a static topology into a
per-round *sequence* of edge masks, with the realized graph evolving as a
Markov chain whose state is a small pytree of device arrays.  Every process
is a pure ``(state, round_idx, key) -> (state, GraphEvent)`` transition, so
the whole sequence compiles inside the engine's fused ``lax.scan`` schedule
(and the per-round loop, bit-identically — pinned in tests/test_dynamics.py).

A :class:`GraphEvent` is what one round of the process realizes:

  * ``live``      — ``[N, max_deg]`` {0,1} in the padded-neighbour layout:
    which edges of the static layout exist THIS round.  Always a subset of
    ``neighbor_mask`` and always symmetric (an undirected edge is up or down
    for both endpoints — ``live[i, e] == live[j, rev]`` for ``j =
    nbr_idx[i, e]``);
  * ``alive``     — ``[N]`` {0,1}: devices present this round.  A dead node
    runs no local steps, transmits nothing, receives nothing, and its
    params/optimizer state freeze bit-exactly;
  * ``rejoined``  — ``[N]`` {0,1}: devices that were dead last round and are
    back this round.  The transports use this to RESET per-link comm state
    (references, residuals, adaptive thresholds) on every edge incident to a
    rejoining device — a rejoined device is a fresh device, its peers'
    caches of it (and its caches of them) are gone.  See
    :meth:`repro.comm.EdgeGossipTransport.reset_edges`.

The shipped catalog (`make_process` names):

  ``static``            — the identity: the frozen topology, every round.
    ``World(dynamics=StaticGraph())`` is bit-identical to ``dynamics=None``.
  ``edge_dropout``      — i.i.d. per-round edge failures: each undirected
    edge is independently down with probability ``p`` each round.
  ``gilbert_elliott``   — bursty links: each undirected edge runs its own
    2-state (good/bad) Markov chain with P(good->bad) = ``p_gb`` and
    P(bad->good) = ``p_bg``; the stationary up-probability is
    ``p_bg / (p_gb + p_bg)`` and the mean burst (outage) length is
    ``1 / p_bg`` rounds.
  ``node_churn``        — device churn: each node runs a 2-state Markov
    chain, leaving w.p. ``p_leave`` and rejoining w.p. ``p_rejoin``; an
    edge is live iff both endpoints are.  Stationary aliveness is
    ``p_rejoin / (p_leave + p_rejoin)``.
  ``periodic_rewiring`` — deterministic re-draws: a family of ``num_graphs``
    topologies (default Watts–Strogatz) is materialized up front, the
    engine compiles against their UNION layout, and round r runs graph
    ``(r // period) % num_graphs`` as a mask over the union.  This is how a
    rewiring process — which changes the neighbour *sets* — stays a pure
    on-device transition: the padded layout is static, only the mask moves.
  ``scripted``          — mask-table replay: round r plays row
    ``tables[r]`` of a recorded ``[T, ...]`` live-mask schedule (per-pair
    coins or per-round adjacency matrices), with the `repro.timing`
    period/clamp rule past the table end.  The replay mechanism for
    recorded connectivity traces — and the determinism workhorse for
    reproducing any adversarial schedule in a test.
  ``energy_churn``      — drift-ADAPTIVE churn: each node drains a battery
    by its REALIZED per-round compute seconds (observed from the
    `repro.timing` event clock, one round delayed), dies at empty, and
    recharges while dead until ``rejoin_at``.  The first observing process:
    its transition reads a per-node scalar the engine feeds from the
    timing state (see "Observing processes" below).

Observing processes: a process with ``observes = True`` receives a fourth
transition argument — a per-node ``[N]`` float32 observation vector the
engine supplies (currently: the previous round's realized compute seconds,
``TimingState.last_cost``; zeros at round 0).  The one-round delay keeps
the transition causal inside the fused ``lax.scan``.  An observing process
requires ``World(timing=...)``; `Experiment` raises otherwise.

Both node-axis layouts run the SAME processes.  Bound to a dense
:class:`~repro.graphs.topology.Topology`, ``live`` comes out in the padded
``[N, max_deg]`` layout; bound to a
:class:`~repro.graphs.sparse.SparseTopology`, it comes out as a flat ``[E]``
mask over the directed CSR edge list.  Randomness discipline makes the two
bit-identical: every per-edge draw is ONE uniform per undirected pair, with
pairs enumerated in canonical ascending ``(lo, hi)`` order — the dense
layout scatters the ``[num_pairs]`` coin vector through a precomputed
pair-id panel, the sparse layout through
:func:`repro.graphs.sparse.undirected_pair_ids` — so both endpoints of an
edge, every pod of the shard_map backend, AND both layouts of the same
graph see the same coin.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.sparse import (
    _DENSE_GUARD,
    SparseTopology,
    make_sparse_topology,
    undirected_pair_ids,
)
from repro.graphs.topology import Topology, _from_adjacency, make_topology
from repro.timing.models import PAST_END, past_end_index


class GraphEvent(NamedTuple):
    """One round's realized graph (see module docstring).

    ``live`` is laid out per binding: ``[N, max_deg]`` {0,1} in the padded
    layout (symmetric, subset of ``neighbor_mask``) when bound to a dense
    Topology, or ``[E]`` {0,1} over the directed CSR edge list (with
    ``live[e] == live[rev_edge[e]]``) when bound to a SparseTopology."""

    live: jnp.ndarray      # [N, max_deg] (dense) or [E] (sparse) {0,1} f32
    alive: jnp.ndarray     # [N] {0,1} f32
    rejoined: jnp.ndarray  # [N] {0,1} f32 (dead last round, alive now)


@dataclasses.dataclass(frozen=True)
class BoundProcess:
    """A process bound to a topology: the static layout the engine compiles
    against, the initial device state, and the jittable transition.
    `stationary_live_frac` is the binding's long-run edge-live fraction
    when known (the process's closed form, or — for rewiring — the realized
    family mean over the union layout; None otherwise)."""

    process: "GraphProcess"
    topo: Any                # Topology or SparseTopology static layout
    state0: Any              # pytree of jnp arrays, scan-carried
    step: Callable           # (state, round_idx, key[, obs]) -> (state, GraphEvent)
    stationary_live_frac: Optional[float] = None

    @property
    def name(self) -> str:
        return self.process.name

    @property
    def needs_rng(self) -> bool:
        return self.process.needs_rng

    @property
    def observes(self) -> bool:
        return self.process.observes


def _layout(topo: Topology):
    """The jnp padded-neighbour tensors a step closes over."""
    idx = jnp.asarray(np.maximum(topo.neighbor_idx, 0).astype(np.int32))
    valid = jnp.asarray(topo.neighbor_mask.astype(np.float32))
    return topo.num_nodes, idx, valid


def _pair_layout(topo):
    """Canonical undirected-pair coin plumbing, shared by both layouts.

    Returns ``(num_pairs, to_live)``: pairs are enumerated in ascending
    ``(lo, hi)`` order, identically for a dense Topology and the
    SparseTopology of the same graph, and ``to_live`` scatters a
    ``[num_pairs]`` {0,1} coin vector into the binding's live-mask shape
    (``[N, max_deg]`` dense / ``[E]`` sparse).  ONE coin per undirected
    pair means both endpoints, every pod, and both layouts agree."""
    if isinstance(topo, SparseTopology):
        pid, m = undirected_pair_ids(topo)
        pid_j = jnp.asarray(pid)

        def to_live(up):
            return up[pid_j]

        return m, to_live
    n, _, valid = _layout(topo)
    iu, ju = np.nonzero(np.triu(topo.adjacency, 1))
    codes = iu.astype(np.int64) * n + ju  # row-major triu = (lo, hi) order
    m = int(codes.shape[0])
    if m == 0:
        return 0, lambda up: jnp.zeros_like(valid)
    idx = np.maximum(topo.neighbor_idx, 0).astype(np.int64)
    rows = np.arange(n, dtype=np.int64)[:, None]
    pcode = np.minimum(rows, idx) * n + np.maximum(rows, idx)
    panel_j = jnp.asarray(
        np.clip(np.searchsorted(codes, pcode), 0, m - 1).astype(np.int32))

    def to_live(up):
        return up[panel_j] * valid  # padding slots hit pair 0; valid zeroes them

    return m, to_live


def _pair_coords(topo):
    """The canonical undirected pair (lo, hi) node coordinates, in the same
    ascending ``(lo, hi)`` order `_pair_layout` enumerates — how a recorded
    ``[T, N, N]`` adjacency table is read down to per-pair coins."""
    n = topo.num_nodes
    if isinstance(topo, SparseTopology):
        lo = np.minimum(topo.edge_src, topo.edge_dst).astype(np.int64)
        hi = np.maximum(topo.edge_src, topo.edge_dst).astype(np.int64)
        codes = np.unique(lo * n + hi)
        return codes // n, codes % n
    iu, ju = np.nonzero(np.triu(topo.adjacency, 1))
    return iu.astype(np.int64), ju.astype(np.int64)


def _live_layout(topo):
    """Per-layout aliveness plumbing: ``(n, all_live, live_from_alive)``.

    ``all_live`` is the every-edge-up mask in the binding's layout;
    ``live_from_alive`` maps a ``[N]`` {0,1} aliveness vector to the live
    mask (endpoint-AND — exact {0,1} float products, so dense and sparse
    agree bitwise)."""
    if isinstance(topo, SparseTopology):
        src = jnp.asarray(topo.edge_src.astype(np.int32))
        dst = jnp.asarray(topo.edge_dst.astype(np.int32))
        all_live = jnp.ones((topo.num_directed,), jnp.float32)

        def from_alive(alive):
            return alive[src] * alive[dst]

        return topo.num_nodes, all_live, from_alive
    n, idx, valid = _layout(topo)

    def from_alive(alive):
        return valid * alive[:, None] * alive[idx]

    return n, valid, from_alive


class GraphProcess:
    """Protocol: a topology-to-sequence-of-graphs generator.

    Subclasses override :meth:`prepare` (static layout augmentation — only
    rewiring needs it), :meth:`init_state` and :meth:`make_step`; users call
    :meth:`bind` once and the engine owns the returned transition.  Set
    ``needs_rng = False`` when the transition is deterministic — the engine
    then consumes NO extra rng, which is what makes ``StaticGraph``
    bit-identical to running without dynamics at all.  Set
    ``observes = True`` for a drift-adaptive process whose transition takes
    a fourth ``obs`` argument (a per-node ``[N]`` float32 the engine feeds
    from the `repro.timing` event clock — see the module docstring).
    """

    name: str = "graph-process"
    needs_rng: bool = True
    observes: bool = False

    def bind(self, topo) -> BoundProcess:
        """Bind to a dense Topology or a SparseTopology (the live-mask
        layout follows the binding — see :class:`GraphEvent`)."""
        prepared = self.prepare(topo)
        return BoundProcess(process=self, topo=prepared,
                            state0=self.init_state(prepared),
                            step=self.make_step(prepared),
                            stationary_live_frac=self.stationary_live_frac())

    # ---------------------------------------------------------------- hooks
    def prepare(self, topo):
        """The static layout the engine compiles against (default: the
        world's own topology; rewiring returns the family's union graph)."""
        return topo

    def init_state(self, topo):
        """Initial device state (a pytree of jnp arrays; () if stateless)."""
        return ()

    def make_step(self, topo) -> Callable:
        raise NotImplementedError

    def stationary_live_frac(self) -> Optional[float]:
        """Closed-form long-run fraction of EDGES live per round, when one
        exists (None otherwise).  Feed it to
        :func:`repro.fl.metrics.comm_bytes_per_round` as ``live_frac`` for
        static accounting of the edge-borne (decentralized) methods.
        Careful with `fedavg`: its volume scales with the NODE count, so
        under churn it wants the stationary aliveness
        (:meth:`NodeChurn.stationary_alive_frac`), not this edge
        fraction."""
        return None

    def __repr__(self):
        return f"{type(self).__name__}()"


@dataclasses.dataclass(frozen=True)
class StaticGraph(GraphProcess):
    """The frozen graph, every round — the identity process.

    Exists so "no dynamics" is a point IN the process space: an experiment
    with ``dynamics=StaticGraph()`` is bit-identical to ``dynamics=None``
    (no extra rng is consumed; the live mask is the neighbour mask itself).
    """

    name = "static"
    needs_rng = False

    def make_step(self, topo):
        n, all_live, _ = _live_layout(topo)
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)

        def step(state, round_idx, key):
            del round_idx, key
            return state, GraphEvent(live=all_live, alive=ones, rejoined=zeros)

        return step

    def stationary_live_frac(self) -> float:
        return 1.0


@dataclasses.dataclass(frozen=True)
class EdgeDropout(GraphProcess):
    """i.i.d. edge dropout: every undirected edge is down with probability
    ``p`` each round, independently across edges and rounds — the
    memoryless baseline every bursty model degenerates to."""

    p: float = 0.2

    name = "edge_dropout"

    def __post_init__(self):
        if not 0.0 <= self.p < 1.0:
            raise ValueError(f"drop probability must be in [0, 1), got {self.p}")

    def make_step(self, topo):
        m, to_live = _pair_layout(topo)
        n = topo.num_nodes
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)
        p = jnp.float32(self.p)

        def step(state, round_idx, key):
            del round_idx
            u = jax.random.uniform(key, (m,), jnp.float32)
            up = (u >= p).astype(jnp.float32)
            return state, GraphEvent(live=to_live(up), alive=ones,
                                     rejoined=zeros)

        return step

    def stationary_live_frac(self) -> float:
        return 1.0 - self.p


@dataclasses.dataclass(frozen=True)
class GilbertElliott(GraphProcess):
    """Bursty links: a 2-state (good/bad) Markov chain PER undirected edge.

    Classic Gilbert–Elliott: from good the link fails with probability
    ``p_gb``; from bad it recovers with probability ``p_bg``.  Small
    probabilities mean LONG bursts — e.g. (0.1, 0.3) gives mean outages of
    ~3.3 rounds at a stationary up-rate of 0.75, a much harsher regime than
    i.i.d. dropout at the same average loss because a down edge stays down
    while its endpoints keep drifting apart.  All links start good; the
    chain mixes toward ``p_bg / (p_gb + p_bg)`` at rate ``1 - p_gb - p_bg``.
    """

    p_gb: float = 0.1   # P(good -> bad): burst onset
    p_bg: float = 0.3   # P(bad -> good): burst recovery

    name = "gilbert_elliott"

    def __post_init__(self):
        for nm, v in (("p_gb", self.p_gb), ("p_bg", self.p_bg)):
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1], got {v}")
        if self.p_bg == 0.0:
            raise ValueError("p_bg = 0 makes every outage permanent; use "
                             "edge removal in the topology instead")

    def init_state(self, topo):
        # all links start in the good state (the model-free choice; the
        # chain forgets it at rate 1 - p_gb - p_bg).  State is one chain
        # per undirected pair — the same [num_pairs] vector either layout.
        m, _ = _pair_layout(topo)
        return jnp.ones((m,), jnp.float32)

    def make_step(self, topo):
        m, to_live = _pair_layout(topo)
        n = topo.num_nodes
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)
        p_gb, p_bg = jnp.float32(self.p_gb), jnp.float32(self.p_bg)

        def step(up, round_idx, key):
            del round_idx
            u = jax.random.uniform(key, (m,), jnp.float32)
            new_up = jnp.where(up > 0, u >= p_gb, u < p_bg).astype(jnp.float32)
            return new_up, GraphEvent(live=to_live(new_up), alive=ones,
                                      rejoined=zeros)

        return step

    def stationary_live_frac(self) -> float:
        return self.p_bg / (self.p_gb + self.p_bg)


@dataclasses.dataclass(frozen=True)
class NodeChurn(GraphProcess):
    """Device churn: each node leaves w.p. ``p_leave`` and rejoins w.p.
    ``p_rejoin`` per round (independent 2-state chains).  An edge is live
    iff both endpoints are alive, so a churned node's rows/columns are
    fully masked; the round it rejoins it is flagged in
    ``GraphEvent.rejoined`` and the transports reset every edge incident to
    it (a rejoined device is a FRESH device — its per-link references,
    residuals and adaptive thresholds restart from the zero bootstrap, and
    its first transmissions carry the full model through delta codecs
    again).  Stationary aliveness ``p_rejoin / (p_leave + p_rejoin)``;
    stationary edge-live fraction is its square (endpoint chains are
    independent)."""

    p_leave: float = 0.05
    p_rejoin: float = 0.5

    name = "node_churn"

    def __post_init__(self):
        if not 0.0 <= self.p_leave < 1.0:
            raise ValueError(f"p_leave must be in [0, 1), got {self.p_leave}")
        if not 0.0 < self.p_rejoin <= 1.0:
            raise ValueError(f"p_rejoin must be in (0, 1] (a device that "
                             f"never rejoins is a smaller world), got "
                             f"{self.p_rejoin}")

    def init_state(self, topo):
        return jnp.ones((topo.num_nodes,), jnp.float32)  # everyone present

    def make_step(self, topo):
        n, _, from_alive = _live_layout(topo)
        p_leave, p_rejoin = jnp.float32(self.p_leave), jnp.float32(self.p_rejoin)

        def step(alive, round_idx, key):
            del round_idx
            u = jax.random.uniform(key, (n,), jnp.float32)
            new_alive = jnp.where(alive > 0, u >= p_leave,
                                  u < p_rejoin).astype(jnp.float32)
            rejoined = (1.0 - alive) * new_alive
            return new_alive, GraphEvent(live=from_alive(new_alive),
                                         alive=new_alive, rejoined=rejoined)

        return step

    def stationary_alive_frac(self) -> float:
        """Long-run fraction of devices present (the `live_frac` a
        node-count-priced method like fedavg wants)."""
        return self.p_rejoin / (self.p_leave + self.p_rejoin)

    def stationary_live_frac(self) -> float:
        a = self.stationary_alive_frac()
        return a * a  # endpoint chains are independent


@dataclasses.dataclass(frozen=True)
class PeriodicRewiring(GraphProcess):
    """Deterministic periodic re-draws from a topology family.

    ``num_graphs`` graphs are materialized at bind time (default: connected
    Watts–Strogatz re-draws with per-graph seeds), the engine compiles
    against their UNION layout, and round r masks the union down to graph
    ``(r // period) % num_graphs``.  The union is what makes rewiring —
    which changes neighbour SETS, not just edge liveness — expressible as a
    pure on-device transition: the static geometry (the padded
    ``[N, max_deg]`` panel, or the union's flat ``[E]`` edge list on the
    sparse layout — and with it every compiled program and every per-edge
    comm state tensor) stays fixed, only the precomputed mask row changes.

    The base topology contributes its node count only; the family is drawn
    fresh (``topo_kwargs`` go to the builder, e.g. ``dict(k=4, p=0.1)``).
    Deterministic (``needs_rng = False``): the realized schedule is a pure
    function of the round index, so two backends/schedule modes cannot
    diverge by construction.
    """

    period: int = 5
    num_graphs: int = 4
    topology: str = "watts_strogatz"
    seed: int = 0
    topo_kwargs: Mapping = dataclasses.field(default_factory=dict)

    name = "periodic_rewiring"
    needs_rng = False

    def __post_init__(self):
        if self.period < 1:
            raise ValueError(f"period must be >= 1, got {self.period}")
        if self.num_graphs < 1:
            raise ValueError(f"num_graphs must be >= 1, got {self.num_graphs}")

    def _family(self, n: int):
        kw = dict(self.topo_kwargs)
        if self.topology == "watts_strogatz":
            kw.setdefault("k", 4)
            kw.setdefault("p", 0.1)
        return [make_topology(self.topology, n=n, seed=self.seed + 9176 * g,
                              **kw)
                for g in range(self.num_graphs)]

    def _union_dense(self, n: int):
        family = self._family(n)
        union_adj = np.zeros((n, n), np.int8)
        for t in family:
            union_adj = np.maximum(union_adj, t.adjacency)
        union = _from_adjacency(
            f"rewire_union({self.topology},K={self.num_graphs},n={n})",
            union_adj)
        idx = np.maximum(union.neighbor_idx, 0)
        rows = np.arange(n)[:, None]
        masks = np.stack([
            t.adjacency[rows, idx].astype(np.float32) * union.neighbor_mask
            for t in family
        ])  # [K, N, max_deg] — graph g's edges in the union layout
        return union, masks, float(max(union.neighbor_mask.sum(), 1))

    def _union_sparse(self, n: int):
        # Below the densify guard, draw the SAME dense family — the union
        # graph, per-round masks and weights then match the dense binding
        # edge for edge (the oracle-parity regime).  Above it, the dense
        # samplers are off the table; use the vectorized sparse samplers
        # (a different, documented random stream).
        if n <= _DENSE_GUARD:
            fam_codes = []
            for t in self._family(n):
                iu, ju = np.nonzero(np.triu(t.adjacency, 1))
                fam_codes.append(iu.astype(np.int64) * n + ju)
        else:
            kw = dict(self.topo_kwargs)
            if self.topology == "watts_strogatz":
                kw.setdefault("k", 4)
                kw.setdefault("p", 0.1)
            fam_codes = []
            for g in range(self.num_graphs):
                t = make_sparse_topology(self.topology, n=n,
                                         seed=self.seed + 9176 * g, **kw)
                lo = np.minimum(t.edge_src, t.edge_dst).astype(np.int64)
                hi = np.maximum(t.edge_src, t.edge_dst).astype(np.int64)
                fam_codes.append(np.unique(lo * n + hi))
        union_codes = np.unique(np.concatenate(fam_codes))
        union = SparseTopology.from_pairs(
            f"rewire_union({self.topology},K={self.num_graphs},n={n})",
            n, union_codes // n, union_codes % n)
        ecode = (np.minimum(union.edge_src, union.edge_dst).astype(np.int64)
                 * n + np.maximum(union.edge_src, union.edge_dst))
        masks = np.stack([np.isin(ecode, c).astype(np.float32)
                          for c in fam_codes])  # [K, E] directed-edge masks
        return union, masks, float(max(union.num_directed, 1))

    def bind(self, topo) -> BoundProcess:
        n = topo.num_nodes
        if isinstance(topo, SparseTopology):
            union, masks, denom = self._union_sparse(n)
        else:
            union, masks, denom = self._union_dense(n)
        masks_j = jnp.asarray(masks)
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)
        period, k = self.period, self.num_graphs

        def step(state, round_idx, key):
            del key
            g = (round_idx.astype(jnp.int32) // period) % k
            return state, GraphEvent(live=masks_j[g], alive=ones,
                                     rejoined=zeros)

        return BoundProcess(
            process=self, topo=union, state0=(), step=step,
            stationary_live_frac=float(masks.mean(axis=0).sum() / denom))

    def make_step(self, topo):  # pragma: no cover - bind() owns it
        raise RuntimeError("PeriodicRewiring builds its step in bind()")

    def stationary_live_frac(self) -> Optional[float]:
        """None: the live fraction is a property of the BINDING (the union
        layout defines the denominator) — read it off
        `BoundProcess.stationary_live_frac` after `bind(topo)`."""
        return None


@dataclasses.dataclass(frozen=True)
class ScriptedGraph(GraphProcess):
    """Mask-table replay: round r realizes row ``tables[r]`` of a recorded
    live-mask schedule.

    ``tables`` is either ``[T, num_pairs]`` {0,1} coins over the canonical
    ascending ``(lo, hi)`` undirected-pair enumeration, or ``[T, N, N]``
    {0,1} symmetric adjacency matrices (read down to per-pair coins at the
    STATIC topology's pair coordinates — edges outside the bound layout
    are ignored, exactly like any other process's mask).  Past the table
    end the shared `repro.timing` ``past_end`` rule applies: ``"wrap"``
    replays the schedule periodically, ``"clamp"`` holds the last row
    forever.  Deterministic (``needs_rng = False``) and pair-keyed, so both
    layouts, both backends and both schedule modes realize the identical
    sequence — the replay mechanism for recorded connectivity traces and
    for pinning adversarial schedules in tests."""

    tables: Any  # [T, num_pairs] pair coins or [T, N, N] adjacency, {0,1}
    past_end: str = "wrap"

    name = "scripted"
    needs_rng = False

    def __post_init__(self):
        if self.past_end not in PAST_END:
            raise ValueError(f"past_end must be one of {PAST_END}, "
                             f"got {self.past_end!r}")
        tab = np.asarray(self.tables, np.float32)
        if tab.ndim not in (2, 3) or tab.shape[0] < 1:
            raise ValueError(f"tables must be [T >= 1, num_pairs] or "
                             f"[T >= 1, N, N], got shape {tab.shape}")
        if tab.ndim == 3 and tab.shape[1] != tab.shape[2]:
            raise ValueError(f"adjacency tables must be square per round, "
                             f"got shape {tab.shape}")
        if not np.isin(tab, (0.0, 1.0)).all():
            raise ValueError("scripted masks must be {0, 1}")

    def _coins(self, topo) -> np.ndarray:
        """The [T, num_pairs] coin table in canonical pair order."""
        tab = np.asarray(self.tables, np.float32)
        m, _ = _pair_layout(topo)
        if tab.ndim == 2:
            if tab.shape[1] != m:
                raise ValueError(
                    f"pair-coin tables cover {tab.shape[1]} pairs, the "
                    f"bound topology has {m} (canonical ascending (lo, hi) "
                    f"order)")
            return tab
        if tab.shape[1] != topo.num_nodes:
            raise ValueError(f"adjacency tables cover {tab.shape[1]} nodes, "
                             f"world has {topo.num_nodes}")
        asym = np.abs(tab - np.transpose(tab, (0, 2, 1)))
        if asym.max() > 0:
            raise ValueError("adjacency tables must be symmetric (an "
                             "undirected edge is up or down for both "
                             "endpoints)")
        lo, hi = _pair_coords(topo)
        return tab[:, lo, hi]

    def make_step(self, topo):
        m, to_live = _pair_layout(topo)
        coins = jnp.asarray(self._coins(topo))
        t_len, past_end = int(coins.shape[0]), self.past_end
        n = topo.num_nodes
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)

        def step(state, round_idx, key):
            del key
            up = coins[past_end_index(round_idx, t_len, past_end)]
            return state, GraphEvent(live=to_live(up), alive=ones,
                                     rejoined=zeros)

        return step


@dataclasses.dataclass(frozen=True)
class EnergyChurn(GraphProcess):
    """Drift-adaptive churn: compute drains a battery, dead devices
    recharge.

    Each node starts with ``capacity`` seconds of energy.  Every round an
    alive node drains its REALIZED compute seconds (the `repro.timing`
    event clock's ``last_cost`` observation: step time x trained steps, one
    round delayed — a straggler that trains fewer steps under a deadline
    also drains less); at zero it churns out.  A dead node recharges
    ``recharge`` seconds per round and rejoins once its energy reaches
    ``rejoin_at`` (flagged in ``GraphEvent.rejoined``, so the transports
    reset its incident comm state like any other churn).  Deterministic
    given the observation stream (``needs_rng = False``) — the coupling to
    training is entirely through the observed cost, which is what makes the
    process ADAPTIVE rather than exogenous.  Requires ``World(timing=...)``.
    """

    capacity: float = 32.0
    recharge: float = 4.0
    rejoin_at: float = 16.0

    name = "energy_churn"
    needs_rng = False
    observes = True

    def __post_init__(self):
        if not self.capacity > 0:
            raise ValueError(f"capacity must be > 0, got {self.capacity}")
        if not self.recharge > 0:
            raise ValueError(f"recharge must be > 0 (a device that never "
                             f"recharges never rejoins), got {self.recharge}")
        if not 0.0 < self.rejoin_at <= self.capacity:
            raise ValueError(f"rejoin_at must be in (0, capacity], got "
                             f"{self.rejoin_at}")

    def init_state(self, topo):
        n = topo.num_nodes
        return (jnp.full((n,), self.capacity, jnp.float32),  # energy
                jnp.ones((n,), jnp.float32))                 # alive

    def make_step(self, topo):
        n, _, from_alive = _live_layout(topo)
        cap = jnp.float32(self.capacity)
        rech = jnp.float32(self.recharge)
        rejoin_at = jnp.float32(self.rejoin_at)

        def step(state, round_idx, key, obs):
            del round_idx, key
            energy, alive = state
            e = jnp.clip(energy - alive * obs + (1.0 - alive) * rech,
                         0.0, cap)
            new_alive = jnp.where(alive > 0, e > 0,
                                  e >= rejoin_at).astype(jnp.float32)
            rejoined = (1.0 - alive) * new_alive
            return (e, new_alive), GraphEvent(live=from_alive(new_alive),
                                              alive=new_alive,
                                              rejoined=rejoined)

        return step


# ---------------------------------------------------------------- registry

PROCESSES: Dict[str, Callable[..., GraphProcess]] = {
    "static": StaticGraph,
    "edge_dropout": EdgeDropout,
    "gilbert_elliott": GilbertElliott,
    "node_churn": NodeChurn,
    "periodic_rewiring": PeriodicRewiring,
    "scripted": ScriptedGraph,
    "energy_churn": EnergyChurn,
}


def make_process(name: str, **kwargs) -> GraphProcess:
    """Build a catalog process by name (kwargs go to its constructor)."""
    try:
        cls = PROCESSES[name]
    except KeyError:
        raise ValueError(
            f"unknown graph process {name!r}; available: {sorted(PROCESSES)}"
        ) from None
    return cls(**kwargs)
