"""Time-varying communication graphs as pure on-device state transitions.

The paper's premise is that in pervasive edge scenarios "the interactions
(i.e., the connectivity graph) between devices might not be predetermined" —
yet a frozen :class:`~repro.graphs.topology.Topology` is exactly that.  A
:class:`GraphProcess` opens the axis: it turns a static topology into a
per-round *sequence* of edge masks, with the realized graph evolving as a
Markov chain whose state is a small pytree of device arrays.  Every process
is a pure ``(state, round_idx, key) -> (state, GraphEvent)`` transition, so
the whole sequence compiles inside the engine's fused ``lax.scan`` schedule
(and the per-round loop, bit-identically — pinned in tests/test_dynamics.py).

A :class:`GraphEvent` is what one round of the process realizes:

  * ``live``      — ``[N, max_deg]`` {0,1} in the padded-neighbour layout:
    which edges of the static layout exist THIS round.  Always a subset of
    ``neighbor_mask`` and always symmetric (an undirected edge is up or down
    for both endpoints — ``live[i, e] == live[j, rev]`` for ``j =
    nbr_idx[i, e]``);
  * ``alive``     — ``[N]`` {0,1}: devices present this round.  A dead node
    runs no local steps, transmits nothing, receives nothing, and its
    params/optimizer state freeze bit-exactly;
  * ``rejoined``  — ``[N]`` {0,1}: devices that were dead last round and are
    back this round.  The transports use this to RESET per-link comm state
    (references, residuals, adaptive thresholds) on every edge incident to a
    rejoining device — a rejoined device is a fresh device, its peers'
    caches of it (and its caches of them) are gone.  See
    :meth:`repro.comm.EdgeGossipTransport.reset_edges`.

The shipped catalog (`make_process` names):

  ``static``            — the identity: the frozen topology, every round.
    ``World(dynamics=StaticGraph())`` is bit-identical to ``dynamics=None``.
  ``edge_dropout``      — i.i.d. per-round edge failures: each undirected
    edge is independently down with probability ``p`` each round.
  ``gilbert_elliott``   — bursty links: each undirected edge runs its own
    2-state (good/bad) Markov chain with P(good->bad) = ``p_gb`` and
    P(bad->good) = ``p_bg``; the stationary up-probability is
    ``p_bg / (p_gb + p_bg)`` and the mean burst (outage) length is
    ``1 / p_bg`` rounds.
  ``node_churn``        — device churn: each node runs a 2-state Markov
    chain, leaving w.p. ``p_leave`` and rejoining w.p. ``p_rejoin``; an
    edge is live iff both endpoints are.  Stationary aliveness is
    ``p_rejoin / (p_leave + p_rejoin)``.
  ``periodic_rewiring`` — deterministic re-draws: a family of ``num_graphs``
    topologies (default Watts–Strogatz) is materialized up front, the
    engine compiles against their UNION layout, and round r runs graph
    ``(r // period) % num_graphs`` as a mask over the union.  This is how a
    rewiring process — which changes the neighbour *sets* — stays a pure
    on-device transition: the padded layout is static, only the mask moves.

Randomness discipline matches the engine's: per-edge draws happen over the
FULL ``[N, N]`` upper triangle from the replicated rng stream and are
symmetrized before slotting, so both endpoints of an edge (and every pod of
the shard_map backend) see the same coin.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.topology import Topology, _from_adjacency, make_topology


class GraphEvent(NamedTuple):
    """One round's realized graph (see module docstring)."""

    live: jnp.ndarray      # [N, max_deg] {0,1} f32, symmetric, subset of valid
    alive: jnp.ndarray     # [N] {0,1} f32
    rejoined: jnp.ndarray  # [N] {0,1} f32 (dead last round, alive now)


@dataclasses.dataclass(frozen=True)
class BoundProcess:
    """A process bound to a topology: the static layout the engine compiles
    against, the initial device state, and the jittable transition.
    `stationary_live_frac` is the binding's long-run edge-live fraction
    when known (the process's closed form, or — for rewiring — the realized
    family mean over the union layout; None otherwise)."""

    process: "GraphProcess"
    topo: Topology           # the (possibly augmented) static layout
    state0: Any              # pytree of jnp arrays, scan-carried
    step: Callable           # (state, round_idx, key) -> (state, GraphEvent)
    stationary_live_frac: Optional[float] = None

    @property
    def name(self) -> str:
        return self.process.name

    @property
    def needs_rng(self) -> bool:
        return self.process.needs_rng


def _layout(topo: Topology):
    """The jnp padded-neighbour tensors a step closes over."""
    idx = jnp.asarray(np.maximum(topo.neighbor_idx, 0).astype(np.int32))
    valid = jnp.asarray(topo.neighbor_mask.astype(np.float32))
    return topo.num_nodes, idx, valid


def _symmetric_uniform(key, n: int):
    """[N, N] uniforms with u[i, j] == u[j, i] and zero diagonal: one coin
    per undirected pair, drawn from ONE key so every observer agrees."""
    u = jnp.triu(jax.random.uniform(key, (n, n), jnp.float32), 1)
    return u + u.T

def _edge_slots(mat, idx, valid):
    """Gather a symmetric [N, N] edge field into the [N, max_deg] layout."""
    n = valid.shape[0]
    return mat[jnp.arange(n)[:, None], idx] * valid


class GraphProcess:
    """Protocol: a topology-to-sequence-of-graphs generator.

    Subclasses override :meth:`prepare` (static layout augmentation — only
    rewiring needs it), :meth:`init_state` and :meth:`make_step`; users call
    :meth:`bind` once and the engine owns the returned transition.  Set
    ``needs_rng = False`` when the transition is deterministic — the engine
    then consumes NO extra rng, which is what makes ``StaticGraph``
    bit-identical to running without dynamics at all.
    """

    name: str = "graph-process"
    needs_rng: bool = True

    def bind(self, topo: Topology) -> BoundProcess:
        prepared = self.prepare(topo)
        return BoundProcess(process=self, topo=prepared,
                            state0=self.init_state(prepared),
                            step=self.make_step(prepared),
                            stationary_live_frac=self.stationary_live_frac())

    # ---------------------------------------------------------------- hooks
    def prepare(self, topo: Topology) -> Topology:
        """The static layout the engine compiles against (default: the
        world's own topology; rewiring returns the family's union graph)."""
        return topo

    def init_state(self, topo: Topology):
        """Initial device state (a pytree of jnp arrays; () if stateless)."""
        return ()

    def make_step(self, topo: Topology) -> Callable:
        raise NotImplementedError

    def stationary_live_frac(self) -> Optional[float]:
        """Closed-form long-run fraction of EDGES live per round, when one
        exists (None otherwise).  Feed it to
        :func:`repro.fl.metrics.comm_bytes_per_round` as ``live_frac`` for
        static accounting of the edge-borne (decentralized) methods.
        Careful with `fedavg`: its volume scales with the NODE count, so
        under churn it wants the stationary aliveness
        (:meth:`NodeChurn.stationary_alive_frac`), not this edge
        fraction."""
        return None

    def __repr__(self):
        return f"{type(self).__name__}()"


@dataclasses.dataclass(frozen=True)
class StaticGraph(GraphProcess):
    """The frozen graph, every round — the identity process.

    Exists so "no dynamics" is a point IN the process space: an experiment
    with ``dynamics=StaticGraph()`` is bit-identical to ``dynamics=None``
    (no extra rng is consumed; the live mask is the neighbour mask itself).
    """

    name = "static"
    needs_rng = False

    def make_step(self, topo: Topology):
        n, _, valid = _layout(topo)
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)

        def step(state, round_idx, key):
            del round_idx, key
            return state, GraphEvent(live=valid, alive=ones, rejoined=zeros)

        return step

    def stationary_live_frac(self) -> float:
        return 1.0


@dataclasses.dataclass(frozen=True)
class EdgeDropout(GraphProcess):
    """i.i.d. edge dropout: every undirected edge is down with probability
    ``p`` each round, independently across edges and rounds — the
    memoryless baseline every bursty model degenerates to."""

    p: float = 0.2

    name = "edge_dropout"

    def __post_init__(self):
        if not 0.0 <= self.p < 1.0:
            raise ValueError(f"drop probability must be in [0, 1), got {self.p}")

    def make_step(self, topo: Topology):
        n, idx, valid = _layout(topo)
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)
        p = jnp.float32(self.p)

        def step(state, round_idx, key):
            del round_idx
            up = (_symmetric_uniform(key, n) >= p).astype(jnp.float32)
            return state, GraphEvent(live=_edge_slots(up, idx, valid),
                                     alive=ones, rejoined=zeros)

        return step

    def stationary_live_frac(self) -> float:
        return 1.0 - self.p


@dataclasses.dataclass(frozen=True)
class GilbertElliott(GraphProcess):
    """Bursty links: a 2-state (good/bad) Markov chain PER undirected edge.

    Classic Gilbert–Elliott: from good the link fails with probability
    ``p_gb``; from bad it recovers with probability ``p_bg``.  Small
    probabilities mean LONG bursts — e.g. (0.1, 0.3) gives mean outages of
    ~3.3 rounds at a stationary up-rate of 0.75, a much harsher regime than
    i.i.d. dropout at the same average loss because a down edge stays down
    while its endpoints keep drifting apart.  All links start good; the
    chain mixes toward ``p_bg / (p_gb + p_bg)`` at rate ``1 - p_gb - p_bg``.
    """

    p_gb: float = 0.1   # P(good -> bad): burst onset
    p_bg: float = 0.3   # P(bad -> good): burst recovery

    name = "gilbert_elliott"

    def __post_init__(self):
        for nm, v in (("p_gb", self.p_gb), ("p_bg", self.p_bg)):
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1], got {v}")
        if self.p_bg == 0.0:
            raise ValueError("p_bg = 0 makes every outage permanent; use "
                             "edge removal in the topology instead")

    def init_state(self, topo: Topology):
        # all links start in the good state (the model-free choice; the
        # chain forgets it at rate 1 - p_gb - p_bg)
        return jnp.asarray(topo.adjacency.astype(np.float32))

    def make_step(self, topo: Topology):
        n, idx, valid = _layout(topo)
        adj = jnp.asarray(topo.adjacency.astype(np.float32))
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)
        p_gb, p_bg = jnp.float32(self.p_gb), jnp.float32(self.p_bg)

        def step(up, round_idx, key):
            del round_idx
            u = _symmetric_uniform(key, n)
            new_up = jnp.where(up > 0, u >= p_gb, u < p_bg)
            new_up = new_up.astype(jnp.float32) * adj
            return new_up, GraphEvent(live=_edge_slots(new_up, idx, valid),
                                      alive=ones, rejoined=zeros)

        return step

    def stationary_live_frac(self) -> float:
        return self.p_bg / (self.p_gb + self.p_bg)


@dataclasses.dataclass(frozen=True)
class NodeChurn(GraphProcess):
    """Device churn: each node leaves w.p. ``p_leave`` and rejoins w.p.
    ``p_rejoin`` per round (independent 2-state chains).  An edge is live
    iff both endpoints are alive, so a churned node's rows/columns are
    fully masked; the round it rejoins it is flagged in
    ``GraphEvent.rejoined`` and the transports reset every edge incident to
    it (a rejoined device is a FRESH device — its per-link references,
    residuals and adaptive thresholds restart from the zero bootstrap, and
    its first transmissions carry the full model through delta codecs
    again).  Stationary aliveness ``p_rejoin / (p_leave + p_rejoin)``;
    stationary edge-live fraction is its square (endpoint chains are
    independent)."""

    p_leave: float = 0.05
    p_rejoin: float = 0.5

    name = "node_churn"

    def __post_init__(self):
        if not 0.0 <= self.p_leave < 1.0:
            raise ValueError(f"p_leave must be in [0, 1), got {self.p_leave}")
        if not 0.0 < self.p_rejoin <= 1.0:
            raise ValueError(f"p_rejoin must be in (0, 1] (a device that "
                             f"never rejoins is a smaller world), got "
                             f"{self.p_rejoin}")

    def init_state(self, topo: Topology):
        return jnp.ones((topo.num_nodes,), jnp.float32)  # everyone present

    def make_step(self, topo: Topology):
        n, idx, valid = _layout(topo)
        p_leave, p_rejoin = jnp.float32(self.p_leave), jnp.float32(self.p_rejoin)

        def step(alive, round_idx, key):
            del round_idx
            u = jax.random.uniform(key, (n,), jnp.float32)
            new_alive = jnp.where(alive > 0, u >= p_leave,
                                  u < p_rejoin).astype(jnp.float32)
            rejoined = (1.0 - alive) * new_alive
            live = valid * new_alive[:, None] * new_alive[idx]
            return new_alive, GraphEvent(live=live, alive=new_alive,
                                         rejoined=rejoined)

        return step

    def stationary_alive_frac(self) -> float:
        """Long-run fraction of devices present (the `live_frac` a
        node-count-priced method like fedavg wants)."""
        return self.p_rejoin / (self.p_leave + self.p_rejoin)

    def stationary_live_frac(self) -> float:
        a = self.stationary_alive_frac()
        return a * a  # endpoint chains are independent


@dataclasses.dataclass(frozen=True)
class PeriodicRewiring(GraphProcess):
    """Deterministic periodic re-draws from a topology family.

    ``num_graphs`` graphs are materialized at bind time (default: connected
    Watts–Strogatz re-draws with per-graph seeds), the engine compiles
    against their UNION layout, and round r masks the union down to graph
    ``(r // period) % num_graphs``.  The union is what makes rewiring —
    which changes neighbour SETS, not just edge liveness — expressible as a
    pure on-device transition: the padded ``[N, max_deg]`` geometry (and
    with it every compiled program and every ``[N, max_deg, ...]`` comm
    state tensor) stays fixed, only the precomputed mask row changes.

    The base topology contributes its node count only; the family is drawn
    fresh (``topo_kwargs`` go to the builder, e.g. ``dict(k=4, p=0.1)``).
    Deterministic (``needs_rng = False``): the realized schedule is a pure
    function of the round index, so two backends/schedule modes cannot
    diverge by construction.
    """

    period: int = 5
    num_graphs: int = 4
    topology: str = "watts_strogatz"
    seed: int = 0
    topo_kwargs: Mapping = dataclasses.field(default_factory=dict)

    name = "periodic_rewiring"
    needs_rng = False

    def __post_init__(self):
        if self.period < 1:
            raise ValueError(f"period must be >= 1, got {self.period}")
        if self.num_graphs < 1:
            raise ValueError(f"num_graphs must be >= 1, got {self.num_graphs}")

    def _family(self, n: int):
        kw = dict(self.topo_kwargs)
        if self.topology == "watts_strogatz":
            kw.setdefault("k", 4)
            kw.setdefault("p", 0.1)
        return [make_topology(self.topology, n=n, seed=self.seed + 9176 * g,
                              **kw)
                for g in range(self.num_graphs)]

    def bind(self, topo: Topology) -> BoundProcess:
        n = topo.num_nodes
        family = self._family(n)
        union_adj = np.zeros((n, n), np.int8)
        for t in family:
            union_adj = np.maximum(union_adj, t.adjacency)
        union = _from_adjacency(
            f"rewire_union({self.topology},K={self.num_graphs},n={n})",
            union_adj)
        idx = np.maximum(union.neighbor_idx, 0)
        rows = np.arange(n)[:, None]
        masks = np.stack([
            t.adjacency[rows, idx].astype(np.float32) * union.neighbor_mask
            for t in family
        ])  # [K, N, max_deg] — graph g's edges in the union layout
        masks_j = jnp.asarray(masks)
        ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)
        period, k = self.period, self.num_graphs

        def step(state, round_idx, key):
            del key
            g = (round_idx.astype(jnp.int32) // period) % k
            return state, GraphEvent(live=masks_j[g], alive=ones,
                                     rejoined=zeros)

        return BoundProcess(
            process=self, topo=union, state0=(), step=step,
            stationary_live_frac=float(masks.mean(axis=0).sum()
                                       / max(union.neighbor_mask.sum(), 1)))

    def make_step(self, topo: Topology):  # pragma: no cover - bind() owns it
        raise RuntimeError("PeriodicRewiring builds its step in bind()")

    def stationary_live_frac(self) -> Optional[float]:
        """None: the live fraction is a property of the BINDING (the union
        layout defines the denominator) — read it off
        `BoundProcess.stationary_live_frac` after `bind(topo)`."""
        return None


# ---------------------------------------------------------------- registry

PROCESSES: Dict[str, Callable[..., GraphProcess]] = {
    "static": StaticGraph,
    "edge_dropout": EdgeDropout,
    "gilbert_elliott": GilbertElliott,
    "node_churn": NodeChurn,
    "periodic_rewiring": PeriodicRewiring,
}


def make_process(name: str, **kwargs) -> GraphProcess:
    """Build a catalog process by name (kwargs go to its constructor)."""
    try:
        cls = PROCESSES[name]
    except KeyError:
        raise ValueError(
            f"unknown graph process {name!r}; available: {sorted(PROCESSES)}"
        ) from None
    return cls(**kwargs)
