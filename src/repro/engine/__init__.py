"""repro.engine — one Experiment API over pluggable method strategies.

    from repro.engine import Experiment, Schedule, World

    world = World.synthetic(nodes=16, topology="erdos_renyi", p=0.25)
    exp = Experiment(world, "decdiff+vt",
                     schedule=Schedule(rounds=30, eval_every=5))
    history = exp.run()

Methods plug in as :class:`AggregationStrategy` instances (each declaring a
frozen :class:`Capabilities` record) through :func:`register_method`;
execution lowers through `build_round` — one round body for every strategy
× transport × dynamics combination — to the vmap or shard_map backend and
runs either per-round or as one scan-fused XLA program (`Schedule.mode`).
See docs/api.md for the full tour.
"""
from repro.engine.backends import BACKENDS, build_round  # noqa: F401
from repro.engine.experiment import (  # noqa: F401
    Experiment,
    Schedule,
    TrainConfig,
    World,
)
from repro.engine.strategies import (  # noqa: F401
    AggregationStrategy,
    Capabilities,
    CFAGEStrategy,
    CFAStrategy,
    DecAvgStrategy,
    DecDiffStrategy,
    FedAvgStrategy,
    IsolationStrategy,
    MethodSpec,
    available_methods,
    get_method,
    register_method,
)
