"""`build_round(experiment)`: one round spec, two executions.

Lowers an :class:`~repro.engine.Experiment` to a jit-able round function —
Algorithm 1's (local SGD steps → neighbour exchange → aggregation) as ONE
XLA program per round — on either backend:

  * ``vmap``      — every per-node quantity vmapped over the node axis (the
    legacy `DFLSimulator` execution, ported op-for-op: with the fp32 codec,
    threshold 0 and the fixed policy it is bit-for-bit the pre-engine round);
  * ``shard_map`` — explicit shard_map over the "pod" mesh axis (the
    `repro.dist.dfl_step` formulation generalized to the full method/
    transport roster): each pod owns N/n_pods nodes' params, optimizer
    state, data shards and transport state; the neighbour exchange is an
    all_gather over the pod ring; everything per-node — training, trigger,
    codec, aggregation — runs blockwise on the pod's own rows with the SAME
    per-node ops as the vmap lowering, so the two backends agree
    bit-for-bit (pinned in tests/test_engine.py on the 4-device CPU mesh).

The round function's calling convention depends on the transport and on
whether the experiment carries a `repro.dynamics.GraphProcess` (whose
state is threaded through the round exactly like the transport's):

  no comm:  (params, opt, round_idx, rng) -> (params, opt, rng, loss)
  comm:     (params, opt, comm_state, round_idx, rng)
            -> (params, opt, comm_state, rng, loss, sent_edges, trig_frac)
  dynamics: (params, opt, dyn_state, round_idx, rng)
            -> (params, opt, dyn_state, rng, loss, live_edges)
  both:     (params, opt, comm_state, dyn_state, round_idx, rng)
            -> (params, opt, comm_state, dyn_state, rng, loss,
                sent_edges, trig_frac, live_edges)

With dynamics, the round starts by realizing this round's graph (one pure
state transition -> a GraphEvent): a dead node runs zero local steps and
its params/opt state freeze bit-exactly, the delivery mask is intersected
with the live-edge mask, transports only fire (and only account bytes) on
live edges, and a node that rejoins after churn has its per-link transport
state reset before the exchange.  `trig_frac` is the fired fraction of
LIVE directed edges; `live_edges` their count.

Method behaviour enters exclusively through the experiment's
:class:`~repro.engine.AggregationStrategy` (exchange/aggregate hooks and
the `kind`/`grad_exchange` capabilities) — there is no method branching
here beyond those capabilities.

Randomness discipline (the bit-exactness mechanism): every rng consumption
— per-step dropout keys, hetero step budgets, participation masks, codec
keys, and the dynamics process's edge coins — is computed from the
REPLICATED rng stream over the full node axis and then row-sliced per
block, so the shard_map lowering sees exactly the values the vmap lowering
sees.  Only data movement (the all_gather) differs.  A process that needs
no rng (StaticGraph, PeriodicRewiring) consumes none, which is what makes
`dynamics=StaticGraph()` bit-identical to `dynamics=None`.

Scale note: the shard_map exchange moves the decoded fp32 models because
this is the *simulator* contract (bytes-on-wire are accounted exactly from
`payload_bytes × fired edges`, not from the gather).  The LM-scale rounds
in `repro.dist.dfl_step` are the production formulation of the same
exchange where the all_gather carries the encoded int8 payload and the
dequantize+Eq.6 reduction is fused into the `dequant_neighbor_avg_rows`
Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.comm import EdgeGossipTransport
from repro.comm.trigger import edge_delivery
from repro.dist.sharding import NODE_AXIS
from repro.utils.pytree import tree_flatten_stacked

BACKENDS = ("vmap", "shard_map")


def build_round(exp):
    """Lower `exp` to its jit-able round function (see module docstring)."""
    if exp.backend == "vmap":
        return _build_vmap_round(exp)
    if exp.backend == "shard_map":
        return _build_shardmap_round(exp)
    raise ValueError(
        f"unknown backend {exp.backend!r}; available: {BACKENDS}")


# ------------------------------------------------------------ shared pieces

def _identity_rows(a):
    return a


def _freeze_dead(new_params, old_params, alive):
    """Per-node select: rows with alive == 0 keep their old value bit-exactly
    (gossip masks already guarantee it for aggregation; this also covers
    server-style strategies that would overwrite an offline device)."""
    def sel(nw, od):
        a = alive.reshape(alive.shape + (1,) * (nw.ndim - 1)) > 0
        return jnp.where(a, nw, od)

    return jax.tree.map(sel, new_params, old_params)


def _make_realize(exp):
    """The dynamics prelude: consume (at most) one rng split and run the
    process transition, yielding this round's GraphEvent."""
    bound = exp.bound_dyn
    step, needs_rng = bound.step, bound.needs_rng

    def realize(dyn_state, round_idx, rng):
        if needs_rng:
            rng, dk = jax.random.split(rng)
        else:
            dk = None
        dyn_state, ev = step(dyn_state, round_idx, dk)
        return dyn_state, ev, rng

    return realize


def _make_local_training(exp, *, x, y, counts, rows, loss_reduce):
    """B local SGD(momentum) minibatch steps (Alg. 1 l.4-9) for the block of
    nodes whose data is (x, y, counts); `rows` slices globally-computed
    [N, ...] randomness to the block (identity on the vmap backend).
    `alive` ([N], optional) zeroes the step budget of churned-out devices —
    an offline node trains nothing and its params/opt state freeze."""
    cfg = exp.train
    n = exp.n
    batcher = exp.batcher

    def take_batch(xx, yy, c, step):
        return batcher.take(xx, yy, c, step)

    v_take = jax.vmap(take_batch, in_axes=(0, 0, 0, None))
    v_step = jax.vmap(exp._train_step, in_axes=(0, 0, 0, 0, None, 0))

    def local_training(params, opt, round_idx, rng, alive=None):
        # Heterogeneous E (Alg. 1): per-node step budget for this round;
        # nodes past their budget keep their params (masked update).
        if cfg.hetero_steps_min > 0:
            rng, sub = jax.random.split(rng)
            budgets = rows(jax.random.randint(
                sub, (n,), cfg.hetero_steps_min, cfg.steps_per_round + 1))
        else:
            budgets = rows(jnp.full((n,), cfg.steps_per_round, jnp.int32))
        if alive is not None:
            budgets = budgets * rows(alive).astype(budgets.dtype)

        def body(carry, b):
            params, opt, rng = carry
            step = round_idx * cfg.steps_per_round + b
            xb, yb = v_take(x, y, counts, step)
            rng, sub = jax.random.split(rng)
            drop_keys = rows(jax.random.split(sub, n))
            new_params, new_opt, loss = v_step(params, opt, xb, yb, step,
                                               drop_keys)
            active = (b < budgets).astype(jnp.float32)

            def mix(new, old):
                a = active.reshape(active.shape + (1,) * (new.ndim - 1))
                return (a * new.astype(jnp.float32)
                        + (1 - a) * old.astype(jnp.float32)).astype(old.dtype)

            params = jax.tree.map(mix, new_params, params)
            opt = jax.tree.map(mix, new_opt, opt)
            return (params, opt, rng), jnp.mean(loss)

        (params, opt, rng), losses = jax.lax.scan(
            body, (params, opt, rng), jnp.arange(cfg.steps_per_round))
        return params, opt, rng, loss_reduce(jnp.mean(losses))

    return local_training


def _make_delivery_mask(exp, *, rows):
    """Exogenous per-edge Bernoulli link failures (the paper's
    no-synchronization model), drawn over the FULL [N, max_deg] layout and
    row-sliced so every backend sees the same draws."""
    cfg = exp.train
    nbr_valid = exp.nbr_valid

    def delivery_mask(rng):
        if cfg.participation >= 1.0:
            return rows(nbr_valid)
        u = jax.random.uniform(rng, nbr_valid.shape)
        return rows(nbr_valid * (u < cfg.participation).astype(jnp.float32))

    return delivery_mask


def _make_gradient_exchange(exp):
    """CFA-GE second phase (vmap backend only): neighbours evaluate our
    aggregated model on their data; we descend along the p_ij-weighted mean
    of their gradients."""
    cfg = exp.train
    batcher = exp.batcher
    counts = exp.counts
    nbr_idx, nbr_weight = exp.nbr_idx, exp.nbr_weight
    x_pad, y_pad = exp.x_pad, exp.y_pad
    n = exp.n
    max_deg = int(nbr_idx.shape[1])
    v_grad = jax.vmap(exp._grad_fn, in_axes=(0, 0, 0, 0))

    def gradient_exchange(params, mask, round_idx, rng):
        bs = cfg.batch_size

        def body(acc, d):
            j = nbr_idx[:, d]  # [n] neighbour ids in slot d
            cj = counts[j]
            base = (round_idx * max_deg + d) * bs
            bidx = (base + jnp.arange(bs, dtype=jnp.int32)[None, :]) * batcher.stride
            bidx = bidx % jnp.maximum(cj[:, None], 1)
            xj = x_pad[j[:, None], bidx]  # [n, bs, ...]
            yj = y_pad[j[:, None], bidx]
            keys = jax.random.split(jax.random.fold_in(rng, d), n)
            g = v_grad(params, xj, yj, keys)  # grad of F_j at w_i
            w_d = nbr_weight[:, d] * mask[:, d]

            def add(a, gi):
                wb = w_d.reshape((n,) + (1,) * (gi.ndim - 1))
                return a + wb * gi.astype(jnp.float32)

            return jax.tree.map(add, acc, g), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        acc, _ = jax.lax.scan(body, zeros, jnp.arange(max_deg))
        tot = jnp.sum(nbr_weight * mask, axis=1)  # [n]
        safe = jnp.maximum(tot, 1e-9)
        lr_ge = cfg.ge_lr if cfg.ge_lr is not None else cfg.lr

        def apply(p, a):
            wb = (1.0 / safe).reshape((n,) + (1,) * (a.ndim - 1))
            gate = (tot > 0).astype(jnp.float32).reshape((n,) + (1,) * (a.ndim - 1))
            return (p.astype(jnp.float32) - lr_ge * gate * wb * a).astype(p.dtype)

        return jax.tree.map(apply, params, acc)

    return gradient_exchange


# ------------------------------------------------------------- vmap backend

def _build_vmap_round(exp):
    """Op-for-op the legacy simulator round, with the method's behaviour
    supplied by the strategy hooks instead of an agg-kind dispatch."""
    cfg, strategy, agg_state = exp.train, exp.strategy, exp.agg_state
    nbr_idx = exp.nbr_idx
    transport = exp.transport

    local_training = _make_local_training(
        exp, x=exp.x_pad, y=exp.y_pad, counts=exp.counts,
        rows=_identity_rows, loss_reduce=_identity_rows)
    delivery_mask = _make_delivery_mask(exp, rows=_identity_rows)

    def gossip_aggregate(params, gathered, mask):
        return strategy.aggregate(exp, agg_state, params, gathered, mask)

    if strategy.grad_exchange:
        gradient_exchange = _make_gradient_exchange(exp)

    degrees = jnp.sum(exp.nbr_valid, axis=1)
    total_edges = jnp.sum(degrees)  # directed edge count

    def comm_round_fn(params, opt, comm_state, round_idx, rng):
        """The round with the per-NODE transport in the middle: encode ->
        (event-triggered, possibly failing) wire -> decode -> aggregate.
        With the fp32 codec and threshold 0 this is bit-for-bit the plain
        round (same rng stream, identical payload values)."""
        params, opt, rng, train_loss = local_training(params, opt, round_idx,
                                                      rng)
        rng, sub = jax.random.split(rng)
        link = delivery_mask(sub)  # exogenous failures (participation)
        if transport.wants_rng:
            rng, ck = jax.random.split(rng)
        else:
            ck = None
        decoded, gate, comm_state = transport.exchange(params, comm_state, ck)
        # `decoded` rows of silent nodes hold their cached last-sent model,
        # so "stale" aggregates them at full weight (masking only neighbours
        # that have NEVER transmitted — their cache is still the zero
        # bootstrap reference); "drop" masks any silent node like a failed
        # link.
        if transport.config.on_silence == "drop":
            mask = edge_delivery(gate, link, nbr_idx)
        else:
            mask = edge_delivery(comm_state.ever_sent, link, nbr_idx)
        gathered = strategy.exchange(exp, decoded, nbr_idx)
        params = gossip_aggregate(params, gathered, mask)
        # a transmitting node broadcasts one payload per outgoing edge;
        # failed links still burn the sender's bytes.  Return the edge COUNT
        # (small, exact in f32) — the byte multiply happens in Python so
        # exact accounting survives past f32's 2^24 integers.
        sent_edges = jnp.sum(gate * degrees)
        return (params, opt, comm_state, rng, train_loss,
                sent_edges, sent_edges / total_edges)

    def edge_comm_round_fn(params, opt, comm_state, round_idx, rng):
        """The per-EDGE transport round: every directed link carries its own
        reference/residual/threshold, so the link mask feeds the exchange
        (link-layer ack) and the transport hands back both the
        receiver-layout gathered models (fresh or per-link stale cache) and
        the aggregation mask.  Same rng stream as comm_round_fn, so fp32 +
        threshold 0 + policy "fixed" is bit-for-bit the legacy round
        (pinned in tests/test_comm_per_edge.py)."""
        params, opt, rng, train_loss = local_training(params, opt, round_idx,
                                                      rng)
        rng, sub = jax.random.split(rng)
        link = delivery_mask(sub)  # exogenous failures (participation)
        if transport.wants_rng:
            rng, ck = jax.random.split(rng)
        else:
            ck = None
        gathered, mask, gate, comm_state = transport.exchange(
            params, comm_state, link, ck)
        params = gossip_aggregate(params, gathered, mask)
        # unicast accounting: one payload per FIRED edge (a silent edge of
        # an otherwise-sending node costs nothing); failed links still burn
        # the sender's bytes.
        sent_edges = jnp.sum(gate)
        trig = sent_edges / jnp.float32(transport.num_edges)
        return (params, opt, comm_state, rng, train_loss,
                sent_edges, trig)

    def round_fn(params, opt, round_idx, rng):
        params, opt, rng, train_loss = local_training(params, opt, round_idx,
                                                      rng)
        rng, sub = jax.random.split(rng)
        mask = delivery_mask(sub)

        if strategy.kind == "server":
            params = strategy.aggregate(exp, agg_state, params, params, mask)
        elif strategy.kind == "none":
            pass
        else:
            gathered = strategy.exchange(exp, params, nbr_idx)
            params = gossip_aggregate(params, gathered, mask)
            if strategy.grad_exchange:
                rng, sub = jax.random.split(rng)
                params = gradient_exchange(params, mask, round_idx, sub)

        return params, opt, rng, train_loss

    # ---- dynamics variants: same rounds with the realized graph threaded
    # through (see module docstring).  Written as separate bodies so the
    # static path stays op-for-op untouched; under `StaticGraph` these are
    # bit-identical to the plain bodies (pinned in tests/test_dynamics.py).
    if exp.bound_dyn is not None:
        realize = _make_realize(exp)
        nbr_valid = exp.nbr_valid

        def dyn_round_fn(params, opt, dyn_state, round_idx, rng):
            dyn_state, ev, rng = realize(dyn_state, round_idx, rng)
            params, opt, rng, train_loss = local_training(
                params, opt, round_idx, rng, alive=ev.alive)
            rng, sub = jax.random.split(rng)
            mask = delivery_mask(sub) * ev.live
            old = params
            if strategy.kind == "server":
                params = strategy.aggregate(exp, agg_state, params, params,
                                            mask)
            elif strategy.kind == "none":
                pass
            else:
                gathered = strategy.exchange(exp, params, nbr_idx)
                params = gossip_aggregate(params, gathered, mask)
                if strategy.grad_exchange:
                    rng, sub = jax.random.split(rng)
                    params = gradient_exchange(params, mask, round_idx, sub)
            params = _freeze_dead(params, old, ev.alive)
            return (params, opt, dyn_state, rng, train_loss,
                    jnp.sum(ev.live))

        def dyn_comm_round_fn(params, opt, comm_state, dyn_state, round_idx,
                              rng):
            """comm_round_fn on the realized graph: dead senders are vetoed
            (send_mask), a rejoined node's row returns to bootstrap before
            the exchange, and a transmitting node pays for its LIVE
            outgoing edges only (a non-existent link carries nothing)."""
            dyn_state, ev, rng = realize(dyn_state, round_idx, rng)
            params, opt, rng, train_loss = local_training(
                params, opt, round_idx, rng, alive=ev.alive)
            rng, sub = jax.random.split(rng)
            link = delivery_mask(sub) * ev.live
            if transport.wants_rng:
                rng, ck = jax.random.split(rng)
            else:
                ck = None
            comm_state = transport.reset_rows(comm_state, ev.rejoined)
            decoded, gate, comm_state = transport.exchange(
                params, comm_state, ck, send_mask=ev.alive)
            if transport.config.on_silence == "drop":
                mask = edge_delivery(gate, link, nbr_idx)
            else:
                mask = edge_delivery(comm_state.ever_sent, link, nbr_idx)
            gathered = strategy.exchange(exp, decoded, nbr_idx)
            new_params = gossip_aggregate(params, gathered, mask)
            params = _freeze_dead(new_params, params, ev.alive)
            live_deg = jnp.sum(ev.live, axis=1)
            live_total = jnp.sum(ev.live)
            sent_edges = jnp.sum(gate * live_deg)
            trig = sent_edges / jnp.maximum(live_total, 1.0)
            return (params, opt, comm_state, dyn_state, rng, train_loss,
                    sent_edges, trig, live_total)

        def dyn_edge_comm_round_fn(params, opt, comm_state, dyn_state,
                                   round_idx, rng):
            """edge_comm_round_fn on the realized graph: the transport gets
            the live mask (dead edges cannot fire, their controller state
            freezes) and the reset mask (every edge incident to a rejoined
            node returns to bootstrap)."""
            dyn_state, ev, rng = realize(dyn_state, round_idx, rng)
            params, opt, rng, train_loss = local_training(
                params, opt, round_idx, rng, alive=ev.alive)
            rng, sub = jax.random.split(rng)
            link = delivery_mask(sub) * ev.live
            if transport.wants_rng:
                rng, ck = jax.random.split(rng)
            else:
                ck = None
            rj = ev.rejoined
            reset = jnp.maximum(rj[:, None], rj[nbr_idx]) * nbr_valid
            gathered, mask, gate, comm_state = transport.exchange(
                params, comm_state, link, ck, live=ev.live, reset=reset)
            new_params = gossip_aggregate(params, gathered, mask)
            params = _freeze_dead(new_params, params, ev.alive)
            sent_edges = jnp.sum(gate)
            live_total = jnp.sum(ev.live)
            trig = sent_edges / jnp.maximum(live_total, 1.0)
            return (params, opt, comm_state, dyn_state, rng, train_loss,
                    sent_edges, trig, live_total)

        if transport is None:
            return dyn_round_fn
        return (dyn_edge_comm_round_fn
                if isinstance(transport, EdgeGossipTransport)
                else dyn_comm_round_fn)

    if transport is None:
        return round_fn
    return (edge_comm_round_fn if isinstance(transport, EdgeGossipTransport)
            else comm_round_fn)


# -------------------------------------------------------- shard_map backend

def _build_shardmap_round(exp):
    """The same round shard_mapped over the pod axis (see module docstring).

    All mesh axes are manual (`check_rep=False`) following
    `repro.dist.dfl_step.build_dfl_round_shardmap`; each pod holds its
    nodes' full replicas, so per-node reductions (Eq. 5's global norm, the
    trigger's drift) are complete blockwise and only the model exchange
    crosses pods.
    """
    mesh = exp.mesh
    if mesh is None or NODE_AXIS not in mesh.shape:
        raise ValueError(
            f"backend 'shard_map' needs a mesh with a {NODE_AXIS!r} axis; "
            f"pass mesh= or use backend='vmap'")
    n = exp.n
    n_pods = int(mesh.shape[NODE_AXIS])
    if n % n_pods:
        raise ValueError(f"{n} DFL nodes do not tile the {n_pods}-pod axis")
    per_pod = n // n_pods
    strategy = exp.strategy
    transport = exp.transport
    if strategy.grad_exchange:
        raise NotImplementedError(
            f"method {exp.method.name!r} (gradient exchange) is vmap-only; "
            f"use backend='vmap'")
    if isinstance(transport, EdgeGossipTransport):
        raise NotImplementedError(
            "the per-edge transport is vmap-only (its reverse-slot gather "
            "crosses pods); use backend='vmap' or per_edge=False")

    cfg = exp.train
    nbr_idx, nbr_valid = exp.nbr_idx, exp.nbr_valid
    counts = exp.counts
    agg_state = exp.agg_state
    degrees = jnp.sum(nbr_valid, axis=1)
    total_edges = jnp.sum(degrees)

    def block_rows(i0):
        def rows(a):
            return jax.lax.dynamic_slice_in_dim(a, i0, per_pod, axis=0)
        return rows

    def gather_rows(a_blk):
        return jax.lax.all_gather(a_blk, NODE_AXIS, axis=0, tiled=True)

    def pmean(x):
        return jax.lax.pmean(x, NODE_AXIS)

    def block_prelude(params, opt, round_idx, rng, x_blk, y_blk, alive=None):
        """Local training + participation draw for this pod's rows; returns
        the row slicer so callers share the replicated randomness."""
        rows = block_rows(jax.lax.axis_index(NODE_AXIS) * per_pod)
        local_training = _make_local_training(
            exp, x=x_blk, y=y_blk, counts=rows(counts), rows=rows,
            loss_reduce=pmean)
        delivery_mask = _make_delivery_mask(exp, rows=rows)
        params, opt, rng, train_loss = local_training(params, opt, round_idx,
                                                      rng, alive=alive)
        rng, sub = jax.random.split(rng)
        link = delivery_mask(sub)
        return rows, params, opt, rng, train_loss, link

    def aggregate_block(rows, params, gathered, mask):
        state_blk = (jax.tree.map(rows, agg_state)
                     if strategy.kind == "gossip" else agg_state)
        return strategy.aggregate(exp, state_blk, params, gathered, mask)

    def plain_block(params, opt, round_idx, rng, x_blk, y_blk):
        rows, params, opt, rng, train_loss, link = block_prelude(
            params, opt, round_idx, rng, x_blk, y_blk)
        if strategy.kind == "server":
            full = jax.tree.map(gather_rows, params)
            params = aggregate_block(rows, params, full, link)
        elif strategy.kind == "gossip":
            full = jax.tree.map(gather_rows, params)
            gathered = strategy.exchange(exp, full, rows(nbr_idx))
            params = aggregate_block(rows, params, gathered, link)
        return params, opt, rng, train_loss

    def comm_block(params, opt, comm_state, round_idx, rng, x_blk, y_blk):
        """comm_round_fn blockwise: the trigger/codec run on the pod's own
        rows (state sharded with them), the all_gather moves the decoded
        reconstructions + gates, aggregation runs on the block."""
        rows, params, opt, rng, train_loss, link = block_prelude(
            params, opt, round_idx, rng, x_blk, y_blk)
        if transport.wants_rng:
            rng, ck = jax.random.split(rng)
            keys = rows(jax.random.split(ck, n))
        else:
            keys = jnp.zeros((per_pod, 2), jnp.uint32)
        w_blk, _ = tree_flatten_stacked(params)
        new_last, gate, comm_state = transport.exchange_rows(
            w_blk, comm_state, keys)
        decoded = transport._unflatten(gather_rows(new_last))  # [N, ...]
        gate_full = gather_rows(gate)
        if transport.config.on_silence == "drop":
            mask = edge_delivery(gate_full, link, rows(nbr_idx))
        else:
            ever_full = gather_rows(comm_state.ever_sent)
            mask = edge_delivery(ever_full, link, rows(nbr_idx))
        gathered = strategy.exchange(exp, decoded, rows(nbr_idx))
        params = aggregate_block(rows, params, gathered, mask)
        sent_edges = jax.lax.psum(jnp.sum(gate * rows(degrees)), NODE_AXIS)
        return (params, opt, comm_state, rng, train_loss,
                sent_edges, sent_edges / total_edges)

    # ---- dynamics variants: the process transition runs REPLICATED inside
    # the block (its state is a global graph quantity and its coins come
    # from the replicated rng stream), then every per-node consumer slices
    # the realized event to its rows — the same discipline as every other
    # randomness, so the lowering stays bit-identical to vmap.
    if exp.bound_dyn is not None:
        realize = _make_realize(exp)

        def dyn_plain_block(params, opt, dyn_state, round_idx, rng, x_blk,
                            y_blk):
            dyn_state, ev, rng = realize(dyn_state, round_idx, rng)
            rows, params, opt, rng, train_loss, link = block_prelude(
                params, opt, round_idx, rng, x_blk, y_blk, alive=ev.alive)
            link = link * rows(ev.live)
            old = params
            if strategy.kind == "server":
                full = jax.tree.map(gather_rows, params)
                params = aggregate_block(rows, params, full, link)
            elif strategy.kind == "gossip":
                full = jax.tree.map(gather_rows, params)
                gathered = strategy.exchange(exp, full, rows(nbr_idx))
                params = aggregate_block(rows, params, gathered, link)
            params = _freeze_dead(params, old, rows(ev.alive))
            return (params, opt, dyn_state, rng, train_loss,
                    jnp.sum(ev.live))

        def dyn_comm_block(params, opt, comm_state, dyn_state, round_idx,
                           rng, x_blk, y_blk):
            """comm_block on the realized graph: transport state rows are
            reset/vetoed with their pod's slice of the event; bytes count
            live outgoing edges only."""
            dyn_state, ev, rng = realize(dyn_state, round_idx, rng)
            rows, params, opt, rng, train_loss, link = block_prelude(
                params, opt, round_idx, rng, x_blk, y_blk, alive=ev.alive)
            link = link * rows(ev.live)
            if transport.wants_rng:
                rng, ck = jax.random.split(rng)
                keys = rows(jax.random.split(ck, n))
            else:
                keys = jnp.zeros((per_pod, 2), jnp.uint32)
            comm_state = transport.reset_rows(comm_state, rows(ev.rejoined))
            w_blk, _ = tree_flatten_stacked(params)
            new_last, gate, comm_state = transport.exchange_rows(
                w_blk, comm_state, keys, send_mask=rows(ev.alive))
            decoded = transport._unflatten(gather_rows(new_last))  # [N, ...]
            gate_full = gather_rows(gate)
            if transport.config.on_silence == "drop":
                mask = edge_delivery(gate_full, link, rows(nbr_idx))
            else:
                ever_full = gather_rows(comm_state.ever_sent)
                mask = edge_delivery(ever_full, link, rows(nbr_idx))
            gathered = strategy.exchange(exp, decoded, rows(nbr_idx))
            new_params = aggregate_block(rows, params, gathered, mask)
            params = _freeze_dead(new_params, params, rows(ev.alive))
            live_deg = jnp.sum(ev.live, axis=1)  # [N], replicated
            live_total = jnp.sum(ev.live)
            sent_edges = jax.lax.psum(jnp.sum(gate * rows(live_deg)),
                                      NODE_AXIS)
            trig = sent_edges / jnp.maximum(live_total, 1.0)
            return (params, opt, comm_state, dyn_state, rng, train_loss,
                    sent_edges, trig, live_total)
    else:
        dyn_plain_block = dyn_comm_block = None

    shard = P(NODE_AXIS)
    rep = P()
    if transport is None:
        if exp.bound_dyn is not None:
            sharded = shard_map(
                dyn_plain_block, mesh,
                in_specs=(shard, shard, rep, rep, rep, shard, shard),
                out_specs=(shard, shard, rep, rep, rep, rep),
                check_rep=False)

            def dyn_round_fn(params, opt, dyn_state, round_idx, rng):
                return sharded(params, opt, dyn_state, round_idx, rng,
                               exp.x_pad, exp.y_pad)

            return dyn_round_fn

        sharded = shard_map(
            plain_block, mesh,
            in_specs=(shard, shard, rep, rep, shard, shard),
            out_specs=(shard, shard, rep, rep),
            check_rep=False)

        def round_fn(params, opt, round_idx, rng):
            return sharded(params, opt, round_idx, rng, exp.x_pad, exp.y_pad)

        return round_fn

    if exp.bound_dyn is not None:
        sharded = shard_map(
            dyn_comm_block, mesh,
            in_specs=(shard, shard, shard, rep, rep, rep, shard, shard),
            out_specs=(shard, shard, shard, rep, rep, rep, rep, rep, rep),
            check_rep=False)

        def dyn_comm_round_fn(params, opt, comm_state, dyn_state, round_idx,
                              rng):
            return sharded(params, opt, comm_state, dyn_state, round_idx,
                           rng, exp.x_pad, exp.y_pad)

        return dyn_comm_round_fn

    sharded = shard_map(
        comm_block, mesh,
        in_specs=(shard, shard, shard, rep, rep, shard, shard),
        out_specs=(shard, shard, shard, rep, rep, rep, rep),
        check_rep=False)

    def comm_round_fn(params, opt, comm_state, round_idx, rng):
        return sharded(params, opt, comm_state, round_idx, rng,
                       exp.x_pad, exp.y_pad)

    return comm_round_fn
