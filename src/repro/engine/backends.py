"""`build_round(experiment)`: ONE round body, two lowerings.

Lowers an :class:`~repro.engine.Experiment` to a jit-able round function —
Algorithm 1's (local SGD steps → neighbour exchange → aggregation) as ONE
XLA program per round.  Every strategy × transport × dynamics combination
shares a single round body, written once against the transport layer's
:class:`~repro.comm.PodContext` (a row-slice + all-gather pair), and the
two backends differ ONLY in the context they bind:

  * ``vmap``      — the dense context (identity slice, identity gather):
    every per-node quantity vmapped over the full node axis — the small-N
    oracle;
  * ``shard_map`` — explicit shard_map over the "pod" mesh axis: each pod
    owns N/n_pods nodes' params, optimizer state, data shards and
    sender-private transport rows; the context's gather is a tiled
    `all_gather` over the pod ring carrying the transport's ENCODED payload
    by default (`Experiment(wire=...)` selects the decoded-rows oracle
    wire), and receiver-facing transport caches are replicated so the
    per-edge reverse-slot gather and the CFA-GE neighbour walk read them
    without further collectives.  Everything per-node — training, trigger,
    codec, aggregation, gradient exchange — runs with the SAME per-row ops
    as the dense context, so the two backends agree bit-for-bit (pinned in
    tests/test_engine.py and tests/test_exchange_unified.py on the
    4-device CPU mesh, across the full capability roster).

The round function's calling convention is ONE generic shape over the
four optional scan-carried subsystem states — the transport's comm state,
the `repro.dynamics` process state, the `repro.timing` event clock, and
the `repro.obs` telemetry accumulators — each present iff the experiment
carries the subsystem:

  (params, opt, *states, round_idx, rng)
    -> (params, opt, *states, rng, loss, *extras)

with `states` the present members of (comm_state, dyn_state, time_state,
obs_state) in that order, and `extras` the present accounting groups, in
the same order: (sent_edges, trig_frac) with a transport, (live_edges,)
with dynamics, (sim_time, arrived_edges) with timing, and (obs_snapshot,)
— a dict of per-round channel values — with telemetry.  The no-subsystem
case degenerates to the legacy (params, opt, round_idx, rng) -> (params,
opt, rng, loss).

With dynamics, the round starts by realizing this round's graph (one pure
state transition -> a GraphEvent): a dead node runs zero local steps and
its params/opt state freeze bit-exactly, the delivery mask is intersected
with the live-edge mask, transports only fire (and only account bytes) on
live edges, a node that rejoins after churn has its per-link transport
state reset before the exchange, and server-style aggregation intersects
its data-size weights with the live mask (an offline client's frozen
params carry zero weight).  `trig_frac` is the fired fraction of LIVE
directed edges; `live_edges` their count.  An OBSERVING process
(`EnergyChurn`) additionally receives the event clock's previous-round
realized per-node compute cost as its transition observation.

With timing, the round is priced in simulated seconds.  Under
`Schedule(deadline=d)` each round is a deadline TICK: node i's local-step
budget is capped at `floor(d / dt_i)` (stragglers train fewer steps), and
a payload on edge (j -> i) ARRIVES iff `t_cost_j + transfer_ji <= d`
(send time = the sender's realized compute; transfer = latency +
payload_bytes / bandwidth from the bound `repro.timing` tables).  The
arrival mask is intersected with the link/live masks in THIS one round
body — a late payload is indistinguishable from a failed link: the sender
burns its bytes, per-edge state freezes, and the silence path (stale
cache / drop) covers the receiver.  Without a deadline the schedule stays
synchronous — budgets are uncapped, everything arrives, and the tick is
the round's realized makespan (slowest node + slowest live transfer) — so
the degenerate model is bit-identical to timing=None by construction (no
extra rng is ever consumed: all time tables are bound numpy constants).

Method behaviour enters exclusively through the experiment's strategy
:class:`~repro.engine.Capabilities` record (kind / grad_exchange) and the
strategy's exchange/aggregate hooks — there is no method branching here
beyond the declared capabilities, and every capability lowers to every
backend.

Randomness discipline (the bit-exactness mechanism): every rng consumption
— per-step dropout keys, hetero step budgets, participation masks, codec
keys, gradient-exchange minibatch keys, and the dynamics process's edge
coins — is computed from the REPLICATED rng stream over the full node axis
and then row-sliced per block, so the shard_map lowering sees exactly the
values the vmap lowering sees.  Only data movement (the gather) differs,
and the transport's two wires carry bit-identical information by
construction (decode is deterministic).  A process that needs no rng
(StaticGraph, PeriodicRewiring) consumes none, which is what makes
`dynamics=StaticGraph()` bit-identical to `dynamics=None`.

Byte accounting is exact and replicated: the fired-edge gates come back
full-axis from the exchange, so `sent_edges` is the same full-array sum on
every pod (small integers, exact in f32) and the `payload_bytes ×
sent_edges` multiply happens in Python where it survives past f32's 2^24.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.comm import (DENSE_CTX, EdgeGossipTransport, PodContext,
                        SparseEdgeGossipTransport)
from repro.comm.trigger import edge_delivery
from repro.dist.sharding import NODE_AXIS
from repro.engine.neighborhood import DenseNeighborhood, SparseNeighborhood
from repro.timing import TimingState
from repro.utils.pytree import tree_flatten_stacked

BACKENDS = ("vmap", "shard_map")


def _and_masks(*ms):
    """Product of the non-None {0,1} float masks (None = all-ones = skip);
    None if every factor is absent.  Exact {0,1} products, so composition
    order cannot affect bits."""
    ms = [m for m in ms if m is not None]
    if not ms:
        return None
    out = ms[0]
    for m in ms[1:]:
        out = out * m
    return out


def build_round(exp):
    """Lower `exp` to its jit-able round function (see module docstring)."""
    if exp.backend == "vmap":
        return _build_vmap_round(exp)
    if exp.backend == "shard_map":
        return _build_shardmap_round(exp)
    raise ValueError(
        f"unknown backend {exp.backend!r}; available: {BACKENDS}")


# ------------------------------------------------------------ shared pieces

def _identity_rows(a):
    return a


def _freeze_dead(new_params, old_params, alive):
    """Per-node select: rows with alive == 0 keep their old value bit-exactly
    (gossip masks already guarantee it for aggregation; this also covers
    server-style strategies that would overwrite an offline device)."""
    def sel(nw, od):
        a = alive.reshape(alive.shape + (1,) * (nw.ndim - 1)) > 0
        return jnp.where(a, nw, od)

    return jax.tree.map(sel, new_params, old_params)


def _make_realize(exp):
    """The dynamics prelude: consume (at most) one rng split and run the
    process transition, yielding this round's GraphEvent.  An observing
    process additionally receives `obs` — the event clock's previous-round
    realized per-node compute cost (zeros at round 0)."""
    bound = exp.bound_dyn
    step, needs_rng, observes = bound.step, bound.needs_rng, bound.observes

    def realize(dyn_state, round_idx, rng, obs=None):
        if needs_rng:
            rng, dk = jax.random.split(rng)
        else:
            dk = None
        if observes:
            dyn_state, ev = step(dyn_state, round_idx, dk, obs)
        else:
            dyn_state, ev = step(dyn_state, round_idx, dk)
        return dyn_state, ev, rng

    return realize


def _make_local_training(exp, *, x, y, counts, rows, loss_reduce):
    """B local SGD(momentum) minibatch steps (Alg. 1 l.4-9) for the block of
    nodes whose data is (x, y, counts); `rows` slices globally-computed
    [N, ...] randomness to the block (identity on the vmap backend).
    `alive` ([N], optional) zeroes the step budget of churned-out devices —
    an offline node trains nothing and its params/opt state freeze.
    `cap` ([N] int32, optional) is the event clock's deadline cap
    (`floor(deadline / dt_i)`): a straggler trains only the steps that fit
    in the tick.  Returns the FULL-axis realized budgets alongside, so the
    clock can price each node's round at `budget_i * dt_i` seconds."""
    cfg = exp.train
    n = exp.n
    batcher = exp.batcher

    def take_batch(xx, yy, c, step):
        return batcher.take(xx, yy, c, step)

    v_take = jax.vmap(take_batch, in_axes=(0, 0, 0, None))
    v_step = jax.vmap(exp._train_step, in_axes=(0, 0, 0, 0, None, 0))

    def local_training(params, opt, round_idx, rng, alive=None, cap=None):
        # Heterogeneous E (Alg. 1): per-node step budget for this round;
        # nodes past their budget keep their params (masked update).
        # Budgets are computed FULL-axis (replicated rng, then capped and
        # alive-masked) and row-sliced, so every pod prices every node.
        if cfg.hetero_steps_min > 0:
            rng, sub = jax.random.split(rng)
            budgets_full = jax.random.randint(
                sub, (n,), cfg.hetero_steps_min, cfg.steps_per_round + 1)
        else:
            budgets_full = jnp.full((n,), cfg.steps_per_round, jnp.int32)
        if cap is not None:
            budgets_full = jnp.minimum(budgets_full, cap)
        if alive is not None:
            budgets_full = budgets_full * alive.astype(budgets_full.dtype)
        budgets = rows(budgets_full)

        def body(carry, b):
            params, opt, rng = carry
            step = round_idx * cfg.steps_per_round + b
            xb, yb = v_take(x, y, counts, step)
            rng, sub = jax.random.split(rng)
            drop_keys = rows(jax.random.split(sub, n))
            new_params, new_opt, loss = v_step(params, opt, xb, yb, step,
                                               drop_keys)
            active = (b < budgets).astype(jnp.float32)

            def mix(new, old):
                a = active.reshape(active.shape + (1,) * (new.ndim - 1))
                return (a * new.astype(jnp.float32)
                        + (1 - a) * old.astype(jnp.float32)).astype(old.dtype)

            params = jax.tree.map(mix, new_params, params)
            opt = jax.tree.map(mix, new_opt, opt)
            return (params, opt, rng), jnp.mean(loss)

        (params, opt, rng), losses = jax.lax.scan(
            body, (params, opt, rng), jnp.arange(cfg.steps_per_round))
        return params, opt, rng, loss_reduce(jnp.mean(losses)), budgets_full

    return local_training


def _make_delivery_mask(exp):
    """Exogenous per-edge Bernoulli link failures (the paper's
    no-synchronization model), drawn over the FULL [N, max_deg] layout
    (consumers row-slice at the use site, so every backend sees the same
    draws)."""
    cfg = exp.train
    nbr_valid = exp.nbr_valid

    def delivery_mask(rng):
        if cfg.participation >= 1.0:
            return nbr_valid
        u = jax.random.uniform(rng, nbr_valid.shape)
        return nbr_valid * (u < cfg.participation).astype(jnp.float32)

    return delivery_mask


def _make_gradient_exchange(exp):
    """CFA-GE second phase: neighbours evaluate our aggregated model on
    their data; we descend along the p_ij-weighted mean of their gradients.
    Runs per block row: `rows` slices the neighbour table and the
    replicated minibatch keys; the neighbour DATA is read out of the full
    (replicated) padded arrays, which is what lets the walk cross pods
    without a collective."""
    cfg = exp.train
    batcher = exp.batcher
    counts = exp.counts
    nbr_idx, nbr_weight = exp.nbr_idx, exp.nbr_weight
    x_pad, y_pad = exp.x_pad, exp.y_pad
    n = exp.n
    max_deg = int(nbr_idx.shape[1])
    v_grad = jax.vmap(exp._grad_fn, in_axes=(0, 0, 0, 0))

    def gradient_exchange(rows, params, mask, round_idx, rng):
        bs = cfg.batch_size
        nbr_idx_r = rows(nbr_idx)
        nbr_w_r = rows(nbr_weight)
        r = int(nbr_idx_r.shape[0])

        def body(carry, d):
            acc, tot = carry
            j = nbr_idx_r[:, d]  # [r] neighbour ids in slot d
            cj = counts[j]
            base = (round_idx * max_deg + d) * bs
            bidx = (base + jnp.arange(bs, dtype=jnp.int32)[None, :]) * batcher.stride
            bidx = bidx % jnp.maximum(cj[:, None], 1)
            xj = x_pad[j[:, None], bidx]  # [r, bs, ...]
            yj = y_pad[j[:, None], bidx]
            keys = rows(jax.random.split(jax.random.fold_in(rng, d), n))
            g = v_grad(params, xj, yj, keys)  # grad of F_j at w_i
            w_d = nbr_w_r[:, d] * mask[:, d]

            def add(a, gi):
                wb = w_d.reshape((r,) + (1,) * (gi.ndim - 1))
                return a + wb * gi.astype(jnp.float32)

            return (jax.tree.map(add, acc, g), tot + w_d), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        # totals ride the same scan as the gradient accumulator (not a
        # separate jnp.sum), so a walk truncated to any slot width that
        # covers every real neighbour — the sparse layout's power-of-two
        # bucket widths — accumulates bit-identical (acc, tot) pairs: the
        # trailing slots add exact +0 weights to a carry that starts at +0.
        (acc, tot), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((r,), jnp.float32)), jnp.arange(max_deg))
        safe = jnp.maximum(tot, 1e-9)
        lr_ge = cfg.ge_lr if cfg.ge_lr is not None else cfg.lr

        def apply(p, a):
            wb = (1.0 / safe).reshape((r,) + (1,) * (a.ndim - 1))
            gate = (tot > 0).astype(jnp.float32).reshape((r,) + (1,) * (a.ndim - 1))
            return (p.astype(jnp.float32) - lr_ge * gate * wb * a).astype(p.dtype)

        return jax.tree.map(apply, params, acc)

    return gradient_exchange


def _make_sparse_gradient_exchange(exp):
    """CFA-GE second phase on the sparse layout: the SAME slot walk as
    `_make_gradient_exchange`, run over each width bucket's ragged slot
    tables instead of the `[N, max_deg]` panel.

    Bucket slot k of receiver i IS dense slot k — both enumerate i's CSR
    in-edges sender-ascending — so the minibatch base is computed with the
    GLOBAL dense max_degree and the per-slot keys fold the same k: every
    real slot consumes bit-identical neighbour data, dropout keys and
    composed weights.  Trailing zero-weight slots (a bucket's power-of-two
    width vs max_degree, in either direction) are neutral because both the
    gradient accumulator and the totals ride the scan carry from +0, and
    their padding sources (node 0's data, zero params on dummy rows) are
    finite.  Dummy bucket rows land on the [R+1] trash row and are sliced
    away, mirroring the SparseNeighborhood scatter."""
    cfg = exp.train
    batcher = exp.batcher
    counts = exp.counts
    x_pad, y_pad = exp.x_pad, exp.y_pad
    n = exp.n
    plan = exp.sparse_plan
    max_deg = int(exp.topo.max_degree)
    per_pod = plan.per_pod
    v_grad = jax.vmap(exp._grad_fn, in_axes=(0, 0, 0, 0))

    def take(a, pod):
        return jax.lax.dynamic_index_in_dim(a, pod, axis=0, keepdims=False)

    def pad_row(p):
        return jnp.concatenate([p, jnp.zeros((1,) + p.shape[1:], p.dtype)])

    def gradient_exchange(ctx, params, link_u, live_e, round_idx, rng):
        bs = cfg.batch_size
        pod = ctx.pod if ctx.pod is not None else jnp.int32(0)
        lr_ge = cfg.ge_lr if cfg.ge_lr is not None else cfg.lr
        out = params
        for wd in plan.widths:
            bk = plan.buckets[wd]
            rows_local = take(bk.rows_local, pod)   # [B]
            src = take(bk.src, pod)                 # [B, wd]
            wgt = take(bk.wgt, pod)                 # [B, wd]
            epos = take(bk.epos, pod)               # [B, wd]
            b = int(rows_local.shape[0])
            m = jnp.ones_like(wgt)
            if cfg.participation < 1.0:
                m = m * (link_u[epos] < cfg.participation).astype(jnp.float32)
            if live_e is not None:
                m = m * live_e[epos]
            w_slot = wgt * m                        # [B, wd]
            p_b = jax.tree.map(lambda p: pad_row(p)[rows_local], params)
            gid = jnp.clip(pod * per_pod + rows_local, 0, n - 1)

            def body(carry, k):
                acc, tot = carry
                j = src[:, k]  # [b] sender ids in slot k
                cj = counts[j]
                base = (round_idx * max_deg + k) * bs
                bidx = (base + jnp.arange(bs, dtype=jnp.int32)[None, :]) \
                    * batcher.stride
                bidx = bidx % jnp.maximum(cj[:, None], 1)
                xj = x_pad[j[:, None], bidx]  # [b, bs, ...]
                yj = y_pad[j[:, None], bidx]
                keys = jax.random.split(jax.random.fold_in(rng, k), n)[gid]
                g = v_grad(p_b, xj, yj, keys)  # grad of F_j at w_i
                w_k = w_slot[:, k]

                def add(a, gi):
                    wb = w_k.reshape((b,) + (1,) * (gi.ndim - 1))
                    return a + wb * gi.astype(jnp.float32)

                return (jax.tree.map(add, acc, g), tot + w_k), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), p_b)
            (acc, tot), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((b,), jnp.float32)), jnp.arange(wd))
            safe = jnp.maximum(tot, 1e-9)

            def apply(p, a):
                wb = (1.0 / safe).reshape((b,) + (1,) * (a.ndim - 1))
                gate = (tot > 0).astype(jnp.float32).reshape(
                    (b,) + (1,) * (a.ndim - 1))
                return (p.astype(jnp.float32)
                        - lr_ge * gate * wb * a).astype(p.dtype)

            new_b = jax.tree.map(apply, p_b, acc)
            out = jax.tree.map(
                lambda o, nb: pad_row(o).at[rows_local].set(nb)[:o.shape[0]],
                out, new_b)
        return out

    return gradient_exchange


# ----------------------------------------------------------- the round body

def _make_round_body(exp, *, loss_reduce):
    """The ONE round body, written against a PodContext.

    Returns ``body(ctx, params, opt, comm_state, dyn_state, time_state,
    obs_state, round_idx, rng, x, y)`` -> the full 14-slot tuple
    ``(params, opt, comm_state, dyn_state, time_state, obs_state, rng,
    loss, sent_edges, trig_frac, live_edges, sim_time, arrived_edges,
    obs_snapshot)`` with ``None`` in the slots the experiment does not
    carry (the backend wrappers squeeze those out to the documented
    calling conventions).  All branching below is on STATIC configuration
    — capabilities, transport type, dynamics/timing/telemetry presence —
    so each experiment traces exactly one path.
    """
    cfg, strategy, agg_state = exp.train, exp.strategy, exp.agg_state
    caps = strategy.capabilities
    transport = exp.transport
    per_edge = isinstance(transport,
                          (EdgeGossipTransport, SparseEdgeGossipTransport))
    wire = exp.wire
    nbr_idx, nbr_valid, nbr_weight = exp.nbr_idx, exp.nbr_valid, exp.nbr_weight
    counts = exp.counts
    n = exp.n
    has_dyn = exp.bound_dyn is not None
    realize = _make_realize(exp) if has_dyn else None
    dyn_observes = has_dyn and exp.bound_dyn.observes
    has_time = exp.bound_timing is not None
    has_obs = exp.bound_obs is not None
    tele = exp.bound_obs
    bt = exp.bound_timing
    deadline = exp.deadline if has_time else None
    step_time = bt.step_time if has_time else None
    transfer_e = bt.transfer_e if has_time else None
    transfer_panel = bt.transfer_panel if has_time else None
    sparse = exp.layout == "sparse"
    plan = exp.sparse_plan if sparse else None
    # Does this round exchange payloads over the graph?  Controls whether
    # the synchronous-mode clock tick includes the slowest live link's
    # landing time on top of the compute makespan.
    exchanges = (exp.transport is not None) or caps.kind == "gossip"
    # Gossip aggregation lowers to the strategy's flat form whenever one is
    # declared: one weighted neighbour reduce over a Neighborhood view, the
    # SAME code on both layouts (the dense view is the small-N oracle for
    # the sparse one, so the dense lowering must go through it too).  The
    # per-edge transport also lowers to it — its per-link caches cannot be
    # a single [N, D] table, so the Neighborhood is built over the
    # transport's pre-gathered panel instead (same kernel, same bits; this
    # is what keeps per-edge fp32/thr0 bit-exact vs the per-node round).
    # The padded-gather form remains only for strategies without a flat
    # form.
    use_flat = (caps.kind == "gossip"
                and strategy.flat_aggregate is not None)
    if sparse:
        degrees = plan.degrees
        total_edges = jnp.float32(plan.num_directed)
        delivery_mask = None
        edge_src = jnp.asarray(exp.topo.edge_src.astype(np.int32))
        edge_dst = jnp.asarray(exp.topo.edge_dst.astype(np.int32))
    else:
        delivery_mask = _make_delivery_mask(exp)
        degrees = jnp.sum(nbr_valid, axis=1)
        total_edges = jnp.sum(degrees)  # directed edge count
    if caps.grad_exchange:
        gradient_exchange = (_make_sparse_gradient_exchange(exp) if sparse
                             else _make_gradient_exchange(exp))

    def aggregate(rows, params, gathered, mask):
        state = (jax.tree.map(rows, agg_state) if caps.kind == "gossip"
                 else agg_state)
        return strategy.aggregate(exp, state, params, gathered, mask)

    def body(ctx, params, opt, comm_state, dyn_state, time_state, obs_state,
             round_idx, rng, x, y):
        rows = ctx.rows
        local_training = _make_local_training(
            exp, x=x, y=y, counts=rows(counts), rows=rows,
            loss_reduce=loss_reduce)

        # -- dynamics prelude: realize this round's graph ------------------
        if has_dyn:
            obs = (time_state.last_cost
                   if has_time and dyn_observes else None)
            dyn_state, ev, rng = realize(dyn_state, round_idx, rng, obs)
            alive = ev.alive
        else:
            ev, alive = None, None

        # -- event-clock prelude: per-node step times + deadline cap -------
        # A deadline tick caps node i at floor(deadline / dt_i) local steps
        # (a straggler trains fewer); without a deadline (synchronous mode)
        # the budgets are untouched and the tick stretches to the realized
        # makespan below.  Timing consumes NO rng: dt comes from the bound
        # model's numpy draws keyed at bind time.
        if has_time:
            dt = step_time(round_idx)
            if deadline is not None:
                cap = jnp.minimum(
                    jnp.floor(jnp.float32(deadline) / dt),
                    jnp.float32(cfg.steps_per_round)).astype(jnp.int32)
            else:
                cap = None
        else:
            dt = cap = None

        # -- Alg. 1 l.4-9: local SGD (dead nodes run zero steps) -----------
        params, opt, rng, train_loss, budgets_full = local_training(
            params, opt, round_idx, rng, alive=alive, cap=cap)
        # realized per-node compute cost this round (0 for dead nodes)
        t_cost = (budgets_full.astype(jnp.float32) * dt if has_time
                  else None)

        # -- exogenous link failures ∩ the live graph ----------------------
        # The split happens unconditionally on both layouts so the rng
        # stream stays aligned; the DRAWS differ by layout (dense draws the
        # [N, max_deg] panel, sparse one uniform per directed edge), which
        # is why oracle equivalence is stated at participation == 1.0 —
        # there, neither layout draws at all.
        rng, sub = jax.random.split(rng)
        # Arrival under a deadline tick: edge (j -> i)'s payload lands at
        # t_cost_j + latency_ji + bytes/bandwidth_ji and is delivered iff it
        # lands by the deadline.  A late payload is EXACTLY a failed link —
        # same freeze/stale/drop silence path, sender's bytes still burned.
        if sparse:
            link_full = arr_full = None
            link_u = (jax.random.uniform(sub, (plan.num_directed,))
                      if cfg.participation < 1.0 else None)
            arr_e = ((t_cost[edge_src] + transfer_e
                      <= jnp.float32(deadline)).astype(jnp.float32)
                     if deadline is not None else None)
        else:
            link_u = arr_e = None
            link_full = delivery_mask(sub)
            if has_dyn:
                link_full = link_full * ev.live
            if deadline is not None:
                arr_full = (t_cost[nbr_idx] + transfer_panel
                            <= jnp.float32(deadline)).astype(
                                jnp.float32) * nbr_valid
                link_full = link_full * arr_full
            else:
                arr_full = None
        old_params = params

        def flat_gossip(params, gate_vec, table_mat=None, edge_mask=None,
                        mask_full=None):
            """The flat-form gossip update: flatten the block's models,
            build the layout's Neighborhood over the full [N, D] table
            (gathered here unless the transport already decoded one), and
            run the strategy's flat aggregate.  `gate_vec` [N] {0,1} is the
            senders' broadcast gate; `edge_mask` [E] {0,1} is the sparse
            layout's per-edge factor (liveness ∩ arrival ∩ delivery
            history); `mask_full` [N, max_deg] {0,1} is the dense layout's
            fully-composed counterpart — when given it REPLACES the default
            gate·link composition (the per-node transport computes its
            silence semantics there)."""
            local_mat, unflatten = tree_flatten_stacked(params)
            if table_mat is None:
                table_mat = ctx.gather(local_mat)
            if sparse:
                pod = ctx.pod if ctx.pod is not None else jnp.int32(0)
                nb = SparseNeighborhood(plan, pod, table_mat, local_mat,
                                        unflatten, gate_vec, link_u,
                                        cfg.participation,
                                        edge_mask=edge_mask)
            else:
                if mask_full is not None:
                    w = rows(nbr_weight) * rows(mask_full)
                else:
                    w = rows(nbr_weight) * edge_delivery(
                        gate_vec, rows(link_full), rows(nbr_idx))
                nb = DenseNeighborhood(table_mat, rows(nbr_idx), w,
                                       local_mat, unflatten)
            state = jax.tree.map(rows, agg_state)
            return strategy.flat_aggregate(exp, state, nb)

        # -- the exchange + aggregation, by declared capability ------------
        # With telemetry, each transport branch also captures its fired /
        # delivered edge masks in the RECEIVER orientation (the dense
        # [N, max_deg] panel or the flat [E] bank — the same full-axis
        # replicated quantities the byte accounting sums, so the channel
        # accumulators agree with `sent_edges` exactly).
        sent_edges = trig = new_comm = None
        obs_fired = obs_deliv = None
        if transport is None:
            if caps.kind == "server":
                # server-style: global average over the full stack, with
                # data-size weights intersected with liveness — an offline
                # client's frozen params carry zero weight (the all-ones
                # mask without dynamics is an exact no-op).
                full = jax.tree.map(ctx.gather, params)
                params = aggregate(rows, params, full, alive)
            elif caps.kind == "gossip":
                if use_flat:
                    params = flat_gossip(
                        params, jnp.ones((n,), jnp.float32),
                        edge_mask=_and_masks(
                            ev.live if sparse and has_dyn else None, arr_e))
                else:
                    full = jax.tree.map(ctx.gather, params)
                    gathered = strategy.exchange(exp, full, rows(nbr_idx))
                    params = aggregate(rows, params, gathered,
                                       rows(link_full))
                if caps.grad_exchange:
                    rng, sub = jax.random.split(rng)
                    if sparse:
                        params = gradient_exchange(
                            ctx, params, link_u,
                            _and_masks(ev.live if has_dyn else None, arr_e),
                            round_idx, sub)
                    else:
                        params = gradient_exchange(rows, params,
                                                   rows(link_full),
                                                   round_idx, sub)
            # kind == "none": isolation — no communication at all.
        elif per_edge:
            # per-EDGE transport: every directed link carries its own
            # reference/residual/threshold; the full link mask feeds the
            # exchange (link-layer ack through the layout swap) and the
            # transport hands back both the receiver-layout gathered models
            # (fresh or per-link stale cache) and the aggregation mask.
            if transport.wants_rng:
                rng, ck = jax.random.split(rng)
            else:
                ck = None
            if sparse:
                # flat [E] path: a CSR directed edge id is both the sender-
                # and receiver-layout address of its link, so participation
                # draws, liveness and rejoin resets compose per edge id and
                # the transport returns the per-edge reconstruction bank
                # the SparseNeighborhood addresses by CSR position — no
                # layout swap, no reverse-slot gather.
                link_e = (jnp.ones((plan.num_directed,), jnp.float32)
                          if link_u is None
                          else (link_u < cfg.participation).astype(
                              jnp.float32))
                if has_dyn:
                    rj = ev.rejoined
                    reset = jnp.maximum(rj[edge_src], rj[edge_dst])
                    live = ev.live
                    link_e = link_e * live
                else:
                    reset = live = None
                if arr_e is not None:
                    # a late payload is a failed link: the receiver's
                    # per-edge cache freezes and its bank serves the stale
                    # (or dropped) reconstruction, bit-identically.
                    link_e = link_e * arr_e
                edge_table, mask_e, gate_full, new_comm = transport.exchange(
                    params, comm_state, link_e, ck, live=live, reset=reset,
                    ctx=ctx, wire=wire)
                # participation/liveness/gates are already folded into the
                # [E] masks, so the view gets no gate_vec/link_u of its own.
                local_mat, unflatten = tree_flatten_stacked(params)
                pod = ctx.pod if ctx.pod is not None else jnp.int32(0)
                nb = SparseNeighborhood(
                    plan, pod, None, local_mat, unflatten, None, None, 1.0,
                    edge_table=edge_table, edge_mask=mask_e)
                params = strategy.flat_aggregate(
                    exp, jax.tree.map(rows, agg_state), nb)
                if has_obs:
                    obs_fired = gate_full
                    obs_deliv = gate_full * link_e
            else:
                if has_dyn:
                    rj = ev.rejoined
                    reset = jnp.maximum(rj[:, None], rj[nbr_idx]) * nbr_valid
                    live = ev.live
                else:
                    reset = live = None
                gathered, mask, gate_full, new_comm = transport.exchange(
                    params, comm_state, link_full, ck, live=live,
                    reset=reset, ctx=ctx, wire=wire)
                if use_flat:
                    # flat form over the transport's pre-gathered per-link
                    # panel (no single [N, D] table exists: slot models are
                    # per-link stale caches), composed weights ω·|D|·mask —
                    # the same kernel reduce as the per-node path, so
                    # fp32/thr0 stays bit-exact against it.
                    local_mat, unflatten = tree_flatten_stacked(params)
                    panel = jnp.concatenate(
                        [l.reshape(l.shape[0], l.shape[1], -1)
                          .astype(jnp.float32)
                         for l in jax.tree.leaves(gathered)], axis=2)
                    nb = DenseNeighborhood(None, None,
                                           rows(nbr_weight) * mask,
                                           local_mat, unflatten, panel=panel)
                    params = strategy.flat_aggregate(
                        exp, jax.tree.map(rows, agg_state), nb)
                else:
                    params = aggregate(rows, params, gathered, mask)
                if has_obs:
                    obs_fired = transport.recv_layout(gate_full)
                    obs_deliv = obs_fired * link_full
            # unicast accounting: one payload per FIRED edge (a silent edge
            # of an otherwise-sending node costs nothing); failed links
            # still burn the sender's bytes.
            sent_edges = jnp.sum(gate_full)
            if has_dyn:
                trig = sent_edges / jnp.maximum(jnp.sum(ev.live), 1.0)
            else:
                trig = sent_edges / jnp.float32(transport.num_edges)
        else:
            # per-NODE transport: encode -> (event-triggered, possibly
            # failing) wire -> decode -> aggregate.  With the fp32 codec
            # and threshold 0 this is bit-for-bit the plain round (same rng
            # stream, identical payload values).
            if transport.wants_rng:
                rng, ck = jax.random.split(rng)
            else:
                ck = None
            if has_dyn:
                # a rejoined node's row returns to bootstrap before the
                # exchange; dead senders are vetoed outright.
                comm_state = transport.reset_rows(comm_state, ev.rejoined,
                                                  ctx=ctx)
                send_mask = rows(ev.alive)
            else:
                send_mask = None
            decoded, gate_full, new_comm = transport.exchange(
                params, comm_state, ck, send_mask=send_mask, ctx=ctx,
                wire=wire)
            # `decoded` rows of silent nodes hold their cached last-sent
            # model, so "stale" aggregates them at full weight — masking
            # only edges that have NEVER DELIVERED, whose receiver-side
            # cache is still the zero bootstrap reference.  Delivery, not
            # transmission: a payload sent into a dead/failed/late link
            # never reached this receiver, so `ever_recv` must not flip
            # (the old `ever_sent` gate flipped on send and let receivers
            # aggregate bootstrap zeros as if they were models).  "drop"
            # masks any silent or undelivered edge like a failed link.
            stale = transport.config.on_silence != "drop"
            if sparse:
                live_e = ev.live if has_dyn else None
                # current-round exogenous edge factors (participation is
                # applied inside the Neighborhood view via link_u)
                cur_e = _and_masks(live_e, arr_e)
                part_e = ((link_u < cfg.participation).astype(jnp.float32)
                          if link_u is not None else None)
                delivered_e = _and_masks(gate_full[edge_src], part_e,
                                         live_e, arr_e)
                new_comm = transport.note_delivery(new_comm, delivered_e)
                if has_obs:
                    obs_fired = (gate_full[edge_src] * ev.live if has_dyn
                                 else gate_full[edge_src])
                    obs_deliv = delivered_e
                if stale:
                    params = flat_gossip(
                        params, None,
                        table_mat=tree_flatten_stacked(decoded)[0],
                        edge_mask=_and_masks(cur_e, new_comm.ever_recv))
                else:
                    params = flat_gossip(
                        params, gate_full,
                        table_mat=tree_flatten_stacked(decoded)[0],
                        edge_mask=cur_e)
            else:
                delivered_full = edge_delivery(gate_full, link_full,
                                               nbr_idx)
                new_comm = transport.note_delivery(new_comm, delivered_full)
                if has_obs:
                    obs_fired = gate_full[nbr_idx] * (ev.live if has_dyn
                                                      else nbr_valid)
                    obs_deliv = delivered_full
                if stale:
                    mask_full = link_full * new_comm.ever_recv
                else:
                    mask_full = delivered_full
                if use_flat:
                    params = flat_gossip(
                        params, None,
                        table_mat=tree_flatten_stacked(decoded)[0],
                        mask_full=mask_full)
                else:
                    gathered = strategy.exchange(exp, decoded,
                                                 rows(nbr_idx))
                    params = aggregate(rows, params, gathered,
                                       rows(mask_full))
            # broadcast accounting: a transmitting node pays one payload
            # per outgoing edge — its LIVE outgoing edges under dynamics (a
            # non-existent link carries nothing); failed links still burn
            # the sender's bytes.
            if has_dyn:
                if sparse:
                    # Σ_e gate[src_e]·live_e — the flat-edge form of the
                    # dense gate·live_outdeg sum (both are sums of exact
                    # small integers, so f32 accumulates them exactly).
                    sent_edges = jnp.sum(gate_full[edge_src] * ev.live)
                else:
                    live_deg = jnp.sum(ev.live, axis=1)
                    sent_edges = jnp.sum(gate_full * live_deg)
                trig = sent_edges / jnp.maximum(jnp.sum(ev.live), 1.0)
            else:
                sent_edges = jnp.sum(gate_full * degrees)
                trig = sent_edges / total_edges

        # -- dynamics epilogue: freeze the dead, count the live ------------
        if has_dyn:
            params = _freeze_dead(params, old_params, rows(ev.alive))
            live_total = jnp.sum(ev.live)
        else:
            live_total = None

        # -- event-clock epilogue: advance the simulated clock -------------
        # Deadline mode ticks by exactly `deadline` (the round IS the tick);
        # synchronous mode ticks by the realized makespan — the slowest
        # node's compute, stretched to the slowest LIVE link's landing time
        # when the round exchanges payloads (everyone waits for everyone:
        # that is the cost the deadline frontier is measured against).
        if has_time:
            if deadline is not None:
                tick = jnp.float32(deadline)
            else:
                tick = jnp.max(t_cost)
                if exchanges:
                    if sparse:
                        lv = (ev.live if has_dyn
                              else jnp.ones_like(transfer_e))
                        land = lv * (t_cost[edge_src] + transfer_e)
                    else:
                        lv = ev.live if has_dyn else nbr_valid
                        land = lv * (t_cost[nbr_idx] + transfer_panel)
                    tick = jnp.maximum(tick, jnp.max(land))
            sim_t = time_state.t + tick
            if deadline is not None:
                if sparse:
                    arr_live = (arr_e * ev.live if has_dyn else arr_e)
                else:
                    arr_live = (arr_full * ev.live if has_dyn
                                else arr_full)
                arrived = jnp.sum(arr_live)
            else:
                # no deadline: every live edge's payload arrives
                arrived = (jnp.sum(ev.live) if has_dyn else total_edges)
            new_time = TimingState(t=sim_t, last_cost=t_cost)
        else:
            sim_t = arrived = new_time = None

        # -- telemetry epilogue: channel arithmetic on the carried dict ----
        # Pure full-axis arithmetic over quantities the round already
        # computed (no rng, no extra collectives — the params-reading
        # consensus/drift probes live OUTSIDE the round, gated to eval
        # rounds by the runner), so `telemetry=None` stays bit-identical
        # by construction.
        if has_obs:
            obs_state, obs_out = tele.step(
                obs_state, budgets=budgets_full, t_cost=t_cost,
                fired=obs_fired, delivered=obs_deliv)
        else:
            obs_out = None

        return (params, opt, new_comm, dyn_state, new_time, obs_state, rng,
                train_loss, sent_edges, trig, live_total, sim_t, arrived,
                obs_out)

    return body


def _squeeze(out):
    """Drop the None slots of the full 14-tuple, yielding the documented
    per-configuration calling convention (the slot ORDER is fixed, so the
    surviving entries line up with the module-docstring signatures)."""
    return tuple(o for o in out if o is not None)


def _unpack_states(exp, rest):
    """Split a round_fn's positional tail ``(*states, round_idx, rng)``
    into the body's fixed slots, with None for the states the experiment
    does not carry.  States appear in (comm, dyn, time, obs) order."""
    rest = list(rest)
    comm_state = rest.pop(0) if exp.transport is not None else None
    dyn_state = rest.pop(0) if exp.bound_dyn is not None else None
    time_state = rest.pop(0) if exp.bound_timing is not None else None
    obs_state = rest.pop(0) if exp.bound_obs is not None else None
    round_idx, rng = rest
    return comm_state, dyn_state, time_state, obs_state, round_idx, rng


# ------------------------------------------------------------- vmap backend

def _build_vmap_round(exp):
    """The dense lowering: the round body under the identity context."""
    body = _make_round_body(exp, loss_reduce=_identity_rows)
    x, y = exp.x_pad, exp.y_pad

    def round_fn(params, opt, *rest):
        comm_state, dyn_state, time_state, obs_state, round_idx, rng = \
            _unpack_states(exp, rest)
        return _squeeze(body(DENSE_CTX, params, opt, comm_state, dyn_state,
                             time_state, obs_state, round_idx, rng, x, y))

    return round_fn


# -------------------------------------------------------- shard_map backend

def _build_shardmap_round(exp):
    """The same round body shard_mapped over the pod axis.

    All mesh axes are manual (`check_rep=False`) following
    `repro.dist.dfl_step.build_dfl_round_shardmap`; each pod holds its
    nodes' full replicas, so per-node reductions (Eq. 5's global norm, the
    trigger's drift) are complete blockwise and only the exchange's gather
    crosses pods.  Transport state splits by the transport's `state_specs`:
    sender-private rows (residuals, per-edge thresholds/EMAs) shard with
    their pod; receiver-facing caches (`last_sent`, the ever-sent/-delivered
    flags) are replicated and recomputed identically on every pod from the
    gathered wire, which is what lets the per-edge reverse-slot gather and
    the CFA-GE neighbour walk run blockwise.
    """
    mesh = exp.mesh
    if mesh is None or NODE_AXIS not in mesh.shape:
        raise ValueError(
            f"backend 'shard_map' needs a mesh with a {NODE_AXIS!r} axis; "
            f"pass mesh= or use backend='vmap'")
    n = exp.n
    n_pods = int(mesh.shape[NODE_AXIS])
    if n % n_pods:
        raise ValueError(f"{n} DFL nodes do not tile the {n_pods}-pod axis")
    per_pod = n // n_pods
    transport = exp.transport
    has_comm = transport is not None
    has_dyn = exp.bound_dyn is not None
    has_time = exp.bound_timing is not None
    has_obs = exp.bound_obs is not None

    def pmean(v):
        return jax.lax.pmean(v, NODE_AXIS)

    body = _make_round_body(exp, loss_reduce=pmean)

    def make_ctx():
        pod = jax.lax.axis_index(NODE_AXIS)
        i0 = pod * per_pod

        def rows(a):
            return jax.lax.dynamic_slice_in_dim(a, i0, per_pod, axis=0)

        def gather(a):
            return jax.lax.all_gather(a, NODE_AXIS, axis=0, tiled=True)

        return PodContext(rows=rows, gather=gather, pod=pod)

    shard = P(NODE_AXIS)
    rep = P()
    # State specs in (comm, dyn, time, obs) order.  Dynamics state, the
    # TimingState (scalar clock + [N] last-cost) and the telemetry
    # accumulator dict (full-axis channel sums) are fully replicated:
    # every pod advances them identically from replicated rng/masks, the
    # same discipline that keeps the backends bit-identical everywhere
    # else.  Transport state splits by the transport's own `state_specs`;
    # the single `rep` spec is a pytree PREFIX covering every leaf of the
    # telemetry dict.
    state_specs = []
    if has_comm:
        state_specs.append(transport.state_specs(shard, rep))
    if has_dyn:
        state_specs.append(rep)
    if has_time:
        state_specs.append(rep)
    if has_obs:
        state_specs.append(rep)
    state_specs = tuple(state_specs)
    # Replicated extras past (rng, loss):
    # (sent, trig | live | sim_t, arr | obs_snapshot).
    n_extras = 2 * has_comm + has_dyn + 2 * has_time + has_obs

    def block(params, opt, *rest):
        comm_state, dyn_state, time_state, obs_state, round_idx, rng = \
            _unpack_states(exp, rest[:-2])
        x, y = rest[-2:]
        return _squeeze(body(make_ctx(), params, opt, comm_state, dyn_state,
                             time_state, obs_state, round_idx, rng, x, y))

    sharded = shard_map(
        block, mesh,
        in_specs=(shard, shard) + state_specs + (rep, rep, shard, shard),
        out_specs=((shard, shard) + state_specs + (rep, rep)
                   + (rep,) * n_extras),
        check_rep=False)

    def round_fn(params, opt, *rest):
        return sharded(params, opt, *rest, exp.x_pad, exp.y_pad)

    return round_fn
