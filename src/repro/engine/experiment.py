"""`Experiment`: the single front door for decentralized-learning runs.

    Experiment(world, method, comm=..., backend=..., schedule=...).run()

packages the paper's whole experimental procedure — heterogeneous per-node
init, B local SGD(momentum) steps, neighbour exchange (optionally through
the repro.comm gossip transport), method aggregation, periodic evaluation —
behind one object:

  * `world`    — the physical problem: model, topology, per-node datasets,
    test set (:class:`World`, or `World.synthetic(...)` for the paper's
    synthetic setups), optionally with a `repro.dynamics.GraphProcess`
    making the topology time-varying (edge dropout, bursty links, churn,
    rewiring — see docs/dynamics.md);
  * `method`   — a name in the strategy registry (`available_methods()`;
    plug in your own with `register_method`);
  * `comm`     — optional `repro.comm.CommConfig`: codecs, event triggers,
    per-edge state, exact bytes-on-wire accounting.  The per-node or
    per-edge transport is selected from the config and the strategy's
    declared :class:`~repro.engine.Capabilities` — never by caller
    branching — and every transport runs on every backend;
  * `backend`  — "vmap" (one jitted program over the stacked node axis) or
    "shard_map" (the same program over the "pod" mesh axis, one block of
    nodes per pod; bit-identical to vmap, see engine.backends);
  * `wire`     — what the shard_map exchange gathers: "encoded" (default —
    the codec payload crosses the pod interconnect; every pod decodes the
    same bytes) or "decoded" (the reconstructed fp32 rows — the small-N
    oracle).  Bit-identical by construction; a no-op under vmap;
  * `schedule` — rounds / eval cadence / execution mode: "fused" compiles
    the WHOLE schedule (K rounds + gated evals) into one `lax.scan` program
    dispatched once, "loop" dispatches one XLA call per round (the legacy
    behaviour; same math bit-for-bit, see BENCH_engine.json for the
    rounds/sec gap).

Mutable run state (params, optimizer and transport state, rng, byte
accounting) lives on the instance so `run()` can be called repeatedly and
metrics continue where the last call stopped.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time as _time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import (WIRES, CommConfig, EdgeGossipTransport,
                        GossipTransport, SparseEdgeGossipTransport)
from repro.core.virtual_teacher import make_loss_fn
from repro.data.allocation import pad_node_datasets
from repro.data.pipeline import Batcher
from repro.dist.sharding import NODE_AXIS
from repro.dynamics import GraphProcess
from repro.engine import backends
from repro.engine.neighborhood import build_sparse_plan
from repro.engine.strategies import MethodSpec, get_method
from repro.fl.metrics import RoundMetrics
from repro.fl.trainer import make_eval_fn, make_grad_fn, make_train_step
from repro.graphs.sparse import SparseTopology
from repro.graphs.topology import Topology
from repro.models.api import SmallModel
from repro.obs import (RunLedger, Telemetry, log_round, round_record,
                       run_manifest)
from repro.optim.sgd import sgd_momentum
from repro.timing import Timing
from repro.utils.pytree import tree_flatten_stacked

SCHEDULE_MODES = ("fused", "loop")
LAYOUTS = ("dense", "sparse")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Local-training and aggregation hyper-parameters (Alg. 1 knobs)."""

    steps_per_round: int = 4   # B in Alg. 1 (minibatch steps between exchanges)
    batch_size: int = 32
    lr: float = 1e-3
    momentum: float = 0.9
    beta: float = 0.95         # VT confidence (Eq. 7)
    s: float = 1.0             # DecDiff damping (Eq. 5)
    participation: float = 1.0  # per-neighbour delivery probability per round
    seed: int = 0
    eval_batch: int = 128
    ge_lr: Optional[float] = None  # CFA-GE gradient-apply LR (default: lr)
    # Heterogeneous local training (paper Alg. 1: E "is not necessarily the
    # same at all nodes"): per-node number of local steps per round, sampled
    # uniformly from [min, steps_per_round].  0 disables (= homogeneous).
    hetero_steps_min: int = 0


@dataclasses.dataclass(frozen=True)
class Schedule:
    """How many rounds, how often to eval, and how the rounds execute.

    `deadline` (simulated seconds; requires `World(timing=...)`) turns each
    round into an event-clock DEADLINE TICK: a node trains as many local
    steps as fit in the deadline (capped at `steps_per_round` — stragglers
    train fewer), and a payload is aggregated only if `send_time + latency
    + bytes/bandwidth <= deadline`; late arrivals fall into the existing
    stale/drop silence paths.  `deadline=None` keeps the schedule
    synchronous — every round waits for the slowest node and link and the
    clock merely reports the makespan.  See docs/timing.md."""

    rounds: int = 100
    eval_every: int = 5
    mode: str = "fused"  # "fused" (one lax.scan program) | "loop" (per-round)
    deadline: Optional[float] = None  # simulated seconds per round tick

    def __post_init__(self):
        if self.mode not in SCHEDULE_MODES:
            raise ValueError(f"schedule mode must be one of {SCHEDULE_MODES}, "
                             f"got {self.mode!r}")
        if self.deadline is not None and not self.deadline > 0:
            raise ValueError(f"deadline must be > 0 simulated seconds, "
                             f"got {self.deadline}")

    @staticmethod
    def eval_rounds(rounds: int, eval_every: int):
        """The eval cadence (the single source both schedule modes use):
        after round 0, every `eval_every` rounds, and after the last
        round."""
        return [r for r in range(rounds)
                if r % eval_every == 0 or r == rounds - 1]


@dataclasses.dataclass
class World:
    """The physical problem: who talks to whom, over what data.

    `topo` is either a dense :class:`~repro.graphs.Topology` (padded
    [N, max_deg] layout, the small-N default) or a
    :class:`~repro.graphs.SparseTopology` (CSR edge list — the 10^4+-node
    layout; `Experiment` selects the matching engine automatically, see
    `Experiment(layout=...)`).

    `dynamics` optionally makes "who talks to whom" time-varying: a
    :class:`repro.dynamics.GraphProcess` (edge dropout, Gilbert–Elliott
    bursty links, node churn, periodic rewiring, …) that realizes a
    per-round live-edge mask over the topology — `topo` then describes the
    POSSIBLE links and the process decides which exist each round.  See
    docs/dynamics.md."""

    model: SmallModel
    topo: "Topology | SparseTopology"
    xs: List[np.ndarray]       # per-node train inputs
    ys: List[np.ndarray]       # per-node train labels
    x_test: np.ndarray
    y_test: np.ndarray
    dynamics: Optional[GraphProcess] = None
    # Optional event clock (repro.timing): per-node step times and per-edge
    # latency/bandwidth pricing each round in simulated seconds.  With
    # `Schedule(deadline=...)` the rounds become deadline ticks (stragglers
    # train fewer steps, late payloads miss the round); without one the
    # schedule stays synchronous and the clock reports the makespan.
    timing: Optional[Timing] = None
    # Optional telemetry (repro.obs): opt-in per-node/per-edge channel
    # accumulators riding the scan carry (consensus/drift probes, exact
    # per-edge bytes, staleness ages, ...), a schema-validated JSONL run
    # ledger, and Chrome-trace export of the event clock.  `telemetry=None`
    # is bit-identical to an engine without the subsystem.  See
    # docs/observability.md.
    telemetry: Optional[Telemetry] = None

    @classmethod
    def synthetic(cls, dataset: str = "synth-mnist", nodes: int = 16,
                  topology: str = "erdos_renyi", seed: int = 0,
                  scale: float = 0.05, min_per_class: int = 1,
                  model: Optional[SmallModel] = None,
                  dynamics: Optional[GraphProcess] = None,
                  timing: Optional[Timing] = None,
                  telemetry: Optional[Telemetry] = None, **topo_kwargs):
        """The paper's synthetic worlds in one call: seeded dataset,
        complex-network topology (extra kwargs go to the graph builder,
        e.g. p=0.25 for ER, m=2 for BA), truncated-Zipf non-IID split."""
        import inspect

        from repro.data import make_dataset, zipf_allocation
        from repro.data.allocation import split_by_allocation
        from repro.graphs import make_topology
        from repro.graphs.topology import TOPOLOGY_BUILDERS
        from repro.models.mlp_cnn import model_for_dataset

        ds = make_dataset(dataset, seed=seed, scale=scale)
        builder = TOPOLOGY_BUILDERS.get(topology)
        if builder is not None and \
                "seed" in inspect.signature(builder).parameters:
            topo_kwargs.setdefault("seed", seed)
        topo = make_topology(topology, n=nodes, **topo_kwargs)
        alloc = zipf_allocation(ds.y_train, nodes, seed=seed,
                                min_per_class=min_per_class)
        xs, ys = split_by_allocation(ds.x_train, ds.y_train, alloc)
        model = model or model_for_dataset(dataset, ds.num_classes)
        return cls(model=model, topo=topo, xs=xs, ys=ys,
                   x_test=ds.x_test, y_test=ds.y_test, dynamics=dynamics,
                   timing=timing, telemetry=telemetry)


def _default_mesh(n: int):
    """A pure pod mesh over the local devices: the largest pod count that
    tiles the node axis (1 pod on a single-device host — the shard_map
    lowering then still runs, just without an actual exchange axis split)."""
    d = len(jax.devices())
    while n % d:
        d -= 1
    return jax.make_mesh((d,), (NODE_AXIS,))


class Experiment:
    """One method over one world — see module docstring."""

    def __init__(self, world: World, method: str = "decdiff+vt", *,
                 comm: Optional[CommConfig] = None, backend: str = "vmap",
                 wire: str = "encoded",
                 schedule: Optional[Schedule] = None,
                 train: Optional[TrainConfig] = None, mesh=None,
                 layout: Optional[str] = None, **train_overrides):
        if backend not in backends.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"available: {backends.BACKENDS}")
        if wire not in WIRES:
            raise ValueError(f"unknown wire {wire!r}; available: {WIRES}")
        if layout is not None and layout not in LAYOUTS:
            raise ValueError(f"unknown layout {layout!r}; "
                             f"available: {LAYOUTS}")
        self.wire = wire
        self.method: MethodSpec = get_method(method)
        self.strategy = self.method.strategy
        self.world = world
        self.backend = backend
        self.schedule = schedule or Schedule()
        train = train or TrainConfig()
        if train_overrides:
            train = dataclasses.replace(train, **train_overrides)
        self.train = train

        model, topo = world.model, world.topo
        if not (topo.num_nodes == len(world.xs) == len(world.ys)):
            raise ValueError(
                f"world has {topo.num_nodes} nodes but "
                f"{len(world.xs)}/{len(world.ys)} data shards")
        # --- node-axis layout: dense padded [N, max_deg] (the small-N
        # oracle) or sparse CSR edge list (the 10^4+-node engine).  The
        # layout follows the topology type unless overridden — dense over a
        # SparseTopology densifies it (guarded ≤4096 nodes, the oracle
        # regime); sparse over a Topology converts it, so the same world
        # can run both for equivalence pins.
        if layout is None:
            layout = "sparse" if isinstance(topo, SparseTopology) else "dense"
        self.layout = layout
        # Layout support is capability-driven: the strategy's Capabilities
        # record declares which node-axis layouts it lowers to, plus ONE
        # derived restriction — a gossip strategy without a flat_aggregate
        # form only has the padded-gather lowering, which is dense-only.
        caps = self.strategy.capabilities
        allowed = tuple(
            lo for lo in caps.layouts
            if not (lo == "sparse" and caps.kind == "gossip"
                    and self.strategy.flat_aggregate is None))
        if layout not in allowed:
            why = ("declares no flat_aggregate form, so only the dense "
                   "padded-gather lowering exists"
                   if layout in caps.layouts else
                   "declares it unsupported in its Capabilities record")
            raise ValueError(
                f"method {method!r}: strategy "
                f"{type(self.strategy).__name__} {why}; supported layouts: "
                f"{allowed}")
        if layout == "dense" and isinstance(topo, SparseTopology):
            topo = topo.to_topology()
        elif layout == "sparse" and not isinstance(topo, SparseTopology):
            topo = SparseTopology.from_topology(topo)
        # --- dynamics (repro.dynamics): bind the graph process once; it may
        # augment the static layout (rewiring compiles against the family's
        # union graph), so everything below derives from the bound topo.
        self.dynamics = world.dynamics
        self.bound_dyn = None
        if world.dynamics is not None:
            if not isinstance(world.dynamics, GraphProcess):
                raise TypeError(
                    f"World.dynamics must be a repro.dynamics.GraphProcess, "
                    f"got {type(world.dynamics).__name__}")
            self.bound_dyn = world.dynamics.bind(topo)
            topo = self.bound_dyn.topo
        self.model = model
        self.topo = topo
        self.n = topo.num_nodes
        self.mesh = (mesh if mesh is not None else
                     _default_mesh(self.n) if backend == "shard_map" else None)

        x_pad, y_pad, counts = pad_node_datasets(world.xs, world.ys)
        self.x_pad = jnp.asarray(x_pad)
        self.y_pad = jnp.asarray(y_pad.astype(np.int32))
        self.counts = jnp.asarray(counts.astype(np.int32))
        self.x_test = jnp.asarray(world.x_test)
        self.y_test = jnp.asarray(world.y_test.astype(np.int32))

        # --- graph tensors (padded dense layout OR the sparse plan) ---
        if self.layout == "sparse":
            n_pods = 1
            if backend == "shard_map" and self.mesh is not None:
                n_pods = int(dict(self.mesh.shape).get(NODE_AXIS, 1))
            self.nbr_idx = None
            self.nbr_valid = None
            self.nbr_weight = None
            self.sparse_plan = build_sparse_plan(topo, counts, n_pods)
        else:
            self.sparse_plan = None
            idx = topo.neighbor_idx.astype(np.int32)
            self.nbr_idx = jnp.asarray(np.maximum(idx, 0))
            self.nbr_valid = jnp.asarray(
                topo.neighbor_mask.astype(np.float32))
            # combined ω_ij * |D_j| weights (aggregators normalize
            # internally, which realizes p_ij = |D_j| / Σ_{N_i} |D_j| of
            # Eqs. 4/6/9).
            omega = topo.neighbor_weights()  # [N, D]
            dj = counts[np.maximum(idx, 0)].astype(np.float32)
            self.nbr_weight = jnp.asarray(omega * dj * topo.neighbor_mask)

        self.optimizer = sgd_momentum(lr=train.lr, momentum=train.momentum)
        self.loss_fn = make_loss_fn(self.method.loss, beta=train.beta)
        self.batcher = Batcher(batch_size=train.batch_size)
        self._train_step = make_train_step(self.model, self.optimizer,
                                           self.loss_fn)
        self._grad_fn = make_grad_fn(self.model, self.loss_fn)
        self._eval_raw = jax.vmap(
            make_eval_fn(self.model,
                         batch_size=min(train.eval_batch, len(world.x_test))),
            in_axes=(0, None, None),
        )
        self._eval = jax.jit(self._eval_raw)

        # --- init (heterogeneous unless the method coordinates) ---
        base = jax.random.PRNGKey(train.seed)
        if self.method.common_init:
            keys = jnp.broadcast_to(jax.random.PRNGKey(train.seed + 1),
                                    (self.n, 2))
        else:
            keys = jax.random.split(jax.random.fold_in(base, 17), self.n)
        self.params = jax.vmap(self.model.init)(keys)
        self.opt_state = jax.vmap(self.optimizer.init)(self.params)
        self.rng = jax.random.fold_in(base, 23)

        # --- gossip transport (capability-gated; repro.comm) ---
        self.comm = comm
        self.transport = None
        self.comm_state = None
        self.comm_bytes_total = 0.0
        self._trig_sum = 0.0
        self._comm_rounds = 0
        self.trig_history: List[float] = []  # per-round triggered fraction
        if comm is not None:
            if not self.strategy.capabilities.transport:
                from repro.engine.strategies import _REGISTRY
                roster = sorted(m for m, s in _REGISTRY.items()
                                if s.strategy.capabilities.transport)
                raise ValueError(
                    f"comm transport models neighbour model-gossip only; "
                    f"method {method!r} is unsupported "
                    f"(transport-capable methods: {roster})")
            if comm.use_per_edge:
                if self.layout == "sparse":
                    self.transport = SparseEdgeGossipTransport(
                        comm, self.params, topo)
                else:
                    self.transport = EdgeGossipTransport(
                        comm, self.params, topo.neighbor_idx,
                        topo.neighbor_mask)
            elif self.layout == "sparse":
                self.transport = GossipTransport(
                    comm, self.params,
                    edge_src=topo.edge_src, edge_dst=topo.edge_dst)
            else:
                self.transport = GossipTransport(
                    comm, self.params, nbr_idx=topo.neighbor_idx,
                    nbr_valid=topo.neighbor_mask)
            self.comm_state = self.transport.init_state(self.params)

        # --- dynamics state + live-edge accounting ---
        self.dyn_state = (self.bound_dyn.state0
                          if self.bound_dyn is not None else None)
        self._total_directed = (float(topo.num_directed)
                                if self.layout == "sparse"
                                else float(topo.neighbor_mask.sum()))
        self._live_sum = 0.0
        self._live_rounds = 0
        self.live_history: List[float] = []  # per-round live-edge fraction

        # --- event clock (repro.timing): bind the time models once, priced
        # from the transport's EXACT bytes-on-wire (dense fp32 model size
        # without one) ---
        self.timing = world.timing
        self.bound_timing = None
        self.time_state = None
        self.deadline = self.schedule.deadline
        if world.timing is not None:
            if not isinstance(world.timing, Timing):
                raise TypeError(
                    f"World.timing must be a repro.timing.Timing, "
                    f"got {type(world.timing).__name__}")
            if self.transport is not None:
                payload = float(self.transport.payload_bytes)
            else:
                flat, _ = tree_flatten_stacked(self.params)
                payload = 4.0 * float(flat.shape[1])
            self.bound_timing = world.timing.bind(topo, payload)
            self.time_state = self.bound_timing.state0
        elif self.deadline is not None:
            raise ValueError(
                "Schedule(deadline=...) prices rounds in simulated seconds "
                "and needs World(timing=...) to define them")
        if (self.bound_dyn is not None and self.bound_dyn.observes
                and self.bound_timing is None):
            raise ValueError(
                f"dynamics process {self.bound_dyn.name!r} observes the "
                f"event clock's per-node compute cost; give the world a "
                f"repro.timing.Timing (World(timing=...))")
        self.sim_time = 0.0
        self.sim_time_history: List[float] = []  # absolute seconds per round
        self._arrived_sum = 0.0
        self._arrived_rounds = 0
        self.arrived_history: List[float] = []  # per-round arrived fraction

        # --- telemetry (repro.obs): bind the channel selection once; the
        # accumulator dict becomes one more scan-carried state and the
        # per-round snapshots one more extras group.  The ledger (when
        # configured) opens here with the run manifest.
        self.telemetry = world.telemetry
        self.bound_obs = None
        self.obs_state = None
        # layout-native channel snapshots, one per round (ALL rounds, not
        # just eval rounds — the trace exporter diffs the cumulative
        # channels round by round)
        self.obs_history: List[Dict] = []
        self.ledger = None
        if world.telemetry is not None:
            if not isinstance(world.telemetry, Telemetry):
                raise TypeError(
                    f"World.telemetry must be a repro.obs.Telemetry, "
                    f"got {type(world.telemetry).__name__}")
            self.bound_obs = world.telemetry.bind(self)
            if self.bound_obs is not None:
                self.obs_state = self.bound_obs.state0
            if world.telemetry.ledger is not None:
                self.ledger = RunLedger(world.telemetry.ledger)
                self.ledger.write_manifest(run_manifest(self))
        # the params-reading probes (consensus/drift) are instantaneous
        # norms consumed only at eval rounds, so they run under the SAME
        # gate as the eval itself: the fused program inlines `_probes_raw`
        # in its static-flag cond, loop mode calls the jitted version at
        # eval rounds — non-eval rounds never pay the flatten + norms.
        self._probes_raw = self._probes = None
        if self.bound_obs is not None and self.bound_obs.has_probes:
            _tele = self.bound_obs

            def _probes_raw(params):
                return _tele.eval_probes(tree_flatten_stacked(params)[0])

            self._probes_raw = _probes_raw
            self._probes = jax.jit(_probes_raw)

        # --- method state + the lowered round ---
        self.agg_state = self.strategy.init_state(self)
        self._round_raw = backends.build_round(self)
        # donate the round-carried state: params, opt, then
        # comm/dyn/time/obs
        donate = tuple(range(2 + sum(self._state_flags())))
        self._round = jax.jit(self._round_raw, donate_argnums=donate)
        self._fused_cache = {}

    # ------------------------------------------------------------------
    def evaluate(self) -> RoundMetrics:
        acc, loss = self._eval(self.params, self.x_test, self.y_test)
        return RoundMetrics(round=-1, acc_per_node=np.asarray(acc),
                            loss_per_node=np.asarray(loss))

    # ------------------------------------------------------------------
    # The generic round calling convention (shared with engine.backends):
    #   round_fn(params, opt, *states, round_idx, rng)
    #     -> (params, opt, *states, rng, loss, *extras)
    # with `states` the present members of (comm_state, dyn_state,
    # time_state, obs_state) in that order and `extras` the present groups
    # of (sent, trig | live | sim_t, arrived | obs_snapshot).  Both
    # schedule modes and the fused scan body unpack by the same four flags.
    def _state_flags(self):
        return (self.transport is not None, self.bound_dyn is not None,
                self.bound_timing is not None, self.bound_obs is not None)

    def _get_states(self):
        has_comm, has_dyn, has_time, has_obs = self._state_flags()
        states = ()
        states += (self.comm_state,) if has_comm else ()
        states += (self.dyn_state,) if has_dyn else ()
        states += (self.time_state,) if has_time else ()
        states += (self.obs_state,) if has_obs else ()
        return states

    def _set_states(self, states):
        has_comm, has_dyn, has_time, has_obs = self._state_flags()
        states = list(states)
        if has_comm:
            self.comm_state = states.pop(0)
        if has_dyn:
            self.dyn_state = states.pop(0)
        if has_time:
            self.time_state = states.pop(0)
        if has_obs:
            self.obs_state = states.pop(0)
        assert not states

    def _fused_program(self, rounds: int, eval_every: int):
        """One jitted program for the whole schedule: `lax.scan` over the
        rounds with the eval gated per round by a static flag array (the
        non-eval branch is never executed, only compiled), stacking per-node
        accuracy/loss — and the per-round accounting extras (fired edges,
        live edges, simulated time) — as scan outputs."""
        key = (rounds, eval_every)
        cached = self._fused_cache.get(key)
        if cached is not None:
            return cached
        evals = set(Schedule.eval_rounds(rounds, eval_every))
        flags = np.asarray([1 if r in evals else 0 for r in range(rounds)],
                           np.int32)
        round_fn = self._round_raw
        eval_fn = self._eval_raw
        # telemetry's params probes share the eval's static gate: the
        # untaken branch returns structural zeros, so non-eval rounds
        # never execute the flatten + norm traffic
        probes_fn = self._probes_raw
        probe_zeros = (self.bound_obs.probe_zeros()
                       if probes_fn is not None else {})
        x_test, y_test, n = self.x_test, self.y_test, self.n
        n_states = sum(self._state_flags())

        def _eval_on(p):
            acc, loss = eval_fn(p, x_test, y_test)
            return acc, loss, (probes_fn(p) if probes_fn is not None
                               else {})

        def _eval_off(p):
            return (jnp.zeros((n,), jnp.float32),
                    jnp.zeros((n,), jnp.float32), probe_zeros)

        def gated_eval(flag, params):
            return jax.lax.cond(flag > 0, _eval_on, _eval_off, params)

        def body(carry, xs):
            r, flag = xs
            params, opt = carry[:2]
            states, rng = carry[2:2 + n_states], carry[-1]
            out = round_fn(params, opt, *states, r, rng)
            carry = out[:2 + n_states] + (out[2 + n_states],)  # ... + rng
            extras = out[4 + n_states:]  # everything past the loss slot
            acc, loss, probes = gated_eval(flag, carry[0])
            ys = (acc, loss) + tuple(extras)
            if probes_fn is not None:
                ys = ys + (probes,)
            return carry, ys

        def program(carry):
            return jax.lax.scan(
                body, carry,
                (jnp.arange(rounds, dtype=jnp.int32), jnp.asarray(flags)))

        fused = jax.jit(program, donate_argnums=(0,))
        self._fused_cache[key] = fused
        return fused

    def _account_comm(self, sent_edges, trig):
        """Identical (order-preserving) float accounting in both modes —
        the byte multiply stays in Python so exact accounting survives past
        f32's 2^24 integers."""
        self.comm_bytes_total += self.transport.payload_bytes * float(
            sent_edges)
        self._trig_sum += float(trig)
        self._comm_rounds += 1
        self.trig_history.append(float(trig))

    def _account_live(self, live_edges):
        """Dynamics accounting: the round's realized fraction of the static
        layout's directed edges (same Python-side discipline as comm)."""
        frac = float(live_edges) / max(self._total_directed, 1.0)
        self._live_sum += frac
        self._live_rounds += 1
        self.live_history.append(frac)

    def _account_time(self, sim_t, arrived_edges):
        """Event-clock accounting: `sim_t` is the ABSOLUTE simulated time at
        the end of the round; `arrived_edges` counts live directed edges
        whose payload made the deadline (all of them in synchronous mode).
        The arrived fraction is against the round's live edges under a
        dynamics process, the full static layout otherwise."""
        self.sim_time = float(sim_t)
        self.sim_time_history.append(self.sim_time)
        denom = (self.live_history[-1] * self._total_directed
                 if self.bound_dyn is not None else self._total_directed)
        frac = float(arrived_edges) / max(denom, 1.0)
        self._arrived_sum += frac
        self._arrived_rounds += 1
        self.arrived_history.append(frac)

    def _account_obs(self, snapshot):
        """Telemetry accounting: keep the round's layout-native channel
        snapshot (numpy) — `RoundMetrics.detail` and the trace exporter
        materialize from these on the host."""
        self.obs_history.append(jax.tree.map(np.asarray, snapshot))

    def _account_extras(self, extras):
        """Route one round's extras group-by-group (the generic convention:
        (sent, trig | live | sim_t, arrived | obs_snapshot) for the
        present subsystems)."""
        extras = list(extras)
        if self.transport is not None:
            self._account_comm(extras.pop(0), extras.pop(0))
        if self.bound_dyn is not None:
            self._account_live(extras.pop(0))
        if self.bound_timing is not None:
            self._account_time(extras.pop(0), extras.pop(0))
        if self.bound_obs is not None:
            self._account_obs(extras.pop(0))
        assert not extras

    def _finish_metrics(self, m: RoundMetrics, history, verbose,
                        probes=None):
        if self.transport is not None:
            m.bytes_on_wire = self.comm_bytes_total
            m.triggered_frac = self._trig_sum / max(self._comm_rounds, 1)
        if self.bound_dyn is not None:
            m.live_edge_frac = self._live_sum / max(self._live_rounds, 1)
        if self.bound_timing is not None:
            m.sim_time = self.sim_time
            m.arrived_frac = self._arrived_sum / max(self._arrived_rounds, 1)
        if self.bound_obs is not None and self.obs_history:
            m.detail = self.bound_obs.materialize(
                self.obs_history[-1], acc_per_node=m.acc_per_node,
                probes=probes)
        history.append(m)
        if self.ledger is not None:
            self.ledger.write(round_record(m))
        if verbose:
            log_round(self.method.name, m)

    def _run_fused(self, rounds, eval_every, verbose) -> List[RoundMetrics]:
        cold = (rounds, eval_every) not in self._fused_cache
        fused = self._fused_program(rounds, eval_every)
        n_states = sum(self._state_flags())
        carry = (self.params, self.opt_state) + self._get_states() \
            + (self.rng,)
        if self.ledger is not None and cold:
            # compile-time counter for the ledger: AOT-lower and compile
            # the SAME jitted program (same jaxpr, donation honored) so
            # the compile seconds are separable from the dispatch; the
            # compiled executable replaces the cache entry and serves
            # every later call.
            t0 = _time.perf_counter()
            fused = fused.lower(carry).compile()
            self._compile_s = _time.perf_counter() - t0
            self._fused_cache[(rounds, eval_every)] = fused
        self._cold_compile = cold
        carry, ys = fused(carry)
        self.params, self.opt_state = carry[:2]
        self._set_states(carry[2:2 + n_states])
        self.rng = carry[-1]
        acc_r, loss_r = np.asarray(ys[0]), np.asarray(ys[1])
        # the telemetry extras group is a DICT of stacked arrays — convert
        # per leaf (scalars and dicts alike) rather than per group
        extras_r = [jax.tree.map(np.asarray, e) for e in ys[2:]]
        # the eval-gated params probes ride as the LAST scan output, after
        # the round extras (zeros on non-eval rounds — never read there)
        probes_r = extras_r.pop() if self._probes_raw is not None else None

        evals = set(Schedule.eval_rounds(rounds, eval_every))
        history: List[RoundMetrics] = []
        for r in range(rounds):
            self._account_extras(
                [jax.tree.map(lambda a: a[r], e) for e in extras_r])
            if r in evals:
                m = RoundMetrics(round=r, acc_per_node=acc_r[r],
                                 loss_per_node=loss_r[r])
                probes = (jax.tree.map(lambda a: a[r], probes_r)
                          if probes_r is not None else None)
                self._finish_metrics(m, history, verbose, probes=probes)
        return history

    def _run_loop(self, rounds, eval_every, verbose) -> List[RoundMetrics]:
        evals = set(Schedule.eval_rounds(rounds, eval_every))
        n_states = sum(self._state_flags())
        history: List[RoundMetrics] = []
        for r in range(rounds):
            out = self._round(self.params, self.opt_state,
                              *self._get_states(), jnp.int32(r), self.rng)
            self.params, self.opt_state = out[:2]
            self._set_states(out[2:2 + n_states])
            self.rng = out[2 + n_states]
            self._account_extras(out[4 + n_states:])
            if r in evals:
                m = self.evaluate()
                m.round = r
                probes = (jax.tree.map(np.asarray,
                                       self._probes(self.params))
                          if self._probes is not None else None)
                self._finish_metrics(m, history, verbose, probes=probes)
        return history

    def run(self, rounds: Optional[int] = None,
            eval_every: Optional[int] = None, verbose: bool = False,
            mode: Optional[str] = None) -> List[RoundMetrics]:
        """Run the schedule; returns the eval history (includes round 0 =
        after the initial local training, matching the paper's Fig. 1
        x-axis).  Repeated calls continue from the current state (round
        indices restart, so the deterministic batch schedule repeats).

        Verbose round lines go through the ``repro.obs.round`` logging
        stream
        (same text as always), the JSONL ledger gets one record per eval
        round plus a run summary (wall seconds, rounds/sec, compile-time
        counters), and `Telemetry(profile_dir=...)` wraps the run in a
        `jax.profiler` capture."""
        rounds = self.schedule.rounds if rounds is None else rounds
        eval_every = (self.schedule.eval_every if eval_every is None
                      else eval_every)
        mode = self.schedule.mode if mode is None else mode
        if mode not in SCHEDULE_MODES:
            raise ValueError(f"schedule mode must be one of {SCHEDULE_MODES}, "
                             f"got {mode!r}")
        self._cold_compile = None
        self._compile_s = None
        profile = contextlib.nullcontext()
        if self.telemetry is not None and self.telemetry.profile_dir:
            profile = jax.profiler.trace(self.telemetry.profile_dir)
        t0 = _time.perf_counter()
        with profile:
            if mode == "fused":
                history = self._run_fused(rounds, eval_every, verbose)
            else:
                history = self._run_loop(rounds, eval_every, verbose)
        if self.ledger is not None:
            wall = _time.perf_counter() - t0
            rec = {"kind": "summary", "mode": mode, "rounds": int(rounds),
                   "wall_s": wall,
                   "rounds_per_sec": rounds / max(wall, 1e-9)}
            if self._cold_compile is not None:
                rec["cold_compile"] = bool(self._cold_compile)
            if self._compile_s is not None:
                rec["compile_s"] = self._compile_s
            self.ledger.write(rec)
        return history
