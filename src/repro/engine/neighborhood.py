"""Neighborhood views: ONE flat gossip reduce for both node-axis layouts.

The engine's gossip strategies aggregate through a `Neighborhood` — an
object exposing the five primitives a coordination-free update needs:

  * ``local()``        — the block's own models as one [R, D] fp32 matrix;
  * ``reduce()``       — (Σ_k w·x_k [R, D], Σ_k w [R]) over delivered
    neighbour models;
  * ``reduce_delta()`` — the same contraction over (x_k - local);
  * ``n_active()``     — the count of delivered neighbours per receiver;
  * ``unflatten(out)`` — back to the params pytree.

Two implementations share those semantics bit-for-bit:

  * :class:`DenseNeighborhood` — the `[R, max_deg]` padded layout over a
    full `[N, D]` model table (the small-N oracle);
  * :class:`SparseNeighborhood` — degree-bucketed ragged edge blocks from a
    :class:`SparsePlan` (CSR edge list → per-pod per-width slot tables),
    O(N + E) state instead of O(N·max_deg).

Both evaluate every per-receiver contraction through
`repro.kernels.ops.segment_neighbor_avg`, whose kernel contracts each
receiver row independently — so the reduce is bitwise invariant to row
blocking (vmap's R=N vs a pod's R=N/P) and to K-width zero padding (the
dense max_deg slots vs a sparse bucket's power-of-two width).  Totals ride
the contraction as a ones column (a separate `jnp.sum(w)` would not be
width-invariant), and normalization happens AFTER the reduce, on per-row
scalars, in the strategy's `flat_aggregate`.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import segment_neighbor_avg


class WidthBucket(NamedTuple):
    """One degree bucket's slot tables, stacked over the pod axis.

    All arrays lead with [P, B] (B = the bucket's receiver count, padded to
    the max over pods with inert dummy rows: rows_local = per_pod → the
    scatter trash row, wgt = 0)."""

    rows_local: jnp.ndarray  # [P, B] int32, receiver row within the pod
    src: jnp.ndarray         # [P, B, K] int32 sender node ids (pad 0)
    wgt: jnp.ndarray         # [P, B, K] f32 ω_e·|D_src| (pad 0)
    epos: jnp.ndarray        # [P, B, K] int32 directed-edge position (pad 0)


class SparsePlan(NamedTuple):
    """The static ragged layout: everything the round body needs to gossip
    over a :class:`~repro.graphs.SparseTopology` without dense [N, N] or
    [N, max_deg] state."""

    widths: Tuple[int, ...]          # static ascending bucket widths
    buckets: Dict[int, WidthBucket]  # width -> stacked slot tables
    degrees: jnp.ndarray             # [N] f32 in-degree (byte accounting)
    num_directed: int
    per_pod: int
    n_pods: int


def _bucket_width(deg: int) -> int:
    """Per-receiver slot width: next power of two, floor 8 — total padded
    slots are ≤ 2E + 8N, vs N·max_deg for the dense layout (O(N^2) on
    hubs)."""
    return max(8, 1 << int(np.ceil(np.log2(max(deg, 1)))))


def build_sparse_plan(st, counts: np.ndarray, n_pods: int) -> SparsePlan:
    """Lay a SparseTopology out as per-pod, per-width slot tables.

    Nodes map to pods in contiguous blocks (node i → pod i // per_pod), the
    same row blocks the shard_map backend slices; `counts` are the per-node
    |D_i| data sizes folded into the gossip weights exactly as the dense
    layout folds them (ω_e · |D_src| in float32, in that order)."""
    n = st.num_nodes
    if n % n_pods:
        raise ValueError(f"{n} nodes do not tile {n_pods} pods")
    per_pod = n // n_pods
    offsets = st.row_offsets
    degs = np.diff(offsets).astype(np.int64)
    counts = np.asarray(counts)
    wgt_edge = st.edge_weight * counts[st.edge_src].astype(np.float32)
    widths = sorted({_bucket_width(int(d)) for d in degs})
    node_width = np.array([_bucket_width(int(d)) for d in degs])

    buckets = {}
    for wd in widths:
        per_pod_rows = []
        for p in range(n_pods):
            block = np.arange(p * per_pod, (p + 1) * per_pod)
            per_pod_rows.append(block[node_width[block] == wd])
        b = max(r.shape[0] for r in per_pod_rows)
        rows_local = np.full((n_pods, b), per_pod, np.int32)
        src = np.zeros((n_pods, b, wd), np.int32)
        wgt = np.zeros((n_pods, b, wd), np.float32)
        epos = np.zeros((n_pods, b, wd), np.int32)
        for p, nodes in enumerate(per_pod_rows):
            for k, i in enumerate(nodes):
                lo, hi = int(offsets[i]), int(offsets[i + 1])
                d = hi - lo
                rows_local[p, k] = i - p * per_pod
                src[p, k, :d] = st.edge_src[lo:hi]
                wgt[p, k, :d] = wgt_edge[lo:hi]
                epos[p, k, :d] = np.arange(lo, hi)
        buckets[wd] = WidthBucket(
            rows_local=jnp.asarray(rows_local), src=jnp.asarray(src),
            wgt=jnp.asarray(wgt), epos=jnp.asarray(epos))

    return SparsePlan(
        widths=tuple(widths), buckets=buckets,
        degrees=jnp.asarray(degs.astype(np.float32)),
        num_directed=st.num_directed, per_pod=per_pod, n_pods=n_pods)


class DenseNeighborhood:
    """The padded-layout view: table [N, D], nbr_idx/w [R, max_deg].

    When the transport has ALREADY materialized the per-slot neighbour
    models (the per-edge transport's reverse-slot gather yields per-link
    reconstructions that need not agree across receivers, so no single
    [N, D] table exists), pass them as ``panel`` [R, max_deg, D] instead of
    ``table``/``nbr_idx`` — the reduce contracts the panel directly through
    the same kernel, so the bits match the table form whenever the values
    do."""

    def __init__(self, table, nbr_idx, w, local_mat, unflatten_fn,
                 panel=None):
        self.table = table
        self.nbr_idx = nbr_idx
        self.w = w
        self.local_mat = local_mat
        self._unflatten = unflatten_fn
        self.panel = panel

    def _vals(self):
        return (self.panel if self.panel is not None
                else self.table[self.nbr_idx])

    def local(self):
        return self.local_mat

    def reduce(self):
        return segment_neighbor_avg(self._vals(), self.w)

    def reduce_delta(self):
        vals = self._vals() - self.local_mat[:, None, :]
        return segment_neighbor_avg(vals, self.w)

    def n_active(self):
        return jnp.sum((self.w > 0).astype(jnp.float32), axis=1)

    def unflatten(self, out):
        return self._unflatten(out)


class SparseNeighborhood:
    """The ragged view: per-width buckets gathered from a full [N, D] table,
    scattered back to pod rows through a trash slot (row R of an [R+1]
    accumulator; dummy bucket rows land there and are sliced away).

    `gate_vec` [N] {0,1} are the senders' broadcast gates (trigger fired /
    ever-sent; None skips the factor entirely — e.g. the per-edge transport
    folds its gates into `edge_mask` instead); `link_u` [E] are this
    round's replicated per-directed-edge uniforms (None when participation
    == 1).  All gate factors are exact {0,1} floats, so the composed
    weights equal the dense layout's ω_e·|D_src|·gate·link products
    bit-for-bit.

    Two optional [E] edge-indexed inputs extend the view to the full
    scenario matrix without changing the reduce:

      * ``edge_table`` [E, D] — per-DIRECTED-EDGE values (the sparse
        per-edge transport's reconstruction bank): bucket slots then gather
        `edge_table[epos]` instead of `table[src]`, the flat-edge analogue
        of the dense panel form (receiver slots ARE CSR edge positions, so
        no reverse gather is needed);
      * ``edge_mask`` [E] {0,1} — a per-directed-edge weight factor (a
        dynamics live mask, or the per-edge transport's aggregation mask),
        applied through `epos` exactly where the dense layout multiplies
        its `[N, max_deg]` mask panel.

    Padding slots point at edge 0 (finite garbage) with wgt = 0, which the
    `segment_neighbor_avg` kernel contract makes bit-neutral."""

    def __init__(self, plan: SparsePlan, pod, table, local_mat, unflatten_fn,
                 gate_vec, link_u, participation: float, *,
                 edge_table=None, edge_mask=None):
        self.plan = plan
        self.pod = pod
        self.table = table
        self.local_mat = local_mat
        self._unflatten = unflatten_fn
        self.gate_vec = gate_vec
        self.link_u = link_u
        self.participation = participation
        self.edge_table = edge_table
        self.edge_mask = edge_mask

    def _take(self, a):
        """Select this pod's slab of a [P, ...] plan array."""
        return jax.lax.dynamic_index_in_dim(a, self.pod, axis=0,
                                            keepdims=False)

    def _weights(self, src, wgt, epos):
        w = wgt if self.gate_vec is None else wgt * self.gate_vec[src]
        if self.participation < 1.0:
            w = w * (self.link_u[epos] < self.participation).astype(
                jnp.float32)
        if self.edge_mask is not None:
            w = w * self.edge_mask[epos]
        return w

    def local(self):
        return self.local_mat

    def _reduce(self, delta: bool):
        r, d = self.local_mat.shape
        sums = jnp.zeros((r + 1, d), jnp.float32)
        tot = jnp.zeros((r + 1,), jnp.float32)
        local_pad = jnp.concatenate(
            [self.local_mat, jnp.zeros((1, d), jnp.float32)])
        for wd in self.plan.widths:
            bk = self.plan.buckets[wd]
            rows_local = self._take(bk.rows_local)
            src = self._take(bk.src)
            epos = self._take(bk.epos)
            vals = (self.edge_table[epos] if self.edge_table is not None
                    else self.table[src])
            if delta:
                vals = vals - local_pad[rows_local][:, None, :]
            w = self._weights(src, self._take(bk.wgt), epos)
            s, t = segment_neighbor_avg(vals, w)
            sums = sums.at[rows_local].set(s)
            tot = tot.at[rows_local].set(t)
        return sums[:r], tot[:r]

    def reduce(self):
        return self._reduce(delta=False)

    def reduce_delta(self):
        return self._reduce(delta=True)

    def n_active(self):
        r = self.local_mat.shape[0]
        na = jnp.zeros((r + 1,), jnp.float32)
        for wd in self.plan.widths:
            bk = self.plan.buckets[wd]
            w = self._weights(self._take(bk.src), self._take(bk.wgt),
                              self._take(bk.epos))
            na = na.at[self._take(bk.rows_local)].set(
                jnp.sum((w > 0).astype(jnp.float32), axis=1))
        return na[:r]

    def unflatten(self, out):
        return self._unflatten(out)
