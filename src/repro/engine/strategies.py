"""Method strategies: the paper's aggregation roster behind one protocol.

An :class:`AggregationStrategy` is what a *method* does at the communication
step of Algorithm 1 — everything else (local SGD, delivery masks, codecs,
event triggers) is shared machinery owned by the engine.  The protocol is
three hooks:

  * ``init_state(exp)``   — the static per-experiment tensors the strategy
    aggregates with (gossip weights, data-size weights, …), sliced per pod
    block by the shard_map backend;
  * ``exchange(exp, params, nbr_idx)`` — what a node receives: by default
    the padded-neighbour gather of the stacked models.  When the experiment
    carries a `CommConfig` the engine swaps this for the gossip transport
    (codec + event trigger + per-link state), which is why a strategy that
    wants transport support only has to declare the capability;
  * ``aggregate(exp, state, params, gathered, mask)`` — the update itself,
    consuming :mod:`repro.core.aggregation` / :mod:`repro.core.decdiff`
    through one interface.

Capabilities replace caller branching: every strategy carries ONE frozen
:class:`Capabilities` record — ``kind`` ("gossip" | "server" | "none")
tells the engine whether neighbours are exchanged at all, ``grad_exchange``
opts into the CFA-GE second phase, and ``transport`` is derived — the
engine selects the per-node or per-edge transport from the `CommConfig`,
never from the method name, and lowers every capability combination to
every backend (there are no backend-specific capabilities).  The record is
validated once, at :func:`register_method` time, so a strategy whose
declared capabilities are inconsistent fails at registration with the
available roster in the message, not inside a jitted round.  The legacy
``kind`` / ``grad_exchange`` / ``supports_transport`` attributes remain as
read-only views of the record.

A *method* (what users name in ``Experiment(method=...)``) is a
:class:`MethodSpec`: a strategy plus the loss ("ce" | "vt") and the init
coordination flag (the paper's novel axis — heterogeneous init is the
default, `common_init=True` reproduces the coordinated flavours).  The
module-level registry is open: :func:`register_method` plugs in new methods
(e.g. DFML-style mutual learning or personalized triggering variants)
without touching the engine.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import (
    cfa_aggregate,
    decavg_aggregate,
    fedavg_aggregate,
)
from repro.core.decdiff import decdiff_aggregate_stacked

KINDS = ("gossip", "server", "none")
LAYOUTS = ("dense", "sparse")


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a strategy's communication step IS — declared once, validated at
    :func:`register_method` time, and the ONLY thing backend lowering reads.

    kind: "gossip" — aggregate over delivered neighbour models;
          "server" — global aggregation over all nodes (FedAvg star);
          "none"   — no aggregation (isolation).
    grad_exchange: CFA-GE second phase — neighbours evaluate our aggregated
      model on their data and we descend along their weighted gradients.
      Only meaningful on gossip strategies (the phase walks the neighbour
      table).
    layouts: the node-axis layouts the strategy lowers to.  Every built-in
      capability combination supports both; a strategy restricts this only
      when its update genuinely needs state one layout cannot carry.
      Layout rejection in `Experiment` is driven by THIS field (plus one
      derived restriction: a gossip strategy without a `flat_aggregate`
      form only has the padded-gather lowering, which is dense-only), so
      the construction-time error can name exactly which layouts support
      the method instead of pattern-matching on strings.
    """

    kind: str = "gossip"
    grad_exchange: bool = False
    layouts: Tuple[str, ...] = LAYOUTS

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"Capabilities.kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if self.grad_exchange and self.kind != "gossip":
            raise ValueError(
                f"grad_exchange walks the neighbour table, so it requires "
                f"kind='gossip', got kind={self.kind!r}")
        layouts = tuple(self.layouts)
        if not layouts or any(lo not in LAYOUTS for lo in layouts):
            raise ValueError(
                f"Capabilities.layouts must be a non-empty subset of "
                f"{LAYOUTS}, got {self.layouts!r}")
        object.__setattr__(self, "layouts", layouts)

    @property
    def transport(self) -> bool:
        """Can the neighbour exchange ride the repro.comm gossip transport?
        True exactly for plain model-gossip: transport payload state models
        *model* traffic, not CFA-GE's extra gradient legs or FedAvg's star."""
        return self.kind == "gossip" and not self.grad_exchange


class AggregationStrategy:
    """Base strategy: padded-neighbour gather exchange, abstract aggregate.

    Subclass and override :meth:`aggregate` (and optionally
    :meth:`init_state` / :meth:`exchange`); instances are stateless —
    everything per-experiment lives in the ``state`` pytree returned by
    ``init_state`` and in the experiment itself.  Declare a non-default
    communication shape by setting the ``capabilities`` class attribute to
    a :class:`Capabilities` record; ``kind`` / ``grad_exchange`` /
    ``supports_transport`` are read-only views of it.
    """

    name: str = "base"
    #: the declared communication shape; replaced wholesale in subclasses
    #: (never mutated — the record is frozen).
    capabilities: Capabilities = Capabilities()
    #: gossip strategies may additionally implement ``flat_aggregate(exp,
    #: state, nb)``: the same update expressed over a Neighborhood view
    #: (see :mod:`repro.engine.neighborhood`) — one weighted reduce over
    #: delivered neighbours followed by per-row scalar normalization on the
    #: flattened [R, D] model matrix.  The flat form is what both node-axis
    #: layouts (dense oracle and sparse edge-list) lower to, so a strategy
    #: that provides it runs at 10^4+ nodes; None means dense-layout only.
    flat_aggregate = None

    @property
    def kind(self) -> str:
        return self.capabilities.kind

    @property
    def grad_exchange(self) -> bool:
        return self.capabilities.grad_exchange

    @property
    def supports_transport(self) -> bool:
        return self.capabilities.transport

    # ---------------------------------------------------------------- hooks
    def init_state(self, exp) -> Dict[str, jnp.ndarray]:
        """Static aggregation tensors, leaves with leading node axis [N, ...]
        (the shard_map backend slices them per pod block).  Default: the
        combined ω_ij·|D_j| neighbour weights and the per-node |D_i|.  On
        the sparse layout the padded weight panel does not exist (the plan
        carries the edge weights); only the per-node tensors remain."""
        state = {"counts": exp.counts.astype(jnp.float32)}
        if exp.nbr_weight is not None:
            state["weights"] = exp.nbr_weight
        return state

    def exchange(self, exp, params, nbr_idx):
        """Neighbour exchange: stacked models -> [R, max_deg, ...] per-slot
        views.  `params` holds every candidate sender ([N, ...]); `nbr_idx`
        the receiver rows being served (the full [N, max_deg] table on the
        vmap backend, the pod's block on shard_map)."""
        return jax.tree.map(lambda p: p[nbr_idx], params)

    def aggregate(self, exp, state, params, gathered, mask):
        """New local models for the block: `params` [R, ...], `gathered`
        [R, max_deg, ...] (or [N, ...] full stack for "server" strategies),
        `mask` [R, max_deg] {0,1} delivered-this-round."""
        raise NotImplementedError

    def __repr__(self):  # pragma: no cover - debugging nicety
        return f"{type(self).__name__}(name={self.name!r}, kind={self.kind!r})"


class IsolationStrategy(AggregationStrategy):
    """ISOL baseline: never communicate, keep the local model."""

    name = "isol"
    capabilities = Capabilities(kind="none")

    def aggregate(self, exp, state, params, gathered, mask):
        del state, gathered, mask
        return params


class FedAvgStrategy(AggregationStrategy):
    """Server-side FedAvg over ALL clients (the partially-decentralized FED
    baseline); `gathered` is the full stacked model set and `mask` the [N]
    {0,1} live-client vector (all-ones without a dynamics process — an
    exact no-op on the weights).  The server intersects the data-size
    weights with liveness: a churned-out client's frozen params carry zero
    weight instead of being averaged in as if it had trained this round."""

    name = "fedavg"
    capabilities = Capabilities(kind="server")

    def aggregate(self, exp, state, params, gathered, mask):
        counts = state["counts"] if mask is None else state["counts"] * mask
        avg = fedavg_aggregate(gathered, counts)
        return jax.tree.map(
            lambda a, p: jnp.broadcast_to(
                a[None], (p.shape[0],) + a.shape).astype(p.dtype),
            avg, params)


class DecAvgStrategy(AggregationStrategy):
    """Eq. 4 coordinate-wise average of {local} ∪ {delivered neighbours},
    with the local model weighted ω_ii·|D_i| (DecAvg under common init,
    DecHetero under per-node init — the init is the experiment's axis)."""

    name = "decavg"

    def aggregate(self, exp, state, params, gathered, mask):
        def one(local, stacked, w, m, sw):
            return decavg_aggregate(local, stacked, w, mask=m, self_weight=sw)

        return jax.vmap(one, in_axes=(0, 0, 0, 0, 0))(
            params, gathered, state["weights"], mask, state["counts"])

    def flat_aggregate(self, exp, state, nb):
        sums, tot = nb.reduce()
        sw = state["counts"]
        total = tot + sw
        out = (sw / total)[:, None] * nb.local() + sums / total[:, None]
        return nb.unflatten(out)


class CFAStrategy(AggregationStrategy):
    """Eq. 9 consensus step (Savazzi et al.): w_i += ε Σ_j p_ij (w_j - w_i)."""

    name = "cfa"

    def aggregate(self, exp, state, params, gathered, mask):
        def one(local, stacked, w, m):
            return cfa_aggregate(local, stacked, w, mask=m)

        return jax.vmap(one, in_axes=(0, 0, 0, 0))(
            params, gathered, state["weights"], mask)

    def flat_aggregate(self, exp, state, nb):
        sums, tot = nb.reduce_delta()
        na = nb.n_active()
        safe = jnp.where(tot > 0, tot, 1.0)
        eps = jnp.where(na > 0, 1.0 / jnp.maximum(na, 1.0), 0.0)
        gate = jnp.where(tot > 0, 1.0, 0.0)
        out = nb.local() + ((gate * eps) / safe)[:, None] * sums
        return nb.unflatten(out)


class CFAGEStrategy(CFAStrategy):
    """CFA + gradient exchange: the engine runs the second phase (neighbour
    gradients of OUR aggregated model on THEIR data) when this capability is
    set — doubling communication twice over, the paper's efficiency foil."""

    name = "cfa"  # the aggregation IS Eq. 9; the exchange capability differs
    capabilities = Capabilities(grad_exchange=True)


class DecDiffStrategy(AggregationStrategy):
    """The paper's proposal: Eq. 6 neighbourhood average (excluding self)
    then the Eq. 5 distance-attenuated step, with damping s from the
    experiment's TrainConfig."""

    name = "decdiff"

    def aggregate(self, exp, state, params, gathered, mask):
        return jax.vmap(
            functools.partial(decdiff_aggregate_stacked, s=exp.train.s),
            in_axes=(0, 0, 0, 0),
        )(params, gathered, state["weights"], mask)

    def flat_aggregate(self, exp, state, nb):
        sums, tot = nb.reduce()
        safe = jnp.where(tot > 0, tot, 1.0)
        avg = sums / safe[:, None]
        diff = avg - nb.local()
        d = jnp.sqrt(jnp.sum(diff * diff, axis=1))
        scale = jnp.where(tot > 0, 1.0 / (d + exp.train.s), 0.0)
        out = nb.local() + scale[:, None] * diff
        return nb.unflatten(out)


# --------------------------------------------------------------- registry

@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """A runnable method: strategy + loss + init coordination."""

    name: str
    strategy: AggregationStrategy
    loss: str = "ce"            # "ce" | "vt" (virtual teacher, Eq. 7-8)
    common_init: bool = False   # True = coordinated init (FedAvg/DecAvg)


_REGISTRY: Dict[str, MethodSpec] = {}


def register_method(name: str, strategy: AggregationStrategy, *,
                    loss: str = "ce", common_init: bool = False,
                    overwrite: bool = False) -> MethodSpec:
    """Register a method so `Experiment(method=name)` can run it.

    `strategy` is an :class:`AggregationStrategy` instance; `loss` one of
    the losses `repro.core.virtual_teacher.make_loss_fn` knows ("ce",
    "vt"); `common_init` coordinates the per-node initializations.
    Re-registering an existing name requires `overwrite=True` (typos should
    fail loudly; deliberate replacement is a capability).

    Capability validation happens HERE, once: the strategy must carry a
    :class:`Capabilities` record (itself internally consistent — the frozen
    dataclass validates on construction) and may not shadow the derived
    `kind`/`grad_exchange` views with stale class attributes, so that the
    record the backends lower from is the one the author declared.
    """
    if not isinstance(strategy, AggregationStrategy):
        raise TypeError(f"strategy must be an AggregationStrategy instance, "
                        f"got {type(strategy).__name__}")
    caps = strategy.capabilities
    if not isinstance(caps, Capabilities):
        raise TypeError(
            f"method {name!r}: strategy.capabilities must be a Capabilities "
            f"record, got {type(caps).__name__} (registered methods: "
            f"{sorted(_REGISTRY)})")
    if (strategy.kind, strategy.grad_exchange) != (caps.kind,
                                                   caps.grad_exchange):
        raise ValueError(
            f"method {name!r}: kind/grad_exchange ({strategy.kind!r}, "
            f"{strategy.grad_exchange}) shadow the Capabilities record "
            f"({caps.kind!r}, {caps.grad_exchange}) — declare the shape on "
            f"`capabilities` only (registered methods: {sorted(_REGISTRY)})")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"method {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    spec = MethodSpec(name=name, strategy=strategy, loss=loss,
                      common_init=common_init)
    _REGISTRY[name] = spec
    return spec


def get_method(name: str) -> MethodSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def available_methods() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# The paper roster (§V-B.5) + beyond-paper ablation combos.  Strategies are
# stateless, so flavours share instances.
_ISOL = IsolationStrategy()
_FEDAVG = FedAvgStrategy()
_DECAVG = DecAvgStrategy()
_CFA = CFAStrategy()
_CFAGE = CFAGEStrategy()
_DECDIFF = DecDiffStrategy()

register_method("isol", _ISOL)
register_method("fedavg", _FEDAVG, common_init=True)
register_method("decavg", _DECAVG, common_init=True)
register_method("dechetero", _DECAVG)
register_method("cfa", _CFA)
register_method("cfa-ge", _CFAGE)
register_method("decdiff", _DECDIFF)
register_method("decdiff+vt", _DECDIFF, loss="vt")
# beyond-paper ablation combos:
register_method("dechetero+vt", _DECAVG, loss="vt")
register_method("cfa+vt", _CFA, loss="vt")
register_method("fedavg+vt", _FEDAVG, loss="vt", common_init=True)
register_method("decdiff+vt+coord", _DECDIFF, loss="vt", common_init=True)
