from repro.comm import CommConfig  # noqa: F401  (historical re-export; tests/users import it from here)
from repro.fl.metrics import (  # noqa: F401
    RoundMetrics,
    characteristic_time,
    comm_bytes_per_round,
)
from repro.fl.trainer import centralized_train  # noqa: F401
