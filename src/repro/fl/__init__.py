from repro.fl.simulator import DFLSimulator, SimulatorConfig, METHODS  # noqa: F401
from repro.fl.metrics import (  # noqa: F401
    characteristic_time,
    comm_bytes_per_round,
    RoundMetrics,
)
from repro.fl.trainer import centralized_train  # noqa: F401
