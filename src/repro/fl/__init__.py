from repro.comm import CommConfig  # noqa: F401  (re-export: lives on SimulatorConfig.comm)
from repro.fl.metrics import (  # noqa: F401
    RoundMetrics,
    characteristic_time,
    comm_bytes_per_round,
)
from repro.fl.simulator import METHODS, DFLSimulator, SimulatorConfig  # noqa: F401
from repro.fl.trainer import centralized_train  # noqa: F401
