"""Metrics for decentralized learning experiments.

  * per-round accuracy / test-loss statistics across nodes,
  * characteristic time (paper Table IV): rounds to reach a fraction of the
    centralized benchmark's accuracy,
  * communication accounting (paper §VI-A.3): bytes moved per round per
    method — the quantity behind "DecDiff+VT is more communication-efficient
    than CFA-GE".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.graphs.topology import Topology


@dataclasses.dataclass
class RoundMetrics:
    round: int
    acc_per_node: np.ndarray  # [N]
    loss_per_node: np.ndarray  # [N]
    # Comm-transport accounting (None when the simulator runs without a
    # CommConfig): cumulative bytes actually put on the wire up to and
    # including this round, and the running mean fraction of LIVE directed
    # edges that carried a payload per round (identical definition for the
    # per-node and per-edge transports, and proportional to bytes in both;
    # without a dynamics process every edge of the static layout is live).
    bytes_on_wire: Optional[float] = None
    triggered_frac: Optional[float] = None
    # Dynamics accounting (None without a repro.dynamics GraphProcess): the
    # running mean fraction of the static layout's directed edges that were
    # LIVE per round.  Bytes are only ever accounted on live edges — a
    # non-existent link carries nothing and costs nothing (unlike a
    # `participation` Bernoulli failure, which the sender pays for).
    live_edge_frac: Optional[float] = None
    # Event-clock accounting (None without a repro.timing Timing): the
    # ABSOLUTE simulated time in seconds at the end of this round (the
    # time-to-accuracy x-axis; with Schedule(deadline=d) this is (round+1)*d,
    # otherwise the cumulative synchronous makespan), and the running mean
    # fraction of live directed edges whose payload ARRIVED by the deadline
    # (1.0 in synchronous mode — everything waits).  A late payload still
    # burns the sender's bytes (the PR-5 failed-link convention) but is not
    # aggregated until a later round re-delivers or the stale cache serves it.
    sim_time: Optional[float] = None
    arrived_frac: Optional[float] = None
    # Telemetry detail (None without a repro.obs Telemetry): the selected
    # channels materialized at this eval round — node channels as [N]
    # arrays, edge channels as [E] arrays in the canonical
    # (dst, src)-sorted directed-edge order shared by both layouts (see
    # docs/observability.md for the catalog).  Cumulative channels
    # (steps/compute/bytes/trigger) cover every round up to and including
    # this one, mirroring `bytes_on_wire`.
    detail: Optional[Dict[str, np.ndarray]] = None

    @property
    def acc_mean(self) -> float:
        return float(self.acc_per_node.mean())

    @property
    def acc_std(self) -> float:
        return float(self.acc_per_node.std())

    @property
    def loss_mean(self) -> float:
        return float(self.loss_per_node.mean())


def characteristic_time(history: Sequence[RoundMetrics], centralized_acc: float,
                        thresholds=(0.5, 0.8, 0.9, 0.95)) -> Dict[float, Optional[int]]:
    """Paper Table IV: first round at which the node-average accuracy reaches
    `thr * centralized_acc`.

    Edge-case contract (tested in tests/test_obs.py):

      * a threshold that is NEVER reached within the horizon maps to
        ``None`` — callers must treat None as "did not converge", not 0;
      * ``centralized_acc <= 0`` raises ``ValueError``: every target
        ``thr * centralized_acc`` would be <= 0, so round 0 would "reach"
        all of them vacuously and the table would claim instant
        convergence for any method (pass the actual centralized benchmark
        accuracy, which is positive by definition);
      * an empty history raises ``ValueError`` (there is no round to
        report) rather than silently returning all-None.
    """
    if len(history) == 0:
        raise ValueError(
            "characteristic_time got an empty history; run the experiment "
            "(or pass its eval history) before computing Table IV")
    if not centralized_acc > 0:
        raise ValueError(
            f"centralized_acc must be > 0 (the centralized benchmark "
            f"accuracy the thresholds are fractions of), got "
            f"{centralized_acc}")
    out: Dict[float, Optional[int]] = {}
    for thr in thresholds:
        target = thr * centralized_acc
        hit = None
        for m in history:
            if m.acc_mean >= target:
                hit = m.round
                break
        out[thr] = hit
    return out


def comm_bytes_per_round(method: str, topo: Topology, model_bytes: int,
                         live_frac: float = 1.0) -> int:
    """Total bytes moved in the system per always-send communication round.

    `model_bytes` is the serialized per-edge payload size; with a comm codec
    in play pass `codec.payload_bytes_for(model_size)` (exact bytes on wire,
    repro.comm.codecs) rather than the raw fp32 tree size.  Event-triggered
    runs are accounted dynamically by the simulator instead
    (RoundMetrics.bytes_on_wire).

    `live_frac` prices a time-varying topology: the EXPECTED fraction of
    links live per round under a `repro.dynamics.GraphProcess` (its
    `stationary_live_frac()`, when the closed form exists) — bytes are only
    accounted on live edges, so the per-round volume scales linearly.
    Dynamic runs are accounted exactly by the simulator
    (RoundMetrics.live_edge_frac / bytes_on_wire); this static formula is
    the expectation.

    Model-exchange methods ship one model per directed edge.  CFA-GE
    additionally ships (a) the freshly aggregated model back out and (b) the
    gradients computed by each neighbour — doubling the volume twice over
    plain model exchange (paper: "doubling the information transmitted" per
    direction).  FedAvg ships one model up + one down per client (under
    churn, `live_frac` is the stationary aliveness of the client
    population).  ISOL and Centralized move nothing (Centralized's one-off
    dataset upload is not a per-round cost)."""
    if not 0.0 <= live_frac <= 1.0:
        raise ValueError(f"live_frac must be in [0, 1], got {live_frac}")
    directed_edges = 2 * topo.num_edges
    m = method.lower()
    if m in ("isol", "centralized", "none"):
        return 0
    if m in ("fed", "fedavg"):
        return int(round(2 * topo.num_nodes * model_bytes * live_frac))
    if m in ("cfa-ge", "cfage"):
        # models out + aggregated model out for gradient eval + gradients back
        return int(round(directed_edges * model_bytes * 2 * 2 * live_frac))
    # decavg / dechetero / cfa / decdiff / decdiff+vt: parameters only.
    return int(round(directed_edges * model_bytes * live_frac))


def accuracy_table(histories: Dict[str, List[RoundMetrics]]) -> Dict[str, Dict[str, float]]:
    """Final-round summary akin to the paper's Table II.  A method with an
    empty history raises ValueError (a run that never evaluated has no
    final round to tabulate)."""
    table = {}
    for method, hist in histories.items():
        if len(hist) == 0:
            raise ValueError(
                f"accuracy_table: method {method!r} has an empty history "
                f"(no eval rounds); run it before tabulating")
        last = hist[-1]
        table[method] = {
            "acc_mean": last.acc_mean,
            "acc_std": last.acc_std,
            "loss_mean": last.loss_mean,
            "round": last.round,
        }
    return table
