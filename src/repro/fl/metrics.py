"""Metrics for decentralized learning experiments.

  * per-round accuracy / test-loss statistics across nodes,
  * characteristic time (paper Table IV): rounds to reach a fraction of the
    centralized benchmark's accuracy,
  * communication accounting (paper §VI-A.3): bytes moved per round per
    method — the quantity behind "DecDiff+VT is more communication-efficient
    than CFA-GE".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.graphs.topology import Topology


@dataclasses.dataclass
class RoundMetrics:
    round: int
    acc_per_node: np.ndarray  # [N]
    loss_per_node: np.ndarray  # [N]
    # Comm-transport accounting (None when the simulator runs without a
    # CommConfig): cumulative bytes actually put on the wire up to and
    # including this round, and the running mean fraction of DIRECTED EDGES
    # that carried a payload per round (identical definition for the
    # per-node and per-edge transports, and proportional to bytes in both).
    bytes_on_wire: Optional[float] = None
    triggered_frac: Optional[float] = None

    @property
    def acc_mean(self) -> float:
        return float(self.acc_per_node.mean())

    @property
    def acc_std(self) -> float:
        return float(self.acc_per_node.std())

    @property
    def loss_mean(self) -> float:
        return float(self.loss_per_node.mean())


def characteristic_time(history: Sequence[RoundMetrics], centralized_acc: float,
                        thresholds=(0.5, 0.8, 0.9, 0.95)) -> Dict[float, Optional[int]]:
    """Paper Table IV: first round at which the node-average accuracy reaches
    `thr * centralized_acc`.  None = never within the horizon."""
    out: Dict[float, Optional[int]] = {}
    for thr in thresholds:
        target = thr * centralized_acc
        hit = None
        for m in history:
            if m.acc_mean >= target:
                hit = m.round
                break
        out[thr] = hit
    return out


def comm_bytes_per_round(method: str, topo: Topology, model_bytes: int) -> int:
    """Total bytes moved in the system per always-send communication round.

    `model_bytes` is the serialized per-edge payload size; with a comm codec
    in play pass `codec.payload_bytes_for(model_size)` (exact bytes on wire,
    repro.comm.codecs) rather than the raw fp32 tree size.  Event-triggered
    runs are accounted dynamically by the simulator instead
    (RoundMetrics.bytes_on_wire).

    Model-exchange methods ship one model per directed edge.  CFA-GE
    additionally ships (a) the freshly aggregated model back out and (b) the
    gradients computed by each neighbour — doubling the volume twice over
    plain model exchange (paper: "doubling the information transmitted" per
    direction).  FedAvg ships one model up + one down per client.  ISOL and
    Centralized move nothing (Centralized's one-off dataset upload is not a
    per-round cost)."""
    directed_edges = 2 * topo.num_edges
    m = method.lower()
    if m in ("isol", "centralized", "none"):
        return 0
    if m in ("fed", "fedavg"):
        return 2 * topo.num_nodes * model_bytes
    if m in ("cfa-ge", "cfage"):
        # models out + aggregated model out for gradient eval + gradients back
        return directed_edges * model_bytes * 2 * 2
    # decavg / dechetero / cfa / decdiff / decdiff+vt: parameters only.
    return directed_edges * model_bytes


def accuracy_table(histories: Dict[str, List[RoundMetrics]]) -> Dict[str, Dict[str, float]]:
    """Final-round summary akin to the paper's Table II."""
    table = {}
    for method, hist in histories.items():
        last = hist[-1]
        table[method] = {
            "acc_mean": last.acc_mean,
            "acc_std": last.acc_std,
            "loss_mean": last.loss_mean,
            "round": last.round,
        }
    return table
