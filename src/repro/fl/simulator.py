"""Vectorized multi-node DFL simulator (the paper's SAISIM counterpart).

Simulates |V| devices on a complex network running Algorithm 1 (or any of the
baseline methods) with everything vmapped over the node axis, so a whole
communication round — local SGD steps, neighbour exchange, aggregation — is
one jitted XLA program:

  round:  (1) B local SGD(momentum) minibatch steps per node  (Alg.1 l.4-9)
          (2) model exchange with graph neighbours             (l.10-11)
          (3) aggregation (DecAvg / CFA / DecDiff / none)      (l.12-13)
          (4) [CFA-GE only] neighbour-gradient exchange + descent

Heterogeneous initialization (the paper's novel axis) is the default: each
node draws its own init key.  `common_init=True` reproduces the coordinated
flavours (DecAvg, FedAvg).  Partial participation — the paper imposes no
synchronization; a node may hear from a fraction of its neighbours — is
modelled with a per-round Bernoulli delivery mask.

Communication is free by default (full fp32 models).  Passing a
`CommConfig` (repro.comm) routes the exchange through the gossip transport:
payload codecs (bf16 / stochastic int8 / top-k with error feedback), an
event-triggered drift rule replacing always-send, and exact bytes-on-wire +
triggered-fraction accounting on every RoundMetrics.  With
`CommConfig(per_edge=True)` or `policy="adaptive"` the transport keeps its
reference/residual/threshold state per directed link (`[N, max_deg, ...]`),
link failures are acked so a dropped edge's error feedback never leaks into
its siblings, and adaptive thresholds steer every link toward
`target_trigger` (bytes are then counted per fired EDGE, not per sender).

Method registry (paper §V-B.5):
  isol, fedavg, decavg, dechetero, cfa, cfa-ge, decdiff, decdiff+vt
(plus beyond-paper combos: dechetero+vt, cfa+vt, fedavg+vt for ablations).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, EdgeGossipTransport, GossipTransport
from repro.core.aggregation import (
    cfa_aggregate,
    decavg_aggregate,
    fedavg_aggregate,
)
from repro.core.decdiff import decdiff_aggregate_stacked
from repro.core.virtual_teacher import make_loss_fn
from repro.data.allocation import pad_node_datasets
from repro.data.pipeline import Batcher
from repro.fl.metrics import RoundMetrics
from repro.fl.trainer import make_eval_fn, make_grad_fn, make_train_step
from repro.graphs.topology import Topology
from repro.models.api import SmallModel
from repro.optim.sgd import sgd_momentum

METHODS: Dict[str, Dict] = {
    "isol": dict(agg="none", loss="ce", common_init=False),
    "fedavg": dict(agg="server", loss="ce", common_init=True),
    "decavg": dict(agg="decavg", loss="ce", common_init=True),
    "dechetero": dict(agg="decavg", loss="ce", common_init=False),
    "cfa": dict(agg="cfa", loss="ce", common_init=False),
    "cfa-ge": dict(agg="cfa", loss="ce", common_init=False, grad_exchange=True),
    "decdiff": dict(agg="decdiff", loss="ce", common_init=False),
    "decdiff+vt": dict(agg="decdiff", loss="vt", common_init=False),
    # beyond-paper ablation combos:
    "dechetero+vt": dict(agg="decavg", loss="vt", common_init=False),
    "cfa+vt": dict(agg="cfa", loss="vt", common_init=False),
    "fedavg+vt": dict(agg="server", loss="vt", common_init=True),
    "decdiff+vt+coord": dict(agg="decdiff", loss="vt", common_init=True),
}


@dataclasses.dataclass(frozen=True)
class SimulatorConfig:
    method: str = "decdiff+vt"
    rounds: int = 100
    steps_per_round: int = 4  # B in Alg. 1 (minibatch steps between exchanges)
    batch_size: int = 32
    lr: float = 1e-3
    momentum: float = 0.9
    beta: float = 0.95  # VT confidence (Eq. 7)
    s: float = 1.0  # DecDiff damping (Eq. 5)
    participation: float = 1.0  # per-neighbour delivery probability per round
    seed: int = 0
    eval_every: int = 5
    eval_batch: int = 128
    ge_lr: Optional[float] = None  # CFA-GE gradient-apply LR (default: lr)
    # Heterogeneous local training (paper Alg. 1: E "is not necessarily the
    # same at all nodes"): per-node number of local steps per round, sampled
    # uniformly from [min, steps_per_round].  0 disables (= homogeneous).
    hetero_steps_min: int = 0
    # Gossip transport (repro.comm): payload codec + event-triggered sending
    # with exact bytes-on-wire accounting.  None = legacy free-communication
    # model (full fp32 models, always delivered modulo `participation`).
    comm: Optional[CommConfig] = None


class DFLSimulator:
    """Run one method over one (topology, per-node datasets) instance."""

    def __init__(self, model: SmallModel, topo: Topology,
                 xs: List[np.ndarray], ys: List[np.ndarray],
                 x_test: np.ndarray, y_test: np.ndarray,
                 config: SimulatorConfig):
        assert topo.num_nodes == len(xs) == len(ys)
        if config.method not in METHODS:
            raise ValueError(f"unknown method {config.method!r}; available: {sorted(METHODS)}")
        self.model = model
        self.topo = topo
        self.cfg = config
        self.spec = METHODS[config.method]
        self.n = topo.num_nodes

        x_pad, y_pad, counts = pad_node_datasets(xs, ys)
        self.x_pad = jnp.asarray(x_pad)
        self.y_pad = jnp.asarray(y_pad.astype(np.int32))
        self.counts = jnp.asarray(counts.astype(np.int32))
        self.x_test = jnp.asarray(x_test)
        self.y_test = jnp.asarray(y_test.astype(np.int32))

        # --- graph tensors (padded neighbour layout) ---
        idx = topo.neighbor_idx.astype(np.int32)
        self.nbr_idx = jnp.asarray(np.maximum(idx, 0))
        self.nbr_valid = jnp.asarray(topo.neighbor_mask.astype(np.float32))
        # combined ω_ij * |D_j| weights (aggregators normalize internally,
        # which realizes p_ij = |D_j| / Σ_{N_i} |D_j| of Eqs. 4/6/9).
        omega = topo.neighbor_weights()  # [N, D]
        dj = counts[np.maximum(idx, 0)].astype(np.float32)
        self.nbr_weight = jnp.asarray(omega * dj * topo.neighbor_mask)

        self.optimizer = sgd_momentum(lr=config.lr, momentum=config.momentum)
        self.loss_fn = make_loss_fn(self.spec["loss"], beta=config.beta)
        self.batcher = Batcher(batch_size=config.batch_size)
        self._train_step = make_train_step(self.model, self.optimizer, self.loss_fn)
        self._grad_fn = make_grad_fn(self.model, self.loss_fn)
        self._eval = jax.jit(jax.vmap(
            make_eval_fn(self.model, batch_size=min(config.eval_batch, len(x_test))),
            in_axes=(0, None, None),
        ))
        # --- init (heterogeneous unless the method coordinates) ---
        base = jax.random.PRNGKey(config.seed)
        if self.spec.get("common_init", False):
            keys = jnp.broadcast_to(jax.random.PRNGKey(config.seed + 1), (self.n, 2))
        else:
            keys = jax.random.split(jax.random.fold_in(base, 17), self.n)
        self.params = jax.vmap(self.model.init)(keys)
        self.opt_state = jax.vmap(self.optimizer.init)(self.params)
        self.rng = jax.random.fold_in(base, 23)

        # --- gossip transport (optional; neighbour-gossip methods only) ---
        self.transport = None
        self.comm_state = None
        self.comm_bytes_total = 0.0
        self._trig_sum = 0.0
        self._comm_rounds = 0
        self.trig_history: List[float] = []  # per-round triggered fraction
        if config.comm is not None:
            if self.spec["agg"] not in ("decavg", "cfa", "decdiff") or \
                    self.spec.get("grad_exchange", False):
                raise ValueError(
                    f"comm transport models neighbour model-gossip only; "
                    f"method {config.method!r} is unsupported")
            if config.comm.use_per_edge:
                self.transport = EdgeGossipTransport(
                    config.comm, self.params, topo.neighbor_idx,
                    topo.neighbor_mask)
            else:
                self.transport = GossipTransport(config.comm, self.params)
            self.comm_state = self.transport.init_state(self.params)

        donate = (0, 1, 2) if self.transport is not None else (0, 1)
        self._round = jax.jit(self._make_round_fn(), donate_argnums=donate)

    # ------------------------------------------------------------------
    def _make_round_fn(self):
        cfg, spec = self.cfg, self.spec
        nbr_idx, nbr_valid, nbr_weight = self.nbr_idx, self.nbr_valid, self.nbr_weight
        counts, batcher = self.counts, self.batcher
        n = self.n

        def take_batch(x, y, c, step):
            return batcher.take(x, y, c, step)

        v_take = jax.vmap(take_batch, in_axes=(0, 0, 0, None))
        v_step = jax.vmap(self._train_step, in_axes=(0, 0, 0, 0, None, 0))

        def local_training(params, opt, round_idx, rng):
            # Heterogeneous E (Alg. 1): per-node step budget for this round;
            # nodes past their budget keep their params (masked update).
            if cfg.hetero_steps_min > 0:
                rng, sub = jax.random.split(rng)
                budgets = jax.random.randint(
                    sub, (n,), cfg.hetero_steps_min, cfg.steps_per_round + 1)
            else:
                budgets = jnp.full((n,), cfg.steps_per_round, jnp.int32)

            def body(carry, b):
                params, opt, rng = carry
                step = round_idx * cfg.steps_per_round + b
                x, y = v_take(self.x_pad, self.y_pad, counts, step)
                rng, sub = jax.random.split(rng)
                drop_keys = jax.random.split(sub, n)
                new_params, new_opt, loss = v_step(params, opt, x, y, step,
                                                   drop_keys)
                active = (b < budgets).astype(jnp.float32)

                def mix(new, old):
                    a = active.reshape((n,) + (1,) * (new.ndim - 1))
                    return (a * new.astype(jnp.float32)
                            + (1 - a) * old.astype(jnp.float32)).astype(old.dtype)

                params = jax.tree.map(mix, new_params, params)
                opt = jax.tree.map(mix, new_opt, opt)
                return (params, opt, rng), jnp.mean(loss)

            (params, opt, rng), losses = jax.lax.scan(
                body, (params, opt, rng), jnp.arange(cfg.steps_per_round)
            )
            return params, opt, rng, jnp.mean(losses)

        def delivery_mask(rng):
            if cfg.participation >= 1.0:
                return nbr_valid
            u = jax.random.uniform(rng, nbr_valid.shape)
            return nbr_valid * (u < cfg.participation).astype(jnp.float32)

        # --- aggregation dispatch (static on method) ---
        agg_kind = spec["agg"]
        if agg_kind == "decdiff":
            agg_fn = jax.vmap(
                functools.partial(decdiff_aggregate_stacked, s=cfg.s),
                in_axes=(0, 0, 0, 0),
            )
        elif agg_kind == "decavg":
            def _decavg(local, stacked, w, m, sw):
                return decavg_aggregate(local, stacked, w, mask=m, self_weight=sw)
            agg_fn = jax.vmap(_decavg, in_axes=(0, 0, 0, 0, 0))
        elif agg_kind == "cfa":
            def _cfa(local, stacked, w, m):
                return cfa_aggregate(local, stacked, w, mask=m)
            agg_fn = jax.vmap(_cfa, in_axes=(0, 0, 0, 0))
        else:
            agg_fn = None

        v_grad = jax.vmap(self._grad_fn, in_axes=(0, 0, 0, 0))
        max_deg = int(nbr_idx.shape[1])

        def gradient_exchange(params, mask, round_idx, rng):
            """CFA-GE: neighbours evaluate our aggregated model on their data;
            we descend along the p_ij-weighted mean of their gradients."""
            bs = cfg.batch_size

            def body(acc, d):
                j = nbr_idx[:, d]  # [n] neighbour ids in slot d
                cj = counts[j]
                base = (round_idx * max_deg + d) * bs
                bidx = (base + jnp.arange(bs, dtype=jnp.int32)[None, :]) * batcher.stride
                bidx = bidx % jnp.maximum(cj[:, None], 1)
                xj = self.x_pad[j[:, None], bidx]  # [n, bs, ...]
                yj = self.y_pad[j[:, None], bidx]
                keys = jax.random.split(jax.random.fold_in(rng, d), n)
                g = v_grad(params, xj, yj, keys)  # grad of F_j at w_i
                w_d = nbr_weight[:, d] * mask[:, d]

                def add(a, gi):
                    wb = w_d.reshape((n,) + (1,) * (gi.ndim - 1))
                    return a + wb * gi.astype(jnp.float32)

                return jax.tree.map(add, acc, g), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            acc, _ = jax.lax.scan(body, zeros, jnp.arange(max_deg))
            tot = jnp.sum(nbr_weight * mask, axis=1)  # [n]
            safe = jnp.maximum(tot, 1e-9)
            lr_ge = cfg.ge_lr if cfg.ge_lr is not None else cfg.lr

            def apply(p, a):
                wb = (1.0 / safe).reshape((n,) + (1,) * (a.ndim - 1))
                gate = (tot > 0).astype(jnp.float32).reshape((n,) + (1,) * (a.ndim - 1))
                return (p.astype(jnp.float32) - lr_ge * gate * wb * a).astype(p.dtype)

            return jax.tree.map(apply, params, acc)

        def gossip_aggregate(params, gathered, mask):
            if agg_kind == "decavg":
                self_w = counts.astype(jnp.float32)  # ω_ii=1, weight |D_i|
                return agg_fn(params, gathered, nbr_weight, mask, self_w)
            return agg_fn(params, gathered, nbr_weight, mask)

        transport = self.transport
        degrees = jnp.sum(nbr_valid, axis=1)
        total_edges = jnp.sum(degrees)  # directed edge count

        def comm_round_fn(params, opt, comm_state, round_idx, rng):
            """The legacy round with the transport in the middle: encode ->
            (event-triggered, possibly failing) wire -> decode -> aggregate.
            With the fp32 codec and threshold 0 this is bit-for-bit the
            plain round (same rng stream, identical payload values)."""
            from repro.comm.trigger import edge_delivery

            params, opt, rng, train_loss = local_training(params, opt, round_idx, rng)
            rng, sub = jax.random.split(rng)
            link = delivery_mask(sub)  # exogenous failures (participation)
            if transport.wants_rng:
                rng, ck = jax.random.split(rng)
            else:
                ck = None
            decoded, gate, comm_state = transport.exchange(params, comm_state, ck)
            # `decoded` rows of silent nodes hold their cached last-sent
            # model, so "stale" aggregates them at full weight (masking only
            # neighbours that have NEVER transmitted — their cache is still
            # the zero bootstrap reference); "drop" masks any silent node
            # like a failed link.
            if transport.config.on_silence == "drop":
                mask = edge_delivery(gate, link, nbr_idx)
            else:
                mask = edge_delivery(comm_state.ever_sent, link, nbr_idx)
            gathered = jax.tree.map(lambda p: p[nbr_idx], decoded)
            params = gossip_aggregate(params, gathered, mask)
            # a transmitting node broadcasts one payload per outgoing edge;
            # failed links still burn the sender's bytes.  Return the edge
            # COUNT (small, exact in f32) — the byte multiply happens in
            # Python so exact accounting survives past f32's 2^24 integers.
            # triggered_frac is the fraction of directed edges that carried
            # a payload (= degree-weighted sender mean), the SAME definition
            # the per-edge round reports, so frontier rows are comparable
            # across transports and proportional to bytes in both.
            sent_edges = jnp.sum(gate * degrees)
            return (params, opt, comm_state, rng, train_loss,
                    sent_edges, sent_edges / total_edges)

        def edge_comm_round_fn(params, opt, comm_state, round_idx, rng):
            """The per-edge transport round: every directed link carries its
            own reference/residual/threshold, so the link mask feeds the
            exchange (link-layer ack) and the transport hands back both the
            receiver-layout gathered models (fresh or per-link stale cache)
            and the aggregation mask.  Same rng stream as comm_round_fn, so
            fp32 + threshold 0 + policy "fixed" is bit-for-bit the legacy
            round (pinned in tests/test_comm_per_edge.py)."""
            params, opt, rng, train_loss = local_training(params, opt,
                                                          round_idx, rng)
            rng, sub = jax.random.split(rng)
            link = delivery_mask(sub)  # exogenous failures (participation)
            if transport.wants_rng:
                rng, ck = jax.random.split(rng)
            else:
                ck = None
            gathered, mask, gate, comm_state = transport.exchange(
                params, comm_state, link, ck)
            params = gossip_aggregate(params, gathered, mask)
            # unicast accounting: one payload per FIRED edge (a silent edge
            # of an otherwise-sending node costs nothing); failed links
            # still burn the sender's bytes.
            sent_edges = jnp.sum(gate)
            trig = sent_edges / jnp.float32(transport.num_edges)
            return (params, opt, comm_state, rng, train_loss,
                    sent_edges, trig)

        def round_fn(params, opt, round_idx, rng):
            params, opt, rng, train_loss = local_training(params, opt, round_idx, rng)
            rng, sub = jax.random.split(rng)
            mask = delivery_mask(sub)

            if agg_kind == "server":
                p_i = counts.astype(jnp.float32)
                avg = fedavg_aggregate(params, p_i)
                params = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).astype(a.dtype), avg
                )
            elif agg_kind == "none":
                pass
            else:
                gathered = jax.tree.map(lambda p: p[nbr_idx], params)  # [n, D, ...]
                params = gossip_aggregate(params, gathered, mask)
                if spec.get("grad_exchange", False):
                    rng, sub = jax.random.split(rng)
                    params = gradient_exchange(params, mask, round_idx, sub)

            return params, opt, rng, train_loss

        if transport is None:
            return round_fn
        return (edge_comm_round_fn if isinstance(transport, EdgeGossipTransport)
                else comm_round_fn)

    # ------------------------------------------------------------------
    def evaluate(self) -> RoundMetrics:
        acc, loss = self._eval(self.params, self.x_test, self.y_test)
        return RoundMetrics(round=-1, acc_per_node=np.asarray(acc),
                            loss_per_node=np.asarray(loss))

    def run(self, rounds: Optional[int] = None, eval_every: Optional[int] = None,
            verbose: bool = False) -> List[RoundMetrics]:
        """Run the simulation; returns the eval history (includes round 0 =
        after the initial local training, matching the paper's Fig. 1 x-axis)."""
        rounds = self.cfg.rounds if rounds is None else rounds
        eval_every = self.cfg.eval_every if eval_every is None else eval_every
        history: List[RoundMetrics] = []
        for r in range(rounds):
            if self.transport is not None:
                (self.params, self.opt_state, self.comm_state, self.rng, _,
                 sent_edges, trig) = self._round(
                    self.params, self.opt_state, self.comm_state,
                    jnp.int32(r), self.rng)
                self.comm_bytes_total += (self.transport.payload_bytes
                                          * float(sent_edges))
                self._trig_sum += float(trig)
                self._comm_rounds += 1
                self.trig_history.append(float(trig))
            else:
                self.params, self.opt_state, self.rng, _ = self._round(
                    self.params, self.opt_state, jnp.int32(r), self.rng
                )
            if r % eval_every == 0 or r == rounds - 1:
                m = self.evaluate()
                m.round = r
                if self.transport is not None:
                    m.bytes_on_wire = self.comm_bytes_total
                    m.triggered_frac = self._trig_sum / max(self._comm_rounds, 1)
                history.append(m)
                if verbose:
                    comm = ("" if m.bytes_on_wire is None else
                            f"  wire {m.bytes_on_wire / 1e6:.2f} MB"
                            f"  trig {m.triggered_frac:.2f}")
                    print(f"[{self.cfg.method}] round {r:4d}  "
                          f"acc {m.acc_mean:.4f} ± {m.acc_std:.4f}  "
                          f"loss {m.loss_mean:.4f}{comm}")
        return history
