"""DEPRECATED shim: `DFLSimulator` is now `repro.engine.Experiment`.

The vectorized multi-node simulator (the paper's SAISIM counterpart) moved
to :mod:`repro.engine`, which runs the same Algorithm-1 round — local
SGD(momentum) steps, neighbour exchange (optionally through the repro.comm
gossip transport), method aggregation — behind one `Experiment` API with
pluggable method strategies, a vmap AND a shard_map backend, and a
scan-fused multi-round schedule.  Migration table: docs/api.md.

This module keeps the old constructor working, bit-for-bit: the shim lowers
onto `Experiment(world, method, comm=..., backend="vmap")` with the "loop"
schedule, which is the op-for-op port of the legacy round (pinned by
tests/test_engine.py).  Constructing `DFLSimulator` raises a
`DeprecationWarning`; in-repo code must use `Experiment` (the warning is an
error under the repo's pytest config).

Method registry (paper §V-B.5) — now `repro.engine.available_methods()`:
  isol, fedavg, decavg, dechetero, cfa, cfa-ge, decdiff, decdiff+vt
(plus beyond-paper combos: dechetero+vt, cfa+vt, fedavg+vt for ablations).
`METHODS` below is a read-only legacy rendering of that registry.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional

import numpy as np

from repro.comm import CommConfig
from repro.engine.strategies import available_methods, get_method
from repro.fl.metrics import RoundMetrics
from repro.graphs.topology import Topology
from repro.models.api import SmallModel

#: Legacy view of the strategy registry (pre-engine METHODS-dict shape).
METHODS: Dict[str, Dict] = {name: get_method(name).legacy_dict()
                            for name in available_methods()}


@dataclasses.dataclass(frozen=True)
class SimulatorConfig:
    """Legacy all-in-one config; `repro.engine` splits it into
    (method, TrainConfig, Schedule, CommConfig)."""

    method: str = "decdiff+vt"
    rounds: int = 100
    steps_per_round: int = 4  # B in Alg. 1 (minibatch steps between exchanges)
    batch_size: int = 32
    lr: float = 1e-3
    momentum: float = 0.9
    beta: float = 0.95  # VT confidence (Eq. 7)
    s: float = 1.0  # DecDiff damping (Eq. 5)
    participation: float = 1.0  # per-neighbour delivery probability per round
    seed: int = 0
    eval_every: int = 5
    eval_batch: int = 128
    ge_lr: Optional[float] = None  # CFA-GE gradient-apply LR (default: lr)
    hetero_steps_min: int = 0
    comm: Optional[CommConfig] = None


class DFLSimulator:
    """Deprecated façade over :class:`repro.engine.Experiment` (the legacy
    constructor/run/evaluate surface, including the comm accounting
    attributes the old benchmarks read)."""

    def __init__(self, model: SmallModel, topo: Topology,
                 xs: List[np.ndarray], ys: List[np.ndarray],
                 x_test: np.ndarray, y_test: np.ndarray,
                 config: SimulatorConfig):
        warnings.warn(
            "DFLSimulator is deprecated; use repro.engine.Experiment "
            "(see docs/api.md for the migration table)",
            DeprecationWarning, stacklevel=2)
        from repro.engine import Experiment, Schedule, TrainConfig, World

        get_method(config.method)  # unknown-method error, legacy timing
        assert topo.num_nodes == len(xs) == len(ys)
        self.cfg = config
        self._exp = Experiment(
            World(model=model, topo=topo, xs=xs, ys=ys,
                  x_test=x_test, y_test=y_test),
            config.method,
            comm=config.comm,
            backend="vmap",
            schedule=Schedule(rounds=config.rounds,
                              eval_every=config.eval_every, mode="loop"),
            train=TrainConfig(
                steps_per_round=config.steps_per_round,
                batch_size=config.batch_size, lr=config.lr,
                momentum=config.momentum, beta=config.beta, s=config.s,
                participation=config.participation, seed=config.seed,
                eval_batch=config.eval_batch, ge_lr=config.ge_lr,
                hetero_steps_min=config.hetero_steps_min),
        )

    # ------------------------------------------------------- delegation
    @property
    def experiment(self):
        """The underlying Experiment (escape hatch for migration)."""
        return self._exp

    def __getattr__(self, name):
        # model/topo/params/opt_state/rng/transport/comm_state/
        # comm_bytes_total/trig_history/n/... — everything the legacy
        # simulator exposed lives on the Experiment under the same name.
        if name == "_exp":  # not yet constructed (failed __init__ etc.)
            raise AttributeError(name)
        return getattr(self._exp, name)

    @property
    def spec(self) -> Dict:
        """Legacy METHODS-dict entry for the configured method."""
        return METHODS[self.cfg.method]

    def evaluate(self) -> RoundMetrics:
        return self._exp.evaluate()

    def run(self, rounds: Optional[int] = None,
            eval_every: Optional[int] = None,
            verbose: bool = False) -> List[RoundMetrics]:
        return self._exp.run(rounds=rounds, eval_every=eval_every,
                             verbose=verbose)
