"""Local training building blocks shared by the simulator and baselines.

Provides the jitted per-node SGD step factory (used under vmap by the
multi-node simulator) and a standalone centralized trainer (the paper's upper
bound benchmark).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.virtual_teacher import cross_entropy_loss, make_loss_fn
from repro.data.pipeline import minibatches
from repro.models.api import SmallModel
from repro.optim.sgd import Optimizer


def make_train_step(model: SmallModel, optimizer: Optimizer, loss_fn: Callable):
    """Returns step(params, opt_state, x, y, step_idx, rng) -> (params, opt, loss)."""

    def loss_of(params, x, y, rng):
        logits = model.apply(params, x, train=True, rng=rng)
        return loss_fn(logits, y)

    def step(params, opt_state, x, y, step_idx, rng):
        loss, grads = jax.value_and_grad(loss_of)(params, x, y, rng)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step_idx)
        return new_params, new_opt, loss

    return step


def make_grad_fn(model: SmallModel, loss_fn: Callable):
    """grad(params, x, y, rng) of the local loss — used by CFA-GE's exchange."""

    def loss_of(params, x, y, rng):
        logits = model.apply(params, x, train=True, rng=rng)
        return loss_fn(logits, y)

    return jax.grad(loss_of)


def make_eval_fn(model: SmallModel, batch_size: int = 512):
    """Returns eval(params, x_test, y_test) -> (accuracy, mean CE loss).

    Scans over fixed-size test chunks so it can sit under vmap (per-node
    evaluation) without materializing [N, test_size, ...] activations."""

    def eval_fn(params, x_test, y_test):
        n = x_test.shape[0]
        n_batches = n // batch_size  # test sets are sized divisible in benches
        used = n_batches * batch_size
        xb = x_test[:used].reshape(n_batches, batch_size, *x_test.shape[1:])
        yb = y_test[:used].reshape(n_batches, batch_size)

        def body(carry, xy):
            correct, loss_sum = carry
            x, y = xy
            logits = model.apply(params, x, train=False, rng=None)
            pred = jnp.argmax(logits, axis=-1)
            correct = correct + jnp.sum(pred == y)
            loss_sum = loss_sum + cross_entropy_loss(logits, y) * batch_size
            return (correct, loss_sum), None

        (correct, loss_sum), _ = jax.lax.scan(
            body, (jnp.int32(0), jnp.float32(0.0)), (xb, yb)
        )
        return correct / used, loss_sum / used

    return eval_fn


def centralized_train(model: SmallModel, optimizer: Optimizer,
                      x_train: np.ndarray, y_train: np.ndarray,
                      x_test: np.ndarray, y_test: np.ndarray,
                      epochs: int, batch_size: int, seed: int = 0,
                      loss: str = "ce", beta: float = 0.95,
                      eval_every: int = 1) -> Tuple[dict, list]:
    """The paper's Centralized benchmark: all data on one server."""
    rng = np.random.default_rng(seed)
    loss_fn = make_loss_fn(loss, beta=beta)
    step_fn = jax.jit(make_train_step(model, optimizer, loss_fn))
    eval_fn = jax.jit(make_eval_fn(model, batch_size=min(512, len(x_test))))

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = optimizer.init(params)
    history = []
    step_idx = 0
    drop_key = jax.random.PRNGKey(seed + 1)
    for epoch in range(epochs):
        for x, y in minibatches(x_train, y_train, batch_size, rng=rng):
            drop_key, sub = jax.random.split(drop_key)
            params, opt_state, _ = step_fn(
                params, opt_state, jnp.asarray(x), jnp.asarray(y),
                jnp.int32(step_idx), sub,
            )
            step_idx += 1
        if (epoch + 1) % eval_every == 0 or epoch == epochs - 1:
            acc, tloss = eval_fn(params, jnp.asarray(x_test), jnp.asarray(y_test))
            history.append({"epoch": epoch, "acc": float(acc), "loss": float(tloss)})
    return params, history
