from repro.graphs.partition import map_graph_to_pods, pod_adjacency  # noqa: F401
from repro.graphs.sparse import (  # noqa: F401
    SPARSE_BUILDERS,
    SparseTopology,
    make_sparse_topology,
    rev_edge_permutation,
    undirected_pair_ids,
)
from repro.graphs.topology import (  # noqa: F401
    TOPOLOGY_BUILDERS,
    Topology,
    make_topology,
)
