from repro.graphs.partition import map_graph_to_pods  # noqa: F401
from repro.graphs.topology import (  # noqa: F401
    TOPOLOGY_BUILDERS,
    Topology,
    make_topology,
)
