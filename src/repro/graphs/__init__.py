from repro.graphs.topology import (  # noqa: F401
    Topology,
    make_topology,
    TOPOLOGY_BUILDERS,
)
from repro.graphs.partition import map_graph_to_pods  # noqa: F401
