"""Mapping an FL communication graph onto TPU pods.

In the production deployment (DESIGN.md §3) each DFL participant is a pod:
the `pod` mesh axis enumerates graph nodes, and gossip becomes a ring of
`lax.ppermute` steps.  For graphs larger than the pod count we partition the
graph into `num_pods` groups (greedy modularity-ish BFS partition) and each
pod simulates its group's nodes locally, gossiping only the cut edges.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.graphs.topology import Topology


def map_graph_to_pods(topo: Topology, num_pods: int) -> List[List[int]]:
    """Partition graph nodes into `num_pods` balanced, connectivity-aware
    groups.  Returns a list of node-id lists, one per pod.

    Sizes are exact ±1 (`divmod` split: the first `n % num_pods` groups get
    one extra node), never empty — shard_map's equal-row-block layout
    depends on it.  Each group seeds at the highest-degree unassigned node
    (ties broken toward the lowest id) and grows by BFS; a stalled frontier
    (disconnected remainder) fills deterministically from the lowest
    unassigned id."""
    n = topo.num_nodes
    if num_pods < 1:
        raise ValueError(f"num_pods must be >= 1, got {num_pods}")
    if num_pods > n:
        raise ValueError(
            f"num_pods={num_pods} > num_nodes={n} would leave empty pods; "
            "shard_map needs at least one node per pod")
    base, rem = divmod(n, num_pods)
    sizes = [base + 1 if g < rem else base for g in range(num_pods)]
    unassigned = set(range(n))
    groups: List[List[int]] = []
    for size in sizes:
        seed = max(unassigned, key=lambda u: (int(topo.degrees[u]), -u))
        group = [seed]
        unassigned.discard(seed)
        frontier = [seed]
        while len(group) < size and frontier:
            u = frontier.pop(0)
            for v in np.nonzero(topo.adjacency[u])[0]:
                v = int(v)
                if v in unassigned and len(group) < size:
                    group.append(v)
                    unassigned.discard(v)
                    frontier.append(v)
        while len(group) < size and unassigned:
            v = min(unassigned)
            unassigned.discard(v)
            group.append(v)
        groups.append(group)
    assert not unassigned
    return groups


def pod_adjacency(topo: Topology, groups: List[List[int]]) -> np.ndarray:
    """Quotient adjacency between pods: pods are neighbours iff any cut edge
    connects their groups.  Edge weight = summed ω over the cut.

    Vectorized over the edge list; `np.add.at` accumulates in the same
    row-major edge order the old per-node loop used, so the float32 sums
    are bit-identical."""
    p = len(groups)
    where = np.zeros(topo.num_nodes, np.int64)
    for g, nodes in enumerate(groups):
        if nodes:
            where[np.asarray(nodes, np.int64)] = g
    u, v = np.nonzero(topo.adjacency)
    gu, gv = where[u], where[v]
    cut = gu != gv
    w = np.zeros((p, p), np.float32)
    np.add.at(w, (gu[cut], gv[cut]),
              topo.weights[u[cut], v[cut]].astype(np.float32))
    return w
