"""Mapping an FL communication graph onto TPU pods.

In the production deployment (DESIGN.md §3) each DFL participant is a pod:
the `pod` mesh axis enumerates graph nodes, and gossip becomes a ring of
`lax.ppermute` steps.  For graphs larger than the pod count we partition the
graph into `num_pods` groups (greedy modularity-ish BFS partition) and each
pod simulates its group's nodes locally, gossiping only the cut edges.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.graphs.topology import Topology


def map_graph_to_pods(topo: Topology, num_pods: int) -> List[List[int]]:
    """Partition graph nodes into `num_pods` balanced, connectivity-aware
    groups.  Returns a list of node-id lists, one per pod."""
    n = topo.num_nodes
    if num_pods >= n:
        return [[i] for i in range(n)] + [[] for _ in range(num_pods - n)]
    target = -(-n // num_pods)  # ceil
    unassigned = set(range(n))
    groups: List[List[int]] = []
    while unassigned:
        # seed with the highest-degree unassigned node, grow by BFS.
        seed = max(unassigned, key=lambda u: topo.degrees[u])
        group = [seed]
        unassigned.discard(seed)
        frontier = [seed]
        while len(group) < target and frontier:
            u = frontier.pop(0)
            for v in np.nonzero(topo.adjacency[u])[0]:
                v = int(v)
                if v in unassigned and len(group) < target:
                    group.append(v)
                    unassigned.discard(v)
                    frontier.append(v)
        # if BFS stalled (disconnected remainder) take arbitrary nodes.
        while len(group) < target and unassigned:
            v = unassigned.pop()
            group.append(v)
        groups.append(group)
        if len(groups) == num_pods:
            # dump any remainder into the last groups round-robin.
            for k, v in enumerate(sorted(unassigned)):
                groups[k % num_pods].append(v)
            unassigned.clear()
    while len(groups) < num_pods:
        groups.append([])
    return groups


def pod_adjacency(topo: Topology, groups: List[List[int]]) -> np.ndarray:
    """Quotient adjacency between pods: pods are neighbours iff any cut edge
    connects their groups.  Edge weight = summed ω over the cut."""
    p = len(groups)
    where = np.zeros(topo.num_nodes, np.int64)
    for g, nodes in enumerate(groups):
        for u in nodes:
            where[u] = g
    w = np.zeros((p, p), np.float32)
    for u in range(topo.num_nodes):
        for v in np.nonzero(topo.adjacency[u])[0]:
            gu, gv = where[u], where[int(v)]
            if gu != gv:
                w[gu, gv] += topo.weights[u, int(v)]
    return w
