"""Sparse CSR/edge-list topologies: the node axis at 10^4-10^6 participants.

The dense :class:`~repro.graphs.topology.Topology` materializes `[N, N]`
adjacency/weight matrices and a `[N, max_deg]` padded layout — O(N^2) memory
and, on hub-heavy graphs (star, BA), O(N^2) padding even when E is O(N).
:class:`SparseTopology` stores the directed edge list flat (`edge_src`,
`edge_dst`, `edge_weight`, sorted by `(dst, src)`) plus CSR `row_offsets`
over the receiver axis, so memory is O(N + E) and the builders sample
BA/ER/WS graphs with vectorized numpy instead of per-pair Python loops.

The two representations are exact duals at small N: `from_topology` /
`to_topology` round-trip bitwise (same neighbour order — src ascending per
receiver row — and the same float32 ω), which is what lets the dense engine
serve as the sparse engine's bit-equivalence oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.graphs.topology import Topology, _padded_neighbors

# Above this the dense [N, N] detour is refused: 4096^2 float32 weights is
# already 64 MiB and the padded layout on a star would be another 64 MiB.
_DENSE_GUARD = 4096


def _csr_connected(n: int, row_offsets: np.ndarray, edge_src: np.ndarray) -> bool:
    """BFS over the CSR structure with numpy frontier expansion (no per-node
    Python loop): one gather of all frontier neighbours per level."""
    if n == 0:
        return True
    seen = np.zeros(n, bool)
    seen[0] = True
    frontier = np.array([0], np.int64)
    while frontier.size:
        starts = row_offsets[frontier]
        counts = row_offsets[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        base = np.repeat(starts, counts)
        local = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
        nbrs = edge_src[base + local]
        new = np.unique(nbrs[~seen[nbrs]])
        seen[new] = True
        frontier = new
    return bool(seen.all())


@dataclasses.dataclass(frozen=True)
class SparseTopology:
    """A static communication graph in flat directed edge-list form.

    Edges are directed (each undirected link appears twice) and sorted by
    `(dst, src)`: `row_offsets[i]:row_offsets[i+1]` slices the incoming
    edges of receiver `i`, senders ascending — the same per-row neighbour
    order as the dense padded layout."""

    name: str
    num_nodes: int
    edge_src: np.ndarray  # [E] int32, sender of each directed edge
    edge_dst: np.ndarray  # [E] int32, receiver (non-decreasing)
    edge_weight: np.ndarray  # [E] float32, ω_ij
    row_offsets: np.ndarray  # [N+1] int64, CSR offsets over edge_dst
    connected: bool

    @property
    def num_directed(self) -> int:
        return int(self.edge_src.shape[0])

    @property
    def num_edges(self) -> int:
        return self.num_directed // 2

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.row_offsets).astype(np.int64)

    @property
    def max_degree(self) -> int:
        return max(int(self.degrees.max()), 1) if self.num_nodes else 1

    # ------------------------------------------------------------ converters

    @staticmethod
    def from_pairs(name: str, n: int, u: np.ndarray, v: np.ndarray,
                   weights: Optional[np.ndarray] = None) -> "SparseTopology":
        """Build from undirected pairs (self loops dropped, duplicates and
        orientation collapsed; `weights` aligns with the input pairs and the
        first occurrence of a duplicate wins)."""
        u = np.asarray(u, np.int64)
        v = np.asarray(v, np.int64)
        keep = u != v
        u, v = u[keep], v[keep]
        w = (np.ones(u.shape[0], np.float32) if weights is None
             else np.asarray(weights, np.float32)[keep])
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        code = lo * n + hi
        _, first = np.unique(code, return_index=True)
        lo, hi, w = lo[first], hi[first], w[first]
        src = np.concatenate([lo, hi]).astype(np.int32)
        dst = np.concatenate([hi, lo]).astype(np.int32)
        ww = np.concatenate([w, w])
        order = np.lexsort((src, dst))
        src, dst, ww = src[order], dst[order], ww[order]
        offsets = np.searchsorted(dst, np.arange(n + 1)).astype(np.int64)
        return SparseTopology(
            name=name, num_nodes=n, edge_src=src, edge_dst=dst,
            edge_weight=ww, row_offsets=offsets,
            connected=_csr_connected(n, offsets, src),
        )

    @staticmethod
    def from_topology(topo: Topology) -> "SparseTopology":
        dst, src = np.nonzero(topo.adjacency)  # row i = in-neighbourhood of i
        w = topo.weights[dst, src].astype(np.float32)
        offsets = np.searchsorted(dst, np.arange(topo.num_nodes + 1))
        return SparseTopology(
            name=topo.name, num_nodes=topo.num_nodes,
            edge_src=src.astype(np.int32), edge_dst=dst.astype(np.int32),
            edge_weight=w, row_offsets=offsets.astype(np.int64),
            connected=topo.connected,
        )

    def to_topology(self) -> Topology:
        n = self.num_nodes
        if n > _DENSE_GUARD:
            raise ValueError(
                f"refusing to densify a {n}-node SparseTopology "
                f"(> {_DENSE_GUARD}): the [N, N] matrices it would build are "
                "exactly what the sparse layout exists to avoid")
        adj = np.zeros((n, n), np.int8)
        weights = np.zeros((n, n), np.float32)
        adj[self.edge_dst, self.edge_src] = 1
        weights[self.edge_dst, self.edge_src] = self.edge_weight
        nbr, msk, max_deg = _padded_neighbors(adj)
        return Topology(
            name=self.name, num_nodes=n, adjacency=adj, weights=weights,
            neighbor_idx=nbr, neighbor_mask=msk, max_degree=max_deg,
            connected=self.connected,
        )


# ------------------------------------------------------- edge-index helpers


def rev_edge_permutation(st: SparseTopology) -> np.ndarray:
    """[E] permutation pairing each directed edge with its reverse.

    `rev[e]` is the CSR position of the directed edge `(dst[e] -> src[e])` —
    the opposite record of the same undirected link.  The sparse per-edge
    transport keys BOTH directions' state by CSR edge id directly, so this
    permutation replaces the dense layout's `[N, max_deg]` reverse-slot
    gather; it is an involution (`rev[rev[e]] == e`)."""
    n = np.int64(st.num_nodes)
    src = st.edge_src.astype(np.int64)
    dst = st.edge_dst.astype(np.int64)
    # edges are sorted by (dst, src), so dst*n + src is strictly ascending
    # and searchsorted resolves the reverse edge's position exactly.
    rev = np.searchsorted(dst * n + src, src * n + dst)
    return rev.astype(np.int32)


def undirected_pair_ids(st: SparseTopology) -> Tuple[np.ndarray, int]:
    """[E] map from directed edge to canonical undirected pair id.

    Pairs are enumerated in ascending `(lo, hi)` order (`lo*n + hi` codes) —
    the SAME order the dense layout's `np.triu` enumeration yields — so a
    single `[num_pairs]` random draw indexed through this map produces
    bit-identical per-link coins on both layouts.  Returns
    `(pair_id [E] int32, num_pairs)`; `pair_id[e] == pair_id[rev[e]]`."""
    n = np.int64(st.num_nodes)
    lo = np.minimum(st.edge_src, st.edge_dst).astype(np.int64)
    hi = np.maximum(st.edge_src, st.edge_dst).astype(np.int64)
    codes, inv = np.unique(lo * n + hi, return_inverse=True)
    return inv.astype(np.int32), int(codes.shape[0])


# ------------------------------------------------------------------ builders
#
# All samplers are vectorized numpy (no per-pair Python loops) and mirror the
# dense builders' retry convention: attempt k reseeds at `seed + k * 10007`
# until the graph comes out connected (or `ensure_connected=False`).


def _retry(sample: Callable[[int], SparseTopology], seed: int,
           ensure_connected: bool, what: str) -> SparseTopology:
    for attempt in range(64):
        st = sample(seed + attempt * 10007)
        if st.connected or not ensure_connected:
            return st
    raise RuntimeError(f"could not sample a connected {what} graph")


def _pair_decode(n: int, codes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Invert row-major upper-triangle enumeration: code k -> (i, j), i<j.
    Exact integer inversion via searchsorted on the per-row prefix sums."""
    rows = np.arange(n, dtype=np.int64)
    # offsets[i] = number of pairs in rows < i = i*n - i*(i+1)/2
    offsets = rows * n - rows * (rows + 1) // 2
    i = np.searchsorted(offsets, codes, side="right") - 1
    j = codes - offsets[i] + i + 1
    return i, j


def sparse_erdos_renyi(n: int, p: float = 0.2, seed: int = 0,
                       ensure_connected: bool = True) -> SparseTopology:
    """Exact G(n, p): E ~ Binomial(n(n-1)/2, p) distinct pairs, sampled by
    integer pair-code (rejection top-up, no [N, N] bernoulli matrix)."""
    m_all = n * (n - 1) // 2

    def sample(s: int) -> SparseTopology:
        r = np.random.default_rng(s)
        e = int(r.binomial(m_all, p)) if 0.0 < p < 1.0 else int(round(m_all * p))
        codes = np.unique(r.integers(0, m_all, size=e, dtype=np.int64))
        while codes.shape[0] < e:  # top up collisions; a few rounds at most
            extra = r.integers(0, m_all, size=e - codes.shape[0], dtype=np.int64)
            codes = np.unique(np.concatenate([codes, extra]))
        u, v = _pair_decode(n, codes)
        return SparseTopology.from_pairs(f"erdos_renyi(n={n},p={p})", n, u, v)

    return _retry(sample, seed, ensure_connected, f"ER({n},{p})")


def sparse_barabasi_albert(n: int, m: int = 2, seed: int = 0,
                           ensure_connected: bool = True) -> SparseTopology:
    """BA preferential attachment, vectorized (Batagelj–Brandes repeated-nodes
    with pointer chasing instead of a sequential Python loop).

    Node `m` links to seeds 0..m-1; each later node draws m targets uniformly
    from the repeated-endpoints array.  That array's layout is deterministic
    — even slots hold the step's source, odd slots hold drawn targets — so
    a drawn index resolves either immediately (even / seed slot) or by
    chasing to a strictly earlier step's draw: expected O(log) vectorized
    hops.  Duplicate targets within a node collapse (degree can come out
    slightly under m, as in the multigraph formulation); connectivity holds
    by construction, so the retry loop never fires for valid inputs.
    """
    if not 1 <= m < n:
        raise ValueError(f"need 1 <= m < n, got m={m}, n={n}")

    def sample(s: int) -> SparseTopology:
        r = np.random.default_rng(s)
        steps = n - m - 1  # nodes m+1 .. n-1 draw; node m is deterministic
        if steps > 0:
            # draws[t, j]: index into the repeated array (length 2m*(t+1))
            # drawn by node m+1+t for its j-th target.
            high = (2 * m * (np.arange(1, steps + 1, dtype=np.int64)))[:, None]
            draws = r.integers(0, high, size=(steps, m), dtype=np.int64)
            idx = draws.reshape(-1).copy()
            val = np.full(idx.shape[0], -1, np.int64)
            unresolved = np.ones(idx.shape[0], bool)
            while unresolved.any():
                cur = idx[unresolved]
                even = cur % 2 == 0
                seed_slot = (~even) & (cur < 2 * m)
                res = np.where(even, m + cur // (2 * m), (cur - 1) // 2)
                done = even | seed_slot
                where = np.nonzero(unresolved)[0]
                val[where[done]] = res[done]
                unresolved[where[done]] = False
                chase = where[~done]
                if chase.size:
                    c = idx[chase]  # odd slot of step t>=1 -> its own draw
                    t = c // (2 * m) - 1
                    j = (c % (2 * m)) // 2
                    idx[chase] = draws[t, j]
            targets = val.reshape(steps, m)
            vs = np.repeat(np.arange(m + 1, n, dtype=np.int64), m)
            u = np.concatenate([np.arange(m, dtype=np.int64), vs])
            v = np.concatenate([np.full(m, m, np.int64), targets.reshape(-1)])
        else:
            u = np.arange(m, dtype=np.int64)
            v = np.full(m, m, np.int64)
        return SparseTopology.from_pairs(f"barabasi_albert(n={n},m={m})", n, u, v)

    return _retry(sample, seed, ensure_connected, f"BA({n},{m})")


def sparse_watts_strogatz(n: int, k: int = 4, p: float = 0.1, seed: int = 0,
                          ensure_connected: bool = True) -> SparseTopology:
    """WS small world: ring lattice (each node to its k/2 nearest on each
    side), then each lattice edge rewires its far endpoint with probability
    p to a uniform node.  Rewires that would self-loop or duplicate an
    existing edge keep the original link (vectorized reject, one pass)."""
    if k % 2 or not 0 < k < n:
        raise ValueError(f"need even 0 < k < n, got k={k}, n={n}")

    def sample(s: int) -> SparseTopology:
        r = np.random.default_rng(s)
        base = np.arange(n, dtype=np.int64)
        u = np.tile(base, k // 2)
        d = np.repeat(np.arange(1, k // 2 + 1, dtype=np.int64), n)
        v = (u + d) % n
        rewire = r.random(u.shape[0]) < p
        new_v = r.integers(0, n, size=u.shape[0], dtype=np.int64)
        cand = np.where(rewire, new_v, v)
        lo, hi = np.minimum(u, cand), np.maximum(u, cand)
        code = lo * n + hi
        lattice_code = (np.minimum(u, v) * n + np.maximum(u, v))
        # reject: self loop, duplicate of a lattice edge, duplicate of
        # another (earlier-coded) rewire — keep the original lattice link.
        dup = np.isin(code, lattice_code) & (code != lattice_code)
        counts = np.unique(code, return_counts=True)
        clash = np.isin(code, counts[0][counts[1] > 1])
        bad = (u == cand) | dup | (rewire & clash)
        v_final = np.where(bad, v, cand)
        return SparseTopology.from_pairs(
            f"watts_strogatz(n={n},k={k},p={p})", n, u, v_final)

    return _retry(sample, seed, ensure_connected, f"WS({n},{k},{p})")


def sparse_ring(n: int, **kw) -> SparseTopology:
    u = np.arange(n, dtype=np.int64)
    return SparseTopology.from_pairs(f"ring(n={n})", n, u, (u + 1) % n)


def sparse_star(n: int, **kw) -> SparseTopology:
    """Star — max_degree = N-1, the shape the padded dense layout loses on."""
    v = np.arange(1, n, dtype=np.int64)
    return SparseTopology.from_pairs(f"star(n={n})", n, np.zeros(n - 1, np.int64), v)


def sparse_complete(n: int, **kw) -> SparseTopology:
    i, j = np.triu_indices(n, 1)
    return SparseTopology.from_pairs(f"complete(n={n})", n, i, j)


def sparse_grid2d(rows: int, cols: int, **kw) -> SparseTopology:
    n = rows * cols
    ids = np.arange(n, dtype=np.int64).reshape(rows, cols)
    right = np.stack([ids[:, :-1].reshape(-1), ids[:, 1:].reshape(-1)])
    down = np.stack([ids[:-1].reshape(-1), ids[1:].reshape(-1)])
    u, v = np.concatenate([right, down], axis=1)
    return SparseTopology.from_pairs(f"grid2d({rows}x{cols})", n, u, v)


SPARSE_BUILDERS: Dict[str, Callable[..., SparseTopology]] = {
    "erdos_renyi": sparse_erdos_renyi,
    "barabasi_albert": sparse_barabasi_albert,
    "watts_strogatz": sparse_watts_strogatz,
    "ring": sparse_ring,
    "star": sparse_star,
    "complete": sparse_complete,
    "grid2d": sparse_grid2d,
}


def make_sparse_topology(name: str, **kwargs) -> SparseTopology:
    try:
        builder = SPARSE_BUILDERS[name]
    except KeyError:
        raise ValueError(
            f"unknown sparse topology {name!r}; available: "
            f"{sorted(SPARSE_BUILDERS)}"
        ) from None
    return builder(**kwargs)
