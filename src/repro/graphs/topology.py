"""Complex-network topologies for decentralized learning.

The paper runs on an Erdős–Rényi graph (50 nodes, p=0.2 — above the ln(n)/n
connectivity threshold) and motivates with a Barabási–Albert example.  We
provide those plus other standard families from network science so the impact
of topology can be studied (ring, star, complete, Watts–Strogatz, 2-D grid).

A :class:`Topology` packages everything the vmapped simulator and the sharded
runtime need:
  * dense adjacency / weight matrices (numpy, row i = in-neighbourhood of i),
  * padded neighbour index/weight arrays (fixed max-degree layout for vmap),
  * graph metadata (family, parameters, connectivity).

Edge weights ω_ij default to 1 ("a simple communication link"), but any
positive weighting (e.g. social trust) can be attached via `weight_fn`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

try:  # networkx is available in this environment; keep a tiny fallback anyway.
    import networkx as nx

    _HAVE_NX = True
except Exception:  # pragma: no cover
    _HAVE_NX = False


@dataclasses.dataclass(frozen=True)
class Topology:
    """A static communication graph G(V, E) with weighted edges."""

    name: str
    num_nodes: int
    adjacency: np.ndarray  # [N, N] {0,1}, no self loops
    weights: np.ndarray  # [N, N] float, ω_ij (0 where no edge)
    neighbor_idx: np.ndarray  # [N, max_deg] int, padded with -1
    neighbor_mask: np.ndarray  # [N, max_deg] {0,1}
    max_degree: int
    connected: bool

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1).astype(np.int64)

    @property
    def num_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    def neighbor_weights(self) -> np.ndarray:
        """[N, max_deg] ω_ij aligned with neighbor_idx (0 at padding).

        One fancy-indexed gather over the padded layout (padding slots are
        clamped to column 0 and zeroed by the mask) — the O(N·max_deg)
        Python loop this replaces is pinned equivalent in
        tests/test_graphs_data.py."""
        n = self.num_nodes
        idx = np.maximum(self.neighbor_idx, 0)
        gathered = self.weights[np.arange(n)[:, None], idx]
        return (gathered * self.neighbor_mask).astype(np.float32)


def _is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    stack = [0]
    seen[0] = True
    while stack:
        u = stack.pop()
        for v in np.nonzero(adj[u])[0]:
            if not seen[v]:
                seen[v] = True
                stack.append(int(v))
    return bool(seen.all())


def _padded_neighbors(adj: np.ndarray):
    """Vectorized padded neighbour layout from a dense {0,1} adjacency.

    Returns (neighbor_idx, neighbor_mask, max_degree).  `np.nonzero` is
    row-major, so within each row the neighbour ids come out ascending —
    the same order the per-row Python loop produced."""
    n = adj.shape[0]
    degs = adj.sum(axis=1).astype(np.int64)
    max_deg = max(int(degs.max()), 1)
    nbr = -np.ones((n, max_deg), np.int32)
    msk = np.zeros((n, max_deg), np.int8)
    rows, cols = np.nonzero(adj)
    if rows.size:
        starts = np.zeros(n, np.int64)
        np.cumsum(degs[:-1], out=starts[1:])
        pos = np.arange(rows.size) - np.repeat(starts, degs)
        nbr[rows, pos] = cols.astype(np.int32)
        msk[rows, pos] = 1
    return nbr, msk, max_deg


def _from_adjacency(name: str, adj: np.ndarray,
                    weight_fn: Optional[Callable[[int, int, np.random.Generator], float]] = None,
                    rng: Optional[np.random.Generator] = None) -> Topology:
    n = adj.shape[0]
    adj = adj.astype(np.int8)
    np.fill_diagonal(adj, 0)
    adj = np.maximum(adj, adj.T)  # undirected
    if weight_fn is None:
        weights = (adj != 0).astype(np.float32)
    else:
        # keep the explicit upper-triangle loop: weight_fn sees (i, j, rng)
        # in a defined order, so vectorizing would change the rng stream.
        rng = rng or np.random.default_rng(0)
        weights = np.zeros((n, n), np.float32)
        for i in range(n):
            for j in range(i + 1, n):
                if adj[i, j]:
                    w = float(weight_fn(i, j, rng))
                    weights[i, j] = weights[j, i] = w
    nbr, msk, max_deg = _padded_neighbors(adj)
    return Topology(
        name=name,
        num_nodes=n,
        adjacency=adj,
        weights=weights,
        neighbor_idx=nbr,
        neighbor_mask=msk,
        max_degree=max_deg,
        connected=_is_connected(adj),
    )


# ---------------------------------------------------------------- builders


def erdos_renyi(n: int, p: float = 0.2, seed: int = 0, ensure_connected: bool = True,
                **kw) -> Topology:
    """ER(n, p).  The paper uses n=50, p=0.2 (>> ln(50)/50 ≈ 0.078 threshold)."""
    for attempt in range(64):
        s = seed + attempt * 10007
        if _HAVE_NX:
            g = nx.erdos_renyi_graph(n, p, seed=s)
            adj = nx.to_numpy_array(g, dtype=np.int8)
        else:  # pragma: no cover
            r = np.random.default_rng(s)
            adj = (r.random((n, n)) < p).astype(np.int8)
            adj = np.triu(adj, 1)
            adj = adj + adj.T
        topo = _from_adjacency(f"erdos_renyi(n={n},p={p})", adj, **kw)
        if topo.connected or not ensure_connected:
            return topo
    raise RuntimeError(f"could not sample a connected ER({n},{p}) graph")


def barabasi_albert(n: int, m: int = 2, seed: int = 0,
                    ensure_connected: bool = True, **kw) -> Topology:
    """BA preferential-attachment graph (paper Fig. 1 motivating example).

    The networkx builder is connected by construction; the fallback sampler
    can leave early nodes isolated, so it gets the same seeded retry loop as
    :func:`erdos_renyi` (attempt 0 uses `seed` itself, preserving the
    original stream for graphs that come out connected first try).
    """
    for attempt in range(64):
        s = seed + attempt * 10007
        if _HAVE_NX:
            g = nx.barabasi_albert_graph(n, m, seed=s)
            adj = nx.to_numpy_array(g, dtype=np.int8)
        else:
            r = np.random.default_rng(s)
            adj = np.zeros((n, n), np.int8)
            # node m links to every seed node 0..m-1 (as in the standard
            # construction); without this the seeds root disjoint attachment
            # trees and m=1 graphs can never come out connected.
            adj[m, :m] = adj[:m, m] = 1
            for v in range(m + 1, n):
                deg = adj.sum(axis=1)[:v] + 1.0
                targets = r.choice(v, size=min(m, v), replace=False, p=deg / deg.sum())
                for t in targets:
                    adj[v, t] = adj[t, v] = 1
        topo = _from_adjacency(f"barabasi_albert(n={n},m={m})", adj, **kw)
        if topo.connected or not ensure_connected:
            return topo
    raise RuntimeError(f"could not sample a connected BA({n},{m}) graph")


def watts_strogatz(n: int, k: int = 4, p: float = 0.1, seed: int = 0, **kw) -> Topology:
    if _HAVE_NX:
        g = nx.connected_watts_strogatz_graph(n, k, p, seed=seed)
        adj = nx.to_numpy_array(g, dtype=np.int8)
    else:  # pragma: no cover
        raise RuntimeError("watts_strogatz requires networkx")
    return _from_adjacency(f"watts_strogatz(n={n},k={k},p={p})", adj, **kw)


def ring(n: int, **kw) -> Topology:
    adj = np.zeros((n, n), np.int8)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1
    return _from_adjacency(f"ring(n={n})", adj, **kw)


def star(n: int, **kw) -> Topology:
    """Star graph — FL's implicit topology with the server at the hub."""
    adj = np.zeros((n, n), np.int8)
    adj[0, 1:] = adj[1:, 0] = 1
    return _from_adjacency(f"star(n={n})", adj, **kw)


def complete(n: int, **kw) -> Topology:
    adj = np.ones((n, n), np.int8)
    return _from_adjacency(f"complete(n={n})", adj, **kw)


def grid2d(rows: int, cols: int, **kw) -> Topology:
    n = rows * cols
    adj = np.zeros((n, n), np.int8)
    for r in range(rows):
        for c in range(cols):
            u = r * cols + c
            if c + 1 < cols:
                adj[u, u + 1] = adj[u + 1, u] = 1
            if r + 1 < rows:
                adj[u, u + cols] = adj[u + cols, u] = 1
    return _from_adjacency(f"grid2d({rows}x{cols})", adj, **kw)


TOPOLOGY_BUILDERS: Dict[str, Callable[..., Topology]] = {
    "erdos_renyi": erdos_renyi,
    "barabasi_albert": barabasi_albert,
    "watts_strogatz": watts_strogatz,
    "ring": ring,
    "star": star,
    "complete": complete,
    "grid2d": grid2d,
}


def make_topology(name: str, **kwargs) -> Topology:
    try:
        builder = TOPOLOGY_BUILDERS[name]
    except KeyError:
        raise ValueError(
            f"unknown topology {name!r}; available: {sorted(TOPOLOGY_BUILDERS)}"
        ) from None
    return builder(**kwargs)
