"""Pallas TPU kernels for the paper's compute hot-spots.

  decdiff_update — fused global-L2 + attenuated step (Eq. 5) over the
                   flattened model (two streaming passes, block reductions)
  vt_kl_loss     — fused virtual-teacher KL over the vocab axis (Eq. 8),
                   closed form, custom_vjp with fused softmax-p_t backward
  neighbor_avg   — weighted average of stacked neighbour models (Eq. 6)
  dequant_avg    — fused int8-dequantize + weighted average (Eq. 6 applied
                   directly to the comm layer's quantized gossip payloads;
                   single-receiver and receiver-block variants — the block
                   form is what the shard_map DFL round runs on the
                   all_gathered payload)
  decode_attention — fused one-token GQA attention over the ring KV cache
                   (the serving hot spot; online softmax over cache tiles)
  gather_rows    — row gather out of a flattened table (the cross-pod
                   reverse-slot resolution of the per-edge exchange: a pure
                   copy, bitwise identical to fancy indexing)

`ops` holds the jit'd public wrappers (auto interpret=True off-TPU);
`ref` holds the pure-jnp oracles the tests sweep against.
"""
from repro.kernels.ops import (  # noqa: F401
    decdiff_update,
    decdiff_update_tree,
    decode_attention_fused,
    dequant_neighbor_avg,
    dequant_neighbor_avg_rows,
    gather_rows,
    neighbor_avg,
    vt_kl_loss_fused,
)
