"""Pallas kernel: fused DecDiff update (paper Eq. 5) over the flat model.

The model (possibly billions of parameters) is flattened to a 1-D fp32
stream, viewed as [rows, 128] for TPU lane alignment.  Two streaming passes:

  pass A  block-wise Σ(w̄-w)² partial reduction  → [n_blocks] partials
          (host combines + sqrt: d = ||w̄-w||₂, one scalar)
  pass B  w' = w + (w̄-w) · scale, scale = 1/(d+s) broadcast from a (1,1)
          block pinned to grid position 0

Both passes are memory-bound streaming kernels: block (256, 128) fp32 =
128 KiB per operand, 3 operands live → < 0.5 MiB VMEM, far under the ~16 MiB
budget; larger blocks would not change the HBM-bound roofline.  The MXU is
not involved — this is a VPU elementwise/reduce workload; the (8,128)-aligned
second-minor/minor dims are what matters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256  # (256, 128) fp32 = 128 KiB per ref


def _sumsq_kernel(w_ref, wbar_ref, out_ref):
    d = wbar_ref[...] - w_ref[...]
    out_ref[0, 0] = jnp.sum(d * d)


def _step_kernel(w_ref, wbar_ref, scale_ref, out_ref):
    scale = scale_ref[0, 0]
    out_ref[...] = w_ref[...] + (wbar_ref[...] - w_ref[...]) * scale


def sumsq_diff_blocks(w2d: jnp.ndarray, wbar2d: jnp.ndarray, *,
                      interpret: bool = False) -> jnp.ndarray:
    """[R, 128] x2 -> [n_blocks, 1] partial Σ(w̄-w)² (R % BLOCK_ROWS == 0)."""
    rows = w2d.shape[0]
    n_blocks = rows // BLOCK_ROWS
    return pl.pallas_call(
        _sumsq_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        interpret=interpret,
    )(w2d, wbar2d)


def scaled_step_blocks(w2d: jnp.ndarray, wbar2d: jnp.ndarray, scale: jnp.ndarray,
                       *, interpret: bool = False) -> jnp.ndarray:
    """w + (w̄-w)*scale, scale is a [1,1] array broadcast to every block."""
    rows = w2d.shape[0]
    n_blocks = rows // BLOCK_ROWS
    return pl.pallas_call(
        _step_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(w2d.shape, jnp.float32),
        interpret=interpret,
    )(w2d, wbar2d, scale)
