"""Pallas kernel: fused one-token GQA attention over a ring KV cache.

Decode (serve_step) is the serving hot spot: per token it streams the whole
KV cache (window W) from HBM once — a pure memory-bound op that XLA splits
into separate score/softmax/combine kernels with [B,H,W] round trips.  This
kernel fuses the three into one pass with an online softmax over W-tiles:

  grid (B-blocks, W-blocks); per q-head-group block:
    s_w   = q · k_w * scale + mask(slot_pos_w)
    m,l,acc online-softmax accumulate;  out = acc / l  at the last W-block

Masking reproduces layers.decode_attention semantics: a slot participates
iff slot_pos >= 0 and slot_pos <= pos (ring buffer holds only live entries;
a sliding window is implied by ring-buffer overwrite).

Blocks: W-tile 512 slots x (K_h, hd) with K_h*hd <= 1024 lanes-worth; the
working set per step is k/v tiles (512*K*hd*2 bytes) + q — few hundred KiB,
VMEM-safe.  The B dim is tiled by 8 rows for the sublane dimension.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_BLK = 8
W_BLK = 512


def _decode_attn_kernel(q_ref, k_ref, v_ref, sp_ref, pos_ref,
                        o_ref, m_ref, l_ref, *, scale: float, w_blk: int):
    """Blocks:
      q  [B_BLK, H, hd]      (same block for every w-step)
      k  [B_BLK, W_BLK, K, hd]
      v  [B_BLK, W_BLK, K, hd]
      sp [W_BLK]             slot positions (absolute, -1 empty)
      pos [1, 1]             current absolute position
      o  [B_BLK, H, hd]      output (revisited across w-steps)
      m,l [B_BLK, H]         running max / normalizer (scratch outputs)
    """
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)  # [B,H,hd]
    k = k_ref[...].astype(jnp.float32)  # [B,W,K,hd]
    v = v_ref[...].astype(jnp.float32)
    bb, h, hd = q.shape
    kk = k.shape[2]
    g = h // kk
    qg = q.reshape(bb, kk, g, hd)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, k) * scale  # [B,K,G,W]

    pos = pos_ref[0, 0]
    sp = sp_ref[...]
    ok = (sp >= 0) & (sp <= pos)
    s = jnp.where(ok[None, None, None, :], s, -1e30)

    m_new_blk = jnp.max(s, axis=-1).reshape(bb, h)  # [B,H]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = m_new_blk
        p = jnp.exp(s - m_new_blk.reshape(bb, kk, g)[..., None])
        l_ref[...] = jnp.sum(p, axis=-1).reshape(bb, h)
        o_ref[...] = jnp.einsum("bkgw,bwkd->bkgd", p, v).reshape(bb, h, hd)

    @pl.when(j > 0)
    def _acc():
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, m_new_blk)
        alpha = jnp.exp(m_old - m_new)  # [B,H]
        p = jnp.exp(s - m_new.reshape(bb, kk, g)[..., None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1).reshape(bb, h)
        o_ref[...] = (o_ref[...] * alpha[..., None]
                      + jnp.einsum("bkgw,bwkd->bkgd", p, v).reshape(bb, h, hd))
        m_ref[...] = m_new


def decode_attention_blocks(q, k_cache, v_cache, slot_pos, pos, *,
                            interpret: bool = False):
    """q [B,H,hd]; k/v [B,W,K,hd]; slot_pos [W]; pos scalar int32.

    Returns attention output [B,H,hd] (fp32).  B % 8 == 0, W % 512 == 0
    (ops.py pads)."""
    b, h, hd = q.shape
    w = k_cache.shape[1]
    kk = k_cache.shape[2]
    scale = 1.0 / math.sqrt(hd)
    grid = (b // B_BLK, w // W_BLK)
    kern = functools.partial(_decode_attn_kernel, scale=scale, w_blk=W_BLK)
    out, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B_BLK, h, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((B_BLK, W_BLK, kk, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((B_BLK, W_BLK, kk, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((W_BLK,), lambda i, j: (j,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B_BLK, h, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((B_BLK, h), lambda i, j: (i, 0)),
            pl.BlockSpec((B_BLK, h), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, slot_pos, pos)
    return out / jnp.maximum(l[..., None], 1e-30)
