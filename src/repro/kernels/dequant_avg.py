"""Pallas kernel: fused int8-dequantize + weighted neighbour average.

The comm layer's int8 gossip hands each node N quantized neighbour rows
(q [N, D] int8, one fp32 scale per row).  Materializing the dequantized
fp32 models in HBM would cost 4x the payload's footprint and a full extra
HBM round-trip; instead this kernel folds the dequantization into the
Eq. 6 reduction:

    out[d] = Σ_n (w[n] * scale[n]) * q[n, d]

Same tiling as neighbor_avg (the codec changes the wire format, not the
aggregation geometry): D streams in (N, COLS) tiles — int8 rows are 4x
denser per tile, so one tile = N*2048 bytes <= 128 KiB VMEM — and the
per-row weight*scale product collapses into the einsum vector, keeping the
inner loop a single int8->fp32 vector-matrix product on the VPU.

Weights are pre-normalized by the wrapper (ops.dequant_neighbor_avg).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COLS = 2048


def _dequant_avg_kernel(q_ref, ws_ref, out_ref):
    # ws = weight * scale per row: dequantization is just a per-row rescale,
    # so it fuses into the reduction weights for free.
    out_ref[...] = jnp.einsum(
        "n,nd->d", ws_ref[...], q_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)


def dequant_avg_blocks(q: jnp.ndarray, weight_scale: jnp.ndarray, *,
                       interpret: bool = False) -> jnp.ndarray:
    """q [N, D] int8, weight_scale [N] fp32 (= normalized weight x scale)
    -> [D] fp32 weighted dequantized average."""
    n, d = q.shape
    assert d % COLS == 0, d
    return pl.pallas_call(
        _dequant_avg_kernel,
        grid=(d // COLS,),
        in_specs=[
            pl.BlockSpec((n, COLS), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((COLS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(q, weight_scale)


def _dequant_avg_rows_kernel(q_ref, ws_ref, out_ref):
    # ws[r, n] = wn[r, n] * scale[n]: per-receiver weights with the senders'
    # dequantization scales folded in, so the whole Eq. 6 block reduces to
    # one int8->fp32 matrix product per tile on the MXU.
    out_ref[...] = jnp.einsum(
        "rn,nd->rd", ws_ref[...], q_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)


def dequant_avg_rows_blocks(q: jnp.ndarray, ws: jnp.ndarray, *,
                            interpret: bool = False) -> jnp.ndarray:
    """The multi-receiver variant the shard_map round uses: every receiver
    in a pod block averages the SAME N gathered int8 payloads under its own
    weight row.

    q [N, D] int8 (the all_gathered wire payloads), ws [R, N] fp32
    (= row-normalized gossip weights x per-sender scales) -> [R, D] fp32
    weighted dequantized averages.  Same (N, COLS) streaming as the
    single-receiver kernel — each q tile is loaded once and reused for all
    R receivers, which is the point of fusing across the block.
    """
    n, d = q.shape
    r = ws.shape[0]
    assert d % COLS == 0, d
    return pl.pallas_call(
        _dequant_avg_rows_kernel,
        grid=(d // COLS,),
        in_specs=[
            pl.BlockSpec((n, COLS), lambda i: (0, i)),
            pl.BlockSpec((r, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((r, COLS), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(q, ws)
