"""Pallas kernel: row gather out of a stacked table.

The unified exchange resolves the per-edge reverse slot map by reading, for
every receiver row r and slot e, sender ``nbr_idx[r, e]``'s reconstruction
at slot ``rev_slot[r, e]`` — a gather of K rows out of the [M, D] table of
per-link references (M = N x max_deg flattened).  Done with fancy indexing
the gather materializes its index bookkeeping per D-column; here the D axis
streams in (M, COLS) tiles — same geometry as the dequant_avg kernels, one
tile <= N*E*8 KiB VMEM for fp32 — and each tile is read once and scattered
to all K output rows before the next tile lands:

    out[k, d] = tbl[idx[k], d]

A pure copy: no float ops, so kernel vs. XLA-gather is bitwise identical
(pinned in tests/test_kernels.py), which is what lets the engine run it on
every backend without perturbing the vmap oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COLS = 2048


def _gather_rows_kernel(tbl_ref, idx_ref, out_ref):
    # dynamic row indexing of the loaded tile: the whole column tile is in
    # VMEM, so the gather is a register-level row permutation per tile.
    out_ref[...] = jnp.take(tbl_ref[...], idx_ref[...], axis=0)


def gather_rows_blocks(tbl: jnp.ndarray, idx: jnp.ndarray, *,
                       interpret: bool = False) -> jnp.ndarray:
    """tbl [M, D] fp32, idx [K] int32 row ids -> [K, D] gathered rows."""
    m, d = tbl.shape
    k = idx.shape[0]
    assert d % COLS == 0, d
    return pl.pallas_call(
        _gather_rows_kernel,
        grid=(d // COLS,),
        in_specs=[
            pl.BlockSpec((m, COLS), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((k, COLS), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, d), jnp.float32),
        interpret=interpret,
    )(tbl, idx)
