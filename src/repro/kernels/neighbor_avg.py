"""Pallas kernel: weighted neighbour-model average (paper Eq. 6).

stacked [N, D] neighbour parameter rows x normalized weights [N] -> [D].
The neighbour count N is small (graph degree, <= 64) while D is the model
size, so the kernel streams D in (N, COLS) tiles: one tile = N*2048 fp32
<= 512 KiB VMEM.  The reduction over N is a tiny vector-matrix product on
the VPU; HBM streaming of the stacked models is the bound, as expected for
an aggregation op.

Weights are pre-normalized by the wrapper (ops.neighbor_avg), keeping the
kernel a pure weighted sum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COLS = 2048


def _avg_kernel(stacked_ref, w_ref, out_ref):
    out_ref[...] = jnp.einsum(
        "n,nd->d", w_ref[...], stacked_ref[...],
        preferred_element_type=jnp.float32)


def neighbor_avg_blocks(stacked: jnp.ndarray, weights: jnp.ndarray, *,
                        interpret: bool = False) -> jnp.ndarray:
    n, d = stacked.shape
    assert d % COLS == 0, d
    return pl.pallas_call(
        _avg_kernel,
        grid=(d // COLS,),
        in_specs=[
            pl.BlockSpec((n, COLS), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((COLS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(stacked, weights)
