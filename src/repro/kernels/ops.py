"""Public jit'd wrappers for the Pallas kernels.

Handles padding to tile boundaries, the pytree <-> flat-stream view, the
custom_vjp wiring for the fused VT loss, and automatic `interpret=True` when
not running on TPU (this container is CPU-only; interpret mode executes the
kernel bodies in Python for correctness validation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.virtual_teacher import teacher_entropy
from repro.kernels import decdiff_update as _dd
from repro.kernels import neighbor_avg as _na
from repro.kernels import vt_kl_loss as _vt
from repro.utils.pytree import tree_flatten_to_vector


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, multiple, value=0.0):
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1), constant_values=value)
    return x


# ------------------------------------------------------------- decdiff


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def decdiff_update(w_flat, wbar_flat, s: float = 1.0, interpret=None):
    """Eq. 5 on flat vectors via the two-pass Pallas stream."""
    interpret = _interpret_default() if interpret is None else interpret
    n = w_flat.shape[0]
    tile = _dd.BLOCK_ROWS * _dd.LANES
    w = _pad_to(w_flat.astype(jnp.float32), tile).reshape(-1, _dd.LANES)
    wb = _pad_to(wbar_flat.astype(jnp.float32), tile).reshape(-1, _dd.LANES)
    # pad region contributes (wb-w)=0 to the norm because both pads are 0.
    partials = _dd.sumsq_diff_blocks(w, wb, interpret=interpret)
    d = jnp.sqrt(jnp.sum(partials))
    scale = (1.0 / (d + s)).reshape(1, 1)
    out = _dd.scaled_step_blocks(w, wb, scale, interpret=interpret)
    return out.reshape(-1)[:n].astype(w_flat.dtype)


def decdiff_update_tree(params, avg_params, s: float = 1.0, interpret=None):
    """Pytree-level DecDiff step backed by the flat-stream kernel."""
    w, unflatten = tree_flatten_to_vector(params)
    wbar, _ = tree_flatten_to_vector(avg_params)
    return unflatten(decdiff_update(w, wbar, s=s, interpret=interpret))


# ------------------------------------------------------------- vt loss


def _vt_stats(z, labels, interpret):
    b, v = z.shape
    zp = jnp.pad(z, ((0, (-b) % _vt.ROWS), (0, (-v) % _vt.VCOLS)))
    lp = jnp.pad(labels.astype(jnp.int32), (0, (-b) % _vt.ROWS),
                 constant_values=-1)
    mx = _vt.row_max(zp, v, interpret=interpret)
    stats = _vt.row_stats(zp, lp, mx, v, interpret=interpret)
    return zp, lp, mx, stats


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def vt_kl_loss_fused(logits, labels, beta: float = 0.95, interpret=None):
    """Mean KL(p_t || softmax(logits)) — Eq. 8 — fused over the vocab axis.

    logits [B, V] (fp32/bf16), labels [B] int32.  custom_vjp: backward is the
    fused (softmax - p_t) kernel, so autodiff never materializes the teacher.
    """
    loss, _ = _vt_fwd(logits, labels, beta, interpret)
    return loss


def _vt_loss_from_stats(z, labels, mx, stats, beta):
    b, v = z.shape
    sumexp, zsum, zc = stats[:b, 0], stats[:b, 1], stats[:b, 2]
    mxb = mx[:b]
    lse = jnp.log(sumexp) + mxb
    a = (1.0 - beta) / (v - 1)
    cross = beta * zc + a * (zsum - zc) - lse
    return jnp.mean(-teacher_entropy(beta, v) - cross)


def _vt_fwd(logits, labels, beta, interpret):
    interpret = _interpret_default() if interpret is None else interpret
    z = logits.astype(jnp.float32)
    zp, lp, mx, stats = _vt_stats(z, labels, interpret)
    loss = _vt_loss_from_stats(z, labels, mx, stats, beta)
    return loss, (logits, zp, lp, mx, stats)


def _vt_bwd(beta, interpret, res, g):
    interpret_ = _interpret_default() if interpret is None else interpret
    logits, zp, lp, mx, statsp = res
    b, v = logits.shape
    dtype = logits.dtype
    sumexp = jnp.pad(statsp[:, 0], (0, zp.shape[0] - statsp.shape[0]),
                     constant_values=1.0)
    gscale = (g / b).reshape(1, 1).astype(jnp.float32)
    grad = _vt.vt_backward(zp, lp, mx, sumexp, gscale, beta=beta, vocab=v,
                           interpret=interpret_)
    return grad[:b, :v].astype(dtype), None


vt_kl_loss_fused.defvjp(_vt_fwd, _vt_bwd)


# ------------------------------------------------------------- decode attn


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_fused(q, k_cache, v_cache, slot_pos, pos, interpret=None):
    """Fused one-token GQA attention over a ring cache (serve hot spot).

    q [B,H,hd]; k/v [B,W,K,hd]; slot_pos [W] absolute positions (-1 empty);
    pos scalar current position.  Matches layers.decode_attention's
    score/softmax/combine (output fp32)."""
    from repro.kernels import decode_attention as _da

    interpret = _interpret_default() if interpret is None else interpret
    b, h, hd = q.shape
    w = k_cache.shape[1]
    pad_b = (-b) % _da.B_BLK
    pad_w = (-w) % _da.W_BLK
    qp = jnp.pad(q.astype(jnp.float32), ((0, pad_b), (0, 0), (0, 0)))
    kp = jnp.pad(k_cache, ((0, pad_b), (0, pad_w), (0, 0), (0, 0)))
    vp = jnp.pad(v_cache, ((0, pad_b), (0, pad_w), (0, 0), (0, 0)))
    spp = jnp.pad(slot_pos.astype(jnp.int32), (0, pad_w), constant_values=-1)
    pos2 = jnp.reshape(pos.astype(jnp.int32), (1, 1))
    out = _da.decode_attention_blocks(qp, kp, vp, spp, pos2,
                                      interpret=interpret)
    return out[:b]


# ------------------------------------------------------------- gather rows


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(tbl, idx, interpret=None):
    """Row gather `tbl[idx]` via the streaming Pallas kernel.

    tbl [M, D], idx [K] int row ids -> [K, D] (tbl's dtype preserved).
    The unified exchange's reverse-slot resolution: tbl is the flattened
    [N*max_deg, D] per-link reference table, idx the receivers' flattened
    (nbr, rev_slot) pairs.  A pure copy — bitwise identical to `tbl[idx]`.
    """
    from repro.kernels.gather_rows import COLS, gather_rows_blocks

    interpret = _interpret_default() if interpret is None else interpret
    d = tbl.shape[1]
    pad = (-d) % COLS
    tp = jnp.pad(tbl.astype(jnp.float32), ((0, 0), (0, pad)))
    out = gather_rows_blocks(tp, idx.astype(jnp.int32),
                             interpret=interpret)
    return out[:, :d].astype(tbl.dtype)


# ------------------------------------------------------------- neighbor avg


@functools.partial(jax.jit, static_argnames=("interpret",))
def neighbor_avg(stacked, weights, interpret=None):
    """Eq. 6: normalized ω_ij p_ij-weighted average of stacked [N, D] rows."""
    interpret = _interpret_default() if interpret is None else interpret
    n, d = stacked.shape
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)
    pad = (-d) % _na.COLS
    sp = jnp.pad(stacked.astype(jnp.float32), ((0, 0), (0, pad)))
    out = _na.neighbor_avg_blocks(sp, w, interpret=interpret)
    return out[:d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_neighbor_avg(q, scales, weights, interpret=None):
    """Eq. 6 over int8 comm payloads: dequantize-and-accumulate in one pass.

    q [N, D] int8 rows (the neighbours' wire payloads), scales [N] fp32
    per-row quantization scales, weights [N] ω_ij p_ij (normalized here).
    Equals neighbor_avg(q * scales[:, None], weights) without ever writing
    the dequantized models back to HBM.
    """
    from repro.kernels import dequant_avg as _dqa

    interpret = _interpret_default() if interpret is None else interpret
    n, d = q.shape
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)
    ws = w * scales.astype(jnp.float32)
    pad = (-d) % _dqa.COLS
    qp = jnp.pad(q.astype(jnp.int8), ((0, 0), (0, pad)))
    out = _dqa.dequant_avg_blocks(qp, ws, interpret=interpret)
    return out[:d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def segment_neighbor_avg(vals, w, interpret=None):
    """Ragged neighbor reduce: per-receiver (Σ_k w·vals, Σ_k w) in one pass.

    vals [B, K, D] f32 slot-padded neighbour rows (src-ascending per row,
    garbage allowed wherever w is 0), w [B, K] f32 unnormalized gossip
    weights (0 at padding/undelivered slots) -> (sums [B, D], tot [B]).

    A ones column rides along as column D so the totals come out of the
    same per-row contraction as the sums (a separate `jnp.sum(w)` would
    not be bitwise K-width-invariant).  Each receiver row is contracted
    independently inside the kernel (see `repro.kernels.segment_avg`), and
    `lax.map` drives fixed ROWS-row chunks so the kernel traces once: the
    result is bitwise invariant to B, chunking, and K zero-padding — the
    dense engine at small N is therefore an exact oracle for this path.
    """
    from repro.kernels import segment_avg as _sa

    interpret = _interpret_default() if interpret is None else interpret
    b, k, d = vals.shape
    v2 = jnp.concatenate([vals.astype(jnp.float32),
                          jnp.ones((b, k, 1), jnp.float32)], axis=2)
    v2 = jnp.pad(v2, ((0, (-b) % _sa.ROWS), (0, 0), (0, (-(d + 1)) % _sa.COLS)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, (-b) % _sa.ROWS), (0, 0)))
    bp, dp = v2.shape[0], v2.shape[2]
    out = jax.lax.map(
        lambda args: _sa.segment_avg_chunk(args[0], args[1],
                                           interpret=interpret),
        (wp.reshape(bp // _sa.ROWS, _sa.ROWS, k),
         v2.reshape(bp // _sa.ROWS, _sa.ROWS, k, dp)))
    out = out.reshape(bp, dp)[:b]
    return out[:, :d], out[:, d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_segment_neighbor_avg(q, scales, w, interpret=None):
    """Ragged dequantize-and-reduce over int8 payload blocks.

    q [B, K, D] int8 slot-padded wire payloads, scales [B, K] f32 per-slot
    quantization scales, w [B, K] f32 gossip weights -> sums [B, D] f32,
    Σ_k (w_k·s_k)·q_k per receiver.  Sums only: normalization totals must
    come from `segment_neighbor_avg`'s ones-column path so their bits match
    the f32 route (the fused w·s product here associates differently from
    w·(s·q), so this is the fast path, not the oracle-pinned one).
    """
    from repro.kernels import segment_avg as _sa

    interpret = _interpret_default() if interpret is None else interpret
    b, k, d = q.shape
    qp = jnp.pad(q.astype(jnp.int8),
                 ((0, (-b) % _sa.ROWS), (0, 0), (0, (-d) % _sa.COLS)))
    ws = w.astype(jnp.float32) * scales.astype(jnp.float32)
    wsp = jnp.pad(ws, ((0, (-b) % _sa.ROWS), (0, 0)))
    bp, dp = qp.shape[0], qp.shape[2]
    out = jax.lax.map(
        lambda args: _sa.dequant_segment_avg_chunk(args[0], args[1],
                                                   interpret=interpret),
        (wsp.reshape(bp // _sa.ROWS, _sa.ROWS, k),
         qp.reshape(bp // _sa.ROWS, _sa.ROWS, k, dp)))
    return out.reshape(bp, dp)[:b, :d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_neighbor_avg_rows(q, scales, wn, interpret=None):
    """Eq. 6 for a BLOCK of receivers over int8 comm payloads, fused.

    q [N, D] int8 rows (the all_gathered wire payloads), scales [N] fp32
    per-sender quantization scales, wn [R, N] per-receiver gossip weights
    — already row-normalized by the caller (the shard_map round masks and
    normalizes before slicing its pod block; an all-zero row yields an
    all-zero average, the "heard from nobody" case).  Equals
    wn @ (q * scales[:, None]) without materializing the dequantized
    models: each int8 tile is loaded once and reused for all R receivers.
    """
    from repro.kernels import dequant_avg as _dqa

    interpret = _interpret_default() if interpret is None else interpret
    d = q.shape[1]
    ws = wn.astype(jnp.float32) * scales.astype(jnp.float32)[None, :]
    pad = (-d) % _dqa.COLS
    qp = jnp.pad(q.astype(jnp.int8), ((0, 0), (0, pad)))
    out = _dqa.dequant_avg_rows_blocks(qp, ws, interpret=interpret)
    return out[:, :d]
