"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decdiff_update_ref(w: jnp.ndarray, wbar: jnp.ndarray, s: float = 1.0):
    """Eq. 5 on flat fp32 vectors: w + (wbar-w)/(||wbar-w|| + s)."""
    w32 = w.astype(jnp.float32)
    diff = wbar.astype(jnp.float32) - w32
    d = jnp.sqrt(jnp.sum(diff * diff))
    return (w32 + diff / (d + s)).astype(w.dtype)


def vt_kl_loss_ref(logits: jnp.ndarray, labels: jnp.ndarray, beta: float):
    """Eq. 8 mean KL(p_t || softmax(z)) with the materialized teacher."""
    z = logits.astype(jnp.float32)
    v = z.shape[-1]
    a = (1.0 - beta) / (v - 1)
    onehot = jax.nn.one_hot(labels, v, dtype=jnp.float32)
    p_t = onehot * beta + (1.0 - onehot) * a
    logp = jax.nn.log_softmax(z, axis=-1)
    log_pt = jnp.log(jnp.maximum(p_t, 1e-30))
    return jnp.mean(jnp.sum(p_t * (log_pt - logp), axis=-1))


def vt_kl_grad_ref(logits: jnp.ndarray, labels: jnp.ndarray, beta: float):
    """d(mean KL)/d logits = (softmax(z) - p_t) / n_rows."""
    z = logits.astype(jnp.float32)
    v = z.shape[-1]
    a = (1.0 - beta) / (v - 1)
    onehot = jax.nn.one_hot(labels, v, dtype=jnp.float32)
    p_t = onehot * beta + (1.0 - onehot) * a
    p = jax.nn.softmax(z, axis=-1)
    return (p - p_t) / z.shape[0]


def decode_attention_ref(q, k_cache, v_cache, slot_pos, pos):
    """One-token GQA attention over a ring cache — mirrors
    repro.models.lm.layers.decode_attention's math (fp32)."""
    q32 = q.astype(jnp.float32)
    b, h, hd = q32.shape
    kk = k_cache.shape[2]
    g = h // kk
    qg = q32.reshape(b, kk, g, hd)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, k_cache.astype(jnp.float32)) * scale
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    s = jnp.where(ok[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bwkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd)


def neighbor_avg_ref(stacked: jnp.ndarray, weights: jnp.ndarray):
    """Eq. 6 on a stacked [N, D] matrix: normalized weighted average."""
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)
    return jnp.einsum("n,nd->d", w, stacked.astype(jnp.float32))


def dequant_neighbor_avg_ref(q: jnp.ndarray, scales: jnp.ndarray,
                             weights: jnp.ndarray):
    """Eq. 6 over int8 payloads: dequantize rows, then normalized average."""
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)
    dq = q.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]
    return jnp.einsum("n,nd->d", w, dq)


def dequant_neighbor_avg_rows_ref(q: jnp.ndarray, scales: jnp.ndarray,
                                  wn: jnp.ndarray):
    """Multi-receiver Eq. 6 over int8 payloads: dequantize, then apply each
    receiver's (pre-normalized) weight row."""
    dq = q.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]
    return jnp.einsum("rn,nd->rd", wn.astype(jnp.float32), dq)
