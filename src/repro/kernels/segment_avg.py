"""Ragged segment neighbor-average kernels (the sparse engine's reduce).

`neighbor_avg` / `dequant_neighbor_avg_rows` assume one dense `[N, D]` /
`[R, N]` weight panel — O(N^2) state.  The sparse engine instead gathers
each receiver's neighbour rows into slot-padded blocks `[B, K, D]` (K =
bucket width, degree-dependent) and reduces them here.

Bitwise contract: each receiver row is contracted by its OWN unrolled
`einsum("k,kd->d")` GEMV inside the kernel body.  A batched contraction's
bits depend on the batch geometry (probed: `einsum("bk,bkd->bd")` at B=100
differs from the same rows at B=1), so per-row unrolling is what makes the
result invariant to how receivers are blocked into chunks, pods, or degree
buckets — the property the dense-oracle equivalence rests on.  Zero-weight
tail slots (padding, undelivered edges) are bit-neutral for any finite
slot values: a `0.0 * x` term adds ±0.0, which never perturbs an IEEE
accumulator.

Callers drive fixed `[ROWS, K, D]` chunks through `lax.map` (see
`repro.kernels.ops.segment_neighbor_avg`): the kernel traces once per
shape, so interpret mode stays cheap even at 10^4-10^6 total receivers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8  # receiver rows per chunk (fixed so every call shares one geometry)
COLS = 256  # feature columns per grid tile


def _segment_avg_kernel(w_ref, v_ref, o_ref):
    o_ref[...] = jnp.stack([
        jnp.einsum("k,kd->d", w_ref[r], v_ref[r],
                   preferred_element_type=jnp.float32)
        for r in range(ROWS)])


def _dequant_segment_avg_kernel(ws_ref, q_ref, o_ref):
    o_ref[...] = jnp.stack([
        jnp.einsum("k,kd->d", ws_ref[r], q_ref[r].astype(jnp.float32),
                   preferred_element_type=jnp.float32)
        for r in range(ROWS)])


def _cols(dp: int, interpret: bool) -> int:
    """Feature-tile width.  On hardware the COLS grid bounds VMEM; in
    interpret mode every grid point unrolls into the caller's trace, so one
    full-width tile keeps the program linear in ROWS, not in D.  Column
    tiling cannot change bits either way: each output element accumulates
    over the K axis only, so its addition order is tile-independent."""
    return dp if interpret else COLS


@functools.partial(jax.jit, static_argnames=("interpret",))
def segment_avg_chunk(w, v, interpret=True):
    """w [ROWS, K] f32, v [ROWS, K, Dp] f32 (Dp % COLS == 0) -> [ROWS, Dp]."""
    rows, k, dp = v.shape
    cols = _cols(dp, interpret)
    return pl.pallas_call(
        _segment_avg_kernel,
        grid=(dp // cols,),
        in_specs=[pl.BlockSpec((ROWS, k), lambda j: (0, 0)),
                  pl.BlockSpec((ROWS, k, cols), lambda j: (0, 0, j))],
        out_specs=pl.BlockSpec((ROWS, cols), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rows, dp), jnp.float32),
        interpret=interpret,
    )(w, v)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_segment_avg_chunk(ws, q, interpret=True):
    """ws [ROWS, K] f32 (weight*scale), q [ROWS, K, Dp] int8 -> [ROWS, Dp].

    Dequantize-and-reduce in one pass: the int8 payload tile is never
    written back to HBM as float32."""
    rows, k, dp = q.shape
    cols = _cols(dp, interpret)
    return pl.pallas_call(
        _dequant_segment_avg_kernel,
        grid=(dp // cols,),
        in_specs=[pl.BlockSpec((ROWS, k), lambda j: (0, 0)),
                  pl.BlockSpec((ROWS, k, cols), lambda j: (0, 0, j))],
        out_specs=pl.BlockSpec((ROWS, cols), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rows, dp), jnp.float32),
        interpret=interpret,
    )(ws, q)
