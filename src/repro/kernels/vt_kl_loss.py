"""Pallas kernel: fused Virtual-Teacher KL loss (paper Eq. 7-8) over vocab.

For LM-scale class counts (|L| up to 152k) the teacher distribution must
never be materialized.  Using the closed form (core/virtual_teacher.py):

  KL_row = -H(p_t) - [ β z_c + a (Σz - z_c) - lse(z) ],  a = (1-β)/(V-1)

only four per-row reductions over V are needed: max, Σexp(z-max), Σz, z_c.
Kernels:

  pass 1  row max             — grid (nb, nv), running maximum
  pass 2  (Σexp, Σz, z_c)     — grid (nb, nv), running sums using pass-1 max;
          z_c found by comparing lane ids against the label (no gather)
  bwd     (softmax(z) - p_t)·g — one streaming pass, recomputes exp from the
          saved (max, Σexp) row stats; p_t reconstructed from lane-id compare

Blocks are (ROWS=128, VCOLS=512): 256 KiB fp32 per operand — VMEM-safe with
headroom for the three stat rows.  Grid iterates v-blocks innermost so the
running reductions accumulate in the (revisited) output block, the standard
TPU sequential-grid pattern.

The public wrapper (ops.vt_kl_loss_fused) attaches a custom_vjp so the fused
backward replaces the O(B·V) autodiff chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 128
VCOLS = 512
NEG = -1e30


def _max_kernel(z_ref, mx_ref, *, vcols: int, vocab: int):
    j = pl.program_id(1)
    col = jax.lax.broadcasted_iota(jnp.int32, z_ref.shape, 1) + j * vcols
    m = jnp.max(jnp.where(col < vocab, z_ref[...], NEG), axis=1)

    @pl.when(j == 0)
    def _init():
        mx_ref[...] = m

    @pl.when(j > 0)
    def _acc():
        mx_ref[...] = jnp.maximum(mx_ref[...], m)


def _stats_kernel(z_ref, lab_ref, mx_ref, out_ref, *, vcols: int, vocab: int):
    """out [ROWS, 3]: (Σ exp(z-max), Σ z, z_c) accumulated over v-blocks.

    Padding lanes (col >= vocab) are masked INSIDE the kernel — correcting a
    -1e30 pad contribution afterwards would cancel catastrophically in fp32."""
    j = pl.program_id(1)
    z = z_ref[...]
    mx = mx_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) + j * vcols
    valid = col < vocab
    e = jnp.where(valid, jnp.exp(z - mx[:, None]), 0.0)
    zm = jnp.where(valid, z, 0.0)
    hit = col == lab_ref[...][:, None]
    zc = jnp.sum(jnp.where(hit, z, 0.0), axis=1)
    part = jnp.stack([jnp.sum(e, axis=1), jnp.sum(zm, axis=1), zc], axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        out_ref[...] = out_ref[...] + part


def _bwd_kernel(z_ref, lab_ref, mx_ref, sumexp_ref, gscale_ref, out_ref, *,
                vcols: int, beta: float, vocab: int):
    j = pl.program_id(1)
    z = z_ref[...]
    p = jnp.exp(z - mx_ref[...][:, None]) / sumexp_ref[...][:, None]
    col = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) + j * vcols
    hit = col == lab_ref[...][:, None]
    a = (1.0 - beta) / (vocab - 1)
    p_t = jnp.where(hit, beta, a)
    valid = col < vocab  # padding lanes carry no teacher mass
    out_ref[...] = jnp.where(valid, (p - p_t) * gscale_ref[0, 0], 0.0)


def row_max(z, vocab: int, *, interpret=False):
    b, v = z.shape
    grid = (b // ROWS, v // VCOLS)
    kern = functools.partial(_max_kernel, vcols=VCOLS, vocab=vocab)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, VCOLS), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ROWS,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(z)


def row_stats(z, labels, mx, vocab: int, *, interpret=False):
    b, v = z.shape
    grid = (b // ROWS, v // VCOLS)
    kern = functools.partial(_stats_kernel, vcols=VCOLS, vocab=vocab)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, VCOLS), lambda i, j: (i, j)),
            pl.BlockSpec((ROWS,), lambda i, j: (i,)),
            pl.BlockSpec((ROWS,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((ROWS, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 3), jnp.float32),
        interpret=interpret,
    )(z, labels, mx)


def vt_backward(z, labels, mx, sumexp, gscale, *, beta: float, vocab: int,
                interpret=False):
    b, v = z.shape
    grid = (b // ROWS, v // VCOLS)
    kern = functools.partial(_bwd_kernel, vcols=VCOLS, beta=beta, vocab=vocab)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, VCOLS), lambda i, j: (i, j)),
            pl.BlockSpec((ROWS,), lambda i, j: (i,)),
            pl.BlockSpec((ROWS,), lambda i, j: (i,)),
            pl.BlockSpec((ROWS,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, VCOLS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, v), jnp.float32),
        interpret=interpret,
    )(z, labels, mx, sumexp, gscale)
