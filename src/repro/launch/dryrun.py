import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers and compiles.

For each combination this driver builds ShapeDtypeStruct stand-ins for every
input (no allocation), assigns in_shardings from repro.dist.sharding, lowers
and compiles the appropriate step, and records:

  * memory_analysis()  — per-device bytes (argument/output/temp),
  * cost_analysis()    — HLO FLOPs / bytes-accessed,
  * collective traffic — parsed from the post-SPMD HLO (hlo_analysis),

into artifacts/dryrun/<arch>__<shape>__<mesh>.json for the roofline stage.

Steps per shape (see DESIGN.md §4):
  train_4k     -> train_step (single-pod) / DFL round with DecDiff gossip
                  over the pod axis (multi-pod — this is the paper's
                  technique running between pods)
  prefill_32k  -> prefill_step (forward)
  decode_32k   -> serve_step: ONE token against a seq_len KV cache
  long_500k    -> serve_step with sub-quadratic state: native for SSM/hybrid,
                  SWA window for mixtral, ring-buffer window (8192) for
                  full-attention archs (flagged as the sliding-window variant)

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.dist.dfl_step import (
    build_dfl_round,
    build_prefill_step,
    build_serve_step,
    build_train_step,
)
from repro.dist.sharding import (
    make_batch_specs,
    make_cache_specs,
    make_param_specs,
    named,
)
from repro.launch.hlo_analysis import (
    collective_bytes,
    cost_analysis_dict,
    memory_analysis_dict,
)
from repro.launch.mesh import HW, make_production_mesh
from repro.models.lm import build_lm
from repro.optim.sgd import sgd_momentum

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

LONG_WINDOW = 8192  # ring-buffer window for full-attention archs at 500k

# §Perf variants (EXPERIMENTS.md §Perf): named config overrides measured
# against the paper-faithful baseline via --variant.
VARIANTS = {
    "zero3": {"zero3_gather": True},
    "moelocal": {"moe_dispatch": "batch_local"},
    "expertpar": {"moe_dispatch": "batch_local", "expert_parallel": True},
    "gossipbf16": {"_gossip_dtype": "bfloat16"},  # DFL rounds only
    "moelocal+seqshard": {"moe_dispatch": "batch_local",
                          "residual_shard": "batch_seq"},
    "seqshard+gossipbf16": {"residual_shard": "batch_seq",
                            "_gossip_dtype": "bfloat16"},
    "shardmap": {"_dfl_shardmap": True},
    "shardmap+seqshard": {"_dfl_shardmap": True,
                          "residual_shard": "batch_seq"},
    "shardmap+seqshard+gossipbf16": {"_dfl_shardmap": True,
                                     "residual_shard": "batch_seq",
                                     "_gossip_dtype": "bfloat16"},
    "moelocal+bf16probs": {"moe_dispatch": "batch_local",
                           "attn_probs_bf16": True},
    "seqshard": {"residual_shard": "batch_seq"},
    "bf16probs": {"attn_probs_bf16": True},
    "zero3+bf16probs": {"zero3_gather": True, "attn_probs_bf16": True},
    "zero3+seqshard": {"zero3_gather": True, "residual_shard": "batch_seq"},
    "all": {"zero3_gather": True, "attn_probs_bf16": True,
            "residual_shard": "batch_seq"},
}


def _adapt_config(cfg, shape_name: str, layer_override=None):
    """Per-shape config adjustments (documented in DESIGN.md §4)."""
    layer_override = {k: v for k, v in (layer_override or {}).items()
                      if not k.startswith("_")}
    over = {}
    if shape_name == "long_500k" and cfg.family in ("dense", "vlm", "encdec"):
        # sliding-window variant: ring-buffer decode cache bounds state.
        over["decode_window"] = LONG_WINDOW
    if shape_name == "train_4k":
        over["remat"] = True
    else:
        over["remat"] = False
    if layer_override:
        over.update(layer_override)
    return dataclasses.replace(cfg, **over)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def lower_combo(arch: str, shape_name: str, mesh_kind: str, layer_override=None):
    """Returns (lowered, compiled, meta) for one combination."""
    seq_len, global_batch, kind = SHAPES[shape_name]
    gossip_dtype = (layer_override or {}).get("_gossip_dtype")
    dfl_shardmap = (layer_override or {}).get("_dfl_shardmap", False)
    cfg = _adapt_config(get_config(arch), shape_name, layer_override)
    lm = build_lm(cfg)
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_pods = mesh.shape.get("pod", 1)
    optimizer = sgd_momentum(lr=1e-3, momentum=0.9, momentum_dtype=jnp.float32)

    params_a = _abstract(lambda: lm.init(jax.random.PRNGKey(0)))
    meta = dict(arch=arch, shape=shape_name, mesh=mesh_kind, seq_len=seq_len,
                global_batch=global_batch, kind=kind,
                mesh_shape={k: int(v) for k, v in mesh.shape.items()})

    with mesh:
        if kind == "train" and multi:
            # DFL round: one FL node per pod, ring adjacency over pods.
            adj = np.zeros((n_pods, n_pods), np.float32)
            for i in range(n_pods):
                adj[i, (i + 1) % n_pods] = adj[i, (i - 1) % n_pods] = 1.0
            adj /= np.maximum(adj.sum(1, keepdims=True), 1)
            keys = jax.random.split(jax.random.PRNGKey(0), n_pods)
            params_st = _abstract(lambda: jax.vmap(lm.init)(keys))
            opt_st = _abstract(lambda p: jax.vmap(optimizer.init)(p), params_st)
            per_node_batch = global_batch // n_pods
            batch_a = {
                k: jax.ShapeDtypeStruct((n_pods, per_node_batch) + v.shape[1:], v.dtype)
                for k, v in lm.input_specs(global_batch, seq_len).items()
            }
            p_specs = named(make_param_specs(params_st, mesh, dfl_node_axis=True,
                                             expert_parallel=cfg.expert_parallel), mesh)
            o_specs = {"momentum": p_specs}
            b_specs = named(make_batch_specs(batch_a, mesh, dfl_node_axis=True), mesh)
            gd = jnp.dtype(gossip_dtype) if gossip_dtype else None
            if dfl_shardmap:
                from repro.dist.dfl_step import build_dfl_round_shardmap

                step_fn = build_dfl_round_shardmap(lm, optimizer, adj, mesh,
                                                   gossip_dtype=gd)
            else:
                step_fn = build_dfl_round(lm, optimizer, jnp.asarray(adj),
                                          gossip_dtype=gd)
            jitted = jax.jit(step_fn, in_shardings=(p_specs, o_specs, None, b_specs),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_st, opt_st,
                                   jax.ShapeDtypeStruct((), jnp.int32), batch_a)
        elif kind == "train":
            opt_a = _abstract(optimizer.init, params_a)
            batch_a = lm.input_specs(global_batch, seq_len)
            p_specs = named(make_param_specs(params_a, mesh,
                                             expert_parallel=cfg.expert_parallel), mesh)
            o_specs = {"momentum": p_specs}
            b_specs = named(make_batch_specs(batch_a, mesh), mesh)
            step_fn = build_train_step(lm, optimizer)
            jitted = jax.jit(step_fn, in_shardings=(p_specs, o_specs, None, b_specs),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_a, opt_a,
                                   jax.ShapeDtypeStruct((), jnp.int32), batch_a)
        elif kind == "prefill":
            batch_a = lm.input_specs(global_batch, seq_len)
            p_specs = named(make_param_specs(params_a, mesh,
                                             expert_parallel=cfg.expert_parallel), mesh)
            dp = ("pod", "data") if multi else ("data",)
            b_specs = named(make_batch_specs(batch_a, mesh, dp_axes=dp), mesh)
            step_fn = build_prefill_step(lm)
            jitted = jax.jit(step_fn, in_shardings=(p_specs, b_specs))
            lowered = jitted.lower(params_a, batch_a)
        else:  # decode
            cache_a = _abstract(lambda: lm.init_cache(global_batch, seq_len))
            tokens_a = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
            p_specs = named(make_param_specs(params_a, mesh,
                                             expert_parallel=cfg.expert_parallel), mesh)
            c_specs = named(make_cache_specs(cache_a, mesh), mesh)
            t_specs = named(make_batch_specs(tokens_a, mesh), mesh)
            step_fn = build_serve_step(lm)
            jitted = jax.jit(step_fn, in_shardings=(p_specs, c_specs, t_specs),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_a, cache_a, tokens_a)
            meta["cache_bytes_global"] = int(sum(
                np.prod(v.shape) * np.dtype(v.dtype).itemsize
                for v in jax.tree.leaves(cache_a)))

    meta["param_count"] = int(cfg.param_count())
    meta["active_param_count"] = int(cfg.active_param_count())
    compiled = lowered.compile()
    return lowered, compiled, meta


def roofline_terms(meta, cost, coll, n_chips: int):
    """The three roofline terms in seconds (TPU v5e constants).

    cost_analysis() on an SPMD-partitioned module reports PER-PARTITION
    FLOPs/bytes (verified against analytic 6ND), and the parsed collective
    bytes are per-device operand volumes — so every term is already
    per-chip; no further division by n_chips."""
    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes accessed", 0.0)
    coll_b = float(coll.get("total", 0))
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_acc / HW["hbm_bw"]
    t_coll = coll_b / HW["ici_bw"]
    return {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}


def _calibration_points(cfg):
    """Layer-count overrides used to linearize scan-body costs.

    XLA's HloCostAnalysis counts while-loop bodies ONCE (verified), so the
    full compile underreports flops/bytes/collectives inside scan-over-layers
    by ~L.  We compile the same step with 1 and 2 layers (full widths) and
    extrapolate linearly; the hybrid family needs a third point to separate
    the per-mamba-layer and per-shared-block terms."""
    base = {
        # unroll every scan so flops/bytes/collectives are counted per
        # iteration; enlarge attention chunks so the (q,kv)-block grid is
        # small enough to unroll — total flops are chunk-size invariant
        # because the baseline computes every block and masks.
        "scan_unroll": True,
        "attn_chunk_q": 4096,
        "attn_chunk_kv": 8192,
        "remat": False,  # remat doubles counted fwd flops; measure pure cost
    }
    if cfg.family == "hybrid":
        return [
            dict(base, n_layers=1, shared_attn_every=1),
            dict(base, n_layers=2, shared_attn_every=2),
            dict(base, n_layers=2, shared_attn_every=1),
        ]
    if cfg.family == "encdec":
        return [dict(base, n_layers=1, n_enc_layers=1),
                dict(base, n_layers=2, n_enc_layers=2)]
    return [dict(base, n_layers=1), dict(base, n_layers=2)]


def _metrics_of(compiled):
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    out = {"flops": cost.get("flops", 0.0),
           "bytes_accessed": cost.get("bytes accessed", 0.0),
           "transcendentals": cost.get("transcendentals", 0.0)}
    for k, v in coll.items():
        if not k.endswith("_count"):
            out["coll_" + k] = float(v)
    return out


def _combine(a, b, fa, fb):
    keys = set(a) | set(b)
    return {k: fa * a.get(k, 0.0) + fb * b.get(k, 0.0) for k in keys}


def calibrated_metrics(arch: str, shape_name: str, mesh_kind: str,
                       variant_override=None):
    """Linear per-layer extrapolation of per-chip flops/bytes/collectives."""
    cfg = get_config(arch)
    pts = _calibration_points(cfg)
    ms = []
    for ov in pts:
        if variant_override:
            ov = dict(ov, **variant_override)
        _, comp, _ = lower_combo(arch, shape_name, mesh_kind, layer_override=ov)
        ms.append(_metrics_of(comp))
    if cfg.family == "hybrid":
        c1, c2, c3 = ms
        m_layer = _combine(c2, c1, 1.0, -1.0)  # one mamba layer
        s_block = _combine(c3, c2, 1.0, -1.0)  # one shared block
        ovh = _combine(_combine(c1, m_layer, 1.0, -1.0), s_block, 1.0, -1.0)
        g = cfg.n_layers // cfg.shared_attn_every
        total = _combine(_combine(ovh, s_block, 1.0, float(g)),
                         m_layer, 1.0, float(cfg.n_layers))
    else:
        c1, c2 = ms
        per_layer = _combine(c2, c1, 1.0, -1.0)
        total = _combine(c1, per_layer, 1.0, float(cfg.n_layers - 1))
    return {k: max(v, 0.0) for k, v in total.items()}


def model_flops_per_chip(cfg, shape_name: str, n_chips: int) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train (8·N·D with remat counted as useful
    is NOT done — remat recompute is overhead by definition), 2·N·D forward.
    MoE uses active params."""
    seq_len, global_batch, kind = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens / n_chips
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens / n_chips
    tokens = global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens / n_chips


def run_one(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
            force: bool = False, variant: str = None,
            variant_override: dict = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}".replace("/", "_")
    if variant:
        tag += f"__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "ok": False,
           "variant": variant or "baseline",
           "variant_override": variant_override or {}}
    try:
        lowered, compiled, meta = lower_combo(arch, shape_name, mesh_kind,
                                              layer_override=variant_override)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        cost = cost_analysis_dict(compiled)
        mem = memory_analysis_dict(compiled)
        n_chips = int(np.prod(list(meta["mesh_shape"].values())))
        rec.update(meta)
        rec["ok"] = True
        rec["compile_s"] = time.time() - t0
        rec["cost_analysis"] = cost
        rec["memory_analysis"] = mem
        rec["collectives"] = coll
        rec["n_chips"] = n_chips
        # calibrated (scan-aware) per-chip totals -> the roofline uses these
        cal = calibrated_metrics(arch, shape_name, mesh_kind,
                                 variant_override=variant_override)
        rec["calibrated"] = cal
        cost_cal = {"flops": cal.get("flops", 0.0),
                    "bytes accessed": cal.get("bytes_accessed", 0.0)}
        coll_cal = {"total": cal.get("coll_total", 0.0)}
        rec["roofline"] = roofline_terms(meta, cost_cal, coll_cal, n_chips)
        rec["roofline_uncalibrated"] = roofline_terms(meta, cost, coll, n_chips)
        cfg_full = get_config(arch)
        mf = model_flops_per_chip(cfg_full, shape_name, n_chips)
        rec["model_flops_per_chip"] = mf
        rec["useful_flops_ratio"] = (mf / cal["flops"]) if cal.get("flops") else None
        if mem:
            per_dev = (mem.get("argument_size_in_bytes", 0)
                       + mem.get("temp_size_in_bytes", 0)
                       + mem.get("output_size_in_bytes", 0))
            rec["bytes_per_device"] = per_dev
            rec["fits_hbm"] = bool(per_dev <= HW["hbm_bytes"])
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["compile_s"] = time.time() - t0
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="sweep all combos")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", choices=sorted(VARIANTS), default=None,
                    help="apply a §Perf config variant (writes tagged artifact)")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_one(arch, shape, mesh_kind, args.out, force=args.force,
                              variant=args.variant,
                              variant_override=VARIANTS.get(args.variant))
                status = "OK " if rec.get("ok") else "FAIL"
                extra = ""
                if rec.get("ok"):
                    r = rec["roofline"]
                    extra = (f"compute {r['compute_s']*1e3:.2f}ms "
                             f"mem {r['memory_s']*1e3:.2f}ms "
                             f"coll {r['collective_s']*1e3:.2f}ms "
                             f"[{rec.get('compile_s', 0):.0f}s compile]")
                    n_ok += 1
                else:
                    extra = rec.get("error", "")[:160]
                    n_fail += 1
                print(f"[{status}] {arch:24s} {shape:12s} {mesh_kind:6s} {extra}",
                      flush=True)
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
