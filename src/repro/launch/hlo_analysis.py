"""Post-SPMD HLO analysis: collective-traffic accounting for the roofline.

`compiled.cost_analysis()` reports FLOPs and bytes-accessed but NOT collective
traffic, so we parse the optimized HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute /
collective-broadcast op (per-op-type breakdown kept for the §Perf loop).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(" + "|".join(COLLECTIVE_OPS) + r")(-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device *operand* bytes per collective-op type, summed module-wide.

    Post-SPMD HLO shows per-partition shapes.  Operands are referenced by
    name (no inline shape), so we derive operand bytes from the RESULT shape:
      all-reduce / collective-permute / all-to-all : operand == result
      all-gather   : operand = result / group_size (gathered dim grows by G)
      reduce-scatter: operand = result * group_size
    `-done` halves of async pairs are skipped (the `-start` was counted).
    """
    out: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    count: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result, op, startdone = m.groups()
        if startdone == "-done":
            continue
        b = _shape_bytes(result)
        g = _group_size(line)
        if op == "all-gather":
            b = b // g
        elif op == "reduce-scatter":
            b = b * g
        out[op] += b
        count[op] += 1
    total = sum(out.values())
    summary = {"total": total}
    for op in COLLECTIVE_OPS:
        if count[op]:
            summary[op] = out[op]
            summary[op + "_count"] = count[op]
    return summary


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def cost_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)}
