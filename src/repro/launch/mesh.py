"""Production mesh definitions (TPU v5e target).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the `pod` axis
carries the DFL node dim (one decentralized-learning participant per pod).

Functions, not module constants — importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Tiny mesh on the real local devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    return jax.make_mesh((data, max(1, min(model, n // data))), ("data", "model"))


HW = dict(  # TPU v5e constants used by the roofline analysis
    peak_flops_bf16=197e12,  # per chip
    hbm_bw=819e9,  # bytes/s per chip
    ici_bw=50e9,  # bytes/s per link (~per chip usable)
    hbm_bytes=16e9,
)
