"""End-to-end training driver.

Two modes:
  * ``--mode dfl`` (default): P-node decentralized federated training of an
    assigned architecture (reduced preset for CPU) with DecDiff gossip
    between nodes each round — the paper's Algorithm 1 at LM scale.
  * ``--mode single``: plain data-parallel training (the "centralized"
    reference at the systems level).

On real hardware this runs under the production mesh (launch/mesh.py); on
this container it runs the reduced configs on the host CPU mesh.  Synthetic
token streams stand in for the data pipeline (repro.data.tokens).

Example (CPU, ~100M-params-class run):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --preset reduced --steps 200 --nodes 2 --log-every 10
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data.tokens import synthetic_token_batch
from repro.dist.dfl_step import build_dfl_round, build_train_step
from repro.models.lm import build_lm
from repro.optim.sgd import sgd_momentum
from repro.utils.pytree import tree_size


def make_batches(lm, nodes, batch, seq, steps, seed=0):
    """Pre-generate a deterministic synthetic token stream per node."""
    for step in range(steps):
        bs = []
        for node in range(max(nodes, 1)):
            b = synthetic_token_batch(batch, seq, lm.cfg.vocab,
                                      seed=seed + step * 131 + node)
            bs.append(b)
        if nodes == 0:
            yield {k: jnp.asarray(v) for k, v in bs[0].items()}
        else:
            yield {k: jnp.asarray(np.stack([b[k] for b in bs]))
                   for k in bs[0]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["reduced", "full"], default="reduced")
    ap.add_argument("--mode", choices=["dfl", "single"], default="dfl")
    ap.add_argument("--nodes", type=int, default=2, help="DFL nodes (pods)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--beta", type=float, default=0.98, help="VT confidence")
    ap.add_argument("--loss", choices=["vt", "ce"], default="vt")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced(n_layers=4, d_model=256, vocab=2048)
    lm = build_lm(cfg)
    opt = sgd_momentum(lr=args.lr, momentum=0.9)

    if args.mode == "single":
        params = lm.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        step_fn = jax.jit(build_train_step(lm, opt, loss_kind=args.loss,
                                           beta=args.beta))
        stream = make_batches(lm, 0, args.batch, args.seq, args.steps)
    else:
        keys = jax.random.split(jax.random.PRNGKey(0), args.nodes)
        params = jax.vmap(lm.init)(keys)  # heterogeneous init per node
        opt_state = jax.vmap(opt.init)(params)
        adj = np.zeros((args.nodes, args.nodes), np.float32)
        for i in range(args.nodes):
            adj[i, (i + 1) % args.nodes] = adj[i, (i - 1) % args.nodes] = 1.0
        adj /= np.maximum(adj.sum(1, keepdims=True), 1)
        step_fn = jax.jit(build_dfl_round(lm, opt, jnp.asarray(adj),
                                          loss_kind=args.loss, beta=args.beta))
        stream = make_batches(lm, args.nodes, args.batch, args.seq, args.steps)

    n_params = tree_size(params)
    print(f"arch={args.arch} preset={args.preset} mode={args.mode} "
          f"params={n_params/1e6:.1f}M loss={args.loss}")

    t0 = time.time()
    losses = []
    for step, batch in enumerate(stream):
        params, opt_state, loss = step_fn(params, opt_state, jnp.int32(step), batch)
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            rate = (step + 1) / (time.time() - t0)
            print(f"step {step:5d}  loss {float(loss):.4f}  {rate:.2f} it/s",
                  flush=True)
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps,
                               {"params": params, "opt": opt_state},
                               metadata={"arch": args.arch, "mode": args.mode})
        print("checkpoint:", path)
    assert np.isfinite(losses[-1]), "training diverged"
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
