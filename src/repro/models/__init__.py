from repro.models.api import SmallModel, make_small_model, SMALL_MODELS  # noqa: F401
