from repro.models.api import SMALL_MODELS, SmallModel, make_small_model  # noqa: F401
