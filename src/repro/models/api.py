"""Model API for the paper-scale (simulator) models.

A :class:`SmallModel` is an (init, apply) pair over plain dict pytrees:
  init(rng) -> params
  apply(params, x, *, train=False, rng=None) -> logits

The large assigned architectures use the richer interface in
:mod:`repro.models.lm` (forward / prefill / decode with KV caches).
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple


class SmallModel(NamedTuple):
    name: str
    init: Callable
    apply: Callable
    num_classes: int


SMALL_MODELS: Dict[str, Callable[..., SmallModel]] = {}


def register_small_model(name: str):
    def deco(fn):
        SMALL_MODELS[name] = fn
        return fn

    return deco


def make_small_model(name: str, **kwargs) -> SmallModel:
    import repro.models.mlp_cnn  # noqa: F401  (populate registry)

    try:
        return SMALL_MODELS[name](**kwargs)
    except KeyError:
        raise ValueError(
            f"unknown small model {name!r}; available: {sorted(SMALL_MODELS)}"
        ) from None
