from repro.models.lm.api import LM, build_lm  # noqa: F401
from repro.models.lm.config import ArchConfig  # noqa: F401
