from repro.models.lm.config import ArchConfig  # noqa: F401
from repro.models.lm.api import build_lm, LM  # noqa: F401
