"""Unified LM interface over the six architecture families.

    lm = build_lm(cfg)
    params = lm.init(rng)
    logits, aux = lm.forward(params, batch)          # batch: dict
    loss, metrics = lm.loss(params, batch)           # VT-KL or CE next-token
    cache = lm.init_cache(batch_size, seq_len)       # decode state
    logits, cache = lm.decode_step(params, cache, tokens)   # [B,1]
    specs = lm.input_specs(batch, seq_len)           # ShapeDtypeStructs

The training loss is the paper's Virtual Teacher KL (Eq. 8) applied to
next-token prediction (the closed-form vocab reduction — see
core/virtual_teacher.py), selectable vs plain CE via cfg-independent args.
MoE families add the router load-balance auxiliary.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.virtual_teacher import cross_entropy_loss, vt_kl_loss
from repro.models.lm import dense, encdec, hybrid, moe, ssm, vlm
from repro.models.lm.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ArchConfig
    init: Callable
    forward: Callable  # (params, batch) -> (logits, aux)
    init_cache: Callable  # (batch, seq_len) -> cache
    decode_step: Callable  # (params, cache, tokens[B,1]) -> (logits, cache)
    input_specs: Callable  # (batch, seq_len) -> dict[str, ShapeDtypeStruct]
    prep_decode_cache: Optional[Callable] = None  # encdec: fill cross K/V

    def loss(self, params, batch, *, loss_kind: str = "vt", beta: float = 0.98):
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        if loss_kind == "vt":
            main = vt_kl_loss(logits, labels, beta=beta)
        else:
            main = cross_entropy_loss(logits, labels)
        total = main + self.cfg.router_aux_weight * aux
        return total, {"loss": main, "aux": aux}


def _token_specs(batch: int, seq_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }


def build_lm(cfg: ArchConfig) -> LM:
    fam = cfg.family
    if fam == "dense":
        def fwd(params, batch):
            return dense.forward_dense(cfg, params, batch["tokens"]), 0.0

        return LM(cfg, lambda rng: dense.init_dense(rng, cfg), fwd,
                  lambda b, s: dense.init_cache_dense(cfg, b, s),
                  lambda p, c, t: dense.decode_step_dense(cfg, p, c, t),
                  _token_specs)

    if fam == "moe":
        def fwd(params, batch):
            return moe.forward_moe(cfg, params, batch["tokens"])

        return LM(cfg, lambda rng: moe.init_moe_lm(rng, cfg), fwd,
                  lambda b, s: moe.init_cache_moe(cfg, b, s),
                  lambda p, c, t: moe.decode_step_moe(cfg, p, c, t),
                  _token_specs)

    if fam == "ssm":
        def fwd(params, batch):
            return ssm.forward_ssm(cfg, params, batch["tokens"]), 0.0

        return LM(cfg, lambda rng: ssm.init_ssm_lm(rng, cfg), fwd,
                  lambda b, s: ssm.init_cache_ssm(cfg, b, s),
                  lambda p, c, t: ssm.decode_step_ssm(cfg, p, c, t),
                  _token_specs)

    if fam == "hybrid":
        def fwd(params, batch):
            return hybrid.forward_hybrid(cfg, params, batch["tokens"]), 0.0

        return LM(cfg, lambda rng: hybrid.init_hybrid_lm(rng, cfg), fwd,
                  lambda b, s: hybrid.init_cache_hybrid(cfg, b, s),
                  lambda p, c, t: hybrid.decode_step_hybrid(cfg, p, c, t),
                  _token_specs)

    if fam == "encdec":
        def fwd(params, batch):
            return encdec.forward_encdec(cfg, params, batch), 0.0

        def specs(batch, seq_len):
            enc_len = max(seq_len // cfg.enc_seq_divisor, 1)
            return dict(
                _token_specs(batch, seq_len),
                enc_embeds=jax.ShapeDtypeStruct((batch, enc_len, cfg.d_model),
                                                cfg.adtype),
            )

        return LM(cfg, lambda rng: encdec.init_encdec(rng, cfg), fwd,
                  lambda b, s: encdec.init_cache_encdec(cfg, b, s),
                  lambda p, c, t: encdec.decode_step_encdec(cfg, p, c, t),
                  specs,
                  prep_decode_cache=lambda p, c, e: encdec.prefill_cross_cache(cfg, p, c, e))

    if fam == "vlm":
        def fwd(params, batch):
            return vlm.forward_vlm(cfg, params, batch), 0.0

        def specs(batch, seq_len):
            s_text = max(seq_len - cfg.img_tokens, 1)
            return {
                "tokens": jax.ShapeDtypeStruct((batch, s_text), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, s_text), jnp.int32),
                "img_embeds": jax.ShapeDtypeStruct((batch, cfg.img_tokens, cfg.d_model),
                                                   cfg.adtype),
            }

        return LM(cfg, lambda rng: vlm.init_vlm(rng, cfg), fwd,
                  lambda b, s: vlm.init_cache_vlm(cfg, b, s),
                  lambda p, c, t: vlm.decode_step_vlm(cfg, p, c, t),
                  specs)

    raise ValueError(f"unknown family {fam!r}")
