"""Architecture configuration for the assigned large models.

One dataclass covers all six families (dense / moe / ssm / hybrid / encdec /
vlm); family-specific fields are simply unused elsewhere.  The exact per-arch
values live in :mod:`repro.configs` (one file per architecture, citing its
source model card / paper).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    citation: str = ""

    # transformer trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: Optional[int] = None  # None -> MHA (= n_heads)
    head_dim: Optional[int] = None  # None -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    qk_norm: bool = False  # qwen3-style per-head RMSNorm on q and k
    qkv_bias: bool = False  # qwen1.5/2.5-style bias on qkv projections
    rope_theta: float = 1e6
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (GLU) | gelu (plain MLP)
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None  # SWA window (mixtral: 4096)

    # attention execution strategy
    attn_chunk_q: int = 512  # flash-style chunking for long sequences
    attn_chunk_kv: int = 1024
    full_attn_max_seq: int = 4096  # use plain attention at/below this length

    # MoE
    n_experts: int = 0
    top_k: int = 2
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight

    # SSM (mamba2 / SSD)
    ssm_state: int = 0  # N
    ssm_head_dim: int = 64  # P
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # hybrid (zamba2)
    shared_attn_every: int = 0  # 0 = no shared block

    # encdec (whisper)
    n_enc_layers: int = 0
    enc_seq_divisor: int = 2  # stub conv frontend downsampling factor

    # vlm (llava)
    img_tokens: int = 0  # anyres: base 576 + tiles

    # dtypes
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    remat: bool = True
    # Fully unroll lax.scan loops.  Used by the dry-run calibration compiles:
    # XLA's HloCostAnalysis counts while-loop bodies once (not x trip-count),
    # so per-layer costs are measured on small unrolled configs and
    # extrapolated (launch/dryrun.py).
    scan_unroll: bool = False

    # ---- §Perf levers (default off = paper-faithful baseline) ----
    # ZeRO-3 use-site weight gather: constrain per-layer weight slices to
    # model-axis-only sharding so GSPMD all-gathers weights over data rather
    # than psum-ing activations (EXPERIMENTS.md §Perf iteration 1).
    zero3_gather: bool = False
    # Residual-stream/scan-carry sharding: "batch" (baseline) or "batch_seq"
    # (seq dim sharded over model between layers — memory-capacity lever).
    residual_shard: str = "batch"
    # Cast softmax probabilities to bf16 before the attention combine
    # (halves the largest prefill/train buffer's traffic).
    attn_probs_bf16: bool = False
    # MoE dispatch strategy: "global" capacity pool (baseline) or
    # "batch_local" (per-row dispatch; expert buffers stay batch-sharded —
    # kills the global-buffer all-reduce, see §Perf).
    moe_dispatch: str = "global"
    # Expert parallelism: shard the expert dim of expert weights (and the
    # dispatch buffers) over the model axis when E % axis == 0.  The dispatch
    # becomes an all-to-all of activations instead of gathering the (huge)
    # expert weights — the right trade for many-expert models (arctic).
    expert_parallel: bool = False

    # serving
    decode_window: Optional[int] = None  # ring-buffer cache size for long ctx

    def __post_init__(self):
        if self.n_kv_heads is None:
            object.__setattr__(self, "n_kv_heads", self.n_heads)
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---------------- derived ----------------
    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activation_dtype)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test variant of the same family: tiny but structurally
        identical (2 layers, d_model <= 512, <= 4 experts)."""
        small = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads or self.n_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            shared_attn_every=2 if self.shared_attn_every else 0,
            img_tokens=min(self.img_tokens, 16) if self.img_tokens else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            full_attn_max_seq=64,
            attn_chunk_q=16,
            attn_chunk_kv=32,
            param_dtype="float32",
            activation_dtype="float32",
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6ND)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        glu = 3 if self.act == "silu" else 2
        mlp = glu * d * f
        norms = 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            return self.n_layers * (attn + mlp + norms) + emb + d
        if self.family == "moe":
            moe = self.n_experts * glu * d * f + d * self.n_experts
            dense_res = glu * d * f if self.dense_residual else 0
            return self.n_layers * (attn + moe + dense_res + norms) + emb + d
        if self.family == "ssm":
            di, n, h = self.ssm_inner, self.ssm_state, self.ssm_heads
            g = self.ssm_groups
            in_proj = d * (2 * di + 2 * g * n + h)
            out_proj = di * d
            conv = self.ssm_conv * (di + 2 * g * n)
            per = in_proj + out_proj + conv + 2 * h + di + d
            return self.n_layers * per + emb + d
        if self.family == "hybrid":
            ssm_cfg = dataclasses.replace(self, family="ssm")
            base = ssm_cfg.param_count() - emb - d
            shared = attn + mlp + norms
            return base + shared + emb + d
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp + norms)
            dec = self.n_layers * (2 * attn + mlp + 3 * d)
            return enc + dec + emb + d
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        glu = 3 if self.act == "silu" else 2
        inactive = self.n_layers * (self.n_experts - self.top_k) * glu * d * f
        return self.param_count() - inactive
