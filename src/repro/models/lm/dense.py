"""Dense decoder-only LM (llama/qwen family), scan-over-layers.

Covers: deepseek-7b (llama arch), qwen1.5-0.5b / qwen2.5-14b (QKV bias),
qwen3-32b (qk-norm, GQA, head_dim 128) — and serves as the text trunk for
llava (vlm.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constraints import (
    constrain_batch,
    constrain_logits,
    constrain_residual,
    gather_weights,
)
from repro.models.lm.config import ArchConfig
from repro.models.lm.layers import (
    CacheSpec,
    apply_norm,
    attention,
    decode_attention,
    embed,
    init_attention,
    init_embedding,
    init_kv_cache,
    init_linear,
    init_mlp,
    init_norm,
    mlp,
    unembed,
)


def init_layer(rng, cfg: ArchConfig):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": init_norm(cfg),
        "attn": init_attention(k1, cfg),
        "ln2": init_norm(cfg),
        "mlp": init_mlp(k2, cfg),
    }


def init_dense(rng, cfg: ArchConfig):
    k_emb, k_layers, k_unemb = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": init_embedding(k_emb, cfg),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_linear(k_unemb, cfg.d_model, cfg.vocab, cfg)
    return params


def layer_apply(cfg: ArchConfig, lp, x, positions):
    x = x + attention(cfg, lp["attn"], apply_norm(cfg, x, lp["ln1"]), positions)
    x = x + mlp(cfg, lp["mlp"], apply_norm(cfg, x, lp["ln2"]))
    return x


def trunk(cfg: ArchConfig, params, x, positions):
    """Run the scanned layer stack on embedded input x [B,S,D]."""

    def body(h, lp):
        h = constrain_residual(h, cfg.residual_shard)
        if cfg.zero3_gather:
            lp = gather_weights(lp)
        return layer_apply(cfg, lp, h, positions), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"], unroll=cfg.scan_unroll)
    return apply_norm(cfg, x, params["final_norm"])


def forward_dense(cfg: ArchConfig, params, tokens, positions=None):
    """tokens [B,S] -> logits [B,S,V]."""
    x = constrain_batch(embed(cfg, params["embed"], tokens))
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x = trunk(cfg, params, x, positions)
    return constrain_logits(unembed(cfg, params.get("unembed"), params["embed"], x))


def init_cache_dense(cfg: ArchConfig, batch: int, seq_len: int):
    window = seq_len if cfg.decode_window is None else min(cfg.decode_window, seq_len)
    if cfg.sliding_window is not None:
        window = min(window, cfg.sliding_window)
    spec = CacheSpec(batch=batch, window=window, n_kv_heads=cfg.n_kv_heads,
                     head_dim=cfg.head_dim, dtype=cfg.activation_dtype)
    return init_kv_cache(spec, cfg.n_layers)


def decode_step_dense(cfg: ArchConfig, params, cache, tokens):
    """tokens [B,1] -> (logits [B,1,V], cache)."""
    x = embed(cfg, params["embed"], tokens)
    length = cache["length"]

    def body(h, inp):
        lp, lc = inp
        a, lc_new = decode_attention(
            cfg, lp["attn"], apply_norm(cfg, h, lp["ln1"]), lc, length)
        h = h + a
        h = h + mlp(cfg, lp["mlp"], apply_norm(cfg, h, lp["ln2"]))
        return h, lc_new

    layer_caches = {"k": cache["k"], "v": cache["v"], "slot_pos": cache["slot_pos"]}
    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches),
                                 unroll=cfg.scan_unroll)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(cfg, params.get("unembed"), params["embed"], x)
    new_cache = dict(new_caches, length=length + 1)
    return logits, new_cache
