"""Whisper-style encoder-decoder (arXiv:2212.04356) — transformer backbone.

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: the model consumes precomputed frame embeddings [B, S_enc, D] (S_enc
= seq_len / enc_seq_divisor, standing in for the conv stride-2 downsampling).
We use RoPE instead of Whisper's learned absolute positions so decode can run
at arbitrary context lengths (500k test) — a documented TPU-era adaptation
that leaves the enc-dec attention structure intact.

Whisper uses LayerNorm + GELU MLPs + MHA (20 heads, kv=20); the decoder adds
cross-attention to the encoder output.  Decode caches: ring-buffer self-attn
KV per decoder layer + precomputed cross-attn K/V per decoder layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constraints import (
    constrain_logits,
    constrain_residual,
    gather_weights,
)
from repro.models.lm.config import ArchConfig
from repro.models.lm.layers import (
    CacheSpec,
    apply_norm,
    attention,
    cross_kv,
    decode_attention,
    embed,
    init_attention,
    init_embedding,
    init_kv_cache,
    init_linear,
    init_mlp,
    init_norm,
    mlp,
    unembed,
)


def init_enc_layer(rng, cfg: ArchConfig):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": init_norm(cfg),
        "attn": init_attention(k1, cfg),
        "ln2": init_norm(cfg),
        "mlp": init_mlp(k2, cfg),
    }


def init_dec_layer(rng, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": init_norm(cfg),
        "self_attn": init_attention(k1, cfg),
        "ln_x": init_norm(cfg),
        "cross_attn": init_attention(k2, cfg),
        "ln2": init_norm(cfg),
        "mlp": init_mlp(k3, cfg),
    }


def init_encdec(rng, cfg: ArchConfig):
    k_emb, k_enc, k_dec, k_unemb = jax.random.split(rng, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": init_embedding(k_emb, cfg),  # decoder token embeddings
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "enc_final_norm": init_norm(cfg),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "final_norm": init_norm(cfg),
        "unembed": init_linear(k_unemb, cfg.d_model, cfg.vocab, cfg),
    }


def encode(cfg: ArchConfig, params, enc_embeds):
    """Stub-frontend encoder: enc_embeds [B, S_enc, D] -> [B, S_enc, D]."""
    s_enc = enc_embeds.shape[1]
    positions = jnp.arange(s_enc, dtype=jnp.int32)
    x = enc_embeds.astype(cfg.adtype)

    def body(h, lp):
        h = constrain_residual(h, cfg.residual_shard)
        if cfg.zero3_gather:
            lp = gather_weights(lp)
        h = h + attention(cfg, lp["attn"], apply_norm(cfg, h, lp["ln1"]),
                          positions, causal=False)
        h = h + mlp(cfg, lp["mlp"], apply_norm(cfg, h, lp["ln2"]))
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"], unroll=cfg.scan_unroll)
    return apply_norm(cfg, x, params["enc_final_norm"])


def decode_train(cfg: ArchConfig, params, tokens, enc_out):
    """Teacher-forced decoder pass: tokens [B,S_dec] -> logits."""
    s = tokens.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
    x = embed(cfg, params["embed"], tokens)

    def body(h, lp):
        h = constrain_residual(h, cfg.residual_shard)
        if cfg.zero3_gather:
            lp = gather_weights(lp)
        h = h + attention(cfg, lp["self_attn"], apply_norm(cfg, h, lp["ln1"]),
                          positions, causal=True)
        k, v = cross_kv(cfg, lp["cross_attn"], enc_out)
        h = h + attention(cfg, lp["cross_attn"], apply_norm(cfg, h, lp["ln_x"]),
                          positions, causal=False, kv_override=(k, v, enc_pos))
        h = h + mlp(cfg, lp["mlp"], apply_norm(cfg, h, lp["ln2"]))
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"], unroll=cfg.scan_unroll)
    x = apply_norm(cfg, x, params["final_norm"])
    return constrain_logits(unembed(cfg, params.get("unembed"), params["embed"], x))


def forward_encdec(cfg: ArchConfig, params, batch):
    enc_out = encode(cfg, params, batch["enc_embeds"])
    return decode_train(cfg, params, batch["tokens"], enc_out)


def init_cache_encdec(cfg: ArchConfig, batch: int, seq_len: int,
                      enc_len: int = None):
    window = seq_len if cfg.decode_window is None else min(cfg.decode_window, seq_len)
    spec = CacheSpec(batch=batch, window=window, n_kv_heads=cfg.n_kv_heads,
                     head_dim=cfg.head_dim, dtype=cfg.activation_dtype)
    self_cache = init_kv_cache(spec, cfg.n_layers)
    enc_len = enc_len or max(seq_len // cfg.enc_seq_divisor, 1)
    z = lambda: jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads,
                           cfg.head_dim), jnp.dtype(cfg.activation_dtype))
    return {
        "k": self_cache["k"], "v": self_cache["v"],
        "slot_pos": self_cache["slot_pos"],
        "cross_k": z(), "cross_v": z(),
        "length": jnp.zeros((), jnp.int32),
    }


def prefill_cross_cache(cfg: ArchConfig, params, cache, enc_embeds):
    """Run the encoder once and fill the per-layer cross K/V caches."""
    enc_out = encode(cfg, params, enc_embeds)

    def per_layer(lp):
        k, v = cross_kv(cfg, lp["cross_attn"], enc_out)
        return k, v

    ks, vs = jax.vmap(per_layer)(params["dec_layers"])
    return dict(cache, cross_k=ks.astype(cache["cross_k"].dtype),
                cross_v=vs.astype(cache["cross_v"].dtype))


def decode_step_encdec(cfg: ArchConfig, params, cache, tokens):
    """One decoder token against self ring-cache + cross caches."""
    x = embed(cfg, params["embed"], tokens)
    length = cache["length"]
    enc_len = cache["cross_k"].shape[2]
    enc_pos = jnp.arange(enc_len, dtype=jnp.int32)

    def body(h, inp):
        lp, lc_k, lc_v, lc_sp, ck, cv = inp
        lc = {"k": lc_k, "v": lc_v, "slot_pos": lc_sp}
        a, lc_new = decode_attention(cfg, lp["self_attn"],
                                     apply_norm(cfg, h, lp["ln1"]), lc, length)
        h = h + a
        h = h + attention(cfg, lp["cross_attn"], apply_norm(cfg, h, lp["ln_x"]),
                          length[None].astype(jnp.int32), causal=False,
                          kv_override=(ck, cv, enc_pos))
        h = h + mlp(cfg, lp["mlp"], apply_norm(cfg, h, lp["ln2"]))
        return h, (lc_new["k"], lc_new["v"], lc_new["slot_pos"])

    x, (nk, nv, nsp) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["slot_pos"],
         cache["cross_k"], cache["cross_v"]), unroll=cfg.scan_unroll)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(cfg, params.get("unembed"), params["embed"], x)
    new_cache = dict(cache, k=nk, v=nv, slot_pos=nsp, length=length + 1)
    return logits, new_cache
