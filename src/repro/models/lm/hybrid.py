"""Zamba2-style hybrid: a Mamba2 backbone with a single SHARED attention
block invoked periodically (arXiv:2411.15242).

Layout: n_layers mamba2 layers, grouped into n_layers/shared_attn_every
groups; after each group the one shared transformer block (attention + MLP,
one set of weights reused at every invocation) runs on the concatenation of
the current hidden state and the original embedding (projected 2D -> D), as
in the Zamba family.  We omit the per-invocation LoRA deltas on the shared
block (noted in DESIGN.md).

Decode carries the mamba recurrent states of every layer plus ONE ring-buffer
KV cache for the shared block (its invocations all share the cache — each
invocation sees the shared block's own past, which is the Zamba2 semantics of
a shared module with shared KV... we keep one cache per *invocation group* to
preserve causal consistency: [G, B, W, K, hd]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constraints import (
    constrain_batch,
    constrain_logits,
    constrain_residual,
    gather_weights,
)
from repro.models.lm.config import ArchConfig
from repro.models.lm.layers import (
    CacheSpec,
    apply_norm,
    attention,
    decode_attention,
    embed,
    init_attention,
    init_embedding,
    init_kv_cache,
    init_linear,
    init_mlp,
    init_norm,
    mlp,
    unembed,
)
from repro.models.lm.ssm import (
    init_cache_ssm,
    init_ssm_layer,
    ssm_block,
    ssm_decode_block,
)


def _n_groups(cfg: ArchConfig) -> int:
    assert cfg.shared_attn_every > 0 and cfg.n_layers % cfg.shared_attn_every == 0
    return cfg.n_layers // cfg.shared_attn_every


def init_hybrid_lm(rng, cfg: ArchConfig):
    k_emb, k_layers, k_shared, k_proj, k_unemb = jax.random.split(rng, 5)
    g = _n_groups(cfg)
    e = cfg.shared_attn_every
    layer_keys = jax.random.split(k_layers, cfg.n_layers).reshape(g, e, -1)
    k1, k2 = jax.random.split(k_shared)
    return {
        "embed": init_embedding(k_emb, cfg),
        # [G, E, ...] stacked mamba layers: outer python loop over groups,
        # inner scan over the e layers of each group.
        "mamba": jax.vmap(jax.vmap(lambda k: init_ssm_layer(k, cfg)))(layer_keys),
        "shared": {
            "in_proj": init_linear(k_proj, 2 * cfg.d_model, cfg.d_model, cfg),
            "ln1": init_norm(cfg),
            "attn": init_attention(k1, cfg),
            "ln2": init_norm(cfg),
            "mlp": init_mlp(k2, cfg),
        },
        "final_norm": init_norm(cfg),
        "unembed": init_linear(k_unemb, cfg.d_model, cfg.vocab, cfg),
    }


def _shared_block(cfg: ArchConfig, sp, x, x0, positions):
    h = jnp.concatenate([x, x0], axis=-1)
    h = jnp.einsum("...f,fd->...d", h, sp["in_proj"]["w"].astype(h.dtype))
    h = h + attention(cfg, sp["attn"], apply_norm(cfg, h, sp["ln1"]), positions)
    h = h + mlp(cfg, sp["mlp"], apply_norm(cfg, h, sp["ln2"]))
    return x + h


def forward_hybrid(cfg: ArchConfig, params, tokens, positions=None):
    x = constrain_batch(embed(cfg, params["embed"], tokens))
    x0 = x
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    g = _n_groups(cfg)

    def group_scan(h, group_layers):
        def body(hh, lp):
            hh = constrain_residual(hh, cfg.residual_shard)
            if cfg.zero3_gather:
                lp = gather_weights(lp)
            return ssm_block(cfg, lp, hh), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(body_fn, h, group_layers, unroll=cfg.scan_unroll)
        return h

    for gi in range(g):
        group_layers = jax.tree.map(lambda p, _gi=gi: p[_gi], params["mamba"])
        x = group_scan(x, group_layers)
        x = constrain_batch(_shared_block(cfg, params["shared"], x, x0, positions))
    x = apply_norm(cfg, x, params["final_norm"])
    return constrain_logits(unembed(cfg, params.get("unembed"), params["embed"], x))


def init_cache_hybrid(cfg: ArchConfig, batch: int, seq_len: int):
    ssm_cache = init_cache_ssm(cfg, batch, seq_len)
    g = _n_groups(cfg)
    window = seq_len if cfg.decode_window is None else min(cfg.decode_window, seq_len)
    spec = CacheSpec(batch=batch, window=window, n_kv_heads=cfg.n_kv_heads,
                     head_dim=cfg.head_dim, dtype=cfg.activation_dtype)
    attn_cache = init_kv_cache(spec, g)  # one cache per invocation group
    return {
        "conv": ssm_cache["conv"], "state": ssm_cache["state"],
        "attn_k": attn_cache["k"], "attn_v": attn_cache["v"],
        "attn_slot_pos": attn_cache["slot_pos"],
        "length": jnp.zeros((), jnp.int32),
    }


def decode_step_hybrid(cfg: ArchConfig, params, cache, tokens):
    x = embed(cfg, params["embed"], tokens)[:, 0]  # [B,D]
    x0 = x
    g = _n_groups(cfg)
    e = cfg.shared_attn_every
    length = cache["length"]

    conv_all = cache["conv"].reshape(g, e, *cache["conv"].shape[1:])
    state_all = cache["state"].reshape(g, e, *cache["state"].shape[1:])
    new_conv, new_state = [], []
    new_k, new_v, new_sp = [], [], []

    for gi in range(g):
        group_layers = jax.tree.map(lambda p, _gi=gi: p[_gi], params["mamba"])

        def body(h, inp):
            lp, conv_c, st = inp
            h, conv_n, st_n = ssm_decode_block(cfg, lp, h, conv_c, st)
            return h, (conv_n, st_n)

        x, (conv_n, state_n) = jax.lax.scan(
            body, x, (group_layers, conv_all[gi], state_all[gi]),
            unroll=cfg.scan_unroll)
        new_conv.append(conv_n)
        new_state.append(state_n)
        # shared attention block on the single token
        sp = params["shared"]
        hcat = jnp.concatenate([x, x0], axis=-1)[:, None, :]
        h = jnp.einsum("...f,fd->...d", hcat, sp["in_proj"]["w"].astype(hcat.dtype))
        lc = {"k": cache["attn_k"][gi], "v": cache["attn_v"][gi],
              "slot_pos": cache["attn_slot_pos"][gi]}
        a, lc_new = decode_attention(cfg, sp["attn"],
                                     apply_norm(cfg, h, sp["ln1"]), lc, length)
        h = h + a
        h = h + mlp(cfg, sp["mlp"], apply_norm(cfg, h, sp["ln2"]))
        x = x + h[:, 0]
        new_k.append(lc_new["k"])
        new_v.append(lc_new["v"])
        new_sp.append(lc_new["slot_pos"])

    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(cfg, params.get("unembed"), params["embed"], x[:, None, :])
    new_cache = {
        "conv": jnp.stack(new_conv).reshape(cfg.n_layers, *cache["conv"].shape[1:]),
        "state": jnp.stack(new_state).reshape(cfg.n_layers, *cache["state"].shape[1:]),
        "attn_k": jnp.stack(new_k), "attn_v": jnp.stack(new_v),
        "attn_slot_pos": jnp.stack(new_sp),
        "length": length + 1,
    }
    return logits, new_cache
