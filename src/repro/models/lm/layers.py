"""Shared transformer building blocks (pure JAX, scan-over-layers friendly).

Everything here is a pure function over plain dict params.  Per-layer params
are created by `init_*` for ONE layer; the model builders stack L layers by
vmapping the init over per-layer keys, which yields [L, ...] leaves that
`jax.lax.scan` consumes — keeping the lowered HLO size independent of depth.

Attention supports MHA/GQA, RoPE, qk-norm (qwen3), QKV bias (qwen1.5/2.5),
causal / non-causal / sliding-window masks, and two execution paths:

  * plain  — materialized [Sq, Sk] scores; used when S <= full_attn_max_seq.
  * chunked — flash-style online-softmax scan over (q-chunk, kv-chunk) pairs;
    memory O(qc * kvc) instead of O(S^2).  Used for long prefill.

Decode uses a ring-buffer KV cache (absolute positions tracked per slot) so a
sliding-window config keeps only `window` slots even at 500k context.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.lm.config import ArchConfig

# ---------------------------------------------------------------- norms


def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg: ArchConfig, x, p):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.pdtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.pdtype)
    return p


# ---------------------------------------------------------------- rotary


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- linear


def _dense_init(rng, shape, fan_in: int, dtype):
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def init_linear(rng, d_in: int, d_out: int, cfg: ArchConfig, bias: bool = False):
    k1, _ = jax.random.split(rng)
    p = {"w": _dense_init(k1, (d_in, d_out), d_in, cfg.pdtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), cfg.pdtype)
    return p


def linear(x, p):
    y = jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------- attention


def init_attention(rng, cfg: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d = cfg.d_model
    p = {
        "wq": init_linear(k1, d, cfg.q_dim, cfg, bias=cfg.qkv_bias),
        "wk": init_linear(k2, d, cfg.kv_dim, cfg, bias=cfg.qkv_bias),
        "wv": init_linear(k3, d, cfg.kv_dim, cfg, bias=cfg.qkv_bias),
        "wo": init_linear(k4, cfg.q_dim, d, cfg, bias=False),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((cfg.head_dim,), cfg.pdtype)}
        p["k_norm"] = {"scale": jnp.ones((cfg.head_dim,), cfg.pdtype)}
    return p


def _project_qkv(cfg: ArchConfig, p, x, positions, rope: bool):
    b, s, _ = x.shape
    q = linear(x, p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = linear(x, p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = linear(x, p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"])
        k = rms_norm(k, p["k_norm"]["scale"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int], dtype=jnp.float32):
    """Additive mask bias [..., Sq, Sk] from absolute positions."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        ok = ok & (kp <= qp)
    if window is not None:
        ok = ok & (kp > qp - window)
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


def _gqa_scores(q, k, scale):
    """q: [B,Sq,H,hd], k: [B,Sk,K,hd] -> scores [B,K,G,Sq,Sk]."""
    b, sq, h, hd = q.shape
    kk = k.shape[2]
    g = h // kk
    qg = q.reshape(b, sq, kk, g, hd)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def _gqa_combine(probs, v):
    """probs: [B,K,G,Sq,Sk], v: [B,Sk,K,hd] -> [B,Sq,H,hd]."""
    b, kk, g, sq, sk = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(probs.dtype))
    return out.reshape(b, sq, kk * g, v.shape[-1])


def _plain_attention(cfg, q, k, v, q_pos, k_pos, causal, window):
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = _gqa_scores(q, k, scale)  # [B,K,G,Sq,Sk] fp32
    bias = _mask_bias(q_pos, k_pos, causal, window)  # [Sq,Sk], broadcasts
    probs = jax.nn.softmax(scores + bias, axis=-1)
    if cfg.attn_probs_bf16:
        probs = probs.astype(jnp.bfloat16)  # §Perf: halve the S^2 traffic
    return _gqa_combine(probs, v).astype(q.dtype)


def _chunked_attention(cfg, q, k, v, q_pos, k_pos, causal, window):
    """Flash-style two-level scan with online softmax.

    Baseline computes every (q-chunk, kv-chunk) pair and relies on masking for
    causality (fully-masked pairs are wasted FLOPs — see EXPERIMENTS.md §Perf
    for the causal-skip iteration)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kk = cfg.n_kv_heads
    g = h // kk
    qc = min(cfg.attn_chunk_q, sq)
    kc = min(cfg.attn_chunk_kv, sk)
    assert sq % qc == 0 and sk % kc == 0, (sq, qc, sk, kc)
    nq, nk = sq // qc, sk // kc
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(b, nq, qc, h, hd).transpose(1, 0, 2, 3, 4)  # [nq,B,qc,H,hd]
    qpr = q_pos.reshape(nq, qc)
    kr = k.reshape(b, nk, kc, kk, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, kk, hd).transpose(1, 0, 2, 3, 4)
    kpr = k_pos.reshape(nk, kc)

    def q_body(_, q_in):
        qi, qp = q_in  # [B,qc,H,hd], [qc]
        qg = qi.reshape(b, qc, kk, g, hd)

        def kv_body(carry, kv_in):
            m, l, acc = carry
            ki, vi, kp = kv_in
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            s = s + _mask_bias(qp, kp, causal, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kk, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kk, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kk, g, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (kr, vr, kpr),
                                      unroll=cfg.scan_unroll)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, hd)  # [B,qc,H,hd]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (qr, qpr),
                           unroll=cfg.scan_unroll)  # [nq,B,qc,H,hd]
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attention(cfg: ArchConfig, p, x, positions=None, *, causal: bool = True,
              rope: bool = True, kv_override=None):
    """Self- (or cross-, via kv_override) attention over a full sequence.

    kv_override: optional (k, v, k_pos) for cross-attention (whisper decoder).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions, rope)
    q_pos = positions
    if kv_override is not None:
        k, v, k_pos = kv_override
    else:
        k_pos = positions
    sk = k.shape[1]
    if max(s, sk) <= cfg.full_attn_max_seq:
        out = _plain_attention(cfg, q, k, v, q_pos, k_pos, causal, cfg.sliding_window)
    else:
        out = _chunked_attention(cfg, q, k, v, q_pos, k_pos, causal, cfg.sliding_window)
    return linear(out.reshape(b, s, cfg.q_dim), p["wo"])


def cross_kv(cfg: ArchConfig, p, enc_out):
    """Precompute cross-attention K/V from encoder output (whisper decode)."""
    b, s, _ = enc_out.shape
    k = linear(enc_out, p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = linear(enc_out, p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"]["scale"])
    return k, v


# ------------------------------------------------- decode (ring KV cache)


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    batch: int
    window: int  # number of cache slots (= seq_len, or SWA window)
    n_kv_heads: int
    head_dim: int
    dtype: str = "bfloat16"


def init_kv_cache(spec: CacheSpec, n_layers: int):
    z = lambda: jnp.zeros((n_layers, spec.batch, spec.window, spec.n_kv_heads,
                           spec.head_dim), jnp.dtype(spec.dtype))
    return {
        "k": z(),
        "v": z(),
        "slot_pos": jnp.full((n_layers, spec.window), -1, jnp.int32),
        "length": jnp.zeros((), jnp.int32),  # absolute position of next token
    }


def decode_attention(cfg: ArchConfig, p, x, layer_cache, length):
    """One-token attention against a ring-buffer cache.

    x: [B, 1, D]; layer_cache: dict(k,v [B,W,K,hd], slot_pos [W]).
    Returns (out [B,1,D], updated layer_cache).
    """
    b = x.shape[0]
    pos = length  # scalar int32, absolute position of the new token
    q, k_new, v_new = _project_qkv(cfg, p, x, pos[None].astype(jnp.int32), True)
    w = layer_cache["k"].shape[1]
    slot = (pos % w).astype(jnp.int32)
    k_cache = layer_cache["k"].at[:, slot].set(k_new[:, 0])
    v_cache = layer_cache["v"].at[:, slot].set(v_new[:, 0])
    slot_pos = layer_cache["slot_pos"].at[slot].set(pos.astype(jnp.int32))

    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = _gqa_scores(q, k_cache, scale)  # [B,K,G,1,W]
    valid = slot_pos >= 0
    ok = valid & (slot_pos <= pos)
    if cfg.sliding_window is not None:
        ok = ok & (slot_pos > pos - cfg.sliding_window)
    bias = jnp.where(ok, 0.0, -1e30).astype(scores.dtype)  # [W]
    probs = jax.nn.softmax(scores + bias, axis=-1)
    out = _gqa_combine(probs, v_cache).astype(x.dtype)  # [B,1,H,hd]
    out = linear(out.reshape(b, 1, cfg.q_dim), p["wo"])
    return out, {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}


# ---------------------------------------------------------------- MLP


def init_mlp(rng, cfg: ArchConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "silu":
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "wg": init_linear(k1, d, f, cfg),
            "wu": init_linear(k2, d, f, cfg),
            "wd": init_linear(k3, f, d, cfg),
        }
    k1, k2 = jax.random.split(rng)
    return {"w1": init_linear(k1, d, f, cfg, bias=True),
            "w2": init_linear(k2, f, d, cfg, bias=True)}


def mlp(cfg: ArchConfig, p, x):
    if cfg.act == "silu":
        return linear(jax.nn.silu(linear(x, p["wg"])) * linear(x, p["wu"]), p["wd"])
    return linear(jax.nn.gelu(linear(x, p["w1"])), p["w2"])


# ---------------------------------------------------------------- embeddings


def init_embedding(rng, cfg: ArchConfig):
    emb = (jax.random.normal(rng, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
           ).astype(cfg.pdtype)
    return {"table": emb}


def embed(cfg: ArchConfig, p, tokens):
    return jnp.take(p["table"].astype(cfg.adtype), tokens, axis=0)


def unembed(cfg: ArchConfig, p_unemb, p_emb, x):
    if cfg.tie_embeddings:
        w = p_emb["table"].astype(x.dtype).T
    else:
        w = p_unemb["w"].astype(x.dtype)
    return jnp.einsum("...d,dv->...v", x, w)
