"""Mixture-of-Experts LMs: mixtral-8x7b (8e top-2, SWA) and arctic-480b
(128e top-2 + dense residual FFN).

Dispatch is capacity-based sorted scatter (Switch-style, token-dropping):
  1. router softmax -> top-k experts + combine weights per token,
  2. assignments sorted by expert id; each expert processes a [C, D] buffer
     (C = capacity_factor * k * T / E, rounded up to a multiple of 8),
  3. expert GLU applied batched over experts via einsum [E, C, D] x [E, D, F],
  4. outputs scattered back and combined with router weights; dropped tokens
     (over capacity) fall through with zero contribution — the dense residual
     (arctic) or the residual stream still carries them.

On the production mesh the [E, C, D] buffers shard over the model axis
(expert-parallel) when E % axis == 0; the all-to-all this induces shows up in
the dry-run collective schedule (§Roofline).

A Switch-style load-balance auxiliary loss is returned alongside the layer
output and surfaces in the train metrics (weight cfg.router_aux_weight).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constraints import (
    constrain_batch,
    constrain_expert_sharded,
    constrain_logits,
    constrain_residual,
    gather_weights,
)
from repro.models.lm.config import ArchConfig
from repro.models.lm.dense import init_cache_dense
from repro.models.lm.layers import (
    _dense_init,
    apply_norm,
    attention,
    decode_attention,
    embed,
    init_attention,
    init_embedding,
    init_linear,
    init_mlp,
    init_norm,
    mlp,
    unembed,
)


def init_moe_ffn(rng, cfg: ArchConfig):
    k_r, k_g, k_u, k_d = jax.random.split(rng, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": _dense_init(k_r, (d, e), d, jnp.float32),  # router in fp32
        "wg": _dense_init(k_g, (e, d, f), d, cfg.pdtype),
        "wu": _dense_init(k_u, (e, d, f), d, cfg.pdtype),
        "wd": _dense_init(k_d, (e, f, d), f, cfg.pdtype),
    }
    return p


def _capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def moe_ffn(cfg: ArchConfig, p, x):
    """x [B,S,D] -> (out [B,S,D], aux_loss scalar).

    Dispatch strategy (cfg.moe_dispatch):
      "global"      — one capacity pool over all B*S tokens (baseline;
                      faithful single-host formulation, but on the sharded
                      mesh the [E, C_global, D] buffers cross the batch
                      sharding: measured 9.4 GB fp32 all-reduce per mixtral
                      layer — EXPERIMENTS.md §Perf).
      "batch_local" — §Perf variant: the sorted dispatch runs per batch row
                      (vmap over B), so every data shard routes only its own
                      tokens and the expert buffers stay batch-sharded; no
                      cross-shard token motion.  Capacity is enforced per
                      row (same expected load)."""
    b, s, d = x.shape
    if cfg.moe_dispatch == "batch_local":
        return _moe_batch_local(cfg, p, x)
    out, aux = _moe_tokens(cfg, p, x.reshape(b * s, d))
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_batch_local(cfg: ArchConfig, p, x):
    """Per-row sorted dispatch with an EXPLICIT batch dim kept data-sharded.

    Every tensor carries B as dim0 with a sharding constraint, so tokens
    never leave their data shard; the expert-weight contraction then cannot
    psum over data (the output is batch-sharded) and GSPMD is forced into the
    cheap per-layer weight all-gather instead (§Perf iteration log)."""
    b, s, d = x.shape
    k, e = cfg.top_k, cfg.n_experts
    xf = constrain_batch(x)

    logits = jnp.einsum("bsd,de->bse", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # [B,S,E]
    top_w, top_i = jax.lax.top_k(probs, k)  # [B,S,k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)  # [B,S,k,E]
    f_e = jnp.mean(onehot, axis=(0, 1, 2))  # fraction per expert
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)  # same normalization as the global pool

    flat_e = top_i.reshape(b, s * k)  # [B, kS]
    order = jnp.argsort(flat_e, axis=1)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    counts = jnp.sum(onehot, axis=(1, 2)).astype(jnp.int32)  # [B,E]
    starts = jnp.cumsum(counts, axis=1) - counts  # [B,E]
    pos_in_e = (jnp.arange(s * k, dtype=jnp.int32)[None, :]
                - jnp.take_along_axis(starts, sorted_e, axis=1))
    cap = _capacity(cfg, s)
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # OOB drop
    tok = order // k  # [B,kS] source token per assignment
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]

    gathered = jnp.take_along_axis(
        xf.astype(cfg.adtype), tok[..., None], axis=1)  # [B,kS,D]
    buf = jnp.zeros((b, e * cap, d), cfg.adtype).at[bidx, slot].set(
        gathered, mode="drop")
    buf = constrain_batch(buf)
    h = buf.reshape(b, e, cap, d)
    if cfg.expert_parallel:
        # all-to-all: move token slots to the model-shard owning their expert
        h = constrain_expert_sharded(h)
    gate = jax.nn.silu(jnp.einsum("becd,edf->becf", h, p["wg"].astype(h.dtype)))
    up = jnp.einsum("becd,edf->becf", h, p["wu"].astype(h.dtype))
    y = jnp.einsum("becf,efd->becd", gate * up, p["wd"].astype(h.dtype))
    if cfg.expert_parallel:
        y = constrain_expert_sharded(y)
    y = constrain_batch(y.reshape(b, e * cap, d))

    w_sorted = (jnp.take_along_axis(top_w.reshape(b, s * k), order, axis=1)
                * keep.astype(jnp.float32))
    contrib = jnp.take_along_axis(
        y, jnp.minimum(slot, e * cap - 1)[..., None], axis=1)
    contrib = contrib.astype(jnp.float32) * w_sorted[..., None]
    out = jnp.zeros((b, s, d), jnp.float32).at[bidx, tok].add(contrib)
    return constrain_batch(out).astype(x.dtype), aux


def _moe_tokens(cfg: ArchConfig, p, xf):
    """Sorted capacity dispatch + expert GLU over a flat token block [T, D]."""
    t, d = xf.shape
    k = cfg.top_k
    e = cfg.n_experts

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    top_w, top_i = jax.lax.top_k(probs, k)  # [T, k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # Switch load-balance aux: E * Σ_e f_e * P_e
    f_e = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (t * k)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)

    # ---- sorted capacity dispatch ----
    flat_e = top_i.reshape(-1)  # [kT] expert of each assignment
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    cap = _capacity(cfg, t)
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # OOB -> dropped
    tok = order // k  # token index of each sorted assignment

    buf = jnp.zeros((e * cap, d), cfg.adtype).at[slot].set(
        xf[tok].astype(cfg.adtype), mode="drop")
    h = buf.reshape(e, cap, d)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["wg"].astype(h.dtype)))
    up = jnp.einsum("ecd,edf->ecf", h, p["wu"].astype(h.dtype))
    y = jnp.einsum("ecf,efd->ecd", gate * up, p["wd"].astype(h.dtype))
    y = y.reshape(e * cap, d)

    w_sorted = top_w.reshape(-1)[order] * keep.astype(jnp.float32)
    contrib = jnp.take(y, jnp.minimum(slot, e * cap - 1), axis=0)
    contrib = contrib.astype(jnp.float32) * w_sorted[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[tok].add(contrib)
    return out, aux


def init_layer_moe(rng, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {
        "ln1": init_norm(cfg),
        "attn": init_attention(k1, cfg),
        "ln2": init_norm(cfg),
        "moe": init_moe_ffn(k2, cfg),
    }
    if cfg.dense_residual:
        p["dense_mlp"] = init_mlp(k3, cfg)
    return p


def init_moe_lm(rng, cfg: ArchConfig):
    k_emb, k_layers, k_unemb = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": init_embedding(k_emb, cfg),
        "layers": jax.vmap(lambda k: init_layer_moe(k, cfg))(layer_keys),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_linear(k_unemb, cfg.d_model, cfg.vocab, cfg)
    return params


def layer_apply_moe(cfg: ArchConfig, lp, x, positions):
    x = x + attention(cfg, lp["attn"], apply_norm(cfg, x, lp["ln1"]), positions)
    h = apply_norm(cfg, x, lp["ln2"])
    y, aux = moe_ffn(cfg, lp["moe"], h)
    if cfg.dense_residual:
        y = y + mlp(cfg, lp["dense_mlp"], h)
    return x + y, aux


def forward_moe(cfg: ArchConfig, params, tokens, positions=None):
    """tokens [B,S] -> (logits [B,S,V], aux_loss)."""
    x = constrain_batch(embed(cfg, params["embed"], tokens))
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(h, lp):
        h = constrain_residual(h, cfg.residual_shard)
        if cfg.zero3_gather:
            lp = gather_weights(lp)
        h, aux = layer_apply_moe(cfg, lp, h, positions)
        return h, aux

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, auxs = jax.lax.scan(body_fn, x, params["layers"], unroll=cfg.scan_unroll)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = constrain_logits(unembed(cfg, params.get("unembed"), params["embed"], x))
    return logits, jnp.mean(auxs)


init_cache_moe = init_cache_dense


def decode_step_moe(cfg: ArchConfig, params, cache, tokens):
    x = embed(cfg, params["embed"], tokens)
    length = cache["length"]

    def body(h, inp):
        lp, lc = inp
        a, lc_new = decode_attention(
            cfg, lp["attn"], apply_norm(cfg, h, lp["ln1"]), lc, length)
        h = h + a
        hn = apply_norm(cfg, h, lp["ln2"])
        y, _ = moe_ffn(cfg, lp["moe"], hn)
        if cfg.dense_residual:
            y = y + mlp(cfg, lp["dense_mlp"], hn)
        return h + y, lc_new

    layer_caches = {"k": cache["k"], "v": cache["v"], "slot_pos": cache["slot_pos"]}
    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches),
                                 unroll=cfg.scan_unroll)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(cfg, params.get("unembed"), params["embed"], x)
    return logits, dict(new_caches, length=length + 1)
