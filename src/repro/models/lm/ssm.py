"""Mamba2 (SSD — state-space duality) LM, pure JAX, chunk-parallel.

Implements the SSD block decomposition of arXiv:2405.21060: the sequence is
split into chunks of Q tokens; within a chunk the output is the quadratic
"attention-like" term (C_i·B_j masked by the decay kernel), across chunks an
O(1)-per-chunk recurrent state is carried by `lax.scan`.  Total work is
O(S·Q) instead of O(S^2), and decode keeps a per-head [P, N] recurrent state
(natively sub-quadratic: `long_500k` runs without any attention window).

All decay factors are exp of non-positive numbers (a = -exp(A_log)·dt < 0),
so every exponential in the chunked path is <= 1 — numerically safe in bf16.

Layer structure (mamba2):
  in_proj -> (z | xBC | dt); causal depthwise conv on xBC; SSD core;
  gated RMSNorm (y * silu(z)); out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constraints import (
    constrain_batch,
    constrain_logits,
    constrain_residual,
    gather_weights,
)
from repro.models.lm.config import ArchConfig
from repro.models.lm.layers import (
    _dense_init,
    apply_norm,
    embed,
    init_embedding,
    init_linear,
    init_norm,
    unembed,
)


def _split_dims(cfg: ArchConfig):
    di = cfg.ssm_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    h = cfg.ssm_heads
    return di, gn, h


def init_ssm_layer(rng, cfg: ArchConfig):
    di, gn, h = _split_dims(cfg)
    d = cfg.d_model
    conv_ch = di + 2 * gn
    k1, k2, k3 = jax.random.split(rng, 3)
    # dt bias init so softplus(dt_bias) spans ~[1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(k3, (h,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "norm": init_norm(cfg),
        "in_proj": init_linear(k1, d, 2 * di + 2 * gn + h, cfg),
        "conv_w": (_dense_init(k2, (cfg.ssm_conv, conv_ch), cfg.ssm_conv, cfg.pdtype)),
        "conv_b": jnp.zeros((conv_ch,), cfg.pdtype),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(0) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gate_norm": {"scale": jnp.ones((di,), cfg.pdtype)},
        "out_proj": init_linear(jax.random.fold_in(rng, 7), di, d, cfg),
    }


def _causal_depthwise_conv(x, w, b):
    """x [B,S,C], w [K,C] depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),  # [K,1,C] HIO-ish
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return y + b.astype(y.dtype)


def _project(cfg: ArchConfig, lp, x):
    """Shared pre-SSD projection: returns (z, xBC_conv_in, dt_raw)."""
    di, gn, h = _split_dims(cfg)
    zxbcdt = jnp.einsum("...d,df->...f", x, lp["in_proj"]["w"].astype(x.dtype))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * gn]
    dt_raw = zxbcdt[..., 2 * di + 2 * gn :]
    return z, xbc, dt_raw


def _split_xbc(cfg: ArchConfig, xbc):
    di, gn, _ = _split_dims(cfg)
    n, g = cfg.ssm_state, cfg.ssm_groups
    x_ssm = xbc[..., :di]
    b_mat = xbc[..., di : di + gn]
    c_mat = xbc[..., di + gn :]
    shape = xbc.shape[:-1]
    return (x_ssm.reshape(*shape, cfg.ssm_heads, cfg.ssm_head_dim),
            b_mat.reshape(*shape, g, n),
            c_mat.reshape(*shape, g, n))


def _expand_groups(cfg: ArchConfig, m):
    """[..., G, N] -> [..., H, N] by repeating each group for its heads."""
    reps = cfg.ssm_heads // cfg.ssm_groups
    return jnp.repeat(m, reps, axis=-2)


def ssd_chunked(cfg: ArchConfig, x, b_mat, c_mat, a, state0=None):
    """SSD core. x [B,S,H,P]; b/c [B,S,H,N]; a [B,S,H] (negative).

    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    def resh(t):
        return t.reshape(bsz, nc, q, *t.shape[2:]).transpose(1, 0, *range(2, t.ndim + 1))

    xc, bc_, cc_, ac = map(resh, (x, b_mat, c_mat, a))  # leading nc

    if state0 is None:
        state0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    causal = jnp.tril(jnp.ones((q, q), bool))

    def chunk_body(state, inp):
        xk, bk, ck, ak = inp  # [B,q,H,P], [B,q,H,N], ..., [B,q,H]
        xk32 = xk.astype(jnp.float32)
        bk32 = bk.astype(jnp.float32)
        ck32 = ck.astype(jnp.float32)
        ca = jnp.cumsum(ak, axis=1)  # [B,q,H], non-increasing
        total = ca[:, -1]  # [B,H]
        # intra-chunk quadratic term
        cb = jnp.einsum("bihn,bjhn->bhij", ck32, bk32)
        decay = jnp.exp(ca[:, :, None, :] - ca[:, None, :, :])  # [B,i,j,H]
        decay = jnp.where(causal[None, :, :, None], decay, 0.0)
        kern = cb * decay.transpose(0, 3, 1, 2)  # [B,H,i,j]
        y_intra = jnp.einsum("bhij,bjhp->bihp", kern, xk32)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bihn,bhpn->bihp", ck32 * jnp.exp(ca)[..., None], state)
        # state update
        w_j = jnp.exp(total[:, None] - ca)  # [B,q,H] decay to chunk end
        s_add = jnp.einsum("bjhp,bjhn->bhpn", xk32 * w_j[..., None], bk32)
        state_new = state * jnp.exp(total)[:, :, None, None] + s_add
        return state_new, (y_intra + y_inter).astype(x.dtype)

    state, ys = jax.lax.scan(chunk_body, state0, (xc, bc_, cc_, ac),
                             unroll=cfg.scan_unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y, state


def ssm_block(cfg: ArchConfig, lp, x):
    """One mamba2 layer on x [B,S,D] (pre-norm residual block)."""
    h_in = apply_norm(cfg, x, lp["norm"])
    z, xbc, dt_raw = _project(cfg, lp, h_in)
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc, lp["conv_w"], lp["conv_b"]))
    x_ssm, b_mat, c_mat = _split_xbc(cfg, xbc)
    b_h = _expand_groups(cfg, b_mat)
    c_h = _expand_groups(cfg, c_mat)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])  # [B,S,H]
    a = -jnp.exp(lp["A_log"]) * dt  # negative
    xdt = x_ssm.astype(jnp.float32) * dt[..., None]
    y, _ = ssd_chunked(cfg, xdt.astype(x.dtype), b_h, c_h, a)
    y = y.astype(jnp.float32) + lp["D"][None, None, :, None] * x_ssm.astype(jnp.float32)
    bsz, s = x.shape[:2]
    y = y.reshape(bsz, s, cfg.ssm_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    from repro.models.lm.layers import rms_norm

    y = rms_norm(y, lp["gate_norm"]["scale"])
    out = jnp.einsum("...f,fd->...d", y, lp["out_proj"]["w"].astype(y.dtype))
    return x + out


def init_ssm_lm(rng, cfg: ArchConfig):
    k_emb, k_layers, k_unemb = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        "embed": init_embedding(k_emb, cfg),
        "layers": jax.vmap(lambda k: init_ssm_layer(k, cfg))(layer_keys),
        "final_norm": init_norm(cfg),
        "unembed": init_linear(k_unemb, cfg.d_model, cfg.vocab, cfg),
    }


def forward_ssm(cfg: ArchConfig, params, tokens, positions=None):
    del positions
    x = constrain_batch(embed(cfg, params["embed"], tokens))

    def body(h, lp):
        h = constrain_residual(h, cfg.residual_shard)
        if cfg.zero3_gather:
            lp = gather_weights(lp)
        return ssm_block(cfg, lp, h), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"], unroll=cfg.scan_unroll)
    x = apply_norm(cfg, x, params["final_norm"])
    return constrain_logits(unembed(cfg, params.get("unembed"), params["embed"], x))


def init_cache_ssm(cfg: ArchConfig, batch: int, seq_len: int):
    """Recurrent decode state: O(1) in seq_len (the cache size does not
    depend on context length — that's the SSM selling point)."""
    del seq_len
    di, gn, h = _split_dims(cfg)
    conv_ch = di + 2 * gn
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), cfg.adtype),
        "state": jnp.zeros((cfg.n_layers, batch, h, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32),
        "length": jnp.zeros((), jnp.int32),
    }


def ssm_decode_block(cfg: ArchConfig, lp, x1, conv_state, state):
    """Single-token recurrent step.  x1 [B,D]."""
    h_in = apply_norm(cfg, x1, lp["norm"])
    z, xbc, dt_raw = _project(cfg, lp, h_in)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          lp["conv_w"].astype(jnp.float32)) + lp["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out).astype(x1.dtype)
    new_conv_state = window[:, 1:]
    x_ssm, b_mat, c_mat = _split_xbc(cfg, xbc)
    b_h = _expand_groups(cfg, b_mat).astype(jnp.float32)  # [B,H,N]
    c_h = _expand_groups(cfg, c_mat).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])  # [B,H]
    a = -jnp.exp(lp["A_log"]) * dt
    xdt = x_ssm.astype(jnp.float32) * dt[..., None]  # [B,H,P]
    state = state * jnp.exp(a)[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", xdt, b_h)
    y = jnp.einsum("bhn,bhpn->bhp", c_h, state)
    y = y + lp["D"][None, :, None] * x_ssm.astype(jnp.float32)
    y = y.reshape(x1.shape[0], cfg.ssm_inner).astype(x1.dtype) * jax.nn.silu(z)
    from repro.models.lm.layers import rms_norm

    y = rms_norm(y, lp["gate_norm"]["scale"])
    out = jnp.einsum("bf,fd->bd", y, lp["out_proj"]["w"].astype(y.dtype))
    return x1 + out, new_conv_state, state


def decode_step_ssm(cfg: ArchConfig, params, cache, tokens):
    x = embed(cfg, params["embed"], tokens)[:, 0]  # [B,D]

    def body(h, inp):
        lp, conv_c, st = inp
        h, conv_new, st_new = ssm_decode_block(cfg, lp, h, conv_c, st)
        return h, (conv_new, st_new)

    x, (conv_new, state_new) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["state"]),
        unroll=cfg.scan_unroll)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(cfg, params.get("unembed"), params["embed"], x[:, None, :])
    return logits, {"conv": conv_new, "state": state_new, "length": cache["length"] + 1}
