"""LLaVA-NeXT (mistral-7b backbone) — VLM with stubbed vision tower.

Per the assignment carve-out, the SigLIP/CLIP vision encoder + projector are
a STUB: batches carry precomputed, already-projected patch embeddings
[B, img_tokens, D] (anyres tiling: 576 base + 4x576 tile tokens = 2880).
The language model is a dense mistral trunk; image embeddings are prepended
to the text token embeddings, and the LM loss is computed on text positions
only (image positions are masked out of the label loss).

Decode: the KV cache covers the full multimodal sequence; prefill would have
populated the image+prompt prefix, decode_step appends text tokens.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.dist.constraints import constrain_batch, constrain_logits
from repro.models.lm.config import ArchConfig
from repro.models.lm.dense import (
    decode_step_dense,
    forward_dense,
    init_cache_dense,
    init_dense,
    trunk,
)
from repro.models.lm.layers import embed, unembed


def init_vlm(rng, cfg: ArchConfig):
    return init_dense(rng, cfg)


def forward_vlm(cfg: ArchConfig, params, batch):
    """batch: tokens [B,S_text], img_embeds [B,I,D] -> logits [B,S_text,V].

    The full sequence is [img ; text]; positions run across both.  Only text
    positions produce logits (callers compute loss on text labels)."""
    tokens = batch["tokens"]
    img = batch["img_embeds"].astype(cfg.adtype)
    b, s_text = tokens.shape
    i = img.shape[1]
    x_text = embed(cfg, params["embed"], tokens)
    x = constrain_batch(jnp.concatenate([img, x_text], axis=1))
    positions = jnp.arange(i + s_text, dtype=jnp.int32)
    x = trunk(cfg, params, x, positions)
    x = x[:, i:, :]  # text positions only
    return constrain_logits(unembed(cfg, params.get("unembed"), params["embed"], x))


init_cache_vlm = init_cache_dense
decode_step_vlm = decode_step_dense  # decode is text-only, standard path
forward_text_only = forward_dense  # convenience for tests
