"""The paper's local model architectures (Table I), in pure JAX.

  MNIST    : MLP  FC 512-256-128 (+ output head), ReLU
  Fashion  : CNN  Conv 32, 64 (3x3) -> FC 9216-128 (+ head), ReLU
  EMNIST   : CNN  Conv 32, 64 (3x3), MaxPool(2), Dropout(.25),
                  FC 9216-128, Dropout(.5), FC 128 -> classes

Initialization is He-uniform (PyTorch default-like); every node in the
decentralized experiments draws its own init (model heterogeneity), which is
exactly the condition DecDiff targets.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.api import SmallModel, register_small_model


def _linear_init(rng, fan_in: int, fan_out: int):
    k1, k2 = jax.random.split(rng)
    bound = 1.0 / math.sqrt(fan_in)
    w = jax.random.uniform(k1, (fan_in, fan_out), jnp.float32, -bound, bound)
    b = jax.random.uniform(k2, (fan_out,), jnp.float32, -bound, bound)
    return {"w": w, "b": b}


def _conv_init(rng, kh: int, kw: int, cin: int, cout: int):
    k1, k2 = jax.random.split(rng)
    fan_in = kh * kw * cin
    bound = 1.0 / math.sqrt(fan_in)
    w = jax.random.uniform(k1, (kh, kw, cin, cout), jnp.float32, -bound, bound)
    b = jax.random.uniform(k2, (cout,), jnp.float32, -bound, bound)
    return {"w": w, "b": b}


def _conv2d(x, p):
    # x: [B, H, W, C]; w: [kh, kw, cin, cout]; VALID padding (PyTorch default)
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _dropout(x, rate: float, rng, train: bool):
    if not train or rate <= 0.0 or rng is None:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


@register_small_model("mlp")
def make_mlp(num_classes: int = 10, input_dim: int = 784,
             hidden: Sequence[int] = (512, 256, 128)) -> SmallModel:
    dims = [input_dim, *hidden, num_classes]

    def init(rng):
        keys = jax.random.split(rng, len(dims) - 1)
        return {f"fc{i}": _linear_init(k, dims[i], dims[i + 1])
                for i, k in enumerate(keys)}

    def apply(params, x, *, train=False, rng=None):
        del train, rng
        h = x.reshape(x.shape[0], -1)
        for i in range(len(dims) - 1):
            p = params[f"fc{i}"]
            h = h @ p["w"] + p["b"]
            if i < len(dims) - 2:
                h = jax.nn.relu(h)
        return h

    return SmallModel("mlp", init, apply, num_classes)


@register_small_model("cnn")
def make_cnn(num_classes: int = 10, in_hw=(28, 28),
             use_pool_dropout: bool = False) -> SmallModel:
    """Fashion CNN (use_pool_dropout=False) / EMNIST CNN (True).

    Conv 3x3 VALID twice: 28 -> 26 -> 24.  EMNIST variant pools to 12.
    Flatten 12*12*64 = 9216 (matching the paper's FC 9216) -> 128 -> classes.
    The Fashion variant in the paper also lists FC 9216, implying a pool as
    well; we pool in both and treat dropout as the EMNIST-only difference.
    """
    h, w = in_hw
    flat = ((h - 4) // 2) * ((w - 4) // 2) * 64  # 9216 for 28x28

    def init(rng):
        k1, k2, k3, k4 = jax.random.split(rng, 4)
        return {
            "conv0": _conv_init(k1, 3, 3, 1, 32),
            "conv1": _conv_init(k2, 3, 3, 32, 64),
            "fc0": _linear_init(k3, flat, 128),
            "fc1": _linear_init(k4, 128, num_classes),
        }

    def apply(params, x, *, train=False, rng=None):
        if x.ndim == 3:
            x = x[..., None]
        r1 = r2 = None
        if rng is not None:
            r1, r2 = jax.random.split(rng)
        h = jax.nn.relu(_conv2d(x, params["conv0"]))
        h = jax.nn.relu(_conv2d(h, params["conv1"]))
        h = _maxpool2(h)
        if use_pool_dropout:
            h = _dropout(h, 0.25, r1, train)
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params["fc0"]["w"] + params["fc0"]["b"])
        if use_pool_dropout:
            h = _dropout(h, 0.5, r2, train)
        return h @ params["fc1"]["w"] + params["fc1"]["b"]

    return SmallModel("cnn", init, apply, num_classes)


def model_for_dataset(dataset_name: str, num_classes: int) -> SmallModel:
    """Paper Table I mapping."""
    if "mnist" in dataset_name and "fashion" not in dataset_name and "emnist" not in dataset_name:
        return make_mlp(num_classes=num_classes)
    if "fashion" in dataset_name:
        return make_cnn(num_classes=num_classes, use_pool_dropout=False)
    if "emnist" in dataset_name:
        return make_cnn(num_classes=num_classes, use_pool_dropout=True)
    raise ValueError(f"no paper model mapping for dataset {dataset_name!r}")
