"""repro.obs — scan-native observability: telemetry channels, the run
ledger, and event-clock trace export.

    from repro.obs import Telemetry
    world = World.synthetic(nodes=16, telemetry=Telemetry(
        channels="auto", ledger="run.jsonl"))
    exp = Experiment(world, "decdiff+vt", comm=CommConfig(codec="int8"))
    hist = exp.run()
    hist[-1].detail["consensus"]             # per-node ‖w_i − w̄‖
    export_trace(exp, "trace.json")          # open in Perfetto

Opt-in and zero-cost when off: the channel accumulators ride the engine's
one `lax.scan` carry (no host syncs mid-run, no rng consumed), and
`telemetry=None` is bit-identical to an engine without this package —
pinned across backends × layouts × schedule modes in tests/test_obs.py.
See docs/observability.md for the channel catalog, the ledger schema, and
a trace-export worked example.
"""
from repro.obs.channels import (  # noqa: F401
    CHANNELS,
    BoundTelemetry,
    ChannelSpec,
    Telemetry,
    available_channels,
    channels_for,
)
from repro.obs.ledger import (  # noqa: F401
    MANIFEST_EDGE_CAP,
    SCHEMA,
    SCHEMA_VERSION,
    RunLedger,
    format_round,
    get_round_logger,
    log_round,
    read_ledger,
    round_record,
    run_manifest,
    validate_ledger,
    validate_record,
)
from repro.obs.trace import build_trace, export_trace  # noqa: F401
