"""Telemetry channels: declarative per-node / per-edge accumulators that
ride the engine's `lax.scan` carry.

The engine computes per-node step budgets, per-edge fired gates, delivery
masks and event-clock landing times inside every round — and, without this
module, throws them away.  A :class:`Telemetry` selects named CHANNELS from
the catalog below; `Experiment` binds it once into a :class:`BoundTelemetry`
whose accumulator dict becomes one more scan-carried state (threaded
exactly like `TimingState` via `_state_flags`), and whose per-round channel
snapshots come back as one more scan output.  Zero host syncs happen
mid-run, the channels consume NO rng (pure arithmetic over quantities the
round already computes), and with ``telemetry=None`` the engine is
bit-identical to a build without this module (pinned in tests/test_obs.py).

Channel catalog (`CHANNELS`):

  ================  ======  ========  =======================================
  name              axis    needs     meaning (cumulative unless noted)
  ================  ======  ========  =======================================
  node_steps        node    —         local SGD steps actually trained
  node_compute      node    timing    realized compute seconds (Σ budget·dt)
  node_acc          node    —         per-node test accuracy (eval rounds)
  edge_trigger      edge    comm      payloads FIRED on the directed edge
  edge_bytes        edge    comm      exact bytes on wire (payload × fired)
  edge_staleness    edge    comm      rounds since the edge last DELIVERED
                                      (instantaneous age; grows on silence,
                                      resets to 0 on delivery)
  edge_latency      edge    timing    this round's landing time in seconds
                                      (sender compute + link transfer;
                                      instantaneous)
  consensus         node    —         ‖w_i − w̄‖₂ after the round (distance
                                      to the node-mean parameter vector;
                                      probed at eval rounds)
  drift             edge    —         ‖w_src − w_dst‖₂ after the round (the
                                      paper's pairwise divergence, per
                                      directed edge; probed at eval rounds)
  ================  ======  ========  =======================================

Per-EDGE channels are materialized in the canonical `(dst, src)`-sorted
directed-edge order both layouts share (`repro.timing` binds its transfer
tables in the same order) — inside the scan they live in the layout-native
shape (`[N, max_deg]` receiver panel or flat `[E]` bank) and the host-side
:meth:`BoundTelemetry.materialize` converts, so `RoundMetrics.detail` is
layout-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """One catalog entry: which axis it indexes and which optional engine
    subsystems must be present for the quantity to exist at all."""

    axis: str                 # "node" | "edge"
    needs: Tuple[str, ...]    # subset of ("comm", "timing")
    doc: str


CHANNELS: Dict[str, ChannelSpec] = {
    "node_steps": ChannelSpec("node", (), "cumulative trained local steps"),
    "node_compute": ChannelSpec(
        "node", ("timing",), "cumulative realized compute seconds"),
    "node_acc": ChannelSpec(
        "node", (), "per-node test accuracy at eval rounds"),
    "edge_trigger": ChannelSpec(
        "edge", ("comm",), "cumulative fired payload count"),
    "edge_bytes": ChannelSpec(
        "edge", ("comm",), "cumulative exact bytes on wire"),
    "edge_staleness": ChannelSpec(
        "edge", ("comm",), "rounds since the edge last delivered"),
    "edge_latency": ChannelSpec(
        "edge", ("timing",), "this round's landing time in seconds"),
    "consensus": ChannelSpec(
        "node", (), "distance to the node-mean parameter vector"),
    "drift": ChannelSpec(
        "edge", (), "pairwise parameter distance per directed edge"),
}

_NEED_HINT = {
    "comm": "a comm transport (Experiment(comm=CommConfig(...)))",
    "timing": "an event clock (World(timing=repro.timing.Timing(...)))",
}


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Opt-in observability config, selected via ``World(telemetry=...)``.

    `channels` is a sequence of catalog names (strict: a channel whose
    required subsystem the experiment lacks raises at construction), or one
    of two aliases — ``"auto"`` (default: every channel the experiment can
    support) and ``"all"`` (the full catalog, strict).

    `ledger` is an optional path: the run writes a schema-validated JSONL
    ledger there (manifest + one record per eval round + a summary with
    compile-time / rounds-per-second counters — see repro.obs.ledger).

    `profile_dir` optionally wraps `run()` in a `jax.profiler.trace`
    capture (open the result in TensorBoard/Perfetto); channel collection
    itself never needs it.
    """

    channels: Union[str, Tuple[str, ...]] = "auto"
    ledger: Optional[str] = None
    profile_dir: Optional[str] = None

    def __post_init__(self):
        ch = self.channels
        if isinstance(ch, str):
            if ch not in ("auto", "all"):
                raise ValueError(
                    f"unknown channel alias {ch!r}; pass 'auto', 'all', or "
                    f"a sequence of names from {sorted(CHANNELS)}")
            return
        ch = tuple(ch)
        unknown = [c for c in ch if c not in CHANNELS]
        if unknown:
            raise ValueError(
                f"unknown telemetry channel(s) {unknown}; "
                f"available: {sorted(CHANNELS)}")
        object.__setattr__(self, "channels", ch)

    def resolve(self, *, has_comm: bool, has_timing: bool) -> Tuple[str, ...]:
        """The selected channel names, catalog-ordered; strict selections
        raise if a required subsystem is missing."""
        have = set()
        if has_comm:
            have.add("comm")
        if has_timing:
            have.add("timing")
        if self.channels == "auto":
            return tuple(c for c, spec in CHANNELS.items()
                         if set(spec.needs) <= have)
        names = (tuple(CHANNELS) if self.channels == "all"
                 else tuple(self.channels))
        for c in names:
            missing = [n for n in CHANNELS[c].needs if n not in have]
            if missing:
                raise ValueError(
                    f"telemetry channel {c!r} needs "
                    + " and ".join(_NEED_HINT[n] for n in missing)
                    + "; drop the channel or add the subsystem "
                    "(channels='auto' selects only what the experiment "
                    "supports)")
        # catalog order keeps ledgers/details stable across selections
        return tuple(c for c in CHANNELS if c in names)

    def bind(self, exp) -> Optional["BoundTelemetry"]:
        """Resolve the channels against `exp` and precompute the static
        index tensors; None when the selection is empty (ledger-only use —
        the scan then carries no telemetry state at all)."""
        names = self.resolve(has_comm=exp.transport is not None,
                             has_timing=exp.bound_timing is not None)
        if not names:
            return None
        return BoundTelemetry(exp, names)


class BoundTelemetry:
    """A Telemetry bound to one experiment: the scan-carried accumulator
    dict (`state0`), the per-round channel arithmetic (`step`, called from
    the one round body on both backends and both layouts), and the
    host-side canonical materialization (`materialize`)."""

    def __init__(self, exp, names: Tuple[str, ...]):
        self.channels = names
        self.layout = exp.layout
        self.n = int(exp.n)
        self.payload_bytes = (float(exp.transport.payload_bytes)
                              if exp.transport is not None else None)
        topo = exp.topo
        if self.layout == "sparse":
            src = np.asarray(topo.edge_src, np.int64)
            dst = np.asarray(topo.edge_dst, np.int64)
            self._panel_dst = self._panel_slot = None
            self._edge_src_j = jnp.asarray(src.astype(np.int32))
        else:
            # canonical (dst, src)-sorted directed edges: np.nonzero walks
            # the adjacency row-major, so row r's hits come out
            # sender-ascending — exactly receiver r's padded slots 0..deg-1.
            dst, src = np.nonzero(np.asarray(topo.adjacency) > 0)
            deg = np.asarray(topo.neighbor_mask.sum(axis=1), np.int64)
            self._panel_dst = dst
            self._panel_slot = np.concatenate(
                [np.arange(d, dtype=np.int64) for d in deg]) \
                if len(dst) else np.zeros((0,), np.int64)
            self._nbr_idx = jnp.asarray(
                np.maximum(topo.neighbor_idx, 0).astype(np.int32))
            self._nbr_valid = jnp.asarray(
                topo.neighbor_mask.astype(np.float32))
        self.edge_src = src.astype(np.int64)
        self.edge_dst = dst.astype(np.int64)
        self.num_directed = int(len(src))
        if exp.bound_timing is not None:
            self._transfer_e = exp.bound_timing.transfer_e
            self._transfer_panel = exp.bound_timing.transfer_panel
        else:
            self._transfer_e = self._transfer_panel = None
        # canonical-order endpoint indices, both layouts: the drift probe
        # gathers [E, D] rows directly (never the [N, max_deg, D] panel —
        # an order of magnitude more memory traffic on dense worlds), and
        # only over the E/2 undirected pairs: the graph is symmetric, so
        # ‖w_src − w_dst‖ is shared by both directions and scattered back.
        pairs = {}
        for e, (s, t) in enumerate(zip(src.tolist(), dst.tolist())):
            pairs.setdefault((min(s, t), max(s, t)), []).append(e)
        pair_lo = np.array([p[0] for p in pairs], np.int32)
        pair_hi = np.array([p[1] for p in pairs], np.int32)
        pair_of_edge = np.zeros((self.num_directed,), np.int32)
        for i, es in enumerate(pairs.values()):
            for e in es:
                pair_of_edge[e] = i
        self._pair_lo = jnp.asarray(pair_lo)
        self._pair_hi = jnp.asarray(pair_hi)
        self._pair_of_edge = jnp.asarray(pair_of_edge)

        self.has_probes = bool({"consensus", "drift"} & set(names))
        self.needs_fired = bool(
            {"edge_trigger", "edge_bytes"} & set(names))
        self.needs_delivered = "edge_staleness" in names

        edge_shape = ((self.num_directed,) if self.layout == "sparse"
                      else tuple(np.asarray(topo.neighbor_mask).shape))
        state = {"rounds": jnp.float32(0.0)}
        if "node_steps" in names:
            state["node_steps"] = jnp.zeros((self.n,), jnp.float32)
        if "node_compute" in names:
            state["node_secs"] = jnp.zeros((self.n,), jnp.float32)
        if self.needs_fired:
            state["edge_sent"] = jnp.zeros(edge_shape, jnp.float32)
        if self.needs_delivered:
            state["edge_age"] = jnp.zeros(edge_shape, jnp.float32)
        self.state0 = state

    # -- inside the scan -------------------------------------------------
    def step(self, state, *, budgets, t_cost, fired, delivered):
        """One round of channel arithmetic.  All inputs are FULL-axis and
        replicated under shard_map (budgets [N] int, t_cost [N] seconds or
        None, fired/delivered layout-native edge masks or None), so the
        accumulators — and therefore the materialized details — are
        backend-independent.  Counts are small integers summed in f32
        (exact below 2^24).  Consumes no rng.  Returns (new_state,
        snapshot) with the snapshot emitted as one scan output per round.

        The params-reading probes (consensus/drift) deliberately do NOT
        run here: they are instantaneous norms consumed only at eval
        rounds, so the runner computes them through :meth:`eval_probes`
        inside the SAME static flag gate as the eval itself — non-eval
        rounds never pay the [N, D] flatten + norm traffic."""
        new = {"rounds": state["rounds"] + 1.0}
        out = {}
        if "node_steps" in state:
            new["node_steps"] = (state["node_steps"]
                                 + budgets.astype(jnp.float32))
            out["node_steps"] = new["node_steps"]
        if "node_secs" in state:
            new["node_secs"] = state["node_secs"] + t_cost
            out["node_secs"] = new["node_secs"]
        if "edge_sent" in state:
            new["edge_sent"] = state["edge_sent"] + fired
            out["edge_sent"] = new["edge_sent"]
        if "edge_age" in state:
            # +1 per silent round, reset on delivery; padding slots of the
            # dense panel grow too but are dropped by materialize().
            new["edge_age"] = (state["edge_age"] + 1.0) * (1.0 - delivered)
            out["edge_age"] = new["edge_age"]
        if "edge_latency" in self.channels:
            if self.layout == "sparse":
                out["edge_landing"] = (t_cost[self._edge_src_j]
                                       + self._transfer_e)
            else:
                out["edge_landing"] = (t_cost[self._nbr_idx]
                                       + self._transfer_panel) \
                    * self._nbr_valid
        return new, out

    def eval_probes(self, full_mat) -> Dict[str, jnp.ndarray]:
        """The params-reading probes (consensus/drift) from the [N, D]
        flattened post-round parameter matrix.  Instantaneous — no carried
        state — and consumed only at eval rounds, so the runner gates this
        behind the fused program's static eval flag (and calls it from the
        host only at eval rounds in loop mode); the channel values in
        `RoundMetrics.detail` are identical to computing them every round."""
        out: Dict[str, jnp.ndarray] = {}
        if "consensus" in self.channels:
            mean = jnp.mean(full_mat, axis=0)
            out["consensus"] = jnp.sqrt(
                jnp.sum((full_mat - mean[None, :]) ** 2, axis=1))
        if "drift" in self.channels:
            # flat canonical [E] on BOTH layouts (identical program — the
            # dense/sparse parity of this probe holds by construction),
            # computed once per undirected pair and mirrored
            diff = full_mat[self._pair_lo] - full_mat[self._pair_hi]
            half = jnp.sqrt(jnp.sum(diff ** 2, axis=1))
            out["drift"] = half[self._pair_of_edge]
        return out

    def probe_zeros(self) -> Dict[str, jnp.ndarray]:
        """Zeros in :meth:`eval_probes`'s exact structure — the untaken
        branch of the fused program's eval cond."""
        out: Dict[str, jnp.ndarray] = {}
        if "consensus" in self.channels:
            out["consensus"] = jnp.zeros((self.n,), jnp.float32)
        if "drift" in self.channels:
            out["drift"] = jnp.zeros((self.num_directed,), jnp.float32)
        return out

    # -- on the host ------------------------------------------------------
    def _edge(self, a) -> np.ndarray:
        """Layout-native edge array -> canonical (dst, src)-sorted [E]."""
        a = np.asarray(a)
        if self.layout == "sparse":
            return a
        return a[self._panel_dst, self._panel_slot]

    def materialize(self, snapshot, acc_per_node=None,
                    probes=None) -> Dict[str, np.ndarray]:
        """One round's snapshot -> {channel: canonical numpy array}: node
        channels [N], edge channels [E] in the canonical (dst, src) order
        (`edge_src`/`edge_dst` name the endpoints).  `edge_bytes` is the
        exact payload_bytes × fired-count product, computed here in float64
        so it survives past f32's 2^24.  `probes` is the eval round's
        :meth:`eval_probes` output (consensus/drift live there, not in the
        per-round snapshot)."""
        detail: Dict[str, np.ndarray] = {}
        for ch in self.channels:
            if ch == "node_steps":
                detail[ch] = np.asarray(snapshot["node_steps"])
            elif ch == "node_compute":
                detail[ch] = np.asarray(snapshot["node_secs"])
            elif ch == "node_acc":
                if acc_per_node is not None:
                    detail[ch] = np.asarray(acc_per_node)
            elif ch == "edge_trigger":
                detail[ch] = self._edge(snapshot["edge_sent"])
            elif ch == "edge_bytes":
                detail[ch] = (self._edge(snapshot["edge_sent"])
                              .astype(np.float64) * self.payload_bytes)
            elif ch == "edge_staleness":
                detail[ch] = self._edge(snapshot["edge_age"])
            elif ch == "edge_latency":
                detail[ch] = self._edge(snapshot["edge_landing"])
            elif ch == "consensus":
                if probes is not None:
                    detail[ch] = np.asarray(probes["consensus"])
            elif ch == "drift":
                # already flat canonical [E] on both layouts
                if probes is not None:
                    detail[ch] = np.asarray(probes["drift"])
        return detail


def available_channels() -> Tuple[str, ...]:
    """The catalog names, in the stable order details/ledgers use."""
    return tuple(CHANNELS)


def channels_for(names: Sequence[str]) -> Dict[str, ChannelSpec]:
    """Catalog specs for a selection (unknown names raise, same message as
    Telemetry validation)."""
    t = Telemetry(channels=tuple(names))
    return {c: CHANNELS[c] for c in t.channels}
