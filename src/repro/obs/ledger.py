"""The run ledger: a structured, schema-validated JSONL record of a run.

Three record kinds, one JSON object per line:

  * ``manifest`` — written once per ledger: config (method / backend /
    layout / wire / schedule), seed, the selected telemetry channels, the
    environment (python / jax / numpy versions, platform, device count),
    payload bytes, and — when per-edge channels are selected and the graph
    is small enough — the canonical directed-edge endpoint lists so edge
    channels can be joined back to the graph;
  * ``round``    — one per eval round: the full RoundMetrics surface
    (per-node accuracy included) plus the materialized channel `detail`;
  * ``summary``  — one per `run()` call: wall seconds, rounds/sec, and the
    compile-time counters (cold compile + lowering/compile seconds for the
    fused program).

Validation is hand-rolled against `SCHEMA` (stdlib-only — no jsonschema
dependency): required fields with type checks per kind, unknown kinds
rejected.  `validate_ledger(path)` re-validates a written file and returns
the per-kind counts (the CI telemetry smoke lane runs it on every ledger
it emits).

This module also owns the engine's verbose round line: `format_round`
renders the EXACT text `Experiment.run(verbose=True)` has always printed,
and `log_round` emits it through stdlib `logging` (logger
``repro.obs.round``, stdout handler attached on first use) — so verbose
output is stable for existing users while becoming interceptable like any
other logging stream.
"""
from __future__ import annotations

import json
import logging
import platform as _platform
import sys
from typing import Dict, Optional

import numpy as np

SCHEMA_VERSION = 1

# Required fields per record kind (name -> allowed types).  Optional
# fields are validated only when present.
SCHEMA = {
    "manifest": {
        "required": {
            "kind": str, "schema": int, "method": str, "backend": str,
            "layout": str, "wire": str, "mode": str, "rounds": int,
            "eval_every": int, "nodes": int, "num_directed": int,
            "seed": int, "channels": list, "env": dict,
        },
        "optional": {
            "deadline": (int, float, type(None)),
            "payload_bytes": (int, float, type(None)),
            "edges": dict,
        },
    },
    "round": {
        "required": {
            "kind": str, "round": int, "acc_mean": float, "acc_std": float,
            "loss_mean": float, "acc_per_node": list,
        },
        "optional": {
            "bytes_on_wire": (int, float), "triggered_frac": (int, float),
            "live_edge_frac": (int, float), "sim_time": (int, float),
            "arrived_frac": (int, float), "detail": dict,
        },
    },
    "summary": {
        "required": {
            "kind": str, "mode": str, "rounds": int, "wall_s": float,
            "rounds_per_sec": float,
        },
        "optional": {
            "cold_compile": bool, "compile_s": (int, float),
        },
    },
}

# edge lists above this size are omitted from the manifest (the ledger is
# a log, not a graph store; SparseTopology serializes the graph itself)
MANIFEST_EDGE_CAP = 32768


def _jsonable(v):
    """numpy scalars/arrays -> plain python, recursively."""
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v


def validate_record(rec: dict) -> dict:
    """Check one ledger record against SCHEMA; returns it (raises
    ValueError with the offending field otherwise)."""
    if not isinstance(rec, dict):
        raise ValueError(f"ledger record must be an object, got "
                         f"{type(rec).__name__}")
    kind = rec.get("kind")
    if kind not in SCHEMA:
        raise ValueError(f"unknown ledger record kind {kind!r}; "
                         f"expected one of {sorted(SCHEMA)}")
    spec = SCHEMA[kind]
    for field, types in spec["required"].items():
        if field not in rec:
            raise ValueError(f"{kind} record missing required field "
                             f"{field!r}")
        if not isinstance(rec[field], types):
            raise ValueError(
                f"{kind} record field {field!r} has type "
                f"{type(rec[field]).__name__}, expected "
                f"{getattr(types, '__name__', types)}")
    for field, types in spec["optional"].items():
        if field in rec and not isinstance(rec[field], types):
            raise ValueError(
                f"{kind} record field {field!r} has type "
                f"{type(rec[field]).__name__}")
    return rec


def validate_ledger(path: str) -> Dict[str, int]:
    """Validate every line of a written ledger; returns {kind: count}.
    The first record must be the manifest."""
    counts: Dict[str, int] = {}
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not valid JSON: {e}")
            validate_record(rec)
            if i == 0 and rec["kind"] != "manifest":
                raise ValueError(f"{path}: first record must be the "
                                 f"manifest, got {rec['kind']!r}")
            counts[rec["kind"]] = counts.get(rec["kind"], 0) + 1
    if "manifest" not in counts:
        raise ValueError(f"{path}: empty ledger (no manifest)")
    return counts


def run_manifest(exp) -> dict:
    """The manifest record for one Experiment (duck-typed: anything with
    the Experiment surface works)."""
    import jax

    channels = (list(exp.bound_obs.channels)
                if exp.bound_obs is not None else [])
    rec = {
        "kind": "manifest",
        "schema": SCHEMA_VERSION,
        "method": exp.method.name,
        "backend": exp.backend,
        "layout": exp.layout,
        "wire": exp.wire,
        "mode": exp.schedule.mode,
        "rounds": int(exp.schedule.rounds),
        "eval_every": int(exp.schedule.eval_every),
        "deadline": exp.deadline,
        "nodes": int(exp.n),
        "num_directed": int(exp._total_directed),
        "seed": int(exp.train.seed),
        "payload_bytes": (float(exp.transport.payload_bytes)
                          if exp.transport is not None else None),
        "channels": channels,
        "env": {
            "python": sys.version.split()[0],
            "jax": jax.__version__,
            "numpy": np.__version__,
            "platform": _platform.platform(),
            "jax_backend": jax.default_backend(),
            "device_count": jax.device_count(),
        },
    }
    bo = exp.bound_obs
    if (bo is not None and bo.num_directed <= MANIFEST_EDGE_CAP
            and any(c.startswith("edge_") or c == "drift"
                    for c in bo.channels)):
        rec["edges"] = {"src": bo.edge_src.tolist(),
                        "dst": bo.edge_dst.tolist()}
    return validate_record(rec)


def round_record(m) -> dict:
    """One eval round's RoundMetrics -> a validated `round` record."""
    rec = {
        "kind": "round",
        "round": int(m.round),
        "acc_mean": float(m.acc_mean),
        "acc_std": float(m.acc_std),
        "loss_mean": float(m.loss_mean),
        "acc_per_node": np.asarray(m.acc_per_node).tolist(),
    }
    for field in ("bytes_on_wire", "triggered_frac", "live_edge_frac",
                  "sim_time", "arrived_frac"):
        v = getattr(m, field)
        if v is not None:
            rec[field] = float(v)
    if m.detail is not None:
        rec["detail"] = _jsonable(m.detail)
    return validate_record(rec)


class RunLedger:
    """Append-only JSONL writer.  The manifest TRUNCATES the file (one
    ledger = one experiment); every record is validated before it is
    written, so a ledger on disk always re-validates."""

    def __init__(self, path: str):
        self.path = path
        self._started = False

    def write_manifest(self, rec: dict) -> None:
        with open(self.path, "w") as f:
            f.write(json.dumps(validate_record(rec)) + "\n")
        self._started = True

    def write(self, rec: dict) -> None:
        if not self._started:
            raise ValueError("ledger has no manifest yet; RunLedger is "
                             "driven by Experiment — write_manifest first")
        with open(self.path, "a") as f:
            f.write(json.dumps(validate_record(rec)) + "\n")


def read_ledger(path: str):
    """Load a ledger: (manifest, [round records], [summary records]).
    Validates as it reads."""
    manifest, rounds, summaries = None, [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = validate_record(json.loads(line))
            if rec["kind"] == "manifest":
                manifest = rec
            elif rec["kind"] == "round":
                rounds.append(rec)
            else:
                summaries.append(rec)
    if manifest is None:
        raise ValueError(f"{path}: no manifest record")
    return manifest, rounds, summaries


# ------------------------------------------------- the verbose round line

def format_round(method_name: str, m) -> str:
    """The engine's verbose round line — byte-for-byte the text
    `Experiment.run(verbose=True)` printed before the ledger existed."""
    comm = ("" if m.bytes_on_wire is None else
            f"  wire {m.bytes_on_wire / 1e6:.2f} MB"
            f"  trig {m.triggered_frac:.2f}")
    live = ("" if m.live_edge_frac is None else
            f"  live {m.live_edge_frac:.2f}")
    time = ("" if m.sim_time is None else
            f"  t {m.sim_time:.1f}s  arr {m.arrived_frac:.2f}")
    return (f"[{method_name}] round {m.round:4d}  "
            f"acc {m.acc_mean:.4f} ± {m.acc_std:.4f}  "
            f"loss {m.loss_mean:.4f}{comm}{live}{time}")


class _CurrentStdoutHandler(logging.StreamHandler):
    """A StreamHandler that resolves sys.stdout at EMIT time, so pytest's
    capsys (which swaps the stdout object) and user redirections both see
    the verbose lines exactly as `print` did."""

    def __init__(self):
        super().__init__(stream=sys.stdout)

    @property
    def stream(self):
        return sys.stdout

    @stream.setter
    def stream(self, value):  # StreamHandler.__init__ assigns; ignore
        pass


_round_logger: Optional[logging.Logger] = None


def get_round_logger() -> logging.Logger:
    """The ``repro.obs.round`` logger with its stdout handler attached
    once.  It does not propagate (the root logger's formatting must not
    double-print verbose lines); silence it with
    ``logging.getLogger("repro.obs.round").disabled = True`` or swap the
    handler for your own."""
    global _round_logger
    if _round_logger is None:
        logger = logging.getLogger("repro.obs.round")
        logger.setLevel(logging.INFO)
        logger.propagate = False
        if not logger.handlers:
            h = _CurrentStdoutHandler()
            h.setFormatter(logging.Formatter("%(message)s"))
            logger.addHandler(h)
        _round_logger = logger
    return _round_logger


def log_round(method_name: str, m) -> None:
    """Emit one verbose round line through the logging stream."""
    get_round_logger().info(format_round(method_name, m))
