"""Chrome-trace / Perfetto export of a timed run.

`export_trace(exp, path)` turns an experiment that ran with the PR-9 event
clock (`World(timing=...)`) and telemetry (`node_compute`, plus
`edge_trigger` for transfer spans) into a ``trace.json`` in the Chrome
trace-event format — load it in chrome://tracing or https://ui.perfetto.dev:

  * pid 0, one tid per NODE: a complete ("X") span per round covering that
    node's realized local training (`ts` = the round's absolute start on
    the simulated clock, `dur` = its realized compute seconds — stragglers
    render as the long lanes they are);
  * pid 1, one tid per directed EDGE: a span per FIRED payload, starting
    when the sender finishes computing and lasting the edge's transfer
    time, annotated with the EXACT bytes on wire and — under
    `Schedule(deadline=...)` — whether it landed before the deadline.

The span bytes sum exactly to `RoundMetrics.bytes_on_wire` (pinned in
tests/test_obs.py): both are payload_bytes × the same fired-gate counts,
multiplied outside f32.  Times are seconds on the SIMULATED clock, written
in the format's microseconds.
"""
from __future__ import annotations

import json
from typing import Optional

import numpy as np


def _require(cond, msg):
    if not cond:
        raise ValueError(msg)


def build_trace(exp) -> dict:
    """The trace dict for `exp` (see module docstring).  Needs a completed
    run with `World(timing=...)` and a telemetry selection containing
    `node_compute` (edge transfer spans additionally need
    `edge_trigger`)."""
    _require(exp.bound_timing is not None,
             "trace export prices spans on the simulated clock; run with "
             "World(timing=repro.timing.Timing(...))")
    obs = exp.bound_obs
    _require(obs is not None,
             "trace export reads telemetry channels; run with "
             "World(telemetry=repro.obs.Telemetry(...))")
    _require("node_compute" in obs.channels,
             "trace export needs the 'node_compute' channel for the "
             "train spans (channels='auto' selects it with timing on)")
    _require(len(exp.obs_history) > 0,
             "no rounds recorded yet; call run() before export_trace")

    hist = exp.obs_history
    rounds = len(hist)
    n = obs.n
    sim = list(exp.sim_time_history)
    _require(len(sim) == rounds,
             "sim_time_history and telemetry history disagree")
    starts = np.asarray([0.0] + sim[:-1])

    cum_secs = np.stack([np.asarray(s["node_secs"]) for s in hist])
    secs = np.diff(cum_secs, axis=0, prepend=np.zeros((1, n)))
    steps = None
    if "node_steps" in obs.channels:
        cum_steps = np.stack([np.asarray(s["node_steps"]) for s in hist])
        steps = np.diff(cum_steps, axis=0, prepend=np.zeros((1, n)))

    events = [
        {"ph": "M", "pid": 0, "name": "process_name",
         "args": {"name": "nodes (local training)"}},
    ]
    for i in range(n):
        events.append({"ph": "M", "pid": 0, "tid": i, "name": "thread_name",
                       "args": {"name": f"node {i}"}})

    def us(t):
        return float(t) * 1e6

    for r in range(rounds):
        for i in range(n):
            if secs[r, i] <= 0:
                continue  # dead or zero-budget node: no span
            args = {"round": r}
            if steps is not None:
                args["steps"] = int(round(float(steps[r, i])))
            events.append({"ph": "X", "pid": 0, "tid": i,
                           "name": f"train r{r}", "ts": us(starts[r]),
                           "dur": us(secs[r, i]), "args": args})

    if "edge_trigger" in obs.channels and exp.transport is not None:
        src, dst = obs.edge_src, obs.edge_dst
        payload = float(exp.transport.payload_bytes)
        transfer = np.asarray(exp.bound_timing.transfer_e, np.float64)
        deadline = exp.deadline
        cum_sent = np.stack([obs._edge(s["edge_sent"]) for s in hist])
        fired = np.diff(cum_sent, axis=0,
                        prepend=np.zeros((1, obs.num_directed)))
        events.append({"ph": "M", "pid": 1, "name": "process_name",
                       "args": {"name": "edges (payload transfers)"}})
        for e in range(obs.num_directed):
            events.append({"ph": "M", "pid": 1, "tid": e,
                           "name": "thread_name",
                           "args": {"name": f"{src[e]}->{dst[e]}"}})
        for r in range(rounds):
            for e in np.nonzero(fired[r] > 0)[0]:
                t_send = secs[r, src[e]]
                landing = t_send + transfer[e]
                args = {"round": r,
                        "bytes": payload * float(fired[r, e]),
                        "src": int(src[e]), "dst": int(dst[e])}
                if deadline is not None:
                    args["deadline_s"] = float(deadline)
                    args["arrived"] = bool(landing <= deadline)
                events.append({"ph": "X", "pid": 1, "tid": int(e),
                               "name": f"{src[e]}->{dst[e]} r{r}",
                               "ts": us(starts[r] + t_send),
                               "dur": us(transfer[e]), "args": args})

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_trace(exp, path: Optional[str] = None) -> dict:
    """Build the trace and (optionally) write it to `path`; returns the
    trace dict either way."""
    trace = build_trace(exp)
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace
