from repro.optim.sgd import (  # noqa: F401
    Optimizer,
    sgd_momentum,
    adamw,
    make_optimizer,
    cosine_schedule,
    constant_schedule,
)
