from repro.optim.sgd import (  # noqa: F401
    Optimizer,
    adamw,
    constant_schedule,
    cosine_schedule,
    make_optimizer,
    sgd_momentum,
)
