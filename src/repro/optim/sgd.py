"""Optimizers, from scratch (no optax in this environment).

The paper trains with SGD + momentum (eta=0.001; mu=0.5 for MNIST, 0.9 for
Fashion/EMNIST).  We implement that faithfully, plus AdamW and LR schedules
for the large-architecture training driver.

Design: functional, pytree-based, mirrors the (init, update) pattern so any
optimizer slots into the trainer, the vmapped simulator, and the sharded
train_step (optimizer state shards with the same PartitionSpec as params).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable  # params -> opt_state
    update: Callable  # (grads, opt_state, params, step) -> (new_params, new_state)


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def sgd_momentum(lr=1e-3, momentum: float = 0.9, nesterov: bool = False,
                 weight_decay: float = 0.0, momentum_dtype=jnp.float32) -> Optimizer:
    """SGD with (heavy-ball) momentum — the paper's optimizer.

    PyTorch-convention momentum: v <- mu*v + g;  w <- w - lr*v.
    """
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {"momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, momentum_dtype), params)}

    def update(grads, state, params, step):
        lr_t = sched(step)

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            v_new = momentum * v.astype(jnp.float32) + g32
            d = g32 + momentum * v_new if nesterov else v_new
            return (p.astype(jnp.float32) - lr_t * d).astype(p.dtype), v_new.astype(momentum_dtype)

        out = jax.tree.map(upd, grads, state["momentum"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mom = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"momentum": new_mom}

    return Optimizer(init=init, update=update)


def adamw(lr=3e-4, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr_t = sched(step)
        t = step.astype(jnp.float32) + 1.0 if hasattr(step, "astype") else jnp.float32(step) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype),
                    m_new.astype(state_dtype), v_new.astype(state_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree.map(lambda o, _i=i: o[_i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}

    return Optimizer(init=init, update=update)


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "sgdm"
    lr: float = 1e-3
    momentum: float = 0.9
    weight_decay: float = 0.0
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "constant"  # constant | cosine


def make_optimizer(cfg: Optional[OptimizerConfig] = None, **overrides) -> Optimizer:
    cfg = dataclasses.replace(cfg or OptimizerConfig(), **overrides)
    lr: Callable = (
        cosine_schedule(cfg.lr, cfg.warmup, cfg.total_steps)
        if cfg.schedule == "cosine"
        else constant_schedule(cfg.lr)
    )
    if cfg.name in ("sgd", "sgdm"):
        return sgd_momentum(lr=lr, momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    if cfg.name == "adamw":
        return adamw(lr=lr, weight_decay=cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
