"""repro.timing — the event-clock subsystem: time-to-accuracy, not rounds.

    from repro.timing import Timing, LognormalStep, LognormalLink
    world = World.synthetic(nodes=16, topology="barabasi_albert", m=2,
                            timing=Timing(node=LognormalStep(sigma=0.5),
                                          link=LognormalLink()))
    Experiment(world, "decdiff+vt", comm=...,
               schedule=Schedule(rounds=100, deadline=6.0)).run()

A :class:`Timing` prices every round in simulated seconds: per-node step
times (constant / lognormal / straggler tiers / trace tables) and per-edge
latency + bandwidth, with each payload costing its codec's EXACT
bytes-on-wire.  ``Schedule(deadline=...)`` turns rounds into deadline
ticks — a payload is delivered iff ``send_time + latency +
bytes/bandwidth <= deadline``, late arrivals fall into the existing
stale/drop silence paths, and stragglers train fewer local steps.  With
``deadline=None`` the engine stays synchronous (every round waits for the
slowest node and link) and merely reports the simulated makespan.  With
``World(telemetry=...)`` also bound, `repro.obs.export_trace` renders the
realized clock as a Chrome/Perfetto trace — per-node train spans and
per-edge transfer spans with exact bytes and arrival-vs-deadline.  See
docs/timing.md and docs/observability.md.
"""
from repro.timing.models import (  # noqa: F401
    LINK_MODELS,
    NODE_MODELS,
    PAST_END,
    BoundTiming,
    ConstantLink,
    ConstantStep,
    LinkTimeModel,
    LognormalLink,
    LognormalStep,
    NodeTimeModel,
    StragglerStep,
    TableLink,
    Timing,
    TimingState,
    TraceStep,
    make_link_model,
    make_node_model,
    past_end_index,
)
