"""Event-clock models: per-node step time, per-edge latency + bandwidth.

The paper's coordination-free setting has no central clock, yet a
synchronous round schedule advances every node in lock-step — a fiction
that hides exactly the device heterogeneity the setting is about.  This
module prices a round in SIMULATED SECONDS instead:

  * a :class:`NodeTimeModel` assigns every node the wall-clock cost of ONE
    local SGD step (constant, lognormal-heterogeneous, straggler-tiered, or
    trace-table-driven a la per-device capacity traces);
  * a :class:`LinkTimeModel` assigns every directed edge a latency and a
    bandwidth, so a payload of ``payload_bytes`` (the codec's EXACT
    bytes-on-wire from ``repro.comm``) needs ``latency + bytes/bandwidth``
    seconds to cross it.

:class:`Timing` packages one of each; ``Timing.bind(topo, payload_bytes)``
freezes them against a topology into a :class:`BoundTiming` — the per-node
``step_time(round_idx) -> [N]`` schedule plus the per-edge ``transfer``
seconds in the binding's layout (flat ``[E]`` over the canonical CSR
directed edge list for a `SparseTopology`; the padded ``[N, max_deg]``
receiver panel for a dense `Topology`, scattered from the SAME canonical
enumeration so the two layouts agree bit-for-bit on the same graph).

Randomness discipline: every stochastic model draws with NUMPY at bind
time, keyed by its own ``seed`` — binding consumes no jax rng, so an
experiment with timing enabled consumes exactly the rng stream of one
without (the degenerate-timing oracle in tests/test_timing.py).  Per-edge
draws are one draw per UNDIRECTED pair in canonical ascending ``(lo, hi)``
order (the `repro.dynamics` coin discipline), mirrored onto both directed
records, so a link is symmetric and both layouts scatter the same value.

The quantized event clock itself (deadline ticks, arrival masks, straggler
step budgets) lives in the engine round body — see docs/timing.md for the
semantics and `repro.engine.backends` for the lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.graphs.sparse import SparseTopology

PAST_END = ("wrap", "clamp")


def past_end_index(round_idx, length: int, past_end: str):
    """The shared period/clamp rule for ``[T, ...]`` schedule tables past
    the table end: ``wrap`` repeats the table periodically, ``clamp`` holds
    the last row forever.  ``round_idx`` may be a traced int32 scalar."""
    r = jnp.asarray(round_idx).astype(jnp.int32)
    if past_end == "wrap":
        return r % length
    return jnp.minimum(r, length - 1)


def _check_past_end(past_end: str):
    if past_end not in PAST_END:
        raise ValueError(f"past_end must be one of {PAST_END}, "
                         f"got {past_end!r}")


# ----------------------------------------------------------- node models

class NodeTimeModel:
    """Protocol: the wall-clock seconds ONE local SGD step costs per node.

    ``bind(n)`` freezes the model against an ``n``-node world and returns
    ``step_time(round_idx) -> [N] f32`` — strictly positive seconds, pure
    in ``round_idx`` so it compiles inside the fused ``lax.scan``."""

    def bind(self, n: int) -> Callable:
        raise NotImplementedError


def _positive(name: str, v: float):
    if not v > 0:
        raise ValueError(f"{name} must be > 0, got {v}")


@dataclasses.dataclass(frozen=True)
class ConstantStep(NodeTimeModel):
    """Every node takes ``dt`` seconds per local step — the homogeneous
    baseline (and half of the degenerate model that must reproduce the
    synchronous engine bit-for-bit)."""

    dt: float = 1.0

    def __post_init__(self):
        _positive("dt", self.dt)

    def bind(self, n: int) -> Callable:
        dt = jnp.full((n,), self.dt, jnp.float32)
        return lambda round_idx: dt


@dataclasses.dataclass(frozen=True)
class LognormalStep(NodeTimeModel):
    """Static heterogeneous devices: node i's per-step time is one draw
    ``median * exp(sigma * z_i)``, z_i ~ N(0, 1), frozen for the whole run
    (a device's compute capability does not change round to round).  Drawn
    with numpy at bind time — no jax rng is consumed."""

    median: float = 1.0
    sigma: float = 0.5
    seed: int = 0

    def __post_init__(self):
        _positive("median", self.median)
        if self.sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")

    def bind(self, n: int) -> Callable:
        r = np.random.default_rng(self.seed)
        dt = jnp.asarray(
            (self.median * np.exp(self.sigma * r.standard_normal(n)))
            .astype(np.float32))
        return lambda round_idx: dt


@dataclasses.dataclass(frozen=True)
class StragglerStep(NodeTimeModel):
    """A two-tier population: a ``frac`` fraction of nodes (chosen once,
    numpy-seeded) is ``factor``x slower than the ``dt`` baseline — the
    BENCH_time straggler scenario (10% of nodes 8x slower) a synchronous
    engine cannot even express without stalling every round on the slowest
    device."""

    dt: float = 1.0
    frac: float = 0.1
    factor: float = 8.0
    seed: int = 0

    def __post_init__(self):
        _positive("dt", self.dt)
        _positive("factor", self.factor)
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError(f"frac must be in [0, 1], got {self.frac}")

    def slow_nodes(self, n: int) -> np.ndarray:
        """The straggler ids (deterministic in ``seed``; exposed so a bench
        can report per-tier accuracy)."""
        k = int(round(self.frac * n))
        if k == 0:
            return np.zeros((0,), np.int64)
        return np.sort(np.random.default_rng(self.seed)
                       .choice(n, size=k, replace=False))

    def bind(self, n: int) -> Callable:
        dt = np.full((n,), self.dt, np.float32)
        dt[self.slow_nodes(n)] *= self.factor
        dt_j = jnp.asarray(dt)
        return lambda round_idx: dt_j


@dataclasses.dataclass(frozen=True)
class TraceStep(NodeTimeModel):
    """Trace-table-driven step times: ``table[t, i]`` is node i's per-step
    seconds in round t (a recorded device-capacity trace).  Past the table
    end the ``past_end`` rule applies: ``"wrap"`` replays the trace
    periodically, ``"clamp"`` holds the last row."""

    table: Any  # [T, N] positive seconds (array-like)
    past_end: str = "wrap"

    def __post_init__(self):
        _check_past_end(self.past_end)
        tab = np.asarray(self.table, np.float32)
        if tab.ndim != 2 or tab.shape[0] < 1:
            raise ValueError(f"trace table must be [T >= 1, N], "
                             f"got shape {tab.shape}")
        if not (tab > 0).all():
            raise ValueError("trace step times must be strictly positive")

    def bind(self, n: int) -> Callable:
        tab = np.asarray(self.table, np.float32)
        if tab.shape[1] != n:
            raise ValueError(f"trace table covers {tab.shape[1]} nodes, "
                             f"world has {n}")
        tab_j = jnp.asarray(tab)
        t_len, past_end = int(tab.shape[0]), self.past_end

        def step_time(round_idx):
            return tab_j[past_end_index(round_idx, t_len, past_end)]

        return step_time


# ----------------------------------------------------------- link models

def _directed_edges(topo):
    """The canonical directed-edge enumeration both layouts share.

    Returns ``(src, dst, pair_id, num_pairs)`` with edges sorted by
    ``(dst, src)`` — exactly the CSR order of a `SparseTopology` and the
    flattened valid-slot order of the dense padded layout — and
    ``pair_id[e]`` the undirected pair's index in ascending ``(lo, hi)``
    order (the `repro.dynamics` coin enumeration)."""
    if isinstance(topo, SparseTopology):
        src = topo.edge_src.astype(np.int64)
        dst = topo.edge_dst.astype(np.int64)
    else:
        dst, src = np.nonzero(topo.adjacency)  # row-major = (dst, src) sort
        src, dst = src.astype(np.int64), dst.astype(np.int64)
    n = topo.num_nodes
    lo, hi = np.minimum(src, dst), np.maximum(src, dst)
    codes = np.unique(lo * n + hi)
    pair_id = np.searchsorted(codes, lo * n + hi)
    return src, dst, pair_id, int(codes.shape[0])


class LinkTimeModel:
    """Protocol: the seconds one payload needs to cross each directed edge.

    ``bind(topo, payload_bytes)`` returns the per-edge transfer time
    ``latency_e + payload_bytes / bandwidth_e`` as a ``[num_directed]``
    float32 numpy array in the canonical ``(dst, src)`` edge order of
    :func:`_directed_edges` — the engine scatters it into whichever layout
    it compiled."""

    def bind(self, topo, payload_bytes: float) -> np.ndarray:
        raise NotImplementedError


def _transfer(latency, bandwidth, payload_bytes: float) -> np.ndarray:
    lat = np.asarray(latency, np.float64)
    bw = np.asarray(bandwidth, np.float64)
    if (lat < 0).any():
        raise ValueError("latency must be >= 0")
    if not (bw > 0).all():
        raise ValueError("bandwidth must be > 0 (use float('inf') for an "
                         "infinitely fast link)")
    return (lat + payload_bytes / bw).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class ConstantLink(LinkTimeModel):
    """Every link: fixed ``latency`` seconds plus ``payload / bandwidth``
    transfer.  The default (zero latency, infinite bandwidth) is the other
    half of the degenerate model: every payload lands instantly."""

    latency: float = 0.0
    bandwidth: float = float("inf")  # bytes per second

    def bind(self, topo, payload_bytes: float) -> np.ndarray:
        src, _, _, _ = _directed_edges(topo)
        t = _transfer(self.latency, self.bandwidth, payload_bytes)
        return np.full((src.shape[0],), float(t), np.float32)


@dataclasses.dataclass(frozen=True)
class LognormalLink(LinkTimeModel):
    """Heterogeneous links: per-UNDIRECTED-pair lognormal latency and
    bandwidth draws (numpy, at bind time), mirrored onto both directed
    records so a link costs the same in both directions.  Draws are keyed
    by the canonical ascending ``(lo, hi)`` pair order, so the dense and
    sparse bindings of the same graph price every edge identically."""

    latency_median: float = 0.01
    latency_sigma: float = 0.5
    bandwidth_median: float = 1e6
    bandwidth_sigma: float = 0.5
    seed: int = 0

    def __post_init__(self):
        _positive("latency_median", self.latency_median)
        _positive("bandwidth_median", self.bandwidth_median)
        for nm, v in (("latency_sigma", self.latency_sigma),
                      ("bandwidth_sigma", self.bandwidth_sigma)):
            if v < 0:
                raise ValueError(f"{nm} must be >= 0, got {v}")

    def bind(self, topo, payload_bytes: float) -> np.ndarray:
        _, _, pair_id, m = _directed_edges(topo)
        r = np.random.default_rng(self.seed)
        lat = self.latency_median * np.exp(
            self.latency_sigma * r.standard_normal(m))
        bw = self.bandwidth_median * np.exp(
            self.bandwidth_sigma * r.standard_normal(m))
        return _transfer(lat, bw, payload_bytes)[pair_id]


@dataclasses.dataclass(frozen=True)
class TableLink(LinkTimeModel):
    """Explicit per-edge latency/bandwidth tables (recorded network
    traces).  Scalars broadcast; arrays are indexed by the canonical
    directed-edge enumeration (``(dst, src)``-sorted — CSR order), the same
    id that keys the per-edge transport's rng stream."""

    latency: Any = 0.0
    bandwidth: Any = float("inf")

    def bind(self, topo, payload_bytes: float) -> np.ndarray:
        src, _, _, _ = _directed_edges(topo)
        e = int(src.shape[0])
        lat = np.asarray(self.latency, np.float64)
        bw = np.asarray(self.bandwidth, np.float64)
        for nm, v in (("latency", lat), ("bandwidth", bw)):
            if v.ndim and v.shape != (e,):
                raise ValueError(
                    f"TableLink {nm} table has shape {v.shape}; the graph "
                    f"has {e} directed edges ((dst, src)-sorted)")
        return _transfer(np.broadcast_to(lat, (e,)),
                         np.broadcast_to(bw, (e,)), payload_bytes)


# ------------------------------------------------------------- the clock

class TimingState(NamedTuple):
    """The event clock's scan-carried state.

    ``t`` is the absolute simulated time (seconds since round 0);
    ``last_cost`` is the previous round's REALIZED per-node compute seconds
    (step time x trained steps) — the observation a drift-adaptive
    `GraphProcess` (e.g. ``EnergyChurn``) reads, one round delayed so the
    transition stays causal inside the scan."""

    t: jnp.ndarray          # scalar f32, absolute simulated seconds
    last_cost: jnp.ndarray  # [N] f32, last round's realized compute seconds


@dataclasses.dataclass(frozen=True)
class BoundTiming:
    """A `Timing` frozen against a topology (see `Timing.bind`)."""

    timing: "Timing"
    payload_bytes: float
    step_time: Callable        # (round_idx) -> [N] f32 seconds per step
    transfer_e: jnp.ndarray    # [num_directed] f32, canonical CSR order
    transfer_panel: Optional[jnp.ndarray]  # [N, max_deg] f32 (dense binding)
    state0: TimingState

    @property
    def is_dense(self) -> bool:
        return self.transfer_panel is not None


@dataclasses.dataclass(frozen=True)
class Timing:
    """The event-clock configuration: one node model + one link model.

    The default ``Timing()`` is the DEGENERATE model — uniform unit step
    time, zero latency, infinite bandwidth — which the engine must
    reproduce bit-identically to running with no timing at all (the oracle
    that makes the subsystem safe; `Schedule(deadline=None)` then just adds
    a simulated-seconds axis to the same run).  See docs/timing.md."""

    node: NodeTimeModel = dataclasses.field(default_factory=ConstantStep)
    link: LinkTimeModel = dataclasses.field(default_factory=ConstantLink)

    def bind(self, topo, payload_bytes: float) -> BoundTiming:
        """Freeze against ``topo`` (dense `Topology` or `SparseTopology`) and
        a per-payload byte size (the transport's exact ``payload_bytes``, or
        the dense fp32 model size without one)."""
        if not isinstance(self.node, NodeTimeModel):
            raise TypeError(f"Timing.node must be a NodeTimeModel, "
                            f"got {type(self.node).__name__}")
        if not isinstance(self.link, LinkTimeModel):
            raise TypeError(f"Timing.link must be a LinkTimeModel, "
                            f"got {type(self.link).__name__}")
        n = topo.num_nodes
        transfer = np.asarray(self.link.bind(topo, float(payload_bytes)),
                              np.float32)
        if isinstance(topo, SparseTopology):
            panel = None
        else:
            # scatter the canonical (dst, src)-ordered transfer times into
            # the padded receiver panel: slot e of receiver r is r's e-th
            # in-edge sender-ascending, i.e. canonical edge offsets[r] + e.
            valid = topo.neighbor_mask.astype(bool)
            deg = valid.sum(axis=1).astype(np.int64)
            offsets = np.concatenate([np.zeros(1, np.int64), np.cumsum(deg)])
            panel_np = np.zeros(valid.shape, np.float32)
            for r_i in range(n):
                panel_np[r_i, :deg[r_i]] = \
                    transfer[offsets[r_i]:offsets[r_i + 1]]
            panel = jnp.asarray(panel_np)
        state0 = TimingState(t=jnp.float32(0.0),
                             last_cost=jnp.zeros((n,), jnp.float32))
        return BoundTiming(timing=self, payload_bytes=float(payload_bytes),
                           step_time=self.node.bind(n),
                           transfer_e=jnp.asarray(transfer),
                           transfer_panel=panel, state0=state0)


NODE_MODELS = {
    "constant": ConstantStep,
    "lognormal": LognormalStep,
    "straggler": StragglerStep,
    "trace": TraceStep,
}

LINK_MODELS = {
    "constant": ConstantLink,
    "lognormal": LognormalLink,
    "table": TableLink,
}


def make_node_model(name: str, **kwargs) -> NodeTimeModel:
    """Build a catalog node model by name (kwargs to its constructor)."""
    try:
        cls = NODE_MODELS[name]
    except KeyError:
        raise ValueError(f"unknown node time model {name!r}; "
                         f"available: {sorted(NODE_MODELS)}") from None
    return cls(**kwargs)


def make_link_model(name: str, **kwargs) -> LinkTimeModel:
    """Build a catalog link model by name (kwargs to its constructor)."""
    try:
        cls = LINK_MODELS[name]
    except KeyError:
        raise ValueError(f"unknown link time model {name!r}; "
                         f"available: {sorted(LINK_MODELS)}") from None
    return cls(**kwargs)
