"""Pytree arithmetic helpers used across the framework.

Every model/optimizer state in this codebase is a plain pytree (nested dicts
of jnp arrays).  The decentralized-learning algorithms (DecDiff, DecAvg, CFA,
...) are defined as *pytree-level* operations so they are agnostic to the
architecture of the model being trained — an MLP on MNIST-like data and a
480B-parameter MoE use the exact same aggregation code paths.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    """Global inner product over all leaves (fp32 accumulation)."""
    parts = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, parts, jnp.float32(0.0))


def tree_sq_norm(a):
    """Global squared L2 norm over all leaves (fp32 accumulation)."""
    parts = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    return jax.tree.reduce(jnp.add, parts, jnp.float32(0.0))


def tree_l2_norm(a):
    return jnp.sqrt(tree_sq_norm(a))


def tree_l2_dist(a, b):
    return tree_l2_norm(tree_sub(a, b))


def tree_weighted_sum(trees, weights):
    """Sum_k weights[k] * trees[k].  `trees` is a list of like-structured
    pytrees; `weights` a 1-D array/list of scalars."""
    assert len(trees) == len(weights) and len(trees) > 0
    out = tree_scale(trees[0], weights[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = jax.tree.map(lambda o, x, _w=w: o + _w * x, out, t)
    return out


def tree_stack(trees):
    """Stack a list of like-structured pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    """Inverse of tree_stack: split the leading axis into a list of n trees."""
    return [jax.tree.map(lambda x, _i=i: x[_i], tree) for i in range(n)]


def tree_index(tree, i):
    """Take index i along the leading axis of every leaf."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_size(tree) -> int:
    """Total number of scalar parameters."""
    return int(sum(x.size for x in jax.tree.leaves(tree)))


def tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_flatten_to_vector(tree):
    """Concatenate all leaves into one flat fp32 vector (for analysis and the
    Pallas flat-stream kernels).  Returns (vector, unflatten_fn)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [l.size for l in leaves]
    vec = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves]) if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(v):
        out, off = [], 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            out.append(v[off : off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return vec, unflatten


def tree_flatten_stacked(tree):
    """Flatten a pytree with leaves [N, ...] into one [N, D] fp32 matrix.

    The per-node counterpart of `tree_flatten_to_vector`: row i is node i's
    whole model as a flat vector (the layout the comm codecs operate on).
    Returns (matrix, unflatten_fn); `unflatten_fn` accepts any [M, D] matrix
    (M need not equal N — e.g. decoding an all_gathered payload) and restores
    the original leaf shapes/dtypes behind the leading axis.
    """
    leaves, treedef = jax.tree.flatten(tree)
    assert leaves, "empty pytree"
    lead = leaves[0].shape[0]
    tails = [l.shape[1:] for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [math.prod(t) for t in tails]
    mat = jnp.concatenate(
        [l.reshape(lead, -1).astype(jnp.float32) for l in leaves], axis=1)

    def unflatten(m):
        out, off = [], 0
        for tail, dtype, size in zip(tails, dtypes, sizes):
            out.append(m[:, off:off + size]
                       .reshape((m.shape[0],) + tail).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return mat, unflatten


def tree_random_like(rng, tree, scale=1.0):
    """Random-normal pytree with the same structure/shapes (for tests)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(rng, max(len(leaves), 1))
    new = [
        (jax.random.normal(k, l.shape, jnp.float32) * scale).astype(l.dtype)
        for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, new)
