import os
import sys

# src layout without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run on the real (single) CPU device — the 512-device override is
# dryrun.py-only by design.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
