import os
import sys

import pytest

# src layout without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run on the real (single) CPU device — the 512-device override is
# dryrun.py-only by design.
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_collection_modifyitems(config, items):
    """Keep the property-fuzz lane out of tier-1: `fuzz`-marked tests only
    run when explicitly selected (pytest -m fuzz), so the exact ROADMAP
    tier-1 command stays fast and dependency-light.  The marker is named
    `fuzz`, NOT `hypothesis`, because the hypothesis pytest plugin
    auto-applies a `hypothesis` marker to every @given test — reusing that
    name would silently deselect the pre-existing property tests from
    tier-1 wherever hypothesis is installed."""
    markexpr = config.getoption("-m", default="") or ""
    if "fuzz" in markexpr:
        return
    skip = pytest.mark.skip(reason="property-fuzz lane: run `pytest -m fuzz`")
    for item in items:
        if "fuzz" in item.keywords:
            item.add_marker(skip)
