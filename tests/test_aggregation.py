"""Baseline aggregators: DecAvg (Eq. 4), CFA (Eq. 9), FedAvg, CFA-GE step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    cfa_aggregate,
    cfa_ge_gradient_step,
    decavg_aggregate,
    fedavg_aggregate,
    get_aggregator,
    isolation_aggregate,
)
from repro.utils.pytree import tree_l2_dist, tree_random_like, tree_stack


def _tree(seed, scale=1.0):
    proto = {"w": jnp.zeros((3, 4)), "b": jnp.zeros((5,))}
    return tree_random_like(jax.random.PRNGKey(seed), proto, scale=scale)


def test_decavg_is_convex_combination():
    local = _tree(0)
    n1, n2 = _tree(1), _tree(2)
    out = decavg_aggregate(local, tree_stack([n1, n2]), jnp.asarray([1.0, 1.0]),
                           self_weight=1.0)
    expect = jax.tree.map(lambda a, b, c: (a + b + c) / 3, local, n1, n2)
    assert tree_l2_dist(out, expect) < 1e-5


def test_decavg_weights():
    local = _tree(0)
    n1, n2 = _tree(1), _tree(2)
    out = decavg_aggregate(local, tree_stack([n1, n2]), jnp.asarray([3.0, 1.0]),
                           self_weight=0.0)
    expect = jax.tree.map(lambda a, b: 0.75 * a + 0.25 * b, n1, n2)
    assert tree_l2_dist(out, expect) < 1e-5


def test_cfa_eps_full_consensus_two_nodes():
    """With one neighbour, eps = 1/1 moves exactly to the neighbour's model."""
    local, other = _tree(0), _tree(1)
    out = cfa_aggregate(local, tree_stack([other]), jnp.ones(1))
    assert tree_l2_dist(out, other) < 1e-5


def test_cfa_fixed_point_at_consensus():
    local = _tree(0)
    out = cfa_aggregate(local, tree_stack([local, local]), jnp.ones(2))
    assert tree_l2_dist(out, local) < 1e-6


def test_cfa_masked_all_keeps_local():
    local = _tree(0)
    out = cfa_aggregate(local, tree_stack([_tree(1)]), jnp.ones(1),
                        mask=jnp.zeros(1))
    assert tree_l2_dist(out, local) == 0.0


def test_fedavg_weighted():
    m1, m2 = _tree(1), _tree(2)
    out = fedavg_aggregate(tree_stack([m1, m2]), jnp.asarray([3.0, 1.0]))
    expect = jax.tree.map(lambda a, b: 0.75 * a + 0.25 * b, m1, m2)
    assert tree_l2_dist(out, expect) < 1e-5


def test_decavg_on_complete_graph_equals_fedavg():
    """DecAvg on a complete graph with p_ij data weights == server FedAvg."""
    models = [_tree(i) for i in range(4)]
    sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0])
    fed = fedavg_aggregate(tree_stack(models), sizes)
    # node 0's neighbourhood = {1,2,3}; self weight = own size
    out0 = decavg_aggregate(models[0], tree_stack(models[1:]), sizes[1:],
                            self_weight=sizes[0])
    assert tree_l2_dist(fed, out0) < 1e-5


def test_cfa_ge_gradient_step():
    local = _tree(0)
    g1, g2 = _tree(3, 0.1), _tree(4, 0.1)
    out = cfa_ge_gradient_step(local, tree_stack([g1, g2]),
                               jnp.asarray([1.0, 1.0]), lr=0.5)
    expect = jax.tree.map(lambda p, a, b: p - 0.5 * (a + b) / 2, local, g1, g2)
    assert tree_l2_dist(out, expect) < 1e-5


def test_isolation_identity():
    local = _tree(0)
    assert isolation_aggregate(local, None, None) is local


def test_registry():
    assert get_aggregator("decdiff") is not None
    try:
        get_aggregator("bogus")
        raise AssertionError
    except ValueError:
        pass
