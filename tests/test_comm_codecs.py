"""Property tests for the comm codecs (repro.comm.codecs) and the trigger.

Three contracts, each checked two ways: deterministic seeded sweeps that run
in tier-1, and hypothesis fuzz versions (marked `fuzz`) that run in the
dedicated CI lane (`pytest -m fuzz`) so tier-1 stays fast:

  1. decode∘encode error bounds — exact (fp32), one-ulp relative (bf16),
     one quantization grain (int8), exact-on-support (top-k);
  2. bytes_on_wire exactness — the reported count equals the byte length of
     the serialized payload AND the shape-only static prediction;
  3. the error-feedback invariant — residual' + decode(payload) equals the
     pre-compression input + residual, up to the quantization grain, so
     compression delays information but never destroys it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import drift_gate, edge_delivery, make_codec, payload_nbytes

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container lane: tier-1 runs the seeded sweeps only
    HAVE_HYPOTHESIS = False

ALL_CODECS = ("fp32", "bf16", "int8", "topk")


def _codec(name):
    # deterministic int8 so the seeded sweeps are reproducible; the
    # stochastic mode gets its own unbiasedness test below.
    return make_codec(name, **({"stochastic": False} if name == "int8" else {}))


def _vec(seed, n, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)


def serialized_nbytes(payload) -> int:
    """Ground truth: actually serialize every leaf to raw bytes."""
    return sum(len(np.asarray(x).tobytes()) for x in jax.tree.leaves(payload))


# ---------------------------------------------------------------- contracts


def check_bytes_exact(codec, v):
    res = codec.init_residual(v)
    payload, _ = codec.encode(v, rng=jax.random.PRNGKey(0), residual=res)
    got = codec.bytes_on_wire(payload)
    assert got == serialized_nbytes(payload)
    assert got == codec.payload_bytes_for(int(v.shape[0]))
    assert got == payload_nbytes(payload)


def check_roundtrip_bound(name, codec, v):
    n = int(v.shape[0])
    payload, _ = codec.encode(v, residual=None)
    d = np.asarray(codec.decode(payload, out_size=n), np.float32)
    x = np.asarray(v, np.float32)
    if name == "fp32":
        assert np.array_equal(d, x)
    elif name == "bf16":
        # one bf16 ulp relative; atol floor for the subnormal range
        np.testing.assert_allclose(d, x, rtol=1.0 / 128, atol=1e-37)
    elif name == "int8":
        amax = np.max(np.abs(x))
        grain = (amax / 127.0) if amax > 0 else 1.0
        assert np.max(np.abs(d - x)) <= grain * (1 + 1e-5)
    elif name == "topk":
        # decoded entries are exact copies of the input on their support
        nz = d != 0
        assert np.array_equal(d[nz], x[nz])
        assert np.count_nonzero(nz) <= codec.k_for(n)


def check_ef_invariant(name, codec, v, res):
    if not codec.has_residual:
        return
    payload, res2 = codec.encode(v, residual=res)
    d = codec.decode(payload, out_size=int(v.shape[0]))
    x = np.asarray(v, np.float32) + np.asarray(res, np.float32)
    recon = np.asarray(res2, np.float32) + np.asarray(d, np.float32)
    if name == "topk":
        np.testing.assert_array_equal(recon, x)  # bitwise: scatter/gather
    else:
        amax = np.max(np.abs(x))
        grain = (amax / 127.0) if amax > 0 else 1.0
        assert np.max(np.abs(recon - x)) <= grain * 1e-4 + 1e-30


# ------------------------------------------------- tier-1 seeded sweeps


@pytest.mark.parametrize("name", ALL_CODECS)
@pytest.mark.parametrize("n,seed", [(17, 0), (1000, 1), (4096, 2)])
def test_bytes_on_wire_exact(name, n, seed):
    check_bytes_exact(_codec(name), _vec(seed, n))


@pytest.mark.parametrize("name", ALL_CODECS)
@pytest.mark.parametrize("n,seed,scale", [(17, 0, 1.0), (1000, 1, 100.0),
                                          (4096, 2, 1e-3)])
def test_roundtrip_error_bound(name, n, seed, scale):
    check_roundtrip_bound(name, _codec(name), _vec(seed, n, scale))


@pytest.mark.parametrize("name", ("int8", "topk"))
@pytest.mark.parametrize("n,seed", [(33, 3), (1000, 4)])
def test_error_feedback_invariant(name, n, seed):
    codec = _codec(name)
    v = _vec(seed, n)
    res = _vec(seed + 100, n, 0.3)
    check_ef_invariant(name, codec, v, res)


def test_compression_ratios():
    """The wire sizes behind the frontier: bf16 2x, int8 ~4x, top-k ~1/ratio."""
    d = 10_000
    fp32 = _codec("fp32").payload_bytes_for(d)
    assert fp32 == 4 * d
    assert _codec("bf16").payload_bytes_for(d) == 2 * d
    assert _codec("int8").payload_bytes_for(d) == d + 4  # + one fp32 scale
    topk = make_codec("topk", ratio=0.01)
    assert topk.payload_bytes_for(d) == 8 * topk.k_for(d) + 4  # idx+val, +len
    assert fp32 / _codec("int8").payload_bytes_for(d) > 3.9


def test_int8_stochastic_rounding_unbiased():
    """E[decode(encode(x))] == x for the stochastic quantizer."""
    codec = make_codec("int8", stochastic=True)
    v = _vec(7, 256)
    keys = jax.random.split(jax.random.PRNGKey(0), 512)

    def enc_dec(key):
        p, _ = codec.encode(v, rng=key)
        return codec.decode(p)

    mean = np.asarray(jnp.mean(jax.vmap(enc_dec)(keys), axis=0))
    grain = float(jnp.max(jnp.abs(v))) / 127.0
    # the mean must beat the deterministic worst case by a wide margin
    assert np.max(np.abs(mean - np.asarray(v))) < 0.2 * grain


def test_topk_picks_largest_magnitudes():
    codec = make_codec("topk", ratio=0.1)
    v = jnp.asarray(np.r_[np.zeros(90), np.arange(1, 11)], jnp.float32)
    payload, _ = codec.encode(v)
    assert sorted(np.asarray(payload["idx"]).tolist()) == list(range(90, 100))


def test_trigger_gate_semantics():
    w = jnp.asarray([[1.0, 0.0], [0.0, 0.0], [3.0, 4.0]], jnp.float32)
    last = jnp.zeros_like(w)
    gate0, drift = drift_gate(w, last, 0.0)
    assert np.array_equal(np.asarray(gate0), [1, 1, 1])  # 0 = always send
    np.testing.assert_allclose(np.asarray(drift), [1.0, 0.0, 5.0])
    gate2, _ = drift_gate(w, last, 2.0)
    assert np.array_equal(np.asarray(gate2), [0, 0, 1])
    # monotone: raising the threshold never turns a silent node into a sender
    gate9, _ = drift_gate(w, last, 9.0)
    assert np.all(np.asarray(gate9) <= np.asarray(gate2))


def test_edge_delivery_composes_gate_and_links():
    gate = jnp.asarray([1.0, 0.0, 1.0])
    nbr_idx = jnp.asarray([[1, 2], [0, 2], [0, 1]], jnp.int32)
    link = jnp.asarray([[1.0, 1.0], [1.0, 0.0], [0.0, 1.0]], jnp.float32)
    m = np.asarray(edge_delivery(gate, link, nbr_idx))
    # node0 hears: nbr1 (silent) -> 0, nbr2 (sent, link up) -> 1
    # node1 hears: nbr0 (sent) -> 1, nbr2 (sent, link DOWN) -> 0
    # node2 hears: nbr0 (sent, link DOWN) -> 0, nbr1 (silent) -> 0
    assert np.array_equal(m, [[0, 1], [1, 0], [0, 0]])


# --------------------------------------------------- hypothesis fuzz lane


if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=-1e30, max_value=1e30,
                       allow_nan=False, allow_infinity=False, width=32)
    vectors = st.lists(finite, min_size=1, max_size=300).map(
        lambda xs: jnp.asarray(xs, jnp.float32))

    @pytest.mark.fuzz
    @pytest.mark.parametrize("name", ALL_CODECS)
    @settings(max_examples=50, deadline=None)
    @given(v=vectors)
    def test_fuzz_bytes_on_wire_exact(name, v):
        check_bytes_exact(_codec(name), v)

    @pytest.mark.fuzz
    @pytest.mark.parametrize("name", ALL_CODECS)
    @settings(max_examples=50, deadline=None)
    @given(v=vectors)
    def test_fuzz_roundtrip_error_bound(name, v):
        check_roundtrip_bound(name, _codec(name), v)

    @pytest.mark.fuzz
    @pytest.mark.parametrize("name", ("int8", "topk"))
    @settings(max_examples=50, deadline=None)
    @given(v=vectors, seed=st.integers(0, 2**31 - 1))
    def test_fuzz_error_feedback_invariant(name, v, seed):
        res = jnp.asarray(
            np.random.default_rng(seed).standard_normal(v.shape[0]) * 0.3,
            jnp.float32)
        check_ef_invariant(name, _codec(name), v, res)
