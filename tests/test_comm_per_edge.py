"""Per-edge transport semantics (repro.comm.EdgeGossipTransport).

The contracts this file pins:

  1. equivalence floor — per-edge state with the fp32 codec, threshold 0
     and the fixed policy reproduces the legacy per-node round bit-for-bit
     (same rng stream, same aggregation);
  2. isolation — a Bernoulli failure on link (i, j) leaves every OTHER
     link's error-feedback residual and reference bit-identical, and leaves
     (i, j)'s own state exactly at its pre-round value (nothing was
     delivered, so nothing advances);
  3. adaptation — the per-edge drift-rate controller converges each link's
     long-run triggered fraction to `target_trigger` on a seeded world, and
     the pure update rule moves thresholds in the right direction;
  4. momentum top-k — the EF invariant still holds on the residual row,
     momentum = 0 degenerates to plain magnitude selection, and persistent
     coordinates accumulate selection pressure.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    CommConfig,
    EdgeGossipTransport,
    adaptive_threshold_update,
    edge_drift_gate,
    make_codec,
)
from repro.utils.pytree import tree_flatten_stacked


# ------------------------------------------------------------ construction


def _ring4():
    from repro.graphs import make_topology

    topo = make_topology("ring", n=4)
    return topo.neighbor_idx, topo.neighbor_mask


def _stacked_models(n, d=96, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((n, d)), jnp.float32)}


def test_config_validation():
    with pytest.raises(ValueError):
        CommConfig(policy="nope")
    with pytest.raises(ValueError):
        CommConfig(policy="adaptive", target_trigger=0.0)
    assert CommConfig(policy="adaptive").use_per_edge
    assert CommConfig(per_edge=True).use_per_edge
    assert not CommConfig().use_per_edge


def test_edge_state_layout_and_reverse_slots():
    nbr_idx, nbr_mask = _ring4()
    params = _stacked_models(4)
    tr = EdgeGossipTransport(CommConfig(codec="int8", per_edge=True,
                                        stochastic=False),
                             params, nbr_idx, nbr_mask)
    st = tr.init_state(params)
    assert st.last_sent.shape == (4, 2, 96)   # [N, max_deg, D]
    assert st.residual.shape == (4, 2, 96)
    assert st.threshold.shape == (4, 2)
    # reverse slots really invert the neighbour map on every valid edge
    idx = np.asarray(nbr_idx)
    rev = np.asarray(tr.rev_slot)
    for r in range(4):
        for e in range(2):
            j = idx[r, e]
            assert idx[j, rev[r, e]] == r


# ---------------------------------------------------------------- isolation


def _one_exchange(link_mask, seed=0):
    """One int8 exchange on the 4-ring with a chosen link mask."""
    nbr_idx, nbr_mask = _ring4()
    params = _stacked_models(4, seed=seed)
    tr = EdgeGossipTransport(CommConfig(codec="int8", per_edge=True,
                                        stochastic=False),
                             params, nbr_idx, nbr_mask)
    state = tr.init_state(params)
    # advance one clean round first so residuals are non-trivial
    full = jnp.asarray(nbr_mask, jnp.float32)
    _, _, _, state = tr.exchange(params, state, full)
    params2 = {"w": params["w"] + 0.1 * jnp.asarray(
        np.random.default_rng(seed + 1).standard_normal(params["w"].shape),
        jnp.float32)}
    gathered, mask, gate, new_state = tr.exchange(params2, state,
                                                  jnp.asarray(link_mask,
                                                              jnp.float32))
    return tr, state, new_state, gathered, mask


def test_failing_link_leaves_sibling_residuals_bit_identical():
    """The tentpole isolation contract: dropping (i, j) must not perturb the
    error-feedback state of any other link — in particular (i, k), which
    shares the sender — and must leave (i, j)'s own state at its pre-round
    value."""
    nbr_idx, _ = _ring4()
    full = np.ones((4, 2), np.float32)
    # receiver-layout mask: kill the (sender 1 -> receiver 0) link, i.e.
    # receiver 0's slot holding neighbour 1.
    idx = np.asarray(nbr_idx)
    (slot,) = np.nonzero(idx[0] == 1)[0:1]
    failed = full.copy()
    failed[0, slot[0]] = 0.0

    tr, before, clean, _, _ = _one_exchange(full)
    tr2, before2, broken, _, _ = _one_exchange(failed)
    # identical histories up to the failure
    assert np.array_equal(np.asarray(before.residual),
                          np.asarray(before2.residual))

    # sender 1's slot toward receiver 0:
    (d_fail,) = np.nonzero(idx[1] == 0)[0:1]
    d_fail = int(d_fail[0])
    res_clean = np.asarray(clean.residual)
    res_broken = np.asarray(broken.residual)
    last_clean = np.asarray(clean.last_sent)
    last_broken = np.asarray(broken.last_sent)
    for i in range(4):
        for d in range(2):
            if (i, d) == (1, d_fail):
                continue
            # every sibling link: bit-identical state with and without the
            # failure (per-node PR-2 state could not satisfy this: one
            # shared residual per sender)
            assert np.array_equal(res_clean[i, d], res_broken[i, d]), (i, d)
            assert np.array_equal(last_clean[i, d], last_broken[i, d]), (i, d)
    # the failed link delivered nothing: its state is its pre-round value
    assert np.array_equal(res_broken[1, d_fail],
                          np.asarray(before.residual)[1, d_fail])
    assert np.array_equal(last_broken[1, d_fail],
                          np.asarray(before.last_sent)[1, d_fail])
    # ... while the clean run advanced it
    assert not np.array_equal(last_clean[1, d_fail], last_broken[1, d_fail])


def test_stale_cache_is_what_the_receiver_last_got():
    """Receiver-side staleness: after a failure on (j -> r), the gathered
    model for that slot is the reconstruction of j's PREVIOUS delivery (the
    receiver's own cache — exactly what the per-node transport cannot
    track), while the exogenous failure itself still drops the slot from
    this round's aggregation (a loss, not a decision)."""
    nbr_idx, _ = _ring4()
    idx = np.asarray(nbr_idx)
    full = np.ones((4, 2), np.float32)
    (slot,) = np.nonzero(idx[0] == 1)[0:1]
    slot = int(slot[0])
    failed = full.copy()
    failed[0, slot] = 0.0
    tr, before, after, gathered, mask = _one_exchange(failed)
    (d_fail,) = np.nonzero(idx[1] == 0)[0:1]
    d_fail = int(d_fail[0])
    got = np.asarray(gathered["w"])[0, slot]
    want = np.asarray(before.last_sent)[1, d_fail]  # round-1 reconstruction
    assert np.array_equal(got, want)
    # round 1 delivered on every link, so the stale mask keeps the slot on
    assert float(np.asarray(mask)[0, slot]) == 0.0  # exogenous drop masks
    # the drop composes exogenously; a *silent* (un-fired) edge would pass
    # ever_delivered and stay aggregated — covered by the simulator test.


def test_per_edge_fp32_thr0_is_bitexact_vs_legacy():
    """Equivalence floor: per-edge state + fp32 codec + threshold 0 + fixed
    policy is bit-for-bit the legacy per-node transport round (which is
    itself bit-for-bit the pre-comm round) — same rng stream, same
    participation draws, same aggregation."""
    from tests.test_decdiff_mask import _tiny_sim

    legacy = _tiny_sim(CommConfig(codec="fp32", trigger_threshold=0.0))
    edge = _tiny_sim(CommConfig(codec="fp32", trigger_threshold=0.0,
                                per_edge=True))
    for a, b in zip(jax.tree.leaves(legacy.params),
                    jax.tree.leaves(edge.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # edge accounting saw every directed edge fire every round
    assert edge._trig_sum == edge._comm_rounds
    # same wire bytes: broadcast (payload x outdeg) == unicast (payload/edge)
    assert edge.comm_bytes_total == legacy.comm_bytes_total > 0


# --------------------------------------------------------------- adaptation


def test_edge_drift_gate_per_edge_thresholds():
    w = jnp.asarray([[3.0, 4.0], [0.0, 0.0]], jnp.float32)
    last = jnp.zeros((2, 2, 2), jnp.float32)
    thr = jnp.asarray([[1.0, 6.0], [0.0, 0.0]], jnp.float32)
    valid = jnp.asarray([[1.0, 1.0], [1.0, 0.0]], jnp.float32)
    gate, drift = edge_drift_gate(w, last, thr, valid)
    np.testing.assert_allclose(np.asarray(drift),
                               [[5.0, 5.0], [0.0, 0.0]])
    # node 0: slot 0 fires (5 >= 1), slot 1 silent (5 < 6) — per-edge!
    # node 1: zero drift >= zero threshold fires; padding never fires.
    assert np.array_equal(np.asarray(gate), [[1.0, 0.0], [1.0, 0.0]])


def test_adaptive_update_moves_thresholds_toward_target_rate():
    thr = jnp.full((1, 2), 1.0, jnp.float32)
    ema = jnp.full((1, 2), 2.0, jnp.float32)
    drift = jnp.full((1, 2), 2.0, jnp.float32)
    valid = jnp.ones((1, 2), jnp.float32)
    fired = jnp.asarray([[1.0, 0.0]], jnp.float32)
    new_thr, new_ema = adaptive_threshold_update(
        thr, ema, drift, fired, valid, target=0.5, ema_beta=0.9, rate=0.5)
    got = np.asarray(new_thr)
    assert got[0, 0] > 1.0   # fired -> threshold rises
    assert got[0, 1] < 1.0   # silent -> threshold falls
    # symmetric at target 0.5: equal and opposite steps
    np.testing.assert_allclose(got[0, 0] - 1.0, 1.0 - got[0, 1], rtol=1e-6)
    # padding slots stay frozen
    pad_thr, pad_ema = adaptive_threshold_update(
        thr, ema, drift, fired, jnp.zeros_like(valid),
        target=0.5, ema_beta=0.9, rate=0.5)
    assert np.array_equal(np.asarray(pad_thr), np.asarray(thr))
    assert np.array_equal(np.asarray(pad_ema), np.asarray(ema))
    # the EMA seeds from the first observed drift instead of creeping from 0
    _, ema0 = adaptive_threshold_update(
        jnp.zeros((1, 1)), jnp.zeros((1, 1)), jnp.full((1, 1), 3.0),
        jnp.ones((1, 1)), jnp.ones((1, 1)), target=0.5, ema_beta=0.9,
        rate=0.5)
    np.testing.assert_allclose(np.asarray(ema0), [[3.0]])


def test_adaptive_threshold_converges_to_target_triggered_fraction():
    """The satellite convergence contract: on a seeded world the per-edge
    controller steers the long-run triggered fraction to target_trigger."""
    from repro.engine import Experiment, Schedule, World
    from repro.graphs import make_topology
    from repro.models.mlp_cnn import make_mlp

    topo = make_topology("ring", n=4)
    world = World.synthetic(dataset="synth-mnist", nodes=4, topology="ring",
                            seed=3, scale=0.02,
                            model=make_mlp(num_classes=10, hidden=(32,)))
    target = 0.5
    sim = Experiment(
        world, "decdiff+vt",
        comm=CommConfig(codec="int8", policy="adaptive",
                        target_trigger=target),
        schedule=Schedule(rounds=30, eval_every=50),
        steps_per_round=2, batch_size=16, lr=0.1, momentum=0.9, seed=3)
    sim.run()
    trig = np.asarray(sim.trig_history)
    assert trig[0] == 1.0                      # always-send bootstrap
    late = float(trig[-10:].mean())
    assert abs(late - target) < 0.2, trig      # converged near target
    assert 0.0 < late < 1.0                    # and genuinely gating
    # thresholds adapted away from the zero bootstrap on every real edge
    thr = np.asarray(sim.comm_state.threshold)
    valid = np.asarray(topo.neighbor_mask) > 0
    assert (thr[valid] > 0).all()


# ------------------------------------------------------------ momentum topk


def test_topk_momentum_zero_degenerates_to_plain_topk():
    plain = make_codec("topk", ratio=0.1)
    mom0 = make_codec("topk", ratio=0.1, momentum=0.0)
    v = jnp.asarray(np.random.default_rng(0).standard_normal(100), jnp.float32)
    res = plain.init_residual(v)
    assert res.shape == (100,)  # legacy [D] state
    p1, r1 = plain.encode(v, residual=res)
    p2, r2 = mom0.encode(v, residual=res)
    assert np.array_equal(np.asarray(p1["idx"]), np.asarray(p2["idx"]))
    assert np.array_equal(np.asarray(r1), np.asarray(r2))


def test_topk_momentum_ef_invariant_on_residual_row():
    """decode(payload) + residual'[0] == input + residual[0] — compression
    still only delays information; the score row never touches the wire."""
    codec = make_codec("topk", ratio=0.1, momentum=0.9)
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.standard_normal(200), jnp.float32)
    res = jnp.asarray(np.stack([rng.standard_normal(200) * 0.3,
                                np.abs(rng.standard_normal(200))]),
                      jnp.float32)
    payload, new_res = codec.encode(v, residual=res)
    assert new_res.shape == (2, 200)
    dec = codec.decode(payload, out_size=200)
    recon = np.asarray(new_res)[0] + np.asarray(dec)
    want = np.asarray(v) + np.asarray(res)[0]
    np.testing.assert_array_equal(recon, want)  # bitwise: scatter/gather


def test_topk_momentum_accumulates_selection_pressure():
    """A coordinate that keeps mattering wins a slot: with k=1, a persistent
    runner-up beats a rotating cast of transient spikes once its score
    momentum has built up."""
    codec = make_codec("topk", ratio=0.01, momentum=0.9)  # k=1 on size 100
    res = codec.init_residual(jnp.zeros((100,), jnp.float32))
    picked = []
    rng = np.random.default_rng(2)
    for t in range(6):
        x = np.zeros(100, np.float32)
        x[50] = 1.0                      # persistent medium coordinate
        x[int(rng.integers(0, 50))] = 1.5  # transient larger spike
        payload, res = codec.encode(jnp.asarray(x), residual=res)
        picked.append(int(np.asarray(payload["idx"])[0]))
        # drop the EF accumulation between steps to isolate score dynamics
        res = res.at[0].set(0.0)
    assert picked[0] != 50      # first round: raw magnitude wins
    assert 50 in picked[1:]     # momentum eventually promotes the persistent one
    plain = make_codec("topk", ratio=0.01)
    res_p = plain.init_residual(jnp.zeros((100,), jnp.float32))
    x = np.zeros(100, np.float32)
    x[50], x[10] = 1.0, 1.5
    payload, _ = plain.encode(jnp.asarray(x), residual=res_p)
    assert int(np.asarray(payload["idx"])[0]) == 10  # plain never promotes


def test_per_edge_transport_momentum_topk_runs():
    """End-to-end: per-edge state threads the [2, D] momentum residual."""
    nbr_idx, nbr_mask = _ring4()
    params = _stacked_models(4)
    tr = EdgeGossipTransport(
        CommConfig(codec="topk", per_edge=True, topk_ratio=0.1,
                   topk_momentum=0.9),
        params, nbr_idx, nbr_mask)
    st = tr.init_state(params)
    assert st.residual.shape == (4, 2, 2, 96)  # [N, E, 2(ef,score), D]
    link = jnp.asarray(nbr_mask, jnp.float32)
    gathered, mask, gate, st2 = tr.exchange(params, st, link)
    assert gathered["w"].shape == (4, 2, 96)
    assert np.asarray(gate).sum() == 8  # zero thresholds: all edges fire


# --------------------------------------------------- gathered-payload check


def test_exchange_gathers_the_senders_edge_reconstruction():
    """gathered[r, e] must equal sender nbr_idx[r, e]'s reconstruction on
    the slot pointing back at r (fp32: the sender's model itself)."""
    nbr_idx, nbr_mask = _ring4()
    params = _stacked_models(4, seed=5)
    tr = EdgeGossipTransport(CommConfig(codec="fp32", per_edge=True),
                             params, nbr_idx, nbr_mask)
    st = tr.init_state(params)
    gathered, mask, gate, st2 = tr.exchange(
        params, st, jnp.asarray(nbr_mask, jnp.float32))
    w, _ = tree_flatten_stacked(params)
    idx = np.asarray(nbr_idx)
    for r in range(4):
        for e in range(2):
            np.testing.assert_array_equal(
                np.asarray(gathered["w"])[r, e], np.asarray(w)[idx[r, e]])
    assert np.asarray(mask).min() == 1.0  # all delivered -> all aggregated
