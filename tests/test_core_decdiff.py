"""Unit + property tests for DecDiff (paper Eq. 5-6)."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (a dev dependency; CI installs it)")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.decdiff import (
    decdiff_aggregate,
    decdiff_aggregate_stacked,
    decdiff_step,
    neighborhood_average,
)
from repro.utils.pytree import (
    tree_l2_dist,
    tree_random_like,
    tree_stack,
)


def _tree(seed, scale=1.0):
    proto = {"a": jnp.zeros((4, 5)), "b": {"w": jnp.zeros((7,)), "v": jnp.zeros((2, 3))}}
    return tree_random_like(jax.random.PRNGKey(seed), proto, scale=scale)


def test_average_excludes_local_model():
    """Eq. 6 averages only the neighbours (w̄ is a reference point)."""
    n1, n2 = _tree(1), _tree(2)
    avg = neighborhood_average([n1, n2], [1.0, 1.0])
    expect = jax.tree.map(lambda a, b: (a + b) / 2, n1, n2)
    assert tree_l2_dist(avg, expect) < 1e-6


def test_fixed_point_at_average():
    """w == w̄ -> step is exactly zero (0/(0+s))."""
    w = _tree(0)
    out = decdiff_step(w, w)
    assert tree_l2_dist(out, w) == 0.0


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 2**16), scale=st.floats(0.01, 100.0),
       s=st.floats(1.0, 10.0))
def test_never_overshoots(seed, scale, s):
    """Applied step length = d/(d+s) < d: the update never crosses w̄."""
    w = _tree(seed, scale=1.0)
    wbar = _tree(seed + 1, scale=scale)
    d = float(tree_l2_dist(wbar, w))
    out = decdiff_step(w, wbar, s=s)
    step_len = float(tree_l2_dist(out, w))
    assert step_len <= d + 1e-4
    # exact scale: step = d/(d+s)
    np.testing.assert_allclose(step_len, d / (d + s), rtol=2e-4)


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 2**16), s=st.floats(1.0, 4.0))
def test_step_monotone_toward_average(seed, s):
    """After the update the distance to w̄ strictly decreases (d > 0)."""
    w, wbar = _tree(seed), _tree(seed + 7, scale=3.0)
    out = decdiff_step(w, wbar, s=s)
    assert float(tree_l2_dist(out, wbar)) < float(tree_l2_dist(w, wbar))


def test_far_models_move_less_relative():
    """The farther w̄ is, the smaller the applied scale 1/(d+s) — the
    anti-disruption property motivating the design."""
    w = _tree(0)
    far_target = _tree(1, scale=100.0)
    far = decdiff_step(w, far_target)
    # absolute step is bounded by 1 in both cases; relative progress differs
    d_far = float(tree_l2_dist(far_target, w))
    prog_far = 1.0 - float(tree_l2_dist(far, far_target)) / d_far
    assert prog_far < 0.2  # tiny relative progress for far models


def test_stacked_matches_list_variant():
    w = _tree(0)
    neighbors = [_tree(i + 1) for i in range(3)]
    weights = [1.0, 2.0, 0.5]
    a = decdiff_aggregate(w, neighbors, weights)
    b = decdiff_aggregate_stacked(w, tree_stack(neighbors), jnp.asarray(weights))
    assert tree_l2_dist(a, b) < 1e-5


def test_stacked_mask_drops_neighbors():
    w = _tree(0)
    neighbors = [_tree(1), _tree(2), _tree(3)]
    full = decdiff_aggregate(w, neighbors[:2], [1.0, 1.0])
    masked = decdiff_aggregate_stacked(
        w, tree_stack(neighbors), jnp.ones(3), mask=jnp.asarray([1, 1, 0]))
    assert tree_l2_dist(full, masked) < 1e-5


def test_all_masked_keeps_local():
    w = _tree(0)
    out = decdiff_aggregate_stacked(
        w, tree_stack([_tree(1)]), jnp.ones(1), mask=jnp.zeros(1))
    assert tree_l2_dist(out, w) == 0.0


def test_empty_neighborhood_keeps_local():
    w = _tree(0)
    assert decdiff_aggregate(w, [], []) is w
