"""Delivery-mask semantics of DecDiff (paper's no-synchronization assumption).

The paper never requires a synchronized round: a node aggregates whatever
subset of its neighbourhood actually delivered a model.  These tests pin the
two contractual consequences: a masked neighbour has ZERO influence on the
result, and a node that hears from nobody keeps its local model bit-exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decdiff import decdiff_aggregate, decdiff_aggregate_stacked
from repro.dist.dfl_step import build_dfl_round_shardmap, decdiff_gossip
from repro.utils.pytree import tree_index, tree_l2_dist, tree_random_like, tree_stack


def _models(n, seed=0):
    proto = {"w": jnp.zeros((4, 8)), "b": {"v": jnp.zeros((16,))}}
    return [tree_random_like(jax.random.PRNGKey(seed + i), proto)
            for i in range(n)]


def test_all_zero_mask_returns_local_unchanged():
    local, *neighbors = _models(4)
    out = decdiff_aggregate_stacked(local, tree_stack(neighbors),
                                    [1.0, 1.0, 1.0], mask=[0.0, 0.0, 0.0])
    assert float(tree_l2_dist(out, local)) == 0.0


def test_masked_neighbour_never_influences_result():
    local, n1, n2, intruder = _models(4)
    want = decdiff_aggregate(local, [n1, n2], [1.0, 2.0])
    out = decdiff_aggregate_stacked(local, tree_stack([n1, n2, intruder]),
                                    [1.0, 2.0, 5.0], mask=[1.0, 1.0, 0.0])
    assert float(tree_l2_dist(out, want)) < 1e-6
    # swapping the masked neighbour's model AND weight changes nothing
    other = _models(1, seed=99)[0]
    out2 = decdiff_aggregate_stacked(local, tree_stack([n1, n2, other]),
                                     [1.0, 2.0, 123.0], mask=[1.0, 1.0, 0.0])
    assert float(tree_l2_dist(out, out2)) == 0.0


def test_gossip_delivery_mask_matches_sequential_aggregation():
    """decdiff_gossip with a per-edge mask == per-node aggregation over the
    delivered subset; a fully-masked row keeps its local model."""
    n = 4
    models = _models(n, seed=7)
    stacked = tree_stack(models)
    adj = np.zeros((n, n), np.float32)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[i, (i - 1) % n] = 0.5
    mask = np.ones((n, n), np.float32)
    mask[0, :] = 0.0          # node 0 heard from nobody this round
    mask[2, 3] = 0.0          # node 2 lost one of its two neighbours
    out = decdiff_gossip(stacked, jnp.asarray(adj), mask=jnp.asarray(mask))
    for i in range(n):
        delivered = [j for j in range(n) if adj[i, j] * mask[i, j] > 0]
        want = decdiff_aggregate(models[i], [models[j] for j in delivered],
                                 [adj[i, j] for j in delivered])
        assert float(tree_l2_dist(tree_index(out, i), want)) < 1e-6, i


def test_dfl_round_runtime_mask_without_retrace():
    """An all-zero runtime delivery mask turns the round into pure local
    SGD (no gossip), and per-round masks reuse one compiled round_fn."""
    from repro.configs import get_config
    from repro.dist.dfl_step import build_dfl_round, build_train_step
    from repro.models.lm import build_lm
    from repro.optim.sgd import sgd_momentum

    cfg = get_config("qwen1.5-0.5b").reduced(n_layers=2, d_model=128, vocab=512)
    lm = build_lm(cfg)
    opt = sgd_momentum(lr=1e-2, momentum=0.9)
    nodes = 2
    keys = jax.random.split(jax.random.PRNGKey(0), nodes)
    params = jax.vmap(lm.init)(keys)
    opt_state = jax.vmap(opt.init)(params)
    adj = jnp.asarray([[0.0, 1.0], [1.0, 0.0]], jnp.float32)
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(rng.integers(0, cfg.vocab, (nodes, 2, 16)),
                            jnp.int32) for k in ("tokens", "labels")}
    round_fn = jax.jit(build_dfl_round(lm, opt, adj))
    # zero mask == vmapped local train steps, gossip contributes nothing
    local = jax.vmap(build_train_step(lm, opt), in_axes=(0, 0, None, 0))(
        params, opt_state, jnp.int32(0), batch)
    zero = round_fn(params, opt_state, jnp.int32(0), batch,
                    jnp.zeros((nodes, nodes), jnp.float32))
    assert float(tree_l2_dist(zero[0], local[0])) < 1e-5  # jit vs eager fusion
    # full mask == the unmasked round, same compiled function
    full = round_fn(params, opt_state, jnp.int32(0), batch,
                    jnp.ones((nodes, nodes), jnp.float32))
    plain = round_fn(params, opt_state, jnp.int32(0), batch)
    assert float(tree_l2_dist(full[0], plain[0])) < 1e-6
    assert float(tree_l2_dist(full[0], zero[0])) > 1e-2  # gossip really ran


def _tiny_sim(comm, rounds=3):
    """Minimal 4-node world for transport-equivalence checks (returns the
    post-run `repro.engine.Experiment`)."""
    from repro.engine import Experiment, Schedule, World
    from repro.models.mlp_cnn import make_mlp

    world = World.synthetic(dataset="synth-mnist", nodes=4, topology="ring",
                            seed=3, scale=0.02,
                            model=make_mlp(num_classes=10, hidden=(32,)))
    exp = Experiment(world, "decdiff+vt", comm=comm,
                     schedule=Schedule(rounds=rounds, eval_every=10),
                     steps_per_round=2, batch_size=16, lr=0.1, momentum=0.9,
                     participation=0.7, seed=3)
    exp.run()
    return exp


def test_threshold_zero_fp32_transport_is_bitexact_vs_legacy():
    """The tentpole equivalence contract: routing the exchange through the
    comm transport with the fp32 codec and drift threshold 0 reproduces the
    legacy always-send round bit-for-bit — same rng stream (including the
    exogenous participation mask), same payload values, same aggregation."""
    from repro.comm import CommConfig

    legacy = _tiny_sim(None)
    comm = _tiny_sim(CommConfig(codec="fp32", trigger_threshold=0.0))
    for a, b in zip(jax.tree.leaves(legacy.params), jax.tree.leaves(comm.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # and the accounting saw every node send every round
    assert comm.comm_bytes_total > 0
    assert comm._trig_sum == comm._comm_rounds


def test_codec_fp32_gossip_identity():
    """decdiff_gossip(codec=fp32) == decdiff_gossip dense (wire is lossless)."""
    from repro.comm import make_codec

    models = _models(4, seed=11)
    stacked = tree_stack(models)
    adj = np.zeros((4, 4), np.float32)
    for i in range(4):
        adj[i, (i + 1) % 4] = adj[i, (i - 1) % 4] = 0.5
    dense = decdiff_gossip(stacked, jnp.asarray(adj))
    coded = decdiff_gossip(stacked, jnp.asarray(adj),
                           codec=make_codec("fp32"))
    assert float(tree_l2_dist(dense, coded)) == 0.0


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a (pod, data, model) mesh")
def test_dfl_round_shardmap_matches_vmap_round():
    """On a multi-device host the shard_map round must reproduce the vmap
    round (single CPU hosts skip: the pod axis cannot be materialized)."""
    from repro.configs import get_config
    from repro.dist.dfl_step import build_dfl_round
    from repro.models.lm import build_lm
    from repro.optim.sgd import sgd_momentum

    cfg = get_config("qwen1.5-0.5b").reduced(n_layers=2, d_model=128, vocab=512)
    lm = build_lm(cfg)
    opt = sgd_momentum(lr=1e-2, momentum=0.9)
    nodes = 2
    keys = jax.random.split(jax.random.PRNGKey(0), nodes)
    params = jax.vmap(lm.init)(keys)
    opt_state = jax.vmap(opt.init)(params)
    adj = jnp.asarray([[0.0, 1.0], [1.0, 0.0]], jnp.float32)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (nodes, 2, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (nodes, 2, 16)),
                              jnp.int32),
    }
    mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))
    ref = jax.jit(build_dfl_round(lm, opt, adj))(
        params, opt_state, jnp.int32(0), batch)
    with mesh:
        got = jax.jit(build_dfl_round_shardmap(lm, opt, adj, mesh))(
            params, opt_state, jnp.int32(0), batch)
    assert float(tree_l2_dist(ref[0], got[0])) < 1e-4
    assert abs(float(ref[2]) - float(got[2])) < 1e-5
