"""Distribution layer: sharding rules and the pod-level DFL round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.decdiff import decdiff_aggregate
from repro.dist.dfl_step import build_dfl_round, decdiff_gossip
from repro.dist.sharding import (
    leaf_spec,
    make_batch_specs,
    make_cache_specs,
    make_param_specs,
)
from repro.utils.pytree import tree_index, tree_l2_dist, tree_random_like, tree_stack


@pytest.fixture(scope="module")
def mesh():
    # single real CPU device: mesh (1,1) — rules still produce named axes
    return jax.make_mesh((1, 1), ("data", "model"))


def test_leaf_spec_divisibility(mesh):
    # simulate a 16x16 mesh via a fake mesh-shape mapping
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = leaf_spec((1024, 4096), np.float32, 0, "data", "model", FakeMesh())
    assert spec == P("data", "model")  # largest dim 4096 -> model, 1024 -> data
    # non-divisible dims stay unsharded
    spec = leaf_spec((1000, 56), np.float32, 0, "data", "model", FakeMesh())
    assert spec == P(None, None)
    # int leaves replicate
    spec = leaf_spec((1 << 20,), np.int32, 0, "data", "model", FakeMesh())
    assert spec == P(None)
    # small leaves replicate
    spec = leaf_spec((128,), np.float32, 0, "data", "model", FakeMesh())
    assert spec == P(None)


def test_param_specs_reserve_stack_dims():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    tree = {"layers": {"w": jax.ShapeDtypeStruct((64, 1024, 4096), jnp.float32)},
            "embed": {"table": jax.ShapeDtypeStruct((151936, 1024), jnp.float32)}}
    specs = make_param_specs(tree, FakeMesh())
    assert specs["layers"]["w"][0] is None  # L dim never sharded
    assert "model" in specs["layers"]["w"]
    assert specs["embed"]["table"] == P("model", "data")


def test_batch_specs():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    tree = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = make_batch_specs(tree, FakeMesh())
    assert specs["tokens"] == P("data", None)
    # non-divisible batch replicates
    tree = {"tokens": jax.ShapeDtypeStruct((3, 4096), jnp.int32)}
    assert make_batch_specs(tree, FakeMesh())["tokens"] == P(None, None)


def test_cache_specs_avoid_window_dim():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    tree = {"k": jax.ShapeDtypeStruct((64, 128, 32768, 8, 128), jnp.bfloat16)}
    spec = make_cache_specs(tree, FakeMesh())["k"]
    assert spec[1] == "data" and spec[4] == "model" and spec[2] is None


def test_decdiff_gossip_matches_per_node_aggregation():
    """The pod-axis gossip (adjacency einsum + global-norm step) reproduces
    the core DecDiff aggregation node by node."""
    proto = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((32,))}
    models = [tree_random_like(jax.random.PRNGKey(i), proto) for i in range(4)]
    stacked = tree_stack(models)
    # ring adjacency, row-normalized
    adj = np.zeros((4, 4), np.float32)
    for i in range(4):
        adj[i, (i + 1) % 4] = adj[i, (i - 1) % 4] = 0.5
    out = decdiff_gossip(stacked, jnp.asarray(adj), s=1.0)
    for i in range(4):
        neighbors = [models[(i + 1) % 4], models[(i - 1) % 4]]
        want = decdiff_aggregate(models[i], neighbors, [1.0, 1.0], s=1.0)
        assert tree_l2_dist(tree_index(out, i), want) < 1e-5


def _tiny_lm_world(nodes=2):
    from repro.configs import get_config
    from repro.models.lm import build_lm
    from repro.optim.sgd import sgd_momentum

    cfg = get_config("qwen1.5-0.5b").reduced(n_layers=2, d_model=128, vocab=512)
    lm = build_lm(cfg)
    opt = sgd_momentum(lr=1e-2, momentum=0.9)
    keys = jax.random.split(jax.random.PRNGKey(0), nodes)
    params = jax.vmap(lm.init)(keys)
    opt_state = jax.vmap(opt.init)(params)
    adj = jnp.asarray(np.ones((nodes, nodes)) - np.eye(nodes), jnp.float32)
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(rng.integers(0, cfg.vocab, (nodes, 2, 16)),
                            jnp.int32) for k in ("tokens", "labels")}
    return lm, opt, adj, params, opt_state, batch


def test_compressed_vmap_round_tracks_dense_round():
    """int8 wire compression perturbs the DecDiff round by at most the
    quantization grain: the compressed round stays near the dense round and
    the gossip still pulls nodes together."""
    from repro.comm import make_codec

    lm, opt, adj, params, opt_state, batch = _tiny_lm_world()
    dense_fn = jax.jit(build_dfl_round(lm, opt, adj))
    codec = make_codec("int8", stochastic=False)
    comp_fn = jax.jit(build_dfl_round(lm, opt, adj, codec=codec))
    dense = dense_fn(params, opt_state, jnp.int32(0), batch)
    comp = comp_fn(params, opt_state, jnp.int32(0), batch)
    d0 = float(tree_l2_dist(tree_index(params, 0), tree_index(params, 1)))
    d_dense_comp = float(tree_l2_dist(dense[0], comp[0]))
    assert 0.0 < d_dense_comp < 0.05 * d0  # wire noise, not a different round
    d1 = float(tree_l2_dist(tree_index(comp[0], 0), tree_index(comp[0], 1)))
    assert d1 < d0  # compressed DecDiff still contracts the pair


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a (pod, data, model) mesh")
def test_compressed_shardmap_round_matches_compressed_vmap_round():
    """The int8-compressed shard_map round (payload all_gather over the pod
    ring, fused dequantize+DecDiff by default) must reproduce the compressed
    vmap round on a multi-device CPU mesh (CI forces 4 host devices via
    XLA_FLAGS)."""
    from repro.comm import make_codec
    from repro.dist.dfl_step import build_dfl_round_shardmap

    lm, opt, adj, params, opt_state, batch = _tiny_lm_world()
    codec = make_codec("int8", stochastic=False)
    ref = jax.jit(build_dfl_round(lm, opt, adj, codec=codec))(
        params, opt_state, jnp.int32(0), batch)
    mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))
    with mesh:
        got = jax.jit(build_dfl_round_shardmap(lm, opt, adj, mesh,
                                               codec=codec))(
            params, opt_state, jnp.int32(0), batch)
    assert float(tree_l2_dist(ref[0], got[0])) < 1e-4
    assert abs(float(ref[2]) - float(got[2])) < 1e-5


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a (pod, data, model) mesh")
def test_fused_dequant_shardmap_matches_unfused_and_vmap():
    """The kernelized payload path (dequant_neighbor_avg_rows fused into the
    Eq. 6 reduction, fuse_dequant=True — the default) against BOTH oracles:
    the decode-then-average shard_map formulation (fuse_dequant=False) and
    the compressed vmap round."""
    from repro.comm import make_codec
    from repro.dist.dfl_step import build_dfl_round_shardmap

    lm, opt, adj, params, opt_state, batch = _tiny_lm_world()
    codec = make_codec("int8", stochastic=False)
    ref = jax.jit(build_dfl_round(lm, opt, adj, codec=codec))(
        params, opt_state, jnp.int32(0), batch)
    mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))
    with mesh:
        fused = jax.jit(build_dfl_round_shardmap(
            lm, opt, adj, mesh, codec=codec, fuse_dequant=True))(
            params, opt_state, jnp.int32(0), batch)
        unfused = jax.jit(build_dfl_round_shardmap(
            lm, opt, adj, mesh, codec=codec, fuse_dequant=False))(
            params, opt_state, jnp.int32(0), batch)
    assert float(tree_l2_dist(fused[0], ref[0])) < 1e-4
    assert float(tree_l2_dist(fused[0], unfused[0])) < 1e-5
    assert abs(float(fused[2]) - float(ref[2])) < 1e-5


def test_dfl_round_runs_and_descends():
    """2-node DFL round on a tiny LM: loss finite, params move, gossip pulls
    the two nodes together."""
    from repro.configs import get_config
    from repro.models.lm import build_lm
    from repro.optim.sgd import sgd_momentum

    cfg = get_config("qwen1.5-0.5b").reduced()
    lm = build_lm(cfg)
    opt = sgd_momentum(lr=1e-2, momentum=0.9)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    params_st = jax.vmap(lm.init)(keys)
    opt_st = jax.vmap(opt.init)(params_st)
    adj = jnp.asarray([[0.0, 1.0], [1.0, 0.0]], jnp.float32)
    round_fn = jax.jit(build_dfl_round(lm, opt, adj))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 2, 32)), jnp.int32),
    }
    d0 = tree_l2_dist(tree_index(params_st, 0), tree_index(params_st, 1))
    new_params, new_opt, loss = round_fn(params_st, opt_st, jnp.int32(0), batch)
    assert np.isfinite(float(loss))
    d1 = tree_l2_dist(tree_index(new_params, 0), tree_index(new_params, 1))
    assert float(d1) < float(d0)  # DecDiff moved the nodes together
