"""repro.dynamics contracts: process invariants, engine threading, and the
comm-state carry/reset semantics on time-varying graphs.

The load-bearing pins:

  1. invariants — every catalog process realizes a live mask that is a
     symmetric, self-loop-free subset of the static layout; churned nodes
     have fully-masked rows; Gilbert–Elliott's long-run edge-up frequency
     matches the closed form p_bg / (p_gb + p_bg);
  2. identity — `dynamics=StaticGraph()` is bit-identical to
     `dynamics=None` (the process consumes no rng, the masks are the
     neighbour masks);
  3. schedule — loop and scan-fused execution are bit-identical under a
     dynamic process INCLUDING the byte/trigger/live accounting (the
     ISSUE-5 satellite);
  4. backends — vmap and shard_map are bit-identical under every shipped
     process (plain and through the per-node transport), degenerate 1-pod
     everywhere + the real 4-pod mesh in the multihost lane;
  5. churn semantics — a dead device trains nothing and its params freeze;
     bytes are accounted on live edges only; a rejoining device's transport
     state (per-node row / every incident per-edge link) returns to the
     zero bootstrap while all other state stays bit-identical.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, EdgeGossipTransport, GossipTransport
from repro.dynamics import (
    BoundProcess,
    EdgeDropout,
    GilbertElliott,
    GraphEvent,
    GraphProcess,
    NodeChurn,
    PeriodicRewiring,
    StaticGraph,
    make_process,
)
from repro.dynamics.processes import _layout
from repro.engine import Experiment, Schedule, World, build_round
from repro.graphs import make_topology

CATALOG = [
    StaticGraph(),
    EdgeDropout(p=0.3),
    GilbertElliott(p_gb=0.2, p_bg=0.4),
    NodeChurn(p_leave=0.3, p_rejoin=0.6),
    PeriodicRewiring(period=2, num_graphs=3, topo_kwargs={"k": 2, "p": 0.2}),
]

TINY = dict(steps_per_round=2, batch_size=16, lr=0.1, momentum=0.9, seed=3)


@pytest.fixture(scope="module")
def tiny_world():
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=4, topology="ring",
                           seed=3, scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


def _params_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _with_dyn(world, dyn):
    return dataclasses.replace(world, dynamics=dyn)


def _materialize(bound: BoundProcess, ev: GraphEvent) -> np.ndarray:
    """Scatter the [N, max_deg] live mask back to a dense [N, N] matrix."""
    topo = bound.topo
    n = topo.num_nodes
    live = np.asarray(ev.live)
    mat = np.zeros((n, n), np.float32)
    for i in range(n):
        for e in range(topo.max_degree):
            if topo.neighbor_mask[i, e]:
                mat[i, topo.neighbor_idx[i, e]] = live[i, e]
    return mat


def _check_event(bound: BoundProcess, ev: GraphEvent):
    """The GraphEvent invariants every process must satisfy."""
    topo = bound.topo
    live = np.asarray(ev.live)
    alive = np.asarray(ev.alive)
    rejoined = np.asarray(ev.rejoined)
    valid = topo.neighbor_mask.astype(np.float32)
    assert live.shape == valid.shape
    assert set(np.unique(live)) <= {0.0, 1.0}
    assert (live <= valid).all()                      # subset of the layout
    mat = _materialize(bound, ev)
    assert np.array_equal(mat, mat.T)                 # symmetric
    assert mat.diagonal().sum() == 0                  # no self-loops
    assert set(np.unique(alive)) <= {0.0, 1.0}
    assert (rejoined <= alive).all()                  # rejoined => alive now
    # a dead node's row (and, by symmetry, column) is fully masked
    assert (live[alive == 0] == 0).all()


def _drive(process, topo, rounds=6, seed=0):
    """Bind and run a process standalone, yielding its events."""
    bound = process.bind(topo)
    state = bound.state0
    events = []
    for r in range(rounds):
        key = (jax.random.fold_in(jax.random.PRNGKey(seed), r)
               if bound.needs_rng else None)
        state, ev = bound.step(state, jnp.int32(r), key)
        events.append(ev)
    return bound, events


# ------------------------------------------------------- process invariants


@pytest.mark.parametrize("process", CATALOG, ids=lambda p: p.name)
def test_catalog_invariants(process):
    topo = make_topology("barabasi_albert", n=12, m=2, seed=1)
    bound, events = _drive(process, topo, rounds=6)
    for ev in events:
        _check_event(bound, ev)


def test_static_is_identity_mask():
    topo = make_topology("barabasi_albert", n=10, m=2, seed=0)
    bound, events = _drive(StaticGraph(), topo, rounds=3)
    for ev in events:
        assert np.array_equal(np.asarray(ev.live),
                              topo.neighbor_mask.astype(np.float32))
        assert np.asarray(ev.alive).all() and not np.asarray(ev.rejoined).any()


def test_dropout_rate_and_determinism():
    topo = make_topology("complete", n=12)
    process = EdgeDropout(p=0.4)
    bound, events = _drive(process, topo, rounds=40, seed=7)
    fracs = [np.asarray(ev.live).sum() / topo.neighbor_mask.sum()
             for ev in events]
    assert abs(np.mean(fracs) - process.stationary_live_frac()) < 0.05
    # same keys -> identical realization (pure function of (state, r, key))
    _, again = _drive(process, topo, rounds=40, seed=7)
    for a, b in zip(events, again):
        assert np.array_equal(np.asarray(a.live), np.asarray(b.live))


def test_gilbert_elliott_stationary_matches_closed_form():
    """The ISSUE-5 satellite pin: long-run edge-up frequency within
    tolerance of p_bg / (p_gb + p_bg) (chain mixes at 1 - p_gb - p_bg)."""
    topo = make_topology("barabasi_albert", n=10, m=2, seed=2)
    process = GilbertElliott(p_gb=0.2, p_bg=0.6)
    bound = process.bind(topo)
    keys = jax.random.split(jax.random.PRNGKey(11), 2000)

    def body(state, xs):
        r, key = xs
        state, ev = bound.step(state, r, key)
        return state, jnp.sum(ev.live)

    _, lives = jax.lax.scan(
        body, bound.state0, (jnp.arange(2000, dtype=jnp.int32), keys))
    burn = 200
    freq = float(np.asarray(lives)[burn:].mean()) / float(
        topo.neighbor_mask.sum())
    assert abs(freq - process.stationary_live_frac()) < 0.02


def test_gilbert_elliott_bursts_freeze_edges():
    """State is per-edge Markov, not i.i.d.: with p_bg < 1 a downed edge
    can stay down across consecutive rounds (the burst), which i.i.d.
    dropout at the same stationary rate almost never does for long."""
    topo = make_topology("complete", n=8)
    process = GilbertElliott(p_gb=0.3, p_bg=0.2)  # long bursts (mean 5)
    _, events = _drive(process, topo, rounds=30, seed=3)
    lives = np.stack([_materialize(process.bind(topo), ev) for ev in events])
    down_runs = 0
    for i in range(8):
        for j in range(i + 1, 8):
            seq = lives[:, i, j]
            down_runs = max(down_runs, max(
                (len(s) for s in "".join(
                    "d" if v == 0 else "u" for v in seq).split("u") if s),
                default=0))
    assert down_runs >= 3  # at least one multi-round outage realized


def test_churn_rejoined_flags_and_full_masking():
    topo = make_topology("complete", n=10)
    process = NodeChurn(p_leave=0.4, p_rejoin=0.5)
    bound, events = _drive(process, topo, rounds=30, seed=5)
    prev_alive = np.ones(10)
    saw_rejoin = False
    for ev in events:
        _check_event(bound, ev)
        alive = np.asarray(ev.alive)
        rejoined = np.asarray(ev.rejoined)
        assert np.array_equal(rejoined, (1 - prev_alive) * alive)
        saw_rejoin |= rejoined.any()
        # live[i, e] == alive_i * alive_j exactly (complete graph: all slots)
        mat = _materialize(bound, ev)
        expect = np.outer(alive, alive)
        np.fill_diagonal(expect, 0)
        assert np.array_equal(mat, expect)
        prev_alive = alive
    assert saw_rejoin  # the regime actually exercised a rejoin


def test_rewiring_union_layout_and_phase_schedule():
    topo = make_topology("ring", n=12)
    process = PeriodicRewiring(period=3, num_graphs=3, seed=4,
                               topo_kwargs={"k": 4, "p": 0.2})
    bound, events = _drive(process, topo, rounds=9)
    family = process._family(12)
    # the bound layout is the union: every family edge exists in it
    for t in family:
        assert (t.adjacency <= bound.topo.adjacency).all()
    # round r realizes exactly graph (r // period) % K
    for r, ev in enumerate(events):
        g = (r // 3) % 3
        assert np.array_equal(_materialize(bound, ev),
                              family[g].adjacency.astype(np.float32)), r
    # the live fraction is a property of the binding, not the process
    assert process.stationary_live_frac() is None
    assert 0.0 < bound.stationary_live_frac <= 1.0


def test_registry_and_validation():
    assert make_process("edge_dropout", p=0.1).p == 0.1
    with pytest.raises(ValueError) as ei:
        make_process("wormhole")
    assert "edge_dropout" in str(ei.value)  # roster in the message
    with pytest.raises(ValueError):
        EdgeDropout(p=1.5)
    with pytest.raises(ValueError):
        GilbertElliott(p_bg=0.0)
    with pytest.raises(ValueError):
        NodeChurn(p_rejoin=0.0)
    with pytest.raises(ValueError):
        PeriodicRewiring(period=0)


def test_world_rejects_non_process(tiny_world):
    with pytest.raises(TypeError, match="GraphProcess"):
        Experiment(_with_dyn(tiny_world, "edge_dropout"), "decdiff+vt",
                   **TINY)


def test_comm_bytes_per_round_scales_with_live_frac():
    from repro.fl.metrics import comm_bytes_per_round

    topo = make_topology("erdos_renyi", n=20, p=0.3, seed=0)
    full = comm_bytes_per_round("decdiff+vt", topo, 1000)
    half = comm_bytes_per_round("decdiff+vt", topo, 1000,
                                live_frac=EdgeDropout(0.5)
                                .stationary_live_frac())
    assert half * 2 == full
    with pytest.raises(ValueError):
        comm_bytes_per_round("decdiff+vt", topo, 1000, live_frac=1.5)
    # fedavg is node-count-priced: under churn it wants ALIVENESS, which
    # NodeChurn exposes separately from the (squared) edge fraction
    churn = NodeChurn(p_leave=0.1, p_rejoin=0.9)
    assert churn.stationary_live_frac() == pytest.approx(
        churn.stationary_alive_frac() ** 2)


def _neighbor_weights_loop(topo):
    """The original O(N·max_deg) Python-loop rendering of
    Topology.neighbor_weights (the oracle for the vectorized version)."""
    n, d = topo.neighbor_idx.shape
    out = np.zeros((n, d), np.float32)
    for i in range(n):
        for k in range(d):
            j = topo.neighbor_idx[i, k]
            if j >= 0:
                out[i, k] = topo.weights[i, j]
    return out


def test_neighbor_weights_vectorized_equals_loop():
    """Satellite pin: the fancy-indexed neighbor_weights() is bit-equal to
    the double loop it replaced — including non-unit weights and padding.
    Lives here (not test_graphs_data.py) so it runs in the tier-1 lane
    even without hypothesis installed; the fuzzed version rides the
    hypothesis module."""
    for topo in (
        make_topology("erdos_renyi", n=23, p=0.3, seed=5,
                      weight_fn=lambda i, j, rng: rng.uniform(0.5, 2.0)),
        make_topology("barabasi_albert", n=17, m=3, seed=2),
        make_topology("star", n=9),
    ):
        got = topo.neighbor_weights()
        ref = _neighbor_weights_loop(topo)
        assert got.dtype == np.float32 and got.shape == ref.shape
        assert np.array_equal(got, ref), topo.name


# ------------------------------------------------- engine: identity + modes


def test_static_process_bit_identical_to_no_dynamics(tiny_world):
    """StaticGraph consumes no rng and masks nothing: the dynamics plumbing
    under it must reproduce the dynamics-free engine bit-for-bit, with the
    live accounting reporting a fully-live graph."""
    comm = CommConfig(codec="int8", trigger_threshold=0.5, stochastic=True)
    base = Experiment(tiny_world, "decdiff+vt", comm=comm,
                      schedule=Schedule(rounds=4, eval_every=2, mode="fused"),
                      participation=0.7, **TINY)
    hb = base.run()
    stat = Experiment(_with_dyn(tiny_world, StaticGraph()), "decdiff+vt",
                      comm=comm,
                      schedule=Schedule(rounds=4, eval_every=2, mode="fused"),
                      participation=0.7, **TINY)
    hs = stat.run()
    assert _params_equal(base.params, stat.params)
    assert base.comm_bytes_total == stat.comm_bytes_total
    assert base.trig_history == stat.trig_history
    assert stat.live_history == [1.0] * 4
    for a, b in zip(hb, hs):
        assert np.array_equal(a.acc_per_node, b.acc_per_node)
        assert b.live_edge_frac == 1.0


def test_loop_fused_bit_identical_with_dynamics(tiny_world):
    """The ISSUE-5 satellite: Schedule(mode="fused") with dynamics keeps
    byte/trigger/live accounting bit-identical to loop mode."""
    comm = CommConfig(codec="int8", trigger_threshold=0.5)
    dyn = GilbertElliott(p_gb=0.3, p_bg=0.4)
    runs = {}
    for mode in ("loop", "fused"):
        exp = Experiment(_with_dyn(tiny_world, dyn), "decdiff+vt", comm=comm,
                         schedule=Schedule(rounds=5, eval_every=2, mode=mode),
                         participation=0.7, **TINY)
        runs[mode] = (exp, exp.run())
    loop, hl = runs["loop"]
    fused, hf = runs["fused"]
    assert _params_equal(loop.params, fused.params)
    assert loop.comm_bytes_total == fused.comm_bytes_total > 0
    assert loop.trig_history == fused.trig_history
    assert loop.live_history == fused.live_history
    assert 0.0 < min(loop.live_history)  # the process actually realized
    assert min(loop.live_history) < 1.0  # ... a non-trivial mask sequence
    for a, b in zip(hl, hf):
        assert np.array_equal(a.acc_per_node, b.acc_per_node)
        assert a.bytes_on_wire == b.bytes_on_wire
        assert a.live_edge_frac == b.live_edge_frac


def test_dynamic_round_signatures(tiny_world):
    """build_round's calling convention with dynamics (module contract):
    (params, opt, [comm_state,] dyn_state, round_idx, rng)."""
    exp = Experiment(_with_dyn(tiny_world, EdgeDropout(0.2)), "decdiff+vt",
                     schedule=Schedule(rounds=1, eval_every=1), **TINY)
    fn = build_round(exp)
    out = fn(exp.params, exp.opt_state, exp.dyn_state, jnp.int32(0), exp.rng)
    assert len(out) == 6  # params, opt, dyn_state, rng, loss, live_edges
    cexp = Experiment(_with_dyn(tiny_world, EdgeDropout(0.2)), "decdiff+vt",
                      comm=CommConfig(codec="fp32"),
                      schedule=Schedule(rounds=1, eval_every=1), **TINY)
    cfn = build_round(cexp)
    out = cfn(cexp.params, cexp.opt_state, cexp.comm_state, cexp.dyn_state,
              jnp.int32(0), cexp.rng)
    assert len(out) == 9  # + comm_state, sent_edges, trig_frac, live_edges


# ------------------------------------------------- engine: backend equality


def test_shardmap_single_pod_matches_vmap_with_dynamics(tiny_world):
    dyn = NodeChurn(p_leave=0.3, p_rejoin=0.6)
    ref = Experiment(_with_dyn(tiny_world, dyn), "decdiff+vt",
                     schedule=Schedule(rounds=3, eval_every=2, mode="loop"),
                     **TINY)
    ref.run()
    smap = Experiment(_with_dyn(tiny_world, dyn), "decdiff+vt",
                      backend="shard_map",
                      schedule=Schedule(rounds=3, eval_every=2, mode="loop"),
                      **TINY)
    smap.run()
    assert _params_equal(ref.params, smap.params)
    assert ref.live_history == smap.live_history


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a real pod axis")
@pytest.mark.parametrize("process", CATALOG, ids=lambda p: p.name)
def test_vmap_shardmap_bit_identical_per_process(process):
    """The ISSUE-5 acceptance pin: vmap and shard_map are bit-identical
    under EVERY shipped GraphProcess on the forced 4-device CPU mesh —
    plain and through the per-node int8 transport, scan-fused."""
    from repro.models.mlp_cnn import make_mlp

    world = World.synthetic(dataset="synth-mnist", nodes=8,
                            topology="erdos_renyi", p=0.5, seed=3,
                            scale=0.02,
                            model=make_mlp(num_classes=10, hidden=(32,)),
                            dynamics=process)
    comm = CommConfig(codec="int8", trigger_threshold=0.5)
    exps = []
    for backend in ("vmap", "shard_map"):
        plain = Experiment(world, "decdiff+vt", backend=backend,
                           schedule=Schedule(rounds=3, eval_every=2,
                                             mode="loop"), **TINY)
        plain.run()
        fused = Experiment(world, "decdiff+vt", backend=backend, comm=comm,
                           schedule=Schedule(rounds=3, eval_every=2,
                                             mode="fused"),
                           participation=0.7, **TINY)
        fused.run()
        exps.append((plain, fused))
    (pv, cv), (ps, cs) = exps
    assert int(ps.mesh.shape["pod"]) == 4
    assert _params_equal(pv.params, ps.params)
    assert pv.live_history == ps.live_history
    assert _params_equal(cv.params, cs.params)
    assert cv.comm_bytes_total == cs.comm_bytes_total
    assert cv.trig_history == cs.trig_history
    assert cv.live_history == cs.live_history


# --------------------------------------------- churn / comm-state semantics


@dataclasses.dataclass(frozen=True)
class ScriptedChurn(GraphProcess):
    """Test-only: alive follows a fixed [T, N] table (also proves the
    protocol is open — third-party processes run the whole engine)."""

    table: tuple  # T rows of N {0,1}

    name = "scripted_churn"
    needs_rng = False

    def init_state(self, topo):
        return jnp.ones((topo.num_nodes,), jnp.float32)

    def make_step(self, topo):
        n, idx, valid = _layout(topo)
        table = jnp.asarray(self.table, jnp.float32)

        def step(prev_alive, round_idx, key):
            del key
            alive = table[round_idx % table.shape[0]]
            rejoined = (1.0 - prev_alive) * alive
            live = valid * alive[:, None] * alive[idx]
            return alive, GraphEvent(live=live, alive=alive,
                                     rejoined=rejoined)

        return step


def _scripted_world(tiny_world):
    # 4-node ring; node 0: alive, dead, alive (rejoins at round 2)
    table = ((1, 1, 1, 1), (0, 1, 1, 1), (1, 1, 1, 1))
    return _with_dyn(tiny_world, ScriptedChurn(table=table))


def test_dead_node_freezes_and_pays_nothing(tiny_world):
    """Round 1: node 0 is offline — zero local steps, zero bytes, params
    and optimizer state bit-frozen; everyone else keeps training."""
    exp = Experiment(_scripted_world(tiny_world), "decdiff+vt",
                     comm=CommConfig(codec="fp32"),
                     schedule=Schedule(rounds=1, eval_every=1), **TINY)
    fn = build_round(exp)
    p0, o0, cs, ds, rng = (exp.params, exp.opt_state, exp.comm_state,
                           exp.dyn_state, exp.rng)
    p1, o1, cs, ds, rng, _, sent1, _, live1 = fn(p0, o0, cs, ds,
                                                 jnp.int32(0), rng)
    p2, o2, cs, ds, rng, _, sent2, _, live2 = fn(p1, o1, cs, ds,
                                                 jnp.int32(1), rng)
    row = lambda t, i: [np.asarray(leaf)[i] for leaf in jax.tree.leaves(t)]
    # round 0 (all alive): node 0 moved; round 1 (dead): node 0 frozen
    assert not all(np.array_equal(a, b)
                   for a, b in zip(row(p0, 0), row(p1, 0)))
    assert all(np.array_equal(a, b) for a, b in zip(row(p1, 0), row(p2, 0)))
    assert all(np.array_equal(a, b) for a, b in zip(row(o1, 0), row(o2, 0)))
    # the others kept moving
    assert not all(np.array_equal(a, b)
                   for a, b in zip(row(p1, 1), row(p2, 1)))
    # ring(4): 8 directed edges all-alive; node 0 dead kills (0,1) and (0,3)
    assert float(live1) == 8.0 and float(sent1) == 8.0
    assert float(live2) == 4.0 and float(sent2) == 4.0


def test_rejoin_resets_per_node_row_in_engine(tiny_world):
    """With a large fixed threshold, only freshly-reset references can fire
    after the bootstrap round — so the round-2 fired edges are EXACTLY the
    rejoined node's live out-edges, proving the engine applied reset_rows."""
    # threshold 2.6 sits between the per-round drift (~0.94 on this seeded
    # world) and the full model norm (~3.2): only a zero (bootstrap or
    # freshly-reset) reference can fire after round 0.
    exp = Experiment(_scripted_world(tiny_world), "decdiff+vt",
                     comm=CommConfig(codec="fp32", trigger_threshold=2.6),
                     schedule=Schedule(rounds=3, eval_every=3, mode="loop"),
                     **TINY)
    exp.run()
    # round 0: zero references, everyone fires (8 edge-payloads);
    # round 1: drift << threshold, silent (node 0 dead anyway);
    # round 2: node 0 rejoined with a reset row -> drift(0) = ||w_0|| fires
    # on its 2 live out-edges; everyone else stays silent.
    assert exp.trig_history[0] == 1.0
    assert exp.trig_history[1] == 0.0
    assert float(exp.live_history[2]) == 1.0
    assert abs(exp.trig_history[2] - 2.0 / 8.0) < 1e-6, exp.trig_history
    assert float(np.asarray(exp.comm_state.ever_sent)[0]) == 1.0


def test_rejoin_resets_incident_edges_in_engine(tiny_world):
    """Per-edge transport, same construction: after the rejoin round the
    fired edges are exactly the 4 directed live edges INCIDENT to node 0
    (both directions reset — its neighbours' references toward it are gone
    too), not just its own out-edges."""
    exp = Experiment(_scripted_world(tiny_world), "decdiff+vt",
                     comm=CommConfig(codec="fp32", trigger_threshold=2.6,
                                     per_edge=True),
                     schedule=Schedule(rounds=3, eval_every=3, mode="loop"),
                     **TINY)
    exp.run()
    assert exp.trig_history[0] == 1.0
    assert exp.trig_history[1] == 0.0
    assert abs(exp.trig_history[2] - 4.0 / 8.0) < 1e-6, exp.trig_history
    st = exp.comm_state
    # the reset links re-delivered and are live caches again
    ever = np.asarray(st.ever_delivered)
    assert ever[0].sum() == 2.0  # node 0's two ring edges


def test_reset_rows_touches_only_reset_rows():
    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal((4, 16)), jnp.float32)}
    tr = GossipTransport(CommConfig(codec="int8", stochastic=False), params)
    st = tr.init_state(params)
    _, _, st = tr.exchange(params, st)  # advance everything
    reset = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    st2 = tr.reset_rows(st, reset)
    assert (np.asarray(st2.last_sent)[0] == 0).all()
    assert (np.asarray(st2.residual)[0] == 0).all()
    assert np.asarray(st2.ever_sent)[0] == 0
    for f, f2 in zip(st, st2):  # every other row bit-identical
        if f is not None:
            assert np.array_equal(np.asarray(f)[1:], np.asarray(f2)[1:])


def test_reset_edges_touches_only_reset_edges():
    from repro.graphs import make_topology as mt

    topo = mt("ring", n=4)
    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal((4, 16)), jnp.float32)}
    cfg = CommConfig(codec="int8", policy="adaptive", target_trigger=0.9,
                     stochastic=False)
    tr = EdgeGossipTransport(cfg, params, topo.neighbor_idx,
                             topo.neighbor_mask)
    st = tr.init_state(params)
    link = jnp.asarray(topo.neighbor_mask.astype(np.float32))
    for _ in range(3):  # advance thresholds/EMA/references
        _, _, _, st = tr.exchange(params, st, link)
    reset = np.zeros((4, 2), np.float32)
    reset[0, :] = 1.0  # node 0's outgoing links
    st2 = tr.reset_edges(st, jnp.asarray(reset))
    assert (np.asarray(st2.last_sent)[0] == 0).all()
    assert (np.asarray(st2.threshold)[0] == tr.thr0).all()
    assert (np.asarray(st2.drift_ema)[0] == 0).all()
    assert (np.asarray(st2.ever_delivered)[0] == 0).all()
    for f, f2 in zip(st, st2):  # untouched links bit-identical
        if f is not None:
            assert np.array_equal(np.asarray(f)[1:], np.asarray(f2)[1:])
    # frozen-when-down is the OTHER semantics: a live=0 edge advances nothing
    live = jnp.asarray(1.0 - reset) * link
    _, _, gate, st3 = tr.exchange(params, st2, link * live, live=live)
    assert (np.asarray(gate)[0] == 0).all()
    assert np.array_equal(np.asarray(st3.last_sent)[0],
                          np.asarray(st2.last_sent)[0])
    assert np.array_equal(np.asarray(st3.threshold)[0],
                          np.asarray(st2.threshold)[0])


# ------------------------------------------------------------ property lane

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYP = True
except Exception:  # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=20)
    @given(n=st.integers(4, 20), p=st.floats(0.0, 0.95),
           seed=st.integers(0, 2 ** 16), rounds=st.integers(1, 5))
    def test_fuzz_dropout_invariants(n, p, seed, rounds):
        topo = make_topology("barabasi_albert", n=n, m=2, seed=seed % 97)
        bound, events = _drive(EdgeDropout(p=p), topo, rounds=rounds,
                               seed=seed)
        for ev in events:
            _check_event(bound, ev)

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=20)
    @given(n=st.integers(4, 16), p_gb=st.floats(0.0, 1.0),
           p_bg=st.floats(0.05, 1.0), seed=st.integers(0, 2 ** 16))
    def test_fuzz_gilbert_elliott_invariants(n, p_gb, p_bg, seed):
        topo = make_topology("erdos_renyi", n=n, p=0.5, seed=seed % 97)
        bound, events = _drive(GilbertElliott(p_gb=p_gb, p_bg=p_bg), topo,
                               rounds=5, seed=seed)
        for ev in events:
            _check_event(bound, ev)

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=20)
    @given(n=st.integers(4, 16), p_leave=st.floats(0.0, 0.95),
           p_rejoin=st.floats(0.05, 1.0), seed=st.integers(0, 2 ** 16))
    def test_fuzz_churn_invariants(n, p_leave, p_rejoin, seed):
        topo = make_topology("complete", n=n)
        bound, events = _drive(NodeChurn(p_leave=p_leave,
                                         p_rejoin=p_rejoin), topo,
                               rounds=6, seed=seed)
        prev = np.ones(n)
        for ev in events:
            _check_event(bound, ev)
            alive = np.asarray(ev.alive)
            assert np.array_equal(np.asarray(ev.rejoined),
                                  (1 - prev) * alive)
            prev = alive

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=10)
    @given(n=st.integers(8, 20), period=st.integers(1, 4),
           k=st.integers(1, 4), seed=st.integers(0, 2 ** 10))
    def test_fuzz_rewiring_invariants(n, period, k, seed):
        topo = make_topology("ring", n=n)
        process = PeriodicRewiring(period=period, num_graphs=k, seed=seed,
                                   topo_kwargs={"k": 4, "p": 0.2})
        bound, events = _drive(process, topo, rounds=2 * period * k)
        family = process._family(n)
        for r, ev in enumerate(events):
            _check_event(bound, ev)
            g = (r // period) % k
            assert np.array_equal(
                _materialize(bound, ev),
                family[g].adjacency.astype(np.float32))
