"""repro.engine contracts: the strategy registry, the Capabilities record,
and backend/schedule equivalence.

The load-bearing pins:

  1. registry — unknown methods fail with the available roster in the
     message; custom strategies registered through `register_method` run
     end-to-end through the same engine as the built-ins; inconsistent
     capability declarations fail AT REGISTRATION, with the roster;
  2. schedule — the scan-fused runner produces bit-identical params and
     metrics to the per-round Python loop (same rng stream, same ops,
     compiled once under `lax.scan`);
  3. backends — the shard_map lowering is bit-identical to the vmap
     lowering for EVERY declared capability (plain, per-node transport,
     per-edge adaptive transport, CFA-GE gradient exchange), on both wires
     (encoded payload / decoded rows), single-pod here and on the forced
     4-device mesh in tests/test_exchange_unified.py;
  4. dynamics × server — FedAvg under churn aggregates LIVE clients only
     (the offline-clients-frozen-params regression).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.engine import (
    AggregationStrategy,
    Capabilities,
    Experiment,
    Schedule,
    TrainConfig,
    World,
    available_methods,
    build_round,
    get_method,
    register_method,
)
from repro.engine.strategies import _REGISTRY


@pytest.fixture(scope="module")
def tiny_world():
    """4-node ring over a reduced synth-mnist; small MLP."""
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=4, topology="ring",
                           seed=3, scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


TINY = dict(steps_per_round=2, batch_size=16, lr=0.1, momentum=0.9, seed=3)


def _exp(world, method="decdiff+vt", rounds=3, mode="loop", **kw):
    kw = {**TINY, **kw}
    return Experiment(world, method,
                      schedule=Schedule(rounds=rounds, eval_every=2,
                                        mode=mode), **kw)


def _params_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ----------------------------------------------------------------- registry


def test_unknown_method_error_lists_available():
    with pytest.raises(ValueError) as ei:
        get_method("decdfif+vt")  # typo'd
    msg = str(ei.value)
    assert "unknown method 'decdfif+vt'" in msg
    for name in available_methods():
        assert name in msg  # the full roster is in the message


def test_paper_roster_is_registered():
    roster = available_methods()
    for m in ("isol", "fedavg", "decavg", "dechetero", "cfa", "cfa-ge",
              "decdiff", "decdiff+vt"):
        assert m in roster
    spec = get_method("decdiff+vt")
    assert spec.loss == "vt" and not spec.common_init
    assert spec.strategy.supports_transport
    assert not get_method("cfa-ge").strategy.supports_transport
    assert get_method("fedavg").common_init


def test_register_method_guards():
    with pytest.raises(ValueError, match="already registered"):
        register_method("decdiff", get_method("decdiff").strategy)
    with pytest.raises(TypeError, match="AggregationStrategy"):
        register_method("not-a-strategy", lambda: None)


# ------------------------------------------------------------- capabilities


def test_capabilities_record_is_frozen_and_validated():
    caps = Capabilities()
    assert caps.kind == "gossip" and not caps.grad_exchange
    assert caps.transport  # plain model-gossip rides the comm transport
    with pytest.raises(Exception):
        caps.kind = "server"  # frozen
    with pytest.raises(ValueError, match="kind"):
        Capabilities(kind="peer-to-peer")
    with pytest.raises(ValueError, match="grad_exchange"):
        Capabilities(kind="server", grad_exchange=True)
    # the derived transport capability across the roster
    assert not Capabilities(kind="server").transport
    assert not Capabilities(kind="none").transport
    assert not Capabilities(grad_exchange=True).transport


def test_roster_capabilities_are_consistent():
    """Every registered strategy's legacy views delegate to its record."""
    for name in available_methods():
        s = get_method(name).strategy
        caps = s.capabilities
        assert isinstance(caps, Capabilities), name
        assert (s.kind, s.grad_exchange, s.supports_transport) == \
            (caps.kind, caps.grad_exchange, caps.transport), name
    assert get_method("cfa-ge").strategy.capabilities.grad_exchange
    assert get_method("fedavg").strategy.capabilities.kind == "server"
    assert get_method("isol").strategy.capabilities.kind == "none"


def test_register_method_rejects_shadowed_capabilities():
    """A subclass that shadows the derived views with stale class attrs
    (the pre-Capabilities declaration style) must fail at registration —
    with the roster in the message — not silently lower the wrong path."""

    class _Shadowed(AggregationStrategy):
        name = "shadowed"
        kind = "server"  # shadows the capabilities-delegating property

        def aggregate(self, exp, state, params, gathered, mask):
            return params

    with pytest.raises(ValueError, match="shadow") as ei:
        register_method("shadowed-test", _Shadowed())
    assert "decdiff" in str(ei.value)  # the roster is in the message

    class _NotARecord(AggregationStrategy):
        name = "notarecord"
        capabilities = {"kind": "gossip"}

        def aggregate(self, exp, state, params, gathered, mask):
            return params

    with pytest.raises(TypeError, match="Capabilities"):
        register_method("notarecord-test", _NotARecord())
    assert "shadowed-test" not in _REGISTRY
    assert "notarecord-test" not in _REGISTRY


def test_transport_error_lists_capable_roster(tiny_world):
    """The build-time capability error names the methods that DO support
    the transport, so the fix is in the message."""
    with pytest.raises(ValueError, match="model-gossip only") as ei:
        Experiment(tiny_world, "cfa-ge", comm=CommConfig(codec="fp32"))
    msg = str(ei.value)
    for m in ("'decdiff'", "'decdiff+vt'", "'dechetero'", "'cfa'"):
        assert m in msg
    assert "'cfa-ge'" not in msg.split("transport-capable")[1]


class _HeadroomStrategy(AggregationStrategy):
    """A deliberately-custom gossip rule: move each node a fixed fraction
    toward the plain delivered-neighbour mean (no data-size weighting).
    Exists to prove third-party strategies run the whole engine unchanged —
    including the transport, which it supports by capability."""

    name = "headroom"

    def __init__(self, alpha=0.5):
        self.alpha = alpha

    def init_state(self, exp):
        return {"valid": exp.nbr_valid}

    def aggregate(self, exp, state, params, gathered, mask):
        a = self.alpha

        def one(local, stacked, m):
            tot = jnp.maximum(jnp.sum(m), 1.0)
            gate = (jnp.sum(m) > 0).astype(jnp.float32)

            def leaf(li, st):
                mb = m.reshape(m.shape + (1,) * (st.ndim - 1))
                avg = jnp.sum(mb * st.astype(jnp.float32), axis=0) / tot
                lf = li.astype(jnp.float32)
                return (lf + gate * a * (avg - lf)).astype(li.dtype)

            return jax.tree.map(leaf, local, stacked)

        return jax.vmap(one, in_axes=(0, 0, 0))(
            params, gathered, state["valid"] * mask)


def test_custom_strategy_end_to_end(tiny_world):
    """The satellite contract: a registered custom strategy runs the full
    engine (local SGD, exchange, aggregation, eval, and the gossip
    transport selected purely off its capability)."""
    name = "headroom-test"
    register_method(name, _HeadroomStrategy(alpha=0.5), loss="vt")
    try:
        exp = _exp(tiny_world, name, rounds=3, mode="fused")
        hist = exp.run()
        assert np.isfinite(hist[-1].acc_mean)
        iso = _exp(tiny_world, "isol", rounds=3, mode="fused")
        iso.run()
        # gossip genuinely ran: differs from no-communication training
        assert not _params_equal(exp.params, iso.params)
        # capability-selected transport: same custom method, now with the
        # fp32/thr0/fixed transport in the middle — bit-for-bit equal
        comm = Experiment(tiny_world, name,
                          comm=CommConfig(codec="fp32"),
                          schedule=Schedule(rounds=3, eval_every=2,
                                            mode="fused"), **TINY)
        comm.run()
        assert comm.transport is not None
        assert _params_equal(exp.params, comm.params)
    finally:
        _REGISTRY.pop(name, None)


# ------------------------------------------------------ config / validation


def test_schedule_and_backend_validation(tiny_world):
    with pytest.raises(ValueError, match="schedule mode"):
        Schedule(rounds=3, mode="warp")
    with pytest.raises(ValueError, match="unknown backend"):
        Experiment(tiny_world, "decdiff+vt", backend="pmap")
    with pytest.raises(ValueError, match="unknown method"):
        Experiment(tiny_world, "decdiffff")
    with pytest.raises(ValueError, match="model-gossip only"):
        Experiment(tiny_world, "isol", comm=CommConfig(codec="fp32"))
    with pytest.raises(TypeError):
        Experiment(tiny_world, "decdiff+vt", warp_factor=9)


def test_shardmap_lowers_every_capability(tiny_world):
    """The configurations that historically raised at build time on the
    sharded backend — per-edge (adaptive) transport and CFA-GE gradient
    exchange — now lower through the unified exchange and match vmap
    bit-for-bit (single-pod here; real 4-pod axis in
    tests/test_exchange_unified.py)."""
    for method, comm in (
        ("decdiff+vt", CommConfig(codec="int8", per_edge=True,
                                  trigger_threshold=1.0)),
        ("dechetero", CommConfig(codec="int8", policy="adaptive",
                                 target_trigger=0.5)),
        ("cfa-ge", None),
    ):
        exps = []
        for backend in ("vmap", "shard_map"):
            exp = Experiment(tiny_world, method, comm=comm, backend=backend,
                             schedule=Schedule(rounds=3, eval_every=3,
                                               mode="loop"), **TINY)
            exp.run()
            exps.append(exp)
        assert _params_equal(exps[0].params, exps[1].params), method
        assert exps[0].comm_bytes_total == exps[1].comm_bytes_total, method
        assert exps[0].trig_history == exps[1].trig_history, method


def test_wire_validation_and_bit_identity(tiny_world):
    """`wire=` must validate; the encoded-payload gather (the default) and
    the decoded-rows oracle wire carry the same information.  Decode is
    deterministic, so a single exchange step is bitwise identical across
    wires (asserted at op level below); end-to-end the two builds are
    distinct XLA programs whose fusion may differ in the last ulp, so
    params compare at ulp tolerance while the integer-valued accounting
    (bytes, trigger history) must match exactly."""
    with pytest.raises(ValueError, match="unknown wire"):
        Experiment(tiny_world, "decdiff+vt", wire="telepathy")

    # op level: one exchange step, both wires, bitwise equal.
    from repro.comm.transport import GossipTransport

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((8, 33)), jnp.float32)}
    tr = GossipTransport(CommConfig(codec="int8", trigger_threshold=1.0),
                         params)
    st = tr.init_state(params)
    st = st._replace(last_sent=jnp.asarray(
        rng.standard_normal(st.last_sent.shape), jnp.float32))
    key = jax.random.PRNGKey(3)
    step = {w: jax.jit(lambda p, s, k, w=w: tr.exchange(p, s, k, wire=w))(
        params, st, key) for w in ("encoded", "decoded")}
    for a, b in zip(jax.tree.leaves(step["encoded"]),
                    jax.tree.leaves(step["decoded"])):
        assert jnp.array_equal(a, b)

    comm = CommConfig(codec="int8", trigger_threshold=1.0)
    exps = []
    for wire in ("encoded", "decoded"):
        exp = Experiment(tiny_world, "decdiff+vt", comm=comm,
                         backend="shard_map", wire=wire,
                         schedule=Schedule(rounds=3, eval_every=3,
                                           mode="loop"),
                         participation=0.7, **TINY)
        exp.run()
        exps.append(exp)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=3e-6, atol=1e-7),
        exps[0].params, exps[1].params)
    assert exps[0].comm_bytes_total == exps[1].comm_bytes_total
    assert exps[0].trig_history == exps[1].trig_history


def test_train_config_immutable_and_overridable(tiny_world):
    exp = _exp(tiny_world, rounds=2, lr=0.05)
    assert exp.train.lr == 0.05
    assert TrainConfig().lr == 1e-3  # defaults untouched
    with pytest.raises(Exception):
        exp.train.lr = 0.1  # frozen


# --------------------------------------------------- schedule equivalence


def test_fused_schedule_bitexact_vs_loop(tiny_world):
    """The scan-fused runner (one jitted program for K rounds + gated
    evals) must reproduce the per-round loop bit-for-bit: params, eval
    cadence, metrics, and — through the transport — the byte accounting."""
    comm = CommConfig(codec="fp32", trigger_threshold=0.0)
    loop = Experiment(tiny_world, "decdiff+vt", comm=comm,
                      schedule=Schedule(rounds=5, eval_every=2, mode="loop"),
                      participation=0.7, **TINY)
    hl = loop.run()
    fused = Experiment(tiny_world, "decdiff+vt", comm=comm,
                       schedule=Schedule(rounds=5, eval_every=2,
                                         mode="fused"),
                       participation=0.7, **TINY)
    hf = fused.run()
    assert _params_equal(loop.params, fused.params)
    assert [m.round for m in hl] == [m.round for m in hf] == [0, 2, 4]
    for a, b in zip(hl, hf):
        assert np.array_equal(a.acc_per_node, b.acc_per_node)
        assert np.array_equal(a.loss_per_node, b.loss_per_node)
        assert a.bytes_on_wire == b.bytes_on_wire
        assert a.triggered_frac == b.triggered_frac
    assert loop.comm_bytes_total == fused.comm_bytes_total > 0
    assert loop.trig_history == fused.trig_history


def test_fused_schedule_continues_across_runs(tiny_world):
    """Repeated run() calls continue from the evolved state in both modes
    (the legacy contract benchmarks rely on for warmup-then-measure)."""
    a = _exp(tiny_world, rounds=2, mode="loop")
    a.run()
    a.run()
    b = _exp(tiny_world, rounds=2, mode="fused")
    b.run()
    b.run()
    assert _params_equal(a.params, b.params)


# -------------------------------------------------- backend equivalence


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a real pod axis")
def test_vmap_shardmap_scanfused_bit_identical(tiny_world):
    """The ISSUE-4 acceptance pin: the same decdiff+vt spec (with the
    fp32/threshold-0/fixed comm) lowered to vmap, to shard_map over the
    4-pod CPU mesh, and scan-fused on top, yields bit-identical params."""
    comm = CommConfig(codec="fp32", trigger_threshold=0.0)
    runs = {}
    for backend in ("vmap", "shard_map"):
        for mode in ("loop", "fused"):
            exp = Experiment(tiny_world, "decdiff+vt", comm=comm,
                             backend=backend,
                             schedule=Schedule(rounds=3, eval_every=2,
                                               mode=mode), **TINY)
            hist = exp.run()
            runs[(backend, mode)] = (exp, hist)
    ref, ref_hist = runs[("vmap", "loop")]
    assert ref.mesh is None  # the vmap lowering is mesh-free
    for key, (exp, hist) in runs.items():
        assert _params_equal(ref.params, exp.params), key
        assert ref.comm_bytes_total == exp.comm_bytes_total, key
        assert ref.trig_history == exp.trig_history, key
        for a, b in zip(ref_hist, hist):
            assert np.array_equal(a.acc_per_node, b.acc_per_node), key
    smap = runs[("shard_map", "loop")][0]
    assert int(smap.mesh.shape["pod"]) == 4  # a real 4-pod axis was used


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a real pod axis")
def test_shardmap_event_triggered_int8_matches_vmap(tiny_world):
    """Beyond the acceptance floor: the per-NODE transport with a real
    codec + trigger also lowers to shard_map bit-identically (state rows
    shard with their nodes; gates/caches cross pods via all_gather)."""
    comm = CommConfig(codec="int8", trigger_threshold=1.0, stochastic=True)
    exps = []
    for backend in ("vmap", "shard_map"):
        exp = Experiment(tiny_world, "decdiff+vt", comm=comm,
                         backend=backend,
                         schedule=Schedule(rounds=4, eval_every=10,
                                           mode="fused"),
                         participation=0.7, **TINY)
        exp.run()
        exps.append(exp)
    assert _params_equal(exps[0].params, exps[1].params)
    assert exps[0].trig_history == exps[1].trig_history
    assert np.array_equal(np.asarray(exps[0].comm_state.last_sent),
                          np.asarray(exps[1].comm_state.last_sent))


def test_shardmap_single_pod_matches_vmap(tiny_world):
    """On a single-device host the shard_map lowering degenerates to one
    pod and must still match vmap exactly (so the backend is exercised
    everywhere, not only in the multihost CI lane)."""
    ref = _exp(tiny_world, rounds=2, mode="loop")
    ref.run()
    smap = Experiment(tiny_world, "decdiff+vt", backend="shard_map",
                      schedule=Schedule(rounds=2, eval_every=2, mode="loop"),
                      **TINY)
    smap.run()
    assert _params_equal(ref.params, smap.params)


def test_build_round_signature_matches_transport(tiny_world):
    """build_round is the public lowering hook: its calling convention is
    (params, opt, [comm_state,] round_idx, rng)."""
    exp = _exp(tiny_world, rounds=1)
    fn = build_round(exp)
    out = fn(exp.params, exp.opt_state, jnp.int32(0), exp.rng)
    assert len(out) == 4  # params, opt, rng, loss
    cexp = Experiment(tiny_world, "decdiff+vt",
                      comm=CommConfig(codec="fp32"),
                      schedule=Schedule(rounds=1, eval_every=1), **TINY)
    cfn = build_round(cexp)
    out = cfn(cexp.params, cexp.opt_state, cexp.comm_state, jnp.int32(0),
              cexp.rng)
    assert len(out) == 7  # + comm_state, sent_edges, trig_frac


# ------------------------------------------------ server-under-churn bugfix


def _node0_dead():
    """A deterministic process: node 0 is offline every round (never having
    been alive, nothing ever 'rejoins').  Minimal churn fixture for the
    FedAvg liveness regression."""
    from repro.dynamics import GraphEvent, GraphProcess

    class _P(GraphProcess):
        name = "node0-dead"
        needs_rng = False

        def make_step(self, topo):
            idx = jnp.asarray(np.maximum(topo.neighbor_idx, 0))
            valid = jnp.asarray(topo.neighbor_mask.astype(np.float32))
            n = topo.num_nodes
            alive = jnp.ones((n,), jnp.float32).at[0].set(0.0)
            live = valid * alive[:, None] * alive[idx]
            zeros = jnp.zeros((n,), jnp.float32)

            def step(state, round_idx, key):
                del round_idx, key
                return state, GraphEvent(live=live, alive=alive,
                                         rejoined=zeros)

            return step

    return _P()


def test_fedavg_under_churn_averages_live_clients_only(tiny_world):
    """The regression: a churned-out client's frozen params must carry ZERO
    weight in the server average.  fedavg uses common init, so isolating
    the bug is exact: run the same world with node 0 permanently offline,
    recover the post-training pre-aggregation models from an identically-
    seeded no-aggregation run (same init keys, same rng stream through
    local training), and check the engine's round equals the data-size-
    weighted average over the LIVE clients — and NOT the buggy all-clients
    average that would drag in node 0's never-trained init."""
    import dataclasses as _dc

    from repro.core.aggregation import fedavg_aggregate

    world = _dc.replace(tiny_world, dynamics=_node0_dead())
    exp = Experiment(world, "fedavg",
                     schedule=Schedule(rounds=1, eval_every=1, mode="loop"),
                     **TINY)
    counts = np.asarray(exp.counts, np.float32)
    exp.run()

    # the trained-but-unaggregated models, via a common-init isolation twin
    # (identical init keys and rng stream up to the aggregation step)
    name = "isol-coordinated-test"
    register_method(name, get_method("isol").strategy, common_init=True)
    try:
        twin = Experiment(_dc.replace(tiny_world, dynamics=_node0_dead()),
                          name,
                          schedule=Schedule(rounds=1, eval_every=1,
                                            mode="loop"), **TINY)
        p0 = jax.tree.map(np.asarray, twin.params)
        twin.run()
    finally:
        _REGISTRY.pop(name, None)

    alive = np.asarray([0.0, 1.0, 1.0, 1.0], np.float32)
    want_live = fedavg_aggregate(twin.params, jnp.asarray(counts * alive))
    buggy = fedavg_aggregate(twin.params, jnp.asarray(counts))
    got = jax.tree.map(np.asarray, exp.params)
    for g, w, b, init in zip(jax.tree.leaves(got),
                             jax.tree.leaves(want_live),
                             jax.tree.leaves(buggy),
                             jax.tree.leaves(p0)):
        for i in (1, 2, 3):  # live clients hold the live-only average
            assert np.array_equal(g[i], np.asarray(w))
            assert not np.array_equal(g[i], np.asarray(b))
        assert np.array_equal(g[0], init[0])  # the dead client froze
