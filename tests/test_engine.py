"""repro.engine contracts: the strategy registry, backend/schedule
equivalence, and the deprecated DFLSimulator shim.

The load-bearing pins:

  1. registry — unknown methods fail with the available roster in the
     message; custom strategies registered through `register_method` run
     end-to-end through the same engine as the built-ins;
  2. schedule — the scan-fused runner produces bit-identical params and
     metrics to the per-round Python loop (same rng stream, same ops,
     compiled once under `lax.scan`);
  3. backends — the shard_map lowering on the forced 4-device CPU mesh is
     bit-identical to the vmap lowering, plain AND through the fp32/
     threshold-0/fixed transport (the ISSUE-4 acceptance spec), AND
     scan-fused on top;
  4. shim — `DFLSimulator` warns DeprecationWarning and delegates to an
     `Experiment` that reproduces it bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.engine import (
    AggregationStrategy,
    Experiment,
    Schedule,
    TrainConfig,
    World,
    available_methods,
    build_round,
    get_method,
    register_method,
)
from repro.engine.strategies import _REGISTRY


@pytest.fixture(scope="module")
def tiny_world():
    """4-node ring over a reduced synth-mnist; small MLP."""
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=4, topology="ring",
                           seed=3, scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


TINY = dict(steps_per_round=2, batch_size=16, lr=0.1, momentum=0.9, seed=3)


def _exp(world, method="decdiff+vt", rounds=3, mode="loop", **kw):
    kw = {**TINY, **kw}
    return Experiment(world, method,
                      schedule=Schedule(rounds=rounds, eval_every=2,
                                        mode=mode), **kw)


def _params_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ----------------------------------------------------------------- registry


def test_unknown_method_error_lists_available():
    with pytest.raises(ValueError) as ei:
        get_method("decdfif+vt")  # typo'd
    msg = str(ei.value)
    assert "unknown method 'decdfif+vt'" in msg
    for name in available_methods():
        assert name in msg  # the full roster is in the message


def test_paper_roster_is_registered():
    roster = available_methods()
    for m in ("isol", "fedavg", "decavg", "dechetero", "cfa", "cfa-ge",
              "decdiff", "decdiff+vt"):
        assert m in roster
    spec = get_method("decdiff+vt")
    assert spec.loss == "vt" and not spec.common_init
    assert spec.strategy.supports_transport
    assert not get_method("cfa-ge").strategy.supports_transport
    assert get_method("fedavg").common_init


def test_register_method_guards():
    with pytest.raises(ValueError, match="already registered"):
        register_method("decdiff", get_method("decdiff").strategy)
    with pytest.raises(TypeError, match="AggregationStrategy"):
        register_method("not-a-strategy", lambda: None)


class _HeadroomStrategy(AggregationStrategy):
    """A deliberately-custom gossip rule: move each node a fixed fraction
    toward the plain delivered-neighbour mean (no data-size weighting).
    Exists to prove third-party strategies run the whole engine unchanged —
    including the transport, which it supports by capability."""

    name = "headroom"

    def __init__(self, alpha=0.5):
        self.alpha = alpha

    def init_state(self, exp):
        return {"valid": exp.nbr_valid}

    def aggregate(self, exp, state, params, gathered, mask):
        a = self.alpha

        def one(local, stacked, m):
            tot = jnp.maximum(jnp.sum(m), 1.0)
            gate = (jnp.sum(m) > 0).astype(jnp.float32)

            def leaf(li, st):
                mb = m.reshape(m.shape + (1,) * (st.ndim - 1))
                avg = jnp.sum(mb * st.astype(jnp.float32), axis=0) / tot
                lf = li.astype(jnp.float32)
                return (lf + gate * a * (avg - lf)).astype(li.dtype)

            return jax.tree.map(leaf, local, stacked)

        return jax.vmap(one, in_axes=(0, 0, 0))(
            params, gathered, state["valid"] * mask)


def test_custom_strategy_end_to_end(tiny_world):
    """The satellite contract: a registered custom strategy runs the full
    engine (local SGD, exchange, aggregation, eval, and the gossip
    transport selected purely off its capability)."""
    name = "headroom-test"
    register_method(name, _HeadroomStrategy(alpha=0.5), loss="vt")
    try:
        exp = _exp(tiny_world, name, rounds=3, mode="fused")
        hist = exp.run()
        assert np.isfinite(hist[-1].acc_mean)
        iso = _exp(tiny_world, "isol", rounds=3, mode="fused")
        iso.run()
        # gossip genuinely ran: differs from no-communication training
        assert not _params_equal(exp.params, iso.params)
        # capability-selected transport: same custom method, now with the
        # fp32/thr0/fixed transport in the middle — bit-for-bit equal
        comm = Experiment(tiny_world, name,
                          comm=CommConfig(codec="fp32"),
                          schedule=Schedule(rounds=3, eval_every=2,
                                            mode="fused"), **TINY)
        comm.run()
        assert comm.transport is not None
        assert _params_equal(exp.params, comm.params)
    finally:
        _REGISTRY.pop(name, None)


# ------------------------------------------------------ config / validation


def test_schedule_and_backend_validation(tiny_world):
    with pytest.raises(ValueError, match="schedule mode"):
        Schedule(rounds=3, mode="warp")
    with pytest.raises(ValueError, match="unknown backend"):
        Experiment(tiny_world, "decdiff+vt", backend="pmap")
    with pytest.raises(ValueError, match="unknown method"):
        Experiment(tiny_world, "decdiffff")
    with pytest.raises(ValueError, match="model-gossip only"):
        Experiment(tiny_world, "isol", comm=CommConfig(codec="fp32"))
    with pytest.raises(TypeError):
        Experiment(tiny_world, "decdiff+vt", warp_factor=9)


def test_shardmap_backend_capability_gates(tiny_world):
    """Per-edge transport state and CFA-GE are vmap-only; the shard_map
    lowering must say so at build time, not fail inside jit."""
    with pytest.raises(NotImplementedError, match="per-edge"):
        Experiment(tiny_world, "decdiff+vt", backend="shard_map",
                   comm=CommConfig(codec="int8", per_edge=True), **TINY)
    with pytest.raises(NotImplementedError, match="vmap-only"):
        Experiment(tiny_world, "cfa-ge", backend="shard_map", **TINY)


def test_train_config_immutable_and_overridable(tiny_world):
    exp = _exp(tiny_world, rounds=2, lr=0.05)
    assert exp.train.lr == 0.05
    assert TrainConfig().lr == 1e-3  # defaults untouched
    with pytest.raises(Exception):
        exp.train.lr = 0.1  # frozen


# --------------------------------------------------- schedule equivalence


def test_fused_schedule_bitexact_vs_loop(tiny_world):
    """The scan-fused runner (one jitted program for K rounds + gated
    evals) must reproduce the per-round loop bit-for-bit: params, eval
    cadence, metrics, and — through the transport — the byte accounting."""
    comm = CommConfig(codec="fp32", trigger_threshold=0.0)
    loop = Experiment(tiny_world, "decdiff+vt", comm=comm,
                      schedule=Schedule(rounds=5, eval_every=2, mode="loop"),
                      participation=0.7, **TINY)
    hl = loop.run()
    fused = Experiment(tiny_world, "decdiff+vt", comm=comm,
                       schedule=Schedule(rounds=5, eval_every=2,
                                         mode="fused"),
                       participation=0.7, **TINY)
    hf = fused.run()
    assert _params_equal(loop.params, fused.params)
    assert [m.round for m in hl] == [m.round for m in hf] == [0, 2, 4]
    for a, b in zip(hl, hf):
        assert np.array_equal(a.acc_per_node, b.acc_per_node)
        assert np.array_equal(a.loss_per_node, b.loss_per_node)
        assert a.bytes_on_wire == b.bytes_on_wire
        assert a.triggered_frac == b.triggered_frac
    assert loop.comm_bytes_total == fused.comm_bytes_total > 0
    assert loop.trig_history == fused.trig_history


def test_fused_schedule_continues_across_runs(tiny_world):
    """Repeated run() calls continue from the evolved state in both modes
    (the legacy contract benchmarks rely on for warmup-then-measure)."""
    a = _exp(tiny_world, rounds=2, mode="loop")
    a.run()
    a.run()
    b = _exp(tiny_world, rounds=2, mode="fused")
    b.run()
    b.run()
    assert _params_equal(a.params, b.params)


# -------------------------------------------------- backend equivalence


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a real pod axis")
def test_vmap_shardmap_scanfused_bit_identical(tiny_world):
    """The ISSUE-4 acceptance pin: the same decdiff+vt spec (with the
    fp32/threshold-0/fixed comm) lowered to vmap, to shard_map over the
    4-pod CPU mesh, and scan-fused on top, yields bit-identical params."""
    comm = CommConfig(codec="fp32", trigger_threshold=0.0)
    runs = {}
    for backend in ("vmap", "shard_map"):
        for mode in ("loop", "fused"):
            exp = Experiment(tiny_world, "decdiff+vt", comm=comm,
                             backend=backend,
                             schedule=Schedule(rounds=3, eval_every=2,
                                               mode=mode), **TINY)
            hist = exp.run()
            runs[(backend, mode)] = (exp, hist)
    ref, ref_hist = runs[("vmap", "loop")]
    assert ref.mesh is None  # the vmap lowering is mesh-free
    for key, (exp, hist) in runs.items():
        assert _params_equal(ref.params, exp.params), key
        assert ref.comm_bytes_total == exp.comm_bytes_total, key
        assert ref.trig_history == exp.trig_history, key
        for a, b in zip(ref_hist, hist):
            assert np.array_equal(a.acc_per_node, b.acc_per_node), key
    smap = runs[("shard_map", "loop")][0]
    assert int(smap.mesh.shape["pod"]) == 4  # a real 4-pod axis was used


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a real pod axis")
def test_shardmap_event_triggered_int8_matches_vmap(tiny_world):
    """Beyond the acceptance floor: the per-NODE transport with a real
    codec + trigger also lowers to shard_map bit-identically (state rows
    shard with their nodes; gates/caches cross pods via all_gather)."""
    comm = CommConfig(codec="int8", trigger_threshold=1.0, stochastic=True)
    exps = []
    for backend in ("vmap", "shard_map"):
        exp = Experiment(tiny_world, "decdiff+vt", comm=comm,
                         backend=backend,
                         schedule=Schedule(rounds=4, eval_every=10,
                                           mode="fused"),
                         participation=0.7, **TINY)
        exp.run()
        exps.append(exp)
    assert _params_equal(exps[0].params, exps[1].params)
    assert exps[0].trig_history == exps[1].trig_history
    assert np.array_equal(np.asarray(exps[0].comm_state.last_sent),
                          np.asarray(exps[1].comm_state.last_sent))


def test_shardmap_single_pod_matches_vmap(tiny_world):
    """On a single-device host the shard_map lowering degenerates to one
    pod and must still match vmap exactly (so the backend is exercised
    everywhere, not only in the multihost CI lane)."""
    ref = _exp(tiny_world, rounds=2, mode="loop")
    ref.run()
    smap = Experiment(tiny_world, "decdiff+vt", backend="shard_map",
                      schedule=Schedule(rounds=2, eval_every=2, mode="loop"),
                      **TINY)
    smap.run()
    assert _params_equal(ref.params, smap.params)


def test_build_round_signature_matches_transport(tiny_world):
    """build_round is the public lowering hook: its calling convention is
    (params, opt, [comm_state,] round_idx, rng)."""
    exp = _exp(tiny_world, rounds=1)
    fn = build_round(exp)
    out = fn(exp.params, exp.opt_state, jnp.int32(0), exp.rng)
    assert len(out) == 4  # params, opt, rng, loss
    cexp = Experiment(tiny_world, "decdiff+vt",
                      comm=CommConfig(codec="fp32"),
                      schedule=Schedule(rounds=1, eval_every=1), **TINY)
    cfn = build_round(cexp)
    out = cfn(cexp.params, cexp.opt_state, cexp.comm_state, jnp.int32(0),
              cexp.rng)
    assert len(out) == 7  # + comm_state, sent_edges, trig_frac


# --------------------------------------------------------------- the shim


def test_dflsimulator_shim_warns_and_matches_experiment(tiny_world):
    """The legacy front door must (a) raise DeprecationWarning, (b) be
    bit-for-bit the Experiment it wraps, (c) keep the old attribute
    surface (METHODS view, comm accounting)."""
    from repro.fl import DFLSimulator, METHODS, SimulatorConfig

    cfg = SimulatorConfig(method="decdiff+vt", rounds=3, eval_every=2,
                          comm=CommConfig(codec="fp32"), **TINY)
    with pytest.deprecated_call(match="DFLSimulator is deprecated"):
        sim = DFLSimulator(tiny_world.model, tiny_world.topo, tiny_world.xs,
                           tiny_world.ys, tiny_world.x_test,
                           tiny_world.y_test, cfg)
    hist = sim.run()
    exp = Experiment(tiny_world, "decdiff+vt", comm=CommConfig(codec="fp32"),
                     schedule=Schedule(rounds=3, eval_every=2, mode="loop"),
                     **TINY)
    eh = exp.run()
    assert _params_equal(sim.params, exp.params)
    assert sim.comm_bytes_total == exp.comm_bytes_total
    assert [m.round for m in hist] == [m.round for m in eh]
    # legacy surface intact
    assert sim.spec == {"agg": "decdiff", "loss": "vt", "common_init": False}
    assert METHODS["cfa-ge"]["grad_exchange"] is True
    assert METHODS["fedavg"]["agg"] == "server"
