"""One exchange path everywhere — the cross-pod bit-identity matrix.

This file is the acceptance pin for the unified exchange primitive: every
strategy capability (plain, server, per-node transport, per-edge adaptive
transport, CFA-GE gradient exchange) × every dynamics process (static,
EdgeDropout, GilbertElliott, NodeChurn) lowers to the shard_map backend
over a REAL forced 4-device pod mesh and reproduces the vmap lowering
bit-for-bit: final params, total comm bytes, trigger history, and the
realized live fraction per round.

Run via the CI multihost lane:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        pytest -m "fuzz or multihost" tests/test_exchange_unified.py

Single-pod degenerate coverage of the same matrix lives in
tests/test_engine.py (test_shardmap_lowers_every_capability), so the
backend is exercised on every host, not only in the multihost lane.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.dynamics import EdgeDropout, GilbertElliott, NodeChurn
from repro.engine import Experiment, Schedule, World

pytestmark = [
    pytest.mark.multihost,
    pytest.mark.skipif(len(jax.devices()) < 4,
                       reason="needs >= 4 devices for a real pod axis"),
]

TINY = dict(steps_per_round=2, batch_size=16, lr=0.1, momentum=0.9, seed=3)


@pytest.fixture(scope="module")
def tiny_world():
    """8-node ring (divisible by the 4-pod mesh) over reduced synth-mnist."""
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=8, topology="ring",
                           seed=3, scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


def _params_equal(a, b):
    return all(bool(jnp.array_equal(x, y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# (label, method, comm config, extra Experiment kwargs) — one entry per
# declared capability, including both NotImplementedError carve-outs this
# refactor deleted (per-edge adaptive transport and CFA-GE on shard_map).
CONFIGS = [
    ("plain", "decdiff+vt", None, {}),
    ("server", "fedavg", None, {}),
    ("per-node-int8", "decdiff+vt",
     CommConfig(codec="int8", trigger_threshold=1.0), {}),
    ("per-edge-topk", "decdiff+vt",
     CommConfig(codec="topk", topk_ratio=0.25, per_edge=True,
                trigger_threshold=0.5), {}),
    ("per-edge-adaptive", "dechetero",
     CommConfig(codec="int8", policy="adaptive", target_trigger=0.6), {}),
    ("cfa-ge", "cfa-ge", None, {}),
]

DYNAMICS = [
    ("static", None),
    ("dropout", EdgeDropout(p=0.3)),
    ("gilbert-elliott", GilbertElliott(p_gb=0.25, p_bg=0.4)),
    ("churn", NodeChurn(p_leave=0.3, p_rejoin=0.6)),
]


@pytest.mark.parametrize("dyn_label,dyn", DYNAMICS,
                         ids=[d[0] for d in DYNAMICS])
@pytest.mark.parametrize("label,method,comm,extra", CONFIGS,
                         ids=[c[0] for c in CONFIGS])
def test_vmap_shardmap_bit_identical(tiny_world, label, method, comm, extra,
                                     dyn_label, dyn):
    world = (tiny_world if dyn is None
             else dataclasses.replace(tiny_world, dynamics=dyn))
    runs = []
    for backend in ("vmap", "shard_map"):
        exp = Experiment(world, method, comm=comm, backend=backend,
                         schedule=Schedule(rounds=3, eval_every=10,
                                           mode="loop"),
                         **TINY, **extra)
        exp.run()
        runs.append(exp)
    ref, smap = runs
    assert int(smap.mesh.shape["pod"]) == 4  # a real pod axis was used
    assert _params_equal(ref.params, smap.params)
    assert ref.comm_bytes_total == smap.comm_bytes_total
    assert ref.trig_history == smap.trig_history
    assert ref.live_history == smap.live_history


def test_accounting_per_edge_adaptive_under_bursty_links(tiny_world):
    """ISSUE pin: byte and trigger accounting for the per-edge adaptive
    transport under GilbertElliott must agree across backends AND be
    non-trivial (the process realizes bursts; the policy actually gates)."""
    world = dataclasses.replace(
        tiny_world, dynamics=GilbertElliott(p_gb=0.3, p_bg=0.3))
    comm = CommConfig(codec="int8", policy="adaptive", target_trigger=0.5)
    runs = []
    for backend in ("vmap", "shard_map"):
        exp = Experiment(world, "decdiff+vt", comm=comm, backend=backend,
                         schedule=Schedule(rounds=5, eval_every=10,
                                           mode="fused"), **TINY)
        exp.run()
        runs.append(exp)
    ref, smap = runs
    assert ref.comm_bytes_total == smap.comm_bytes_total
    assert ref.trig_history == smap.trig_history
    assert ref.live_history == smap.live_history
    assert 0.0 < min(ref.live_history) < 1.0   # bursts realized
    assert 0.0 < min(ref.trig_history) < 1.0   # the gate actually gated
    assert ref.comm_bytes_total > 0
    # per-edge transport state sharded with its rows and still matches the
    # dense reference bit-for-bit after the cross-pod reverse-slot gather.
    for a, b in zip(jax.tree.leaves(ref.comm_state),
                    jax.tree.leaves(smap.comm_state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_cfa_ge_gradient_exchange_under_churn(tiny_world):
    """The second deleted carve-out: CFA-GE's extra gradient-exchange pass
    (consensus over neighbour params + exchanged gradients) lowers to the
    4-pod mesh bit-identically, with churn's live/reset masks threaded
    through the same unified path."""
    world = dataclasses.replace(
        tiny_world, dynamics=NodeChurn(p_leave=0.25, p_rejoin=0.5))
    runs = []
    for backend in ("vmap", "shard_map"):
        exp = Experiment(world, "cfa-ge", backend=backend,
                         schedule=Schedule(rounds=4, eval_every=10,
                                           mode="fused"), **TINY)
        exp.run()
        runs.append(exp)
    ref, smap = runs
    assert _params_equal(ref.params, smap.params)
    assert ref.live_history == smap.live_history
