"""Topology substrate + non-IID allocation properties."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (a dev dependency; CI installs it)")

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.data.allocation import (
    allocation_gini,
    gini_index,
    pad_node_datasets,
    zipf_allocation,
)
from repro.data.pipeline import Batcher
from repro.data.synth import make_dataset
from repro.graphs import make_topology
from repro.graphs.partition import map_graph_to_pods, pod_adjacency


def test_er_connected_above_threshold():
    topo = make_topology("erdos_renyi", n=50, p=0.2, seed=3)
    assert topo.connected and topo.num_nodes == 50


def test_topology_families():
    for name, kw in [("barabasi_albert", dict(n=30, m=2)),
                     ("watts_strogatz", dict(n=30, k=4, p=0.2)),
                     ("ring", dict(n=10)), ("star", dict(n=10)),
                     ("complete", dict(n=8)), ("grid2d", dict(rows=3, cols=4))]:
        topo = make_topology(name, **kw)
        adj = topo.adjacency
        assert (adj == adj.T).all() and adj.diagonal().sum() == 0
        assert topo.connected
        # padded neighbour lists consistent with adjacency
        for i in range(topo.num_nodes):
            nbrs = {int(j) for j in topo.neighbor_idx[i] if j >= 0}
            assert nbrs == set(np.nonzero(adj[i])[0].tolist())


def test_star_degrees():
    topo = make_topology("star", n=10)
    assert topo.degrees[0] == 9 and (topo.degrees[1:] == 1).all()


@settings(deadline=None, max_examples=15)
@given(n=st.integers(4, 30), seed=st.integers(0, 2 ** 16))
def test_neighbor_weights_equivalence_fuzz(n, seed):
    """Fuzzed arm of the neighbor_weights loop-oracle pin (the seeded
    deterministic arm lives in tests/test_dynamics.py so it runs in tier-1
    even without hypothesis installed)."""
    topo = make_topology(
        "erdos_renyi", n=n, p=0.4, seed=seed,
        weight_fn=lambda i, j, rng: rng.uniform(0.1, 3.0))
    ref = np.zeros_like(topo.neighbor_mask, np.float32)
    for i in range(topo.num_nodes):
        for k in range(topo.neighbor_idx.shape[1]):
            j = topo.neighbor_idx[i, k]
            if j >= 0:
                ref[i, k] = topo.weights[i, j]
    assert np.array_equal(topo.neighbor_weights(), ref)


@settings(deadline=None, max_examples=20)
@given(st.lists(st.floats(0.0, 1e6), min_size=1, max_size=40))
def test_gini_range(xs):
    g = gini_index(xs)
    assert 0.0 <= g <= 1.0


def test_gini_known_values():
    assert gini_index([5, 5, 5, 5]) == 0.0
    assert gini_index([0, 0, 0, 100]) > 0.7


def test_zipf_allocation_partition():
    """Allocation is a disjoint cover of all samples, min-per-class holds."""
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 3000)
    alloc = zipf_allocation(labels, 20, seed=1, min_per_class=1)
    all_idx = np.concatenate(alloc)
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)  # disjoint
    for ix in alloc:
        got = set(labels[ix].tolist())
        assert got == set(range(10))  # every node sees every class


def test_zipf_allocation_skew():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 20000)
    alloc = zipf_allocation(labels, 50, seed=2, min_per_class=1)
    gi = allocation_gini(alloc, labels)
    assert gi > 0.55  # strongly non-IID (paper operates at 0.7-0.85 full-scale)


def test_rank_correlation_increases_quantity_skew():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 20000)
    g0 = allocation_gini(zipf_allocation(labels, 30, seed=3, rank_correlation=0.0))
    g1 = allocation_gini(zipf_allocation(labels, 30, seed=3, rank_correlation=1.0))
    assert g1 > g0 + 0.1


def test_pad_node_datasets():
    xs = [np.ones((3, 2)), np.ones((7, 2)) * 2]
    ys = [np.zeros(3, np.int32), np.ones(7, np.int32)]
    xp, yp, counts = pad_node_datasets(xs, ys)
    assert xp.shape == (2, 7, 2) and (counts == [3, 7]).all()


def test_batcher_deterministic_and_in_range():
    import jax.numpy as jnp

    b = Batcher(batch_size=4)
    x = jnp.arange(10).reshape(10, 1).astype(jnp.float32)
    y = jnp.arange(10).astype(jnp.int32)
    x1, y1 = b.take(x, y, jnp.int32(7), jnp.int32(0))
    x2, y2 = b.take(x, y, jnp.int32(7), jnp.int32(0))
    assert (np.asarray(y1) == np.asarray(y2)).all()
    assert (np.asarray(y1) < 7).all()  # never touches padding region


def test_synth_dataset_learnable_and_standardized():
    ds = make_dataset("synth-mnist", seed=0, scale=0.02)
    assert abs(ds.x_train.mean()) < 0.05 and abs(ds.x_train.std() - 1) < 0.05
    # nearest-class-mean does far better than chance -> class structure exists
    means = np.stack([ds.x_train[ds.y_train == c].mean(0) for c in range(10)])
    d = ((ds.x_test[:, None] - means[None]) ** 2).sum((2, 3))
    acc = (d.argmin(1) == ds.y_test).mean()
    assert acc > 0.3


def test_graph_partition_to_pods():
    topo = make_topology("erdos_renyi", n=20, p=0.3, seed=0)
    groups = map_graph_to_pods(topo, 4)
    assert len(groups) == 4
    got = sorted(n for g in groups for n in g)
    assert got == list(range(20))
    w = pod_adjacency(topo, groups)
    assert w.shape == (4, 4) and (w >= 0).all() and np.allclose(w, w.T)


@settings(deadline=None, max_examples=25)
@given(n=st.integers(2, 60), num_pods=st.integers(1, 60),
       seed=st.integers(0, 2 ** 16))
def test_map_graph_to_pods_partition_property(n, num_pods, seed):
    """Property arm of the partition pin (seeded deterministic arm lives in
    tests/test_sparse_graphs.py): for ANY random graph and ANY pod count
    <= n, the groups are an exact cover with +-1 balanced sizes and no
    empty pod; counts beyond n raise rather than yielding empty pods."""
    topo = make_topology("erdos_renyi", n=n, p=0.5, seed=seed)
    if num_pods > n:
        with pytest.raises(ValueError, match="empty pods"):
            map_graph_to_pods(topo, num_pods)
        return
    groups = map_graph_to_pods(topo, num_pods)
    base, rem = divmod(n, num_pods)
    assert [len(g) for g in groups] == \
        [base + 1 if g < rem else base for g in range(num_pods)]
    assert sorted(x for g in groups for x in g) == list(range(n))
