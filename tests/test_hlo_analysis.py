"""Unit tests for the HLO collective parser feeding the roofline."""
from repro.launch.hlo_analysis import _shape_bytes, collective_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[128,1024]") == 128 * 1024 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert _shape_bytes("pred[7]") == 7


def test_all_reduce_operand_equals_result():
    hlo = ("%all-reduce.1 = f32[128,64]{1,0} all-reduce(%p), channel_id=1, "
           "replica_groups=[16,16]<=[256], to_apply=%add\n")
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 64 * 4
    assert out["all-reduce_count"] == 1
    assert out["total"] == 128 * 64 * 4


def test_all_gather_divides_by_group():
    hlo = ("%all-gather.9 = bf16[256,1024]{1,0} all-gather(%x), "
           "dimensions={0}, replica_groups=[16,16]<=[256], "
           "use_global_device_ids=true\n")
    out = collective_bytes(hlo)
    assert out["all-gather"] == 256 * 1024 * 2 // 16


def test_reduce_scatter_multiplies_by_group():
    hlo = ("%reduce-scatter.2 = f32[8,8]{1,0} reduce-scatter(%x), "
           "replica_groups=[4,8]<=[32], to_apply=%add\n")
    out = collective_bytes(hlo)
    assert out["reduce-scatter"] == 8 * 8 * 4 * 8


def test_all_to_all_tuple_result():
    hlo = ("%all-to-all.5 = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b), "
           "replica_groups=[2,2]<=[4]\n")
    out = collective_bytes(hlo)
    assert out["all-to-all"] == 2 * 4 * 4 * 4


def test_done_halves_skipped():
    hlo = ("%ag-start = bf16[64,64]{1,0} all-gather-start(%x), "
           "replica_groups=[8,8]<=[64]\n"
           "%ag-done = bf16[64,64]{1,0} all-gather-done(%ag-start)\n")
    out = collective_bytes(hlo)
    assert out["all-gather_count"] == 1


def test_collective_permute():
    hlo = ("%collective-permute.3 = f32[16,16]{1,0} collective-permute(%x), "
           "source_target_pairs={{0,1},{1,0}}\n")
    out = collective_bytes(hlo)
    assert out["collective-permute"] == 16 * 16 * 4


def test_non_collective_lines_ignored():
    hlo = "%add.1 = f32[1024]{0} add(%a, %b)\n%dot = f32[8,8] dot(%c, %d)\n"
    assert collective_bytes(hlo)["total"] == 0
