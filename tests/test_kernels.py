"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    decdiff_update,
    decdiff_update_tree,
    neighbor_avg,
    vt_kl_loss_fused,
)
from repro.kernels.ref import (
    decdiff_update_ref,
    neighbor_avg_ref,
    vt_kl_grad_ref,
    vt_kl_loss_ref,
)
from repro.utils.pytree import tree_l2_dist, tree_random_like


@pytest.mark.parametrize("n", [17, 1000, 32768, 100_001, 500_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decdiff_update_sweep(n, dtype):
    rng = np.random.default_rng(n)
    w = jnp.asarray(rng.standard_normal(n), dtype)
    wb = jnp.asarray(rng.standard_normal(n), dtype)
    got = decdiff_update(w, wb, s=1.0)
    want = decdiff_update_ref(w, wb, s=1.0)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("s", [1.0, 2.5])
def test_decdiff_update_s_param(s):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal(5000), jnp.float32)
    wb = jnp.asarray(rng.standard_normal(5000), jnp.float32)
    np.testing.assert_allclose(decdiff_update(w, wb, s=s),
                               decdiff_update_ref(w, wb, s=s), rtol=1e-5)


def test_decdiff_update_tree_matches_core():
    from repro.core.decdiff import decdiff_step

    proto = {"a": jnp.zeros((64, 33)), "b": {"w": jnp.zeros((1000,))}}
    w = tree_random_like(jax.random.PRNGKey(0), proto)
    wb = tree_random_like(jax.random.PRNGKey(1), proto)
    got = decdiff_update_tree(w, wb)
    want = decdiff_step(w, wb)
    assert tree_l2_dist(got, want) < 1e-4


@pytest.mark.parametrize("b,v", [(1, 7), (4, 10), (64, 1000), (130, 4097),
                                 (8, 32000), (2, 151936)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vt_kl_loss_sweep(b, v, dtype):
    rng = np.random.default_rng(b * v)
    z = jnp.asarray(rng.standard_normal((b, v)) * 3, dtype)
    y = jnp.asarray(rng.integers(0, v, b), jnp.int32)
    got = vt_kl_loss_fused(z, y, 0.95)
    want = vt_kl_loss_ref(z, y, 0.95)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(float(got), float(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,v", [(4, 10), (64, 1000), (6, 4097)])
def test_vt_kl_grad_sweep(b, v):
    rng = np.random.default_rng(b + v)
    z = jnp.asarray(rng.standard_normal((b, v)) * 2, jnp.float32)
    y = jnp.asarray(rng.integers(0, v, b), jnp.int32)
    got = jax.grad(lambda zz: vt_kl_loss_fused(zz, y, 0.95))(z)
    want = vt_kl_grad_ref(z, y, 0.95)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("beta", [0.9, 0.95, 0.999])
def test_vt_kl_beta_sweep(beta):
    rng = np.random.default_rng(5)
    z = jnp.asarray(rng.standard_normal((32, 257)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 257, 32), jnp.int32)
    np.testing.assert_allclose(float(vt_kl_loss_fused(z, y, beta)),
                               float(vt_kl_loss_ref(z, y, beta)),
                               rtol=1e-4, atol=1e-5)


def test_vt_kernel_matches_core_closed_form():
    from repro.core.virtual_teacher import vt_kl_loss

    rng = np.random.default_rng(6)
    z = jnp.asarray(rng.standard_normal((16, 100)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 100, 16), jnp.int32)
    np.testing.assert_allclose(float(vt_kl_loss_fused(z, y, 0.95)),
                               float(vt_kl_loss(z, y, beta=0.95)), rtol=1e-4)


@pytest.mark.parametrize("n,d", [(1, 10), (3, 100), (16, 5000), (50, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_neighbor_avg_sweep(n, d, dtype):
    rng = np.random.default_rng(n * d)
    st = jnp.asarray(rng.standard_normal((n, d)), dtype)
    w = jnp.asarray(rng.random(n) + 0.1, jnp.float32)
    got = neighbor_avg(st, w)
    want = neighbor_avg_ref(st, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d", [(1, 10), (3, 100), (16, 5000), (50, 2048)])
def test_dequant_neighbor_avg_sweep(n, d):
    from repro.kernels import dequant_neighbor_avg
    from repro.kernels.ref import dequant_neighbor_avg_ref

    rng = np.random.default_rng(n * d + 1)
    q = jnp.asarray(rng.integers(-127, 128, (n, d)), jnp.int8)
    sc = jnp.asarray(rng.random(n) * 0.02 + 1e-4, jnp.float32)
    w = jnp.asarray(rng.random(n) + 0.1, jnp.float32)
    got = dequant_neighbor_avg(q, sc, w)
    want = dequant_neighbor_avg_ref(q, sc, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_dequant_neighbor_avg_fuses_codec_payload():
    """Feeding the kernel a real int8 codec payload equals dequantize-then-
    neighbor_avg (the unfused two-pass reference)."""
    from repro.comm import make_codec
    from repro.kernels import dequant_neighbor_avg

    codec = make_codec("int8", stochastic=False)
    rng = np.random.default_rng(9)
    vecs = jnp.asarray(rng.standard_normal((6, 4096)), jnp.float32)
    enc = jax.vmap(lambda v: codec.encode(v)[0])(vecs)
    dq = jax.vmap(codec.decode)(enc)  # [6, 4096] dequantized models
    w = jnp.asarray(rng.random(6) + 0.1, jnp.float32)
    got = dequant_neighbor_avg(enc["q"], enc["scale"], w)
    want = neighbor_avg(dq, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("r,n,d", [(1, 1, 10), (2, 4, 100), (4, 8, 5000),
                                   (3, 50, 2048)])
def test_dequant_neighbor_avg_rows_sweep(r, n, d):
    """The receiver-block variant (the shard_map round's payload path)
    against its jnp oracle, including all-zero weight rows (the 'heard from
    nobody' case must yield a zero average, not NaN)."""
    from repro.kernels import dequant_neighbor_avg_rows
    from repro.kernels.ref import dequant_neighbor_avg_rows_ref

    rng = np.random.default_rng(r * 1000 + n * d)
    q = jnp.asarray(rng.integers(-127, 128, (n, d)), jnp.int8)
    sc = jnp.asarray(rng.random(n) * 0.02 + 1e-4, jnp.float32)
    wn = rng.random((r, n)).astype(np.float32)
    wn[0, :] = 0.0  # a fully-masked receiver row
    wn = jnp.asarray(wn)
    got = dequant_neighbor_avg_rows(q, sc, wn)
    want = dequant_neighbor_avg_rows_ref(q, sc, wn)
    assert got.shape == (r, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    assert not np.isnan(np.asarray(got)).any()
    np.testing.assert_array_equal(np.asarray(got)[0], np.zeros(d))


def test_dequant_neighbor_avg_rows_matches_single_receiver_kernel():
    """One row of the block kernel == the single-receiver kernel (modulo
    the latter's internal weight normalization)."""
    from repro.kernels import dequant_neighbor_avg, dequant_neighbor_avg_rows

    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.integers(-127, 128, (5, 4096)), jnp.int8)
    sc = jnp.asarray(rng.random(5) * 0.01 + 1e-4, jnp.float32)
    w = jnp.asarray(rng.random(5) + 0.1, jnp.float32)
    wn = (w / jnp.sum(w))[None, :]  # pre-normalized single row
    got = dequant_neighbor_avg_rows(q, sc, wn)[0]
    want = dequant_neighbor_avg(q, sc, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("b,w,kk,g,hd", [(1, 16, 1, 1, 16), (2, 600, 2, 2, 64),
                                         (4, 1024, 8, 1, 128), (3, 512, 4, 8, 64)])
@pytest.mark.parametrize("cache_dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, w, kk, g, hd, cache_dtype):
    from repro.kernels import decode_attention_fused
    from repro.kernels.ref import decode_attention_ref

    rng = np.random.default_rng(b * w + hd)
    h = kk * g
    q = jnp.asarray(rng.standard_normal((b, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, w, kk, hd)), cache_dtype)
    v = jnp.asarray(rng.standard_normal((b, w, kk, hd)), cache_dtype)
    filled = max(w - 5, 1)
    sp = jnp.asarray([i if i < filled else -1 for i in range(w)], jnp.int32)
    pos = jnp.int32(filled - 1)
    got = decode_attention_fused(q, k, v, sp, pos)
    want = decode_attention_ref(q, k, v, sp, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_ref_matches_model_layer():
    """The kernel-ref math equals the model's decode_attention output."""
    from repro.kernels.ref import decode_attention_ref
    from repro.models.lm.config import ArchConfig
    from repro.models.lm.layers import decode_attention, init_attention

    cfg = ArchConfig(arch_id="t", family="dense", d_model=64, n_heads=4,
                     n_kv_heads=2, head_dim=16, vocab=32,
                     param_dtype="float32", activation_dtype="float32")
    p = init_attention(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, w = 2, 8
    x = jnp.asarray(rng.standard_normal((b, 1, 64)) * 0.3, jnp.float32)
    lc = {
        "k": jnp.asarray(rng.standard_normal((b, w, 2, 16)), jnp.float32),
        "v": jnp.asarray(rng.standard_normal((b, w, 2, 16)), jnp.float32),
        "slot_pos": jnp.asarray([0, 1, 2, 3, -1, -1, -1, -1], jnp.int32),
    }
    length = jnp.int32(4)
    out_model, lc_new = decode_attention(cfg, p, x, lc, length)
    # reproduce via ref: project q the same way, use the UPDATED cache
    from repro.models.lm.layers import _project_qkv

    q, _, _ = _project_qkv(cfg, p, x, length[None], True)
    ref = decode_attention_ref(q[:, 0], lc_new["k"], lc_new["v"],
                               lc_new["slot_pos"], length)
    # model applies wo afterwards; compare pre-wo by inverting is overkill —
    # instead apply wo to ref and compare
    from repro.models.lm.layers import linear

    ref_out = linear(ref.reshape(b, 1, cfg.q_dim).astype(x.dtype), p["wo"])
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(ref_out),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# gather_rows — exchange receiver-row gather (cross-pod reverse-slot path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,d", [(8, 8, 16), (24, 96, 40), (16, 5, 2048), (12, 48, 3000)])
def test_gather_rows_matches_fancy_indexing(m, k, d):
    from repro.kernels.ops import gather_rows

    rng = np.random.default_rng(7)
    tbl = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, m, size=(k,)), jnp.int32)
    out = gather_rows(tbl, idx, interpret=True)
    ref = tbl[idx]
    assert out.dtype == tbl.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gather_rows_reverse_slot_roundtrip():
    """Gathering rev-slot indices out of a flattened [N*E, D] table reproduces
    the dense _swap_layout on a symmetric neighbour layout."""
    from repro.comm import CommConfig
    from repro.comm.transport import EdgeGossipTransport
    from repro.graphs.topology import make_topology
    from repro.kernels.ops import gather_rows

    topo = make_topology("ring", n=6)
    d = 10
    params = {"w": jnp.zeros((topo.num_nodes, d), jnp.float32)}
    tr = EdgeGossipTransport(CommConfig(codec="int8"), params,
                             topo.neighbor_idx, topo.neighbor_mask)
    n, e = tr.n, tr.e
    rng = np.random.default_rng(3)
    tbl = jnp.asarray(rng.standard_normal((n, e, d)), jnp.float32)
    flat_idx = (tr.nbr_idx * e + tr.rev_slot).reshape(-1).astype(jnp.int32)
    out = gather_rows(tbl.reshape(n * e, d), flat_idx, interpret=True).reshape(n, e, d)
    ref = tbl[tr.nbr_idx, tr.rev_slot]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
