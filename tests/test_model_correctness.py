"""Deeper model correctness: decode-vs-prefill consistency, SSD-vs-recurrent
oracle, chunked-vs-plain attention, MoE dispatch equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import build_lm
from repro.models.lm.config import ArchConfig
from repro.models.lm.layers import _chunked_attention, _plain_attention
from repro.models.lm.ssm import ssd_chunked


def _prefill_logits(lm, params, tokens):
    logits, _ = lm.forward(params, {"tokens": tokens, "labels": tokens})
    return logits


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-32b", "mixtral-8x7b",
                                  "mamba2-2.7b"])
def test_decode_matches_prefill(arch):
    """Feeding tokens one-by-one through the cache reproduces the teacher-
    forced forward logits (the fundamental serving-correctness invariant)."""
    cfg = get_config(arch).reduced(ssm_chunk=4)
    if cfg.n_experts:
        # top-k routing amplifies tiny numeric diffs; keep experts tiny
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    seq = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, seq)), jnp.int32)
    full = np.asarray(_prefill_logits(lm, params, tokens), np.float32)

    cache = lm.init_cache(2, seq)
    got = []
    for t in range(seq):
        logits, cache = lm.decode_step(params, cache, tokens[:, t : t + 1])
        got.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, full, rtol=2e-2, atol=2e-2)


def test_ssd_chunked_matches_recurrence():
    """The chunk-parallel SSD equals the naive per-step recurrence
    h_t = exp(a_t) h_{t-1} + B_t x_t;  y_t = C_t . h_t."""
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 16, 3, 4, 5
    cfg = get_config("mamba2-2.7b").reduced(ssm_chunk=4)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
    a = -jnp.asarray(rng.random((b, s, h)) * 0.5, jnp.float32)

    y, state = ssd_chunked(cfg, x, bm, cm, a)

    # oracle recurrence
    hstate = np.zeros((b, h, p, n), np.float32)
    ys = []
    for t in range(s):
        decay = np.exp(np.asarray(a[:, t]))[:, :, None, None]
        hstate = hstate * decay + np.einsum(
            "bhp,bhn->bhpn", np.asarray(x[:, t]), np.asarray(bm[:, t]))
        ys.append(np.einsum("bhn,bhpn->bhp", np.asarray(cm[:, t]), hstate))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), hstate, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24), (False, None)])
def test_chunked_attention_matches_plain(causal, window):
    cfg = ArchConfig(arch_id="t", family="dense", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=2, head_dim=16, vocab=32,
                     attn_chunk_q=16, attn_chunk_kv=32,
                     param_dtype="float32", activation_dtype="float32")
    rng = np.random.default_rng(0)
    b, s = 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, 2, 16)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    plain = _plain_attention(cfg, q, k, v, pos, pos, causal, window)
    chunk = _chunked_attention(cfg, q, k, v, pos, pos, causal, window)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(plain),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_dispatch_matches_dense_computation():
    """With generous capacity, the scatter-dispatch MoE equals the dense
    all-experts weighted combination."""
    from repro.models.lm.moe import init_moe_ffn, moe_ffn

    cfg = get_config("mixtral-8x7b").reduced(capacity_factor=8.0)
    p = init_moe_ffn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)) * 0.3, jnp.float32)
    out, aux = moe_ffn(cfg, p, x)

    # dense oracle
    xf = np.asarray(x, np.float32).reshape(-1, cfg.d_model)
    logits = xf @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_w = np.asarray(top_w / top_w.sum(-1, keepdims=True))
    top_i = np.asarray(top_i)
    wg, wu, wd = (np.asarray(p[k], np.float32) for k in ("wg", "wu", "wd"))
    want = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(cfg.top_k):
            e = top_i[t, j]
            gate = xf[t] @ wg[e]
            up = xf[t] @ wu[e]
            silu = gate / (1 + np.exp(-gate))
            want[t] += top_w[t, j] * ((silu * up) @ wd[e])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), want,
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_sliding_window_masks_old_tokens():
    """With window w, token s attends only to the last w positions: moving
    tokens OUTSIDE the window must not change the output."""
    cfg = ArchConfig(arch_id="t", family="dense", n_layers=1, d_model=32,
                     n_heads=2, n_kv_heads=2, head_dim=16, vocab=32,
                     sliding_window=8, param_dtype="float32",
                     activation_dtype="float32")
    rng = np.random.default_rng(0)
    s = 32
    q = jnp.asarray(rng.standard_normal((1, s, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, s, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, s, 2, 16)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    out1 = _plain_attention(cfg, q, k, v, pos, pos, True, 8)
    k2 = k.at[:, :4].set(99.0)  # outside every query's window for t >= 12
    v2 = v.at[:, :4].set(99.0)
    out2 = _plain_attention(cfg, q, k2, v2, pos, pos, True, 8)
    np.testing.assert_allclose(np.asarray(out1[:, 16:]), np.asarray(out2[:, 16:]),
                               rtol=1e-5)
