"""Deliverable (f): per-architecture smoke tests.

Every assigned architecture is instantiated as a REDUCED variant of the same
family (2 layers, d_model <= 512, <= 4 experts) and runs one forward and one
train step on CPU, asserting output shapes and the absence of NaNs.  Decode
steps are exercised too (two tokens through the cache path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.dfl_step import build_train_step
from repro.models.lm import build_lm
from repro.optim.sgd import sgd_momentum


def _dummy_batch(lm, batch=2, seq=64):
    rng = np.random.default_rng(0)
    out = {}
    for k, s in lm.input_specs(batch, seq).items():
        if np.dtype(s.dtype) == np.int32:
            hi = lm.cfg.vocab if k == "tokens" else max(lm.cfg.vocab - 1, 2)
            out[k] = jnp.asarray(rng.integers(0, hi, s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape) * 0.05, s.dtype)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _dummy_batch(lm)

    logits, aux = lm.forward(params, batch)
    s_expected = batch["tokens"].shape[1]
    assert logits.shape == (2, s_expected, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN in logits"

    opt = sgd_momentum(lr=1e-2, momentum=0.9)
    step = jax.jit(build_train_step(lm, opt))
    new_params, _, loss = step(params, opt.init(params), jnp.int32(0), batch)
    assert np.isfinite(float(loss)), "NaN loss"
    # params actually changed
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved, "train step did not update parameters"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    cache = lm.init_cache(2, 32)
    if lm.prep_decode_cache is not None:
        enc = jnp.asarray(np.random.default_rng(0).standard_normal(
            (2, 16, cfg.d_model)) * 0.05, cfg.adtype)
        cache = lm.prep_decode_cache(params, cache, enc)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = lm.decode_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["length"]) == 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_exactness(arch):
    """The registered config carries the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "qwen3-32b": dict(n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
                          d_ff=25600, vocab=151936, qk_norm=True),
        "qwen1.5-0.5b": dict(n_layers=24, d_model=1024, n_heads=16,
                             n_kv_heads=16, d_ff=2816, vocab=151936,
                             qkv_bias=True),
        "whisper-large-v3": dict(n_layers=32, n_enc_layers=32, d_model=1280,
                                 n_heads=20, d_ff=5120, vocab=51866),
        "mixtral-8x7b": dict(n_layers=32, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=14336, vocab=32000,
                             n_experts=8, top_k=2, sliding_window=4096),
        "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, d_ff=4864, vocab=32000,
                            n_experts=128, top_k=2, dense_residual=True),
        "qwen2.5-14b": dict(n_layers=48, d_model=5120, n_heads=40,
                            n_kv_heads=8, d_ff=13824, vocab=152064,
                            qkv_bias=True),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab=32000,
                            ssm_state=64, shared_attn_every=9),
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, d_ff=0, vocab=50280,
                            ssm_state=128),
        "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32,
                            n_kv_heads=32, d_ff=11008, vocab=102400),
        "llava-next-mistral-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                                      n_kv_heads=8, d_ff=14336, vocab=32000,
                                      img_tokens=2880),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.citation, f"{arch} missing citation"


def test_param_counts_in_expected_range():
    """Analytic parameter counts land near the nameplate sizes."""
    expect = {
        "qwen3-32b": (28e9, 36e9),
        "qwen1.5-0.5b": (0.3e9, 0.7e9),
        "mixtral-8x7b": (40e9, 52e9),
        "arctic-480b": (420e9, 520e9),
        "qwen2.5-14b": (12e9, 17e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "deepseek-7b": (6e9, 8e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_smaller():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
