"""repro.obs contracts: telemetry channels, the run ledger, and trace
export.

The load-bearing pins:

  1. degeneracy — `telemetry=None` is BIT-IDENTICAL to a run with every
     channel on: params, bytes, trigger/live histories and the simulated
     clock agree across backends × layouts × schedule modes (the obs
     state rides the scan carry and consumes no rng by construction);
  2. arithmetic — channels are exact, not sampled: with an fp32 codec and
     a zero threshold every live edge fires every round, so
     `edge_trigger == rounds` per edge, `sum(edge_bytes)` equals the
     engine's own `bytes_on_wire` accounting to the last byte,
     `node_steps == rounds * steps_per_round`, staleness is zero, and
     the drift probe is symmetric in (src, dst);
  3. parity — the materialized detail dict is identical (canonical
     (dst, src) edge order) across dense/sparse, vmap/shard_map and
     loop/fused, so a probe value never depends on the execution engine;
  4. one-scan — the fused schedule with ALL channels on still lowers to
     exactly ONE top-level lax.scan;
  5. ledger/trace — the JSONL ledger round-trips through its schema
     validator with the manifest first, the verbose console line is
     byte-stable against the pre-ledger format, and the exported Chrome
     trace's per-edge transfer spans sum EXACTLY to bytes_on_wire.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.engine import Experiment, Schedule, World
from repro.fl.metrics import (RoundMetrics, accuracy_table,
                              characteristic_time)
from repro.obs import (CHANNELS, Telemetry, available_channels,
                       build_trace, channels_for, export_trace,
                       format_round, read_ledger, validate_ledger,
                       validate_record)
from repro.timing import LognormalLink, LognormalStep, Timing

TINY = dict(steps_per_round=4, batch_size=16, lr=0.1, momentum=0.9, seed=3)

HET = Timing(node=LognormalStep(sigma=0.5, seed=7),
             link=LognormalLink(seed=9))


@pytest.fixture(scope="module")
def ba_world():
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=16,
                           topology="barabasi_albert", m=2, seed=3,
                           scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


@pytest.fixture(scope="module")
def ring_world():
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=4, topology="ring",
                           seed=3, scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


def _with(world, **kw):
    return dataclasses.replace(world, **kw)


def _params_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _fingerprint(exp):
    return (tuple(exp.trig_history), exp.comm_bytes_total,
            tuple(exp.live_history), tuple(exp.sim_time_history))


def _run(world, method="decdiff+vt", **kw):
    args = dict(TINY)
    args.update(kw)
    sched = args.pop("schedule")
    exp = Experiment(world, method, schedule=sched, **args)
    hist = exp.run()
    return exp, hist


def _detail_equal(a, b):
    assert sorted(a) == sorted(b)
    return all(np.allclose(a[k], b[k], rtol=0, atol=0) for k in a)


# --------------------------------------------------- 1. degeneracy oracle

@pytest.mark.parametrize("mode", ["loop", "fused"])
@pytest.mark.parametrize("layout", ["dense", "sparse"])
@pytest.mark.parametrize("backend", ["vmap", "shard_map"])
def test_telemetry_off_bit_identical_matrix(ba_world, backend, layout, mode):
    """All channels on == telemetry=None, bit for bit, on the full
    backend × layout × mode matrix (params AND every accounting stream:
    bytes, trigger fraction, live edges, simulated time)."""
    runs = {}
    for tele in (None, Telemetry()):
        runs[tele is None] = _run(
            _with(ba_world, timing=HET, telemetry=tele),
            comm=CommConfig(codec="int8", trigger_threshold=0.3),
            backend=backend, layout=layout,
            schedule=Schedule(rounds=3, eval_every=3, deadline=4.0,
                              mode=mode))
    (on, _), (off, _) = runs[False], runs[True]
    assert _params_equal(on.params, off.params)
    assert _fingerprint(on) == _fingerprint(off)
    assert on.arrived_history == off.arrived_history


# --------------------------------------------------- 2. channel arithmetic

def test_channels_exact_always_fire(ring_world):
    """fp32 codec + zero threshold: every directed edge fires every round,
    so the per-edge and per-node counters are exact small integers and
    the byte channel reproduces the engine's own accounting."""
    rounds = 3
    exp, hist = _run(
        _with(ring_world, telemetry=Telemetry()),
        comm=CommConfig(codec="fp32", trigger_threshold=0.0),
        schedule=Schedule(rounds=rounds, eval_every=rounds))
    d = hist[-1].detail
    obs = exp.bound_obs
    e = obs.num_directed
    assert e == 8  # 4-ring: two directions per undirected edge
    np.testing.assert_array_equal(d["edge_trigger"], np.full(e, rounds))
    np.testing.assert_array_equal(d["edge_staleness"], np.zeros(e))
    np.testing.assert_array_equal(
        d["node_steps"], np.full(4, rounds * TINY["steps_per_round"]))
    assert float(np.sum(d["edge_bytes"])) == hist[-1].bytes_on_wire
    assert float(np.sum(d["edge_bytes"])) == exp.comm_bytes_total
    # drift is symmetric: ||w_src - w_dst|| == ||w_dst - w_src||
    pair = {(s, t): i for i, (s, t) in
            enumerate(zip(obs.edge_src, obs.edge_dst))}
    for (s, t), i in pair.items():
        assert d["drift"][i] == pytest.approx(d["drift"][pair[(t, s)]],
                                              rel=1e-6)
    # consensus matches a host-side recomputation from the final params
    from repro.utils.pytree import tree_flatten_stacked
    mat = np.asarray(tree_flatten_stacked(exp.params)[0])
    ref = np.linalg.norm(mat - mat.mean(axis=0, keepdims=True), axis=1)
    np.testing.assert_allclose(d["consensus"], ref, rtol=1e-5)


def test_staleness_counts_undelivered_rounds(ring_world):
    """A high threshold silences edges; the staleness channel ages them by
    one per silent round and resets to zero on delivery, so every age is
    bounded by the horizon."""
    rounds = 4
    _, hist = _run(
        _with(ring_world, telemetry=Telemetry()),
        comm=CommConfig(codec="int8", trigger_threshold=50.0),
        schedule=Schedule(rounds=rounds, eval_every=rounds))
    age = hist[-1].detail["edge_staleness"]
    assert np.all(age >= 0) and np.all(age <= rounds)
    assert np.any(age > 0)  # the threshold did silence something


# ------------------------------------------------------------- 3. parity

def test_detail_parity_across_engines(ba_world):
    """The materialized detail dict (canonical edge order) is identical
    across dense/sparse × vmap/shard_map and loop/fused."""
    combos = [("dense", "vmap", "fused"), ("sparse", "vmap", "fused"),
              ("dense", "shard_map", "fused"), ("sparse", "shard_map",
                                                "fused"),
              ("dense", "vmap", "loop")]
    ref = None
    for layout, backend, mode in combos:
        _, hist = _run(
            _with(ba_world, timing=HET, telemetry=Telemetry()),
            comm=CommConfig(codec="int8", trigger_threshold=0.3),
            backend=backend, layout=layout,
            schedule=Schedule(rounds=3, eval_every=3, deadline=4.0,
                              mode=mode))
        d = hist[-1].detail
        if ref is None:
            ref = d
        else:
            assert _detail_equal(ref, d), (layout, backend, mode)


def test_detail_parity_per_edge_transport(ring_world):
    """Same parity pin on the per-edge transport family."""
    ref = None
    for layout in ("dense", "sparse"):
        _, hist = _run(
            _with(ring_world, timing=HET, telemetry=Telemetry()),
            comm=CommConfig(codec="int8", trigger_threshold=0.3,
                            per_edge=True),
            layout=layout,
            schedule=Schedule(rounds=3, eval_every=3, deadline=4.0))
        d = hist[-1].detail
        if ref is None:
            ref = d
        else:
            assert _detail_equal(ref, d), layout


# ------------------------------------------------------------ 4. one-scan

def test_fused_program_is_one_scan_with_channels(ring_world):
    """ALL channels accumulate inside the scan carry: the K-round fused
    schedule still lowers to exactly ONE top-level lax.scan."""
    exp = Experiment(_with(ring_world, timing=HET, telemetry=Telemetry()),
                     "decdiff+vt",
                     comm=CommConfig(codec="int8", trigger_threshold=0.3),
                     schedule=Schedule(rounds=4, eval_every=2, deadline=4.0),
                     **TINY)
    fused = exp._fused_program(4, 2)
    carry = ((exp.params, exp.opt_state) + exp._get_states() + (exp.rng,))
    jaxpr = jax.make_jaxpr(lambda c: fused(c))(carry)
    scans = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "scan"]
    pjits = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "pjit"]
    if pjits:  # the jitted program wraps the scan one level down
        inner = pjits[0].params["jaxpr"].jaxpr
        scans = [e for e in inner.eqns if e.primitive.name == "scan"]
    assert len(scans) == 1


# ----------------------------------------------------- 5. channel catalog

def test_auto_selects_supported_channels(ring_world):
    # full stack: everything
    exp = Experiment(_with(ring_world, timing=HET, telemetry=Telemetry()),
                     "decdiff+vt",
                     comm=CommConfig(codec="int8", trigger_threshold=0.3),
                     schedule=Schedule(rounds=1, eval_every=1), **TINY)
    assert exp.bound_obs.channels == tuple(CHANNELS)
    # no timing: compute/latency channels drop out
    exp2 = Experiment(_with(ring_world, telemetry=Telemetry()),
                      "decdiff+vt",
                      comm=CommConfig(codec="int8", trigger_threshold=0.3),
                      schedule=Schedule(rounds=1, eval_every=1), **TINY)
    assert "node_compute" not in exp2.bound_obs.channels
    assert "edge_latency" not in exp2.bound_obs.channels
    # no transport: every comm-needing channel drops out (drift stays —
    # pairwise divergence needs only the graph, not a transport)
    exp3 = Experiment(_with(ring_world, telemetry=Telemetry()), "decavg",
                      schedule=Schedule(rounds=1, eval_every=1), **TINY)
    assert "drift" in exp3.bound_obs.channels
    assert not any("comm" in CHANNELS[c].needs
                   for c in exp3.bound_obs.channels)


def test_channel_validation_errors(ring_world):
    # unknown channel name
    with pytest.raises(ValueError, match="unknown telemetry channel"):
        Telemetry(channels=("nope",))
    with pytest.raises(ValueError, match="alias"):
        Telemetry(channels="everything")
    # explicit channel whose subsystem is missing names the subsystem
    with pytest.raises(ValueError, match="timing"):
        Experiment(_with(ring_world,
                         telemetry=Telemetry(channels=("node_compute",))),
                   "decdiff+vt",
                   comm=CommConfig(codec="int8", trigger_threshold=0.3),
                   schedule=Schedule(rounds=1, eval_every=1), **TINY)
    # non-Telemetry value is a TypeError at construction
    with pytest.raises(TypeError, match="Telemetry"):
        Experiment(_with(ring_world, telemetry=object()), "decavg",
                   schedule=Schedule(rounds=1, eval_every=1), **TINY)


def test_catalog_helpers():
    assert available_channels() == tuple(CHANNELS)
    picked = channels_for(["drift", "node_steps"])
    assert set(picked) == {"drift", "node_steps"}
    with pytest.raises(ValueError, match="unknown telemetry channel"):
        channels_for(["nope"])
    for spec in CHANNELS.values():
        assert spec.axis in ("node", "edge")
        assert spec.doc


# ------------------------------------------------------- 6. ledger schema

def test_ledger_round_trip(ring_world, tmp_path):
    path = tmp_path / "run.jsonl"
    exp, hist = _run(
        _with(ring_world, timing=HET,
              telemetry=Telemetry(ledger=str(path))),
        comm=CommConfig(codec="int8", trigger_threshold=0.3),
        schedule=Schedule(rounds=4, eval_every=2, deadline=4.0,
                          mode="fused"))
    counts = validate_ledger(str(path))
    assert counts["manifest"] == 1
    assert counts["round"] == len(hist)
    assert counts["summary"] == 1
    manifest, rounds, summaries = read_ledger(str(path))
    assert manifest["nodes"] == 4
    assert manifest["method"] == "decdiff+vt"
    assert manifest["channels"] == list(exp.bound_obs.channels)
    assert manifest["payload_bytes"] == exp.transport.payload_bytes
    assert manifest["env"]["jax"]  # env block is present and non-empty
    for rec, m in zip(rounds, hist):
        assert rec["round"] == m.round
        assert rec["acc_mean"] == pytest.approx(m.acc_mean)
        assert rec["bytes_on_wire"] == m.bytes_on_wire
        got = np.asarray(rec["detail"]["edge_bytes"])
        np.testing.assert_allclose(got, m.detail["edge_bytes"])
    [summary] = summaries
    assert summary["rounds"] == 4
    assert summary["wall_s"] > 0
    assert summary["rounds_per_sec"] > 0
    assert "compile_s" in summary  # fresh experiment: cold compile


def test_validate_record_rejects_garbage():
    with pytest.raises(ValueError, match="kind"):
        validate_record({"no": "kind"})
    with pytest.raises(ValueError, match="unknown ledger record kind"):
        validate_record({"kind": "banana"})
    with pytest.raises(ValueError, match="round"):
        validate_record({"kind": "round", "acc_mean": 0.5})
    with pytest.raises(ValueError, match="acc_mean"):
        validate_record({"kind": "round", "round": 1, "acc_mean": "high",
                         "acc_std": 0.0, "loss_mean": 1.0,
                         "acc_per_node": [0.5]})


def test_ledger_requires_manifest_first(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text(json.dumps({"kind": "round", "round": 0,
                                "acc_mean": 0.1, "acc_std": 0.0,
                                "loss_mean": 1.0,
                                "acc_per_node": [0.1]}) + "\n")
    with pytest.raises(ValueError, match="manifest"):
        validate_ledger(str(path))


def test_verbose_line_is_byte_stable(ring_world, capsys):
    """The structured logger emits EXACTLY the pre-ledger `_print_round`
    text on stdout, so scripts that scrape verbose output keep working."""
    exp = Experiment(_with(ring_world, timing=HET), "decdiff+vt",
                     comm=CommConfig(codec="int8", trigger_threshold=0.3),
                     schedule=Schedule(rounds=2, eval_every=2, deadline=4.0),
                     **TINY)
    hist = exp.run(verbose=True)
    out = capsys.readouterr().out.splitlines()
    expected = [format_round(exp.method.name, m) for m in hist]
    assert [l for l in out if l.startswith("[")] == expected
    # and the format itself is pinned against the historical layout
    m = RoundMetrics(round=7, acc_per_node=np.array([0.5, 0.7]),
                     loss_per_node=np.array([1.0, 2.0]),
                     bytes_on_wire=1024.0, triggered_frac=0.5)
    assert format_round("decdiff+vt", m) == (
        "[decdiff+vt] round    7  acc 0.6000 ± 0.1000  loss 1.5000  "
        "wire 0.00 MB  trig 0.50")


# -------------------------------------------------------- 7. trace export

def test_trace_export_bytes_exact(ring_world, tmp_path):
    exp, hist = _run(
        _with(ring_world, timing=HET, telemetry=Telemetry()),
        comm=CommConfig(codec="int8", trigger_threshold=0.3),
        schedule=Schedule(rounds=4, eval_every=4, deadline=4.0,
                          mode="fused"))
    path = tmp_path / "trace.json"
    trace = export_trace(exp, str(path))
    loaded = json.loads(path.read_text())
    assert loaded == trace
    evs = loaded["traceEvents"]
    spans = [e for e in evs if e.get("ph") == "X"]
    node_spans = [e for e in spans if e["pid"] == 0]
    edge_spans = [e for e in spans if e["pid"] == 1]
    assert len(node_spans) == 4 * 4  # nodes × rounds
    # every transfer span carries exact bytes; their total IS the wire total
    total = sum(e["args"]["bytes"] for e in edge_spans)
    assert total == hist[-1].bytes_on_wire
    # deadline mode: spans annotate arrival vs deadline
    assert all("deadline_s" in e["args"] for e in edge_spans)
    assert all(e["args"]["src"] != e["args"]["dst"] for e in edge_spans)
    # timestamps are microseconds within the simulated horizon
    horizon_us = hist[-1].sim_time * 1e6
    assert all(0 <= e["ts"] <= horizon_us for e in spans)


def test_trace_requires_timing_and_telemetry(ring_world):
    exp, _ = _run(_with(ring_world, timing=HET),
                  comm=CommConfig(codec="int8", trigger_threshold=0.3),
                  schedule=Schedule(rounds=1, eval_every=1, deadline=4.0))
    with pytest.raises(ValueError, match="telemetry"):
        build_trace(exp)
    exp2, _ = _run(_with(ring_world, telemetry=Telemetry()),
                   comm=CommConfig(codec="int8", trigger_threshold=0.3),
                   schedule=Schedule(rounds=1, eval_every=1))
    with pytest.raises(ValueError, match="timing"):
        build_trace(exp2)


# ------------------------------------------------- 8. metrics edge cases

def _metric(round_, acc):
    return RoundMetrics(round=round_, acc_per_node=np.array([acc]),
                        loss_per_node=np.array([1.0]))


def test_characteristic_time_edge_cases():
    hist = [_metric(0, 0.1), _metric(5, 0.5), _metric(9, 0.52)]
    out = characteristic_time(hist, centralized_acc=0.6)
    assert out[0.5] == 5       # first round reaching 0.3
    assert out[0.95] is None   # never reaches 0.57
    with pytest.raises(ValueError, match="empty history"):
        characteristic_time([], centralized_acc=0.6)
    with pytest.raises(ValueError, match="centralized_acc"):
        characteristic_time(hist, centralized_acc=0.0)
    with pytest.raises(ValueError, match="centralized_acc"):
        characteristic_time(hist, centralized_acc=-1.0)


def test_accuracy_table_rejects_empty_history():
    with pytest.raises(ValueError, match="decavg"):
        accuracy_table({"decavg": []})
    table = accuracy_table({"isol": [_metric(3, 0.4)]})
    assert table["isol"]["round"] == 3
    assert table["isol"]["acc_mean"] == pytest.approx(0.4)
