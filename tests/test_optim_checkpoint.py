"""Optimizers (SGD+momentum faithful to the paper) and checkpointing."""
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.optim import adamw, cosine_schedule, make_optimizer, sgd_momentum


def test_sgd_momentum_matches_pytorch_convention():
    """v <- mu v + g; w <- w - lr v (two manual steps)."""
    opt = sgd_momentum(lr=0.1, momentum=0.5)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([1.0, 1.0])}
    st = opt.init(p)
    p1, st = opt.update(g, st, p, jnp.int32(0))
    np.testing.assert_allclose(p1["w"], [0.9, 1.9], rtol=1e-6)
    p2, st = opt.update(g, st, p1, jnp.int32(1))
    # v2 = 0.5*1 + 1 = 1.5 -> w2 = w1 - 0.15
    np.testing.assert_allclose(p2["w"], [0.75, 1.75], rtol=1e-6)


def test_sgd_weight_decay():
    opt = sgd_momentum(lr=0.1, momentum=0.0, weight_decay=0.1)
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([0.0])}
    st = opt.init(p)
    p1, _ = opt.update(g, st, p, jnp.int32(0))
    np.testing.assert_allclose(p1["w"], [1.0 - 0.1 * 0.1], rtol=1e-6)


def test_adamw_descends_quadratic():
    opt = adamw(lr=0.05, weight_decay=0.0)
    p = {"w": jnp.asarray([3.0, -2.0])}
    st = opt.init(p)
    for i in range(200):
        g = {"w": 2 * p["w"]}
        p, st = opt.update(g, st, p, jnp.int32(i))
    assert float(jnp.abs(p["w"]).max()) < 0.1


def test_cosine_schedule():
    sched = cosine_schedule(1.0, warmup=10, total=110, floor=0.1)
    assert float(sched(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.int32(10))), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(sched(jnp.int32(110))), 0.1, rtol=1e-4)


def test_make_optimizer_names():
    assert make_optimizer(name="sgdm") is not None
    assert make_optimizer(name="adamw") is not None
    try:
        make_optimizer(name="nope")
        raise AssertionError
    except ValueError:
        pass


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                   "b": np.zeros(3, np.float32)},
        "momentum": {"w": np.ones((2, 3), np.float32) * 0.5,
                     "b": np.zeros(3, np.float32)},
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 42, tree, metadata={"note": "test"})
    assert latest_step(d) == 42
    restored, manifest = restore_checkpoint(d)
    assert manifest["step"] == 42 and manifest["metadata"]["note"] == "test"
    np.testing.assert_array_equal(restored["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(restored["momentum"]["w"], tree["momentum"]["w"])


def test_checkpoint_multiple_steps(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 5, 3):
        save_checkpoint(d, s, {"x": np.asarray([float(s)])})
    assert latest_step(d) == 5
    tree, _ = restore_checkpoint(d, step=3)
    assert tree["x"][0] == 3.0
