"""§Perf config variants must preserve model semantics (CPU, no mesh)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import build_lm
from repro.models.lm.moe import init_moe_ffn, moe_ffn


def test_moe_batch_local_matches_global():
    """With generous capacity the two dispatch strategies agree exactly."""
    cfg = get_config("mixtral-8x7b").reduced(capacity_factor=8.0)
    p = init_moe_ffn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 8, cfg.d_model)) * 0.3, jnp.float32)
    o1, a1 = moe_ffn(cfg, p, x)
    o2, a2 = moe_ffn(dataclasses.replace(cfg, moe_dispatch="batch_local"), p, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-4)


@pytest.mark.parametrize("overrides", [
    {"residual_shard": "batch_seq"},
    {"zero3_gather": True},
    {"attn_probs_bf16": True},
    {"moe_dispatch": "batch_local"},
])
def test_variant_forward_close_to_baseline(overrides):
    """Off-mesh, every §Perf lever is numerically (near-)neutral."""
    arch = "mixtral-8x7b" if "moe_dispatch" in overrides else "deepseek-7b"
    cfg = get_config(arch).reduced()
    if "moe_dispatch" in overrides:
        # the dispatch strategies agree exactly only when no tokens are
        # dropped (they drop DIFFERENT overflow tokens at tight capacity)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
    }
    base, _ = lm.forward(params, batch)
    lm2 = build_lm(dataclasses.replace(cfg, **overrides))
    var, _ = lm2.forward(params, batch)
    tol = 5e-2 if overrides.get("attn_probs_bf16") else 2e-3
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(var, np.float32), atol=tol, rtol=tol)


def test_gossip_bf16_close_to_fp32():
    from repro.dist.dfl_step import decdiff_gossip
    from repro.utils.pytree import tree_l2_dist, tree_random_like, tree_stack

    proto = {"w": jnp.zeros((32, 16))}
    models = [tree_random_like(jax.random.PRNGKey(i), proto) for i in range(3)]
    st = tree_stack(models)
    adj = jnp.asarray([[0, .5, .5], [.5, 0, .5], [.5, .5, 0]], jnp.float32)
    full = decdiff_gossip(st, adj)
    half = decdiff_gossip(st, adj, gossip_dtype=jnp.bfloat16)
    assert float(tree_l2_dist(full, half)) < 0.05
